"""Concurrency-driver suite: simulated vs threaded dispatchers must agree
on results / call counts / per-tier meter totals, the threaded driver's
wall must be *measured* (a real speedup over the sequential latency sum),
the output cache must be single-flight under concurrent morsels — plus
regression tests for the executor/optimizer correctness fixes that rode
along (RANK score parsing, reduce result-kind flag, optimizer sample-flow
accounting, serve.py --reduced flag)."""
import threading
import time

import pytest

from repro.core import backends as bk
from repro.core import cost as cost_mod
from repro.core import executor as ex
from repro.core import judge as judge_mod
from repro.core import logical_optimizer as lopt
from repro.core import physical_optimizer as popt
from repro.core import plan as P
from repro.core import runtime as rt
from repro.core.table import Table
from repro.data import load_dataset
from repro.testing import ConstOracle, EchoOracle, SleepBackend

from conftest import perfect_backends


@pytest.fixture(scope="module")
def movie_small():
    return load_dataset("movie", max_rows=48)


def _chain_plan():
    return P.LogicalPlan((
        P.Operator(P.FILTER, "The rating is higher than 1.", "IMDB_rating"),
        P.Operator(P.MAP, "According to the movie plot, extract the "
                   "genre(s) of each movie.", "Plot", "Genre"),
        P.Operator(P.REDUCE, "Count the number of movies.", "Title"),
    ))


def _assert_meters_equal(ma, mb):
    assert set(ma.by_tier) == set(mb.by_tier)
    for tier in ma.by_tier:
        ua, ub = ma.by_tier[tier], mb.by_tier[tier]
        assert ua.calls == ub.calls, tier
        assert ua.tok_in == pytest.approx(ub.tok_in)
        assert ua.tok_out == pytest.approx(ub.tok_out)
        assert ua.usd == pytest.approx(ub.usd)
        assert ua.latency_s == pytest.approx(ub.latency_s)


# ---------------------------------------------------------------------------
# Driver equivalence: identical answers and accounting
# ---------------------------------------------------------------------------

def test_driver_equivalence_scalar_and_meter(movie_small):
    table, oracle = movie_small
    plan = _chain_plan()
    runs = {}
    for driver in rt.DRIVERS:
        backends = bk.make_backends(oracle)
        runs[driver] = ex.execute(plan, table, backends, default_tier="m*",
                                  morsel_size=8, driver=driver)
    a, b = runs["simulated"], runs["threads"]
    assert a.scalar == b.scalar
    assert a.is_reduce and b.is_reduce
    assert a.rows_processed == b.rows_processed
    _assert_meters_equal(a.meter, b.meter)


def test_driver_equivalence_table_outputs(movie_small):
    table, oracle = movie_small
    plan = P.LogicalPlan(_chain_plan().ops[:2])     # filter -> map
    runs = {d: ex.execute(plan, table, bk.make_backends(oracle),
                          default_tier="m*", morsel_size=8, driver=d)
            for d in rt.DRIVERS}
    a, b = runs["simulated"], runs["threads"]
    assert a.table.columns[ex.ROWID] == b.table.columns[ex.ROWID]
    assert a.table.columns["Genre"] == b.table.columns["Genre"]


def test_driver_equivalence_batched_calls(movie_small):
    """Threaded chunk boundaries equal the backend's internal batching, so
    batch-prompting call counts and outputs survive the driver swap."""
    table, oracle = movie_small
    op = P.Operator(P.FILTER, "The movie is directed by Christopher "
                    "Nolan.", "Director")
    plan = P.LogicalPlan((op,))
    for batch in (3, 4):
        runs, meters = {}, {}
        for d in rt.DRIVERS:
            meters[d] = bk.UsageMeter()
            runs[d] = ex.execute(plan, table, bk.make_backends(oracle),
                                 batch_size=batch, meter=meters[d],
                                 morsel_size=8, driver=d)
        assert meters["threads"].total.calls \
            == meters["simulated"].total.calls == -(-table.n_rows // batch)
        assert runs["threads"].table.columns[ex.ROWID] \
            == runs["simulated"].table.columns[ex.ROWID]


def test_driver_threaded_wall_is_measured_speedup(movie_small):
    """The ISSUE-2 acceptance bar: 50ms/call fake backend, concurrency 8 —
    measured threaded wall < 0.3x the sequential latency sum, with results,
    call counts, and meter totals identical to the simulated driver."""
    table, oracle = movie_small                     # 48 rows
    plan = P.LogicalPlan((
        P.Operator(P.FILTER, "The rating is higher than 1.",
                   "IMDB_rating"),))
    runs, meters, backends = {}, {}, {}
    for d in rt.DRIVERS:
        backends[d] = {"m*": SleepBackend(oracle, delay_s=0.05)}
        meters[d] = bk.UsageMeter()
        runs[d] = ex.execute(plan, table, backends[d], default_tier="m*",
                             concurrency=8, morsel_size=8,
                             meter=meters[d], driver=d)
    seq_sum = meters["threads"].total.latency_s
    assert seq_sum == pytest.approx(48 * 0.05)
    assert runs["threads"].wall_s < 0.3 * seq_sum   # genuinely overlapped
    # the simulated wall is the event-model prediction of the same overlap
    assert runs["simulated"].wall_s == pytest.approx(
        (48 / 8) * 0.05)
    assert backends["threads"]["m*"].calls_made \
        == backends["simulated"]["m*"].calls_made == 48
    assert runs["threads"].table.columns[ex.ROWID] \
        == runs["simulated"].table.columns[ex.ROWID]
    _assert_meters_equal(meters["threads"], meters["simulated"])


def test_driver_per_tier_cap_bounds_threaded_concurrency(movie_small):
    """per_tier_concurrency caps are serving quotas on the real pools: a
    1-worker tier serializes its calls even under the threaded driver."""
    table, oracle = movie_small
    plan = P.LogicalPlan((
        P.Operator(P.FILTER, "The rating is higher than 1.",
                   "IMDB_rating"),))
    small = table.take(range(8))

    def run(per_tier):
        ctx = rt.ExecutionContext(
            backends={"m*": SleepBackend(oracle, delay_s=0.05)},
            default_tier="m*", concurrency=8, morsel_size=2,
            per_tier_concurrency=per_tier, driver="threads")
        return ex.execute(plan, small, ctx)

    wide = run(None)
    narrow = run({"m*": 1})
    # 8 calls on 8 workers: ideal 0.05s; bound scales with the serialized
    # run so a loaded CI box inflating both doesn't flake the comparison
    assert wide.wall_s < max(0.3, 0.5 * narrow.wall_s)
    assert narrow.wall_s > 8 * 0.05 * 0.8        # 8 calls on 1 worker


def test_driver_cache_single_flight_under_concurrent_morsels():
    """Concurrent morsels racing on identical values must not double-bill:
    the single-flight cache gives both drivers the same hit/miss/call
    totals a sequential run produces."""
    oracle = ConstOracle()
    table = Table({"v": [str(i % 8) for i in range(32)]}, name="dups")
    plan = P.LogicalPlan((P.Operator(P.FILTER, "keep everything", "v"),))
    stats = {}
    for d in rt.DRIVERS:
        backend = SleepBackend(oracle, delay_s=0.02)
        cache = rt.OutputCache()
        meter = bk.UsageMeter()
        res = ex.execute(plan, table, {"m*": backend}, default_tier="m*",
                         morsel_size=8, cache=cache, meter=meter, driver=d)
        stats[d] = (backend.calls_made, cache.misses, cache.hits,
                    meter.total.calls, res.table.n_rows)
    assert stats["threads"] == stats["simulated"]
    calls_made, misses, hits, metered, n_rows = stats["threads"]
    assert calls_made == misses == metered == 8      # one bill per unique v
    assert hits == 24
    assert n_rows == 32


def test_driver_coalesced_duplicate_grouping_is_identical():
    """The PR-2 documented corner, now closed: batch_size > 1 + shared
    cache + duplicate values split across morsels must produce *identical
    call grouping* (and therefore identical UsageMeter totals) under the
    simulated and threads drivers — the BatchCoalescer dedupes before
    batch formation and forms batches in logical row order."""
    oracle = EchoOracle()
    table = Table({"v": [str(i % 8) for i in range(32)]}, name="dups")
    plan = P.LogicalPlan((P.Operator(P.MAP, "annotate", "v", "a"),))
    stats = {}
    for d in rt.DRIVERS:
        backend = SleepBackend(oracle, delay_s=0.003)
        cache = rt.OutputCache()
        meter = bk.UsageMeter()
        res = ex.execute(plan, table, {"m*": backend}, default_tier="m*",
                         batch_size=4, morsel_size=8, cache=cache,
                         meter=meter, driver=d)
        stats[d] = (sorted(backend.groups), backend.calls_made,
                    cache.misses, cache.hits, meter.total.calls,
                    meter.total.latency_s, res.table.columns["a"])
    assert stats["threads"] == stats["simulated"]
    groups, calls, misses, hits, metered, _, outs = stats["simulated"]
    # 8 unique values dedupe into exactly two full batches of 4
    assert calls == metered == 2
    assert groups == [("0", "1", "2", "3"), ("4", "5", "6", "7")]
    assert misses == 8 and hits == 24
    assert outs == [f"A:{i % 8}" for i in range(32)]


def test_driver_equivalence_judge_and_optimizers(movie_small):
    """Judge ratings, logical-optimizer search, and physical-optimizer tier
    assignments are all deterministic in the outputs — so they must be
    byte-identical across drivers."""
    table, oracle = movie_small
    plan = P.LogicalPlan(_chain_plan().ops[:2])
    bad = plan.replace_op(0, plan.ops[0].with_(
        instruction="It is NOT the case that: " + plan.ops[0].instruction))

    ratings, assigns, bests = {}, {}, {}
    for d in rt.DRIVERS:
        ctx = rt.ExecutionContext(backends=bk.make_backends(oracle),
                                  default_tier="m*", concurrency=8,
                                  driver=d)
        ratings[d] = judge_mod.Judge(ctx).rate(
            plan, bad, table.sample(12, seed=3)).rating
        pres = popt.optimize(plan, table, ctx,
                             cfg=popt.PhysicalOptConfig(estimator="approx"))
        assigns[d] = (pres.assignments, pres.scores,
                      pres.meter.total.calls)
        assert pres.opt_wall_s >= 0.0
        lres = lopt.optimize(plan, table, ctx,
                             cfg=lopt.LogicalOptConfig(n_iterations=1))
        bests[d] = (lres.best.signature(), lres.best_cost,
                    lres.meter.total.calls)
    assert ratings["threads"] == pytest.approx(ratings["simulated"])
    assert assigns["threads"] == assigns["simulated"]
    assert bests["threads"] == bests["simulated"]


def test_driver_threaded_wall_covers_shared_judge_runs(movie_small):
    """A dispatcher shared across both judge sample runs reports one
    measured wall covering both (not back-to-back accounting)."""
    table, oracle = movie_small
    plan = P.LogicalPlan((
        P.Operator(P.FILTER, "The rating is higher than 1.",
                   "IMDB_rating"),))
    ctx = rt.ExecutionContext(
        backends={"m*": SleepBackend(oracle, delay_s=0.02)},
        default_tier="m*", concurrency=8, morsel_size=4, driver="threads")
    j = judge_mod.Judge(ctx)
    r = j.rate(plan, plan, table.sample(16, seed=1))
    assert r.rating == pytest.approx(1.0)
    # 16 rows rated twice = 32 potential calls, but the shared cache bills
    # the second run for nothing and the pool overlaps the first; subtract
    # the rating call's own modeled latency to isolate the execution wall
    exec_wall = r.usage.latency_s - cost_mod.DEFAULT_TIERS["m*"].latency(4.0)
    assert exec_wall < 16 * 0.02


# ---------------------------------------------------------------------------
# Bugfix regressions
# ---------------------------------------------------------------------------

def test_driver_rank_parses_numeric_strings():
    """Real LLMs return scores as strings; they must rank by value."""
    t = Table({"x": ["a", "b", "c"]}, name="t")
    op = P.Operator(P.RANK, "score the match", "x", "r")
    ranked, _ = rt.apply_outputs(op, t, ["2", "0.5", "1"])
    assert ranked.columns["r"] == [0, 2, 1]


def test_driver_rank_bools_are_not_scores():
    """bool is an int subclass: True/False outputs (filter-shaped answers)
    must fall back to input-position ranking, not masquerade as 1/0."""
    t = Table({"x": ["a", "b", "c"]}, name="t")
    op = P.Operator(P.RANK, "score the match", "x", "r")
    ranked, _ = rt.apply_outputs(op, t, [True, False, True])
    # positional fallback (0,1,2) reversed — NOT [0, 2, 1] (True-first)
    assert ranked.columns["r"] == [2, 1, 0]
    garbage, _ = rt.apply_outputs(op, t, ["n/a", "n/a", "n/a"])
    assert ranked.columns["r"] == garbage.columns["r"]


def test_driver_unanswerable_reduce_keeps_result_kind(movie_small):
    """A reduce whose truth is unanswerable yields scalar=None; the result
    must still classify as a reduce (value() is None, not the table)."""
    table, oracle = movie_small
    plan = P.LogicalPlan((
        P.Operator(P.REDUCE, "Frobnicate the blorps.", "Title"),))
    for d in rt.DRIVERS:
        res = ex.execute(plan, table, perfect_backends(oracle),
                         default_tier="m*", driver=d)
        assert res.is_reduce
        assert res.value() is None
        assert res.table is None


def test_driver_judge_rates_none_reduce_pair_consistent(movie_small):
    table, oracle = movie_small
    backends = perfect_backends(oracle)
    none_reduce = P.LogicalPlan((
        P.Operator(P.REDUCE, "Frobnicate the blorps.", "Title"),))
    table_plan = P.LogicalPlan((
        P.Operator(P.FILTER, "The rating is higher than 1.",
                   "IMDB_rating"),))
    j = judge_mod.Judge(backends, exec_tier="m*")
    sample = table.sample(8, seed=0)
    # two None-scalar reduces are consistent, not a kind mismatch
    assert j.rate(none_reduce, none_reduce, sample).rating \
        == pytest.approx(1.0)
    r = j.rate(table_plan, none_reduce, sample)
    assert r.rating == 0.0 and r.detail == "result-kind mismatch"


def test_driver_optimizer_sample_flow_shares_execution_cache(movie_small):
    """The physical optimizer's sample flow now routes through
    runtime.run_llm_op with the execution cache and batch size, so the
    final execution reuses (never re-bills) the optimizer's sample calls."""
    table, oracle = movie_small
    plan = P.LogicalPlan(_chain_plan().ops[:2])
    ctx = rt.ExecutionContext(backends=bk.make_backends(oracle),
                              default_tier="m*", cache=rt.OutputCache())
    pres = popt.optimize(plan, table, ctx)
    misses_after_opt = ctx.cache.misses
    assert misses_after_opt > 0          # sample flow populated the cache
    res = ex.execute(pres.plan, table, ctx)
    assert res.table is not None
    assert ctx.cache.hits > 0            # execution reused sample-flow work


def test_driver_serve_reduced_flag_is_reachable():
    """--reduced was store_true with default=True: full-size configs were
    unreachable. BooleanOptionalAction restores --no-reduced."""
    from repro.launch import serve
    ap = serve.build_parser()
    assert ap.parse_args([]).reduced is True
    assert ap.parse_args(["--no-reduced"]).reduced is False
    assert ap.parse_args(["--reduced"]).reduced is True
