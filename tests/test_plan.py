"""Plan IR: structure, legality, selectivity, serialization."""
import pytest

from repro.core import plan as P


def chain(*ops):
    return P.LogicalPlan(tuple(ops), source="t")


def test_default_selectivities():
    assert P.Operator(P.FILTER, "x", "a").selectivity == 0.5
    assert P.Operator(P.MAP, "x", "a", "b").selectivity == 1.0
    assert P.Operator(P.REDUCE, "x", "a").selectivity == 0.0
    assert P.Operator(P.RANK, "x", "a", "r").selectivity == 1.0


def test_fused_filter_selectivity_is_half_over_k():
    # paper §3.1: merged filters 0.5 -> 0.25 (k=2) -> ~0.167 (k=3)
    f2 = P.Operator(P.FILTER, "x", "a", fused_from=2)
    f3 = P.Operator(P.FILTER, "x", "a", fused_from=3)
    assert f2.selectivity == pytest.approx(0.25)
    assert f3.selectivity == pytest.approx(0.5 / 3)


def test_map_requires_output_column():
    with pytest.raises(ValueError):
        P.Operator(P.MAP, "x", "a")


def test_depends_on_column_flow():
    p = chain(
        P.Operator(P.MAP, "genre", "Plot", "Genre"),
        P.Operator(P.FILTER, "crime", "Genre"),
        P.Operator(P.FILTER, "rating", "IMDB"),
    )
    assert p.depends_on(1, 0)           # filter reads map output
    assert not p.depends_on(2, 0)       # rating filter independent
    assert p.movable_before(2) == 0     # can hoist above the map
    assert p.movable_before(1) == 1     # blocked by dependency


def test_reduce_is_barrier():
    p = chain(
        P.Operator(P.REDUCE, "count", "Title"),
        P.Operator(P.FILTER, "rating", "IMDB"),
    )
    assert p.depends_on(1, 0)
    assert p.movable_before(1) == 1


def test_move_and_fuse():
    a = P.Operator(P.FILTER, "A.", "col")
    b = P.Operator(P.FILTER, "B.", "col")
    m = P.Operator(P.MAP, "mm", "x", "y")
    p = chain(m, a, b)
    moved = p.move_op(1, 0)
    assert moved.ops[0].instruction == "A."
    fused = p.fuse_ops(1, 2, a.with_(instruction="A and B.",
                                     fused_from=2, selectivity=None))
    assert len(fused.ops) == 2
    assert fused.ops[1].selectivity == pytest.approx(0.25)


def test_validate_rejects_use_before_def():
    p = chain(
        P.Operator(P.FILTER, "crime", "Genre"),
        P.Operator(P.MAP, "genre", "Plot", "Genre"),
    )
    with pytest.raises(ValueError):
        p.validate()


def test_json_roundtrip():
    p = chain(
        P.Operator(P.MAP, "m", "a", "b", udf="lambda x: x"),
        P.Operator(P.FILTER, "f", "b", tier="m2", fused_from=2),
    )
    q = P.LogicalPlan.from_json(p.to_json())
    assert q.signature() == p.signature()
    assert q.ops[1].tier == "m2"


def test_with_tiers_list_and_dict():
    p = chain(
        P.Operator(P.MAP, "m", "a", "b"),
        P.Operator(P.FILTER, "f", "b", udf="lambda x: True"),
        P.Operator(P.FILTER, "g", "a"),
    )
    tiered = p.with_tiers(["m1", "m3"])       # only LLM ops consume
    assert tiered.ops[0].tier == "m1"
    assert tiered.ops[1].tier is None
    assert tiered.ops[2].tier == "m3"
    tiered2 = p.with_tiers({2: "m*"})
    assert tiered2.ops[2].tier == "m*"
