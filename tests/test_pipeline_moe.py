"""Data pipeline determinism/sharding + MoE dispatch implementations."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data.pipeline import TokenPipeline


def test_pipeline_deterministic_per_step():
    p = TokenPipeline(vocab_size=100, global_batch=8, seq_len=16, seed=3)
    a = p.batch_at(5)["tokens"]
    b = p.batch_at(5)["tokens"]
    np.testing.assert_array_equal(a, b)
    c = p.batch_at(6)["tokens"]
    assert not np.array_equal(a, c)


def test_pipeline_dp_shards_partition_global_batch():
    """Rank shards must tile the exact global batch (no overlap/gap)."""
    full = TokenPipeline(vocab_size=50, global_batch=8, seq_len=4,
                         seed=1).batch_at(2)["tokens"]
    parts = [TokenPipeline(vocab_size=50, global_batch=8, seq_len=4,
                           dp_rank=r, dp_world=4, seed=1).batch_at(2)["tokens"]
             for r in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_pipeline_rejects_indivisible_batch():
    with pytest.raises(ValueError):
        TokenPipeline(vocab_size=10, global_batch=7, seq_len=4, dp_world=2)


def test_pipeline_document_packing():
    docs = ["hello world", "semantic operators over tables"] * 10
    p = TokenPipeline(vocab_size=300, global_batch=4, seq_len=12,
                      documents=docs)
    b = p.batch_at(0)["tokens"]
    assert b.shape == (4, 12)
    assert (b < 300).all()


def test_pipeline_prefetch_iterator():
    p = TokenPipeline(vocab_size=64, global_batch=4, seq_len=8, seed=9)
    it = p.iter_from(3)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"], p.batch_at(3)["tokens"])
    second = next(it)
    np.testing.assert_array_equal(second["tokens"], p.batch_at(4)["tokens"])


# ---------------------------------------------------------------------------
# MoE: shard_map dispatch must match the pjit-gather baseline
# ---------------------------------------------------------------------------

def test_moe_shardmap_matches_gather():
    from repro.configs import get_config, reduced
    from repro.models import ffn, registry
    cfg = reduced(get_config("granite-moe-1b-a400m"))
    bundle = registry.build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    # grab one layer's ffn params (strip the stacked layer dim)
    import repro.models.common as cm
    lp = jax.tree.map(lambda p: cm.Param(p.value[0], p.axes[1:]),
                      params["layers"]["ffn"], is_leaf=cm.is_param)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    y_gather = ffn.moe_forward_gather(lp, x, cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    y_sm = ffn.moe_forward_shardmap(lp, x, cfg, mesh, dp_axes=("data",))
    np.testing.assert_allclose(np.asarray(y_gather), np.asarray(y_sm),
                               atol=2e-4)


def test_engine_continuous_batching_ssm():
    """Continuous batching over recurrent-state (Mamba2) architectures:
    per-slot SSM states must be independent."""
    from repro.configs import get_config, reduced
    from repro.engine import ContinuousBatcher, GenerationEngine
    from repro.models import registry
    cfg = reduced(get_config("mamba2-1.3b"))
    bundle = registry.build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))

    def solo(prompt):
        eng = GenerationEngine(bundle, params, max_len=64, n_slots=1)
        cb = ContinuousBatcher(eng)
        rid = cb.submit(prompt, max_new_tokens=6)
        return cb.run()[rid].output_ids

    prompts = [f"ssm request {i}" for i in range(4)]
    want = [solo(p) for p in prompts]
    eng = GenerationEngine(bundle, params, max_len=64, n_slots=2)
    cb = ContinuousBatcher(eng)
    rids = [cb.submit(p, max_new_tokens=6) for p in prompts]
    got = cb.run()
    for rid, w in zip(rids, want):
        assert got[rid].output_ids == w
