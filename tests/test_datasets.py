"""Datasets: Table-3 fidelity + full oracle coverage of every workload."""
import pytest

from repro.core import executor as ex
from repro.core import plan as P
from repro.data import DATASETS, WORKLOADS, load_dataset

from conftest import perfect_backends

TABLE3 = {"movie": (250, 22), "estate": (1041, 4), "game": (18891, 21)}


@pytest.mark.parametrize("name", DATASETS)
def test_table3_row_and_attr_counts(name):
    table, _ = load_dataset(name)
    rows, attrs = TABLE3[name]
    assert table.n_rows == rows
    assert len(table.columns) == attrs


def test_modalities_match_paper():
    movie, _ = load_dataset("movie")
    assert movie.modalities["Poster"] == "image"
    assert movie.modalities["IMDB_rating"] == "numeric"
    estate, _ = load_dataset("estate")
    assert estate.modalities["image"] == "image"
    game, _ = load_dataset("game")
    assert game.modalities["rating"] == "image"
    assert game.modalities["release_date"] == "date"


def test_image_handles_resolve_to_blobs():
    movie, _ = load_dataset("movie")
    vals = movie.resolve("Poster")
    assert isinstance(vals[0], dict) and "cast" in vals[0]


def test_generation_is_deterministic():
    a, _ = load_dataset("movie")
    from repro.data import movie as movie_mod
    b = movie_mod.generate()
    assert a.columns["Title"] == b.columns["Title"]


@pytest.mark.parametrize("name", DATASETS)
def test_oracle_covers_every_workload_instruction(name):
    """Every operator of every query must be answerable by the oracle —
    executing the full workload with a perfect backend must not raise."""
    rows = 60 if name != "game" else 120
    table, oracle = load_dataset(name, max_rows=rows)
    backends = perfect_backends(oracle)
    for q in WORKLOADS[name]:
        plan = q.plan_for(table)
        plan.validate()
        res = ex.execute(plan, table, backends, default_tier="m*")
        if res.is_reduce and res.scalar is None:
            # a reduce is legitimately None only when the filter chain
            # emptied the table on this small slice (max/avg of nothing);
            # otherwise None means an oracle coverage gap
            pre = P.LogicalPlan(tuple(op for op in plan.ops
                                      if op.kind != P.REDUCE))
            sub = ex.execute(pre, table, backends, default_tier="m*")
            assert sub.table.n_rows == 0, (name, q.qid)
        else:
            assert res.value() is not None, (name, q.qid)


@pytest.mark.parametrize("name", DATASETS)
def test_workload_size_classes(name):
    sizes = {"S": (1, 1), "M": (2, 3), "L": (4, 99)}
    for q in WORKLOADS[name]:
        lo, hi = sizes[q.size]
        n = len(q.plan_for(load_dataset(name, max_rows=4)[0]).ops)
        assert lo <= n <= hi, (name, q.qid, n)


def test_selective_queries_select_nontrivially():
    """Filters should neither keep everything nor drop everything."""
    table, oracle = load_dataset("movie")
    backends = perfect_backends(oracle)
    for qi in (1, 2, 3):
        plan = WORKLOADS["movie"][qi].plan_for(table)
        res = ex.execute(plan, table, backends, default_tier="m*")
        assert 0 < res.table.n_rows < table.n_rows


def test_table_select_take_with_column():
    table, _ = load_dataset("movie", max_rows=10)
    sel = table.select([i % 2 == 0 for i in range(10)])
    assert sel.n_rows == 5
    t2 = table.with_column("X", list(range(10)), "numeric")
    assert t2.column("X") == list(range(10))
    with pytest.raises(ValueError):
        table.with_column("Y", [1, 2])
    s = table.sample(4, seed=1)
    assert s.n_rows == 4
    assert table.sample(4, seed=1).columns == s.columns
