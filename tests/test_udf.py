"""NL -> UDF grammar: the paper's own examples must compile and evaluate."""
import pytest

from repro.core import plan as P
from repro.core import udf


def f(ins):
    return udf.compile_udf(P.Operator(P.FILTER, ins, "c"))


def m(ins):
    return udf.compile_udf(P.Operator(P.MAP, ins, "c", "o"))


def r(ins):
    return udf.compile_udf(P.Operator(P.REDUCE, ins, "c"))


def test_parse_number_formats():
    assert udf.parse_number("8.5") == 8.5
    assert udf.parse_number("92%") == 92
    assert udf.parse_number("N250m") == 250e6
    assert udf.parse_number("430 Million Naira") == 430e6
    assert udf.parse_number("Rp 150,000") == 150000
    assert udf.parse_number("$123.4M") == pytest.approx(123.4e6)
    assert udf.parse_number("no digits") is None


def test_range_filter_paper_example():
    # "Score is higher than 8.5 and lower than 9" -> 8.5 < x < 9 (Fig. 3)
    c = f("The rating is higher than 8.5 and lower than 9.")
    assert c is not None
    assert c.fn("8.7") and not c.fn("9.0") and not c.fn("8.5")


def test_oscar_filter_paper_example():
    c = f("Whether the movie has won 2 Oscars.")
    assert c.fn("Won 2 Oscars. 30 wins total")
    assert not c.fn("Won 3 Oscars.")
    assert not c.fn("5 wins & 3 nominations")


def test_oscar_more_than():
    c = f("Whether the movie has ever won more than 3 Oscars?")
    assert c.fn("Won 4 Oscars.")
    assert not c.fn("Won 3 Oscars.")


def test_entity_filter():
    c = f("The movie is directed by Christopher Nolan.")
    assert c.fn("Christopher Nolan")
    assert not c.fn("Greta Gerwig")


def test_image_instruction_never_compiles():
    assert f("Whether the movie poster image is in the dark style.") is None
    assert f("Observed from the house picture, whether the house has a "
             "yard or not.") is None
    assert m("Extract the style from the poster image.") is None


def test_bedrooms_value_set():
    c = f("Whether the estate has 2 or 3 bedrooms")
    assert c.fn("3 bedroom duplex for sale")
    assert not c.fn("5 bedroom duplex for sale")


def test_map_price_extraction():
    c = m("Extract the house price from the detail about the estate.")
    assert c.fn("... PRICE: N250m") == 250e6


def test_map_fx_conversion():
    c = m("Convert the price in IDR into the price in USD.")
    assert c.fn("Rp 100,000") == pytest.approx(6.5)


def test_reduce_grammar():
    assert r("Count the number of movies.").fn(["a", "b", "c"]) == 3
    assert r("Compute the average price.").fn(["10", "20"]) == 15
    assert r("Compute the total box office gross.").fn(
        ["$1M", "$2M"]) == pytest.approx(3e6)
    assert r("Find the maximum rating.").fn(["8.5", "9.2", "7"]) == 9.2
    assert r("Compute the lowest price for the estates.").fn(
        ["N250m", "N100m"]) == 100e6
    assert r("Find the publisher that appears the most.").fn(
        ["A", "B", "A"]) == "A"


def test_reduce_empty_numeric_returns_none():
    assert r("Compute the average price.").fn(["n/a", "tbd"]) is None


def test_unknown_instruction_returns_none():
    assert f("Does the plot reference obscure mythology?") is None


def test_udf_roundtrip_through_plan():
    c = f("The rating is higher than 9.")
    op = P.Operator(P.FILTER, "The rating is higher than 9.", "c",
                    udf=c.source)
    re = udf.resolve_udf(op)
    assert re.fn("9.5") and not re.fn("8.0")


def test_udf_sandbox_blocks_imports():
    with pytest.raises(Exception):
        udf.CompiledUDF("", eval("lambda x: __import__('os')",
                                 dict(udf._SAFE_GLOBALS)))("x")
