"""Executor (caching, batching, accounting) + judge behaviour + cost model."""
import pytest

from repro.core import backends as bk
from repro.core import cost as cost_mod
from repro.core import executor as ex
from repro.core import judge as judge_mod
from repro.core import plan as P
from repro.core.table import Table
from repro.data import WORKLOADS, load_dataset

from conftest import perfect_backends


@pytest.fixture(scope="module")
def movie_small():
    return load_dataset("movie", max_rows=50)


# ---------------------------------------------------------------------------
# Executor
# ---------------------------------------------------------------------------

def test_execute_matches_udf_semantics(movie_small):
    table, oracle = movie_small
    backends = perfect_backends(oracle)
    plan = P.LogicalPlan((
        P.Operator(P.FILTER, "The rating is higher than 8.", "IMDB_rating"),
        P.Operator(P.REDUCE, "Count the number of movies.", "Title"),
    ))
    got = ex.execute(plan, table, backends, default_tier="m*").value()
    want = sum(1 for r in table.column("IMDB_rating") if float(r) > 8)
    assert got == want


def test_output_cache_avoids_recalls(movie_small):
    table, oracle = movie_small
    backends = bk.make_backends(oracle)
    plan = WORKLOADS["movie"][1].plan_for(table)
    cache = ex.OutputCache()
    m1 = bk.UsageMeter()
    ex.execute(plan, table, backends, cache=cache, meter=m1)
    first_calls = m1.total.calls
    m2 = bk.UsageMeter()
    r2 = ex.execute(plan, table, backends, cache=cache, meter=m2)
    assert m2.total.calls < first_calls / 10
    assert r2.wall_s == 0.0
    assert cache.hits >= table.n_rows


def test_empty_table_short_circuits(movie_small):
    _, oracle = movie_small
    backends = perfect_backends(oracle)
    empty = Table({"A": [], "B": []}, name="t")
    plan = P.LogicalPlan((
        P.Operator(P.FILTER, "The rating is higher than 8.", "A"),
        P.Operator(P.REDUCE, "Count the number of movies.", "B"),
    ))
    res = ex.execute(plan, empty, backends, default_tier="m*")
    assert res.value() == 0


def test_batch_prompting_reduces_calls_and_quality(movie_small):
    table, oracle = movie_small
    backends = bk.make_backends(oracle)
    op = P.Operator(P.FILTER, "The movie is directed by Christopher "
                    "Nolan.", "Director")
    plan = P.LogicalPlan((op,))
    m_b1 = bk.UsageMeter()
    r1 = ex.execute(plan, table, backends, meter=m_b1, batch_size=1)
    m_b4 = bk.UsageMeter()
    r4 = ex.execute(plan, table, backends, meter=m_b4, batch_size=4)
    assert m_b4.total.calls < m_b1.total.calls
    assert m_b4.total.usd < m_b1.total.usd


def test_makespan_concurrency():
    """16 homogeneous 1s calls over W workers (was the waves formula)."""
    from repro.core import runtime as rt
    for workers, want in ((16, 1.0), (4, 4.0), (1, 16.0)):
        sched = rt.EventScheduler(concurrency=workers)
        for _ in range(16):
            sched.submit("m*", 1.0)
        assert sched.makespan == pytest.approx(want)


# ---------------------------------------------------------------------------
# Judge
# ---------------------------------------------------------------------------

def test_judge_rates_identical_plans_1(movie_small):
    table, oracle = movie_small
    backends = perfect_backends(oracle)
    plan = WORKLOADS["movie"][9].plan_for(table)
    j = judge_mod.Judge(backends, exec_tier="m*")
    r = j.rate(plan, plan, table.sample(12))
    assert r.rating == pytest.approx(1.0)


def test_judge_rates_negated_filter_lower(movie_small):
    table, oracle = movie_small
    backends = perfect_backends(oracle)
    plan = WORKLOADS["movie"][1].plan_for(table)     # Nolan filter
    bad = plan.replace_op(0, plan.ops[0].with_(
        instruction="It is NOT the case that: " + plan.ops[0].instruction))
    j = judge_mod.Judge(backends, exec_tier="m*")
    r = j.rate(plan, bad, table.sample(16))
    assert r.rating < 0.3


def test_judge_mismatched_result_kind_is_zero(movie_small):
    table, oracle = movie_small
    backends = perfect_backends(oracle)
    plan = WORKLOADS["movie"][5].plan_for(table)     # filter + count
    dropped = P.LogicalPlan(plan.ops[:-1], plan.source)
    j = judge_mod.Judge(backends, exec_tier="m*")
    assert j.rate(plan, dropped, table.sample(12)).rating == 0.0


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------

def test_plan_cost_selectivity_flow():
    ops = (P.Operator(P.MAP, "m", "a", "b"),
           P.Operator(P.FILTER, "f", "b"),
           P.Operator(P.MAP, "m2", "b", "c"))
    pc = cost_mod.plan_cost(P.LogicalPlan(ops), 1000)
    # second map sees half the rows
    assert pc.per_op[2].rows_in == pytest.approx(500)
    assert pc.per_op[0].llm_calls == 1000


def test_fused_filter_cheaper_than_two():
    two = P.LogicalPlan((P.Operator(P.FILTER, "a", "c"),
                         P.Operator(P.FILTER, "b", "c")))
    one = P.LogicalPlan((P.Operator(P.FILTER, "a and b", "c",
                                    fused_from=2),))
    assert cost_mod.plan_cost(one, 1000).cost \
        < cost_mod.plan_cost(two, 1000).cost


def test_pushdown_cheaper_when_filter_first():
    late = P.LogicalPlan((P.Operator(P.MAP, "m", "a", "b"),
                          P.Operator(P.FILTER, "f", "a")))
    early = late.move_op(1, 0)
    assert cost_mod.plan_cost(early, 1000).cost \
        < cost_mod.plan_cost(late, 1000).cost


def test_udf_ops_cost_nothing():
    p = P.LogicalPlan((P.Operator(P.FILTER, "f", "c",
                                  udf="lambda x: True"),))
    pc = cost_mod.plan_cost(p, 10000)
    assert pc.usd == 0.0 and pc.llm_calls == 0


def test_tier_price_ordering():
    tiers = cost_mod.tier_list()
    for a, b in zip(tiers, tiers[1:]):
        assert a.capability < b.capability
        assert a.usd(1e6, 1e6) < b.usd(1e6, 1e6)
        assert a.latency(100) < b.latency(100)
