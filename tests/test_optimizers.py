"""Logical (Alg. 1) + physical (Alg. 2) optimizers."""
import random

import pytest

from repro.core import backends as bk
from repro.core import executor as ex
from repro.core import logical_optimizer as lopt
from repro.core import physical_optimizer as popt
from repro.core import plan as P
from repro.core import rewriter as rw
from repro.core.cost import DEFAULT_TIERS
from repro.data import WORKLOADS, load_dataset

from conftest import perfect_backends


# ---------------------------------------------------------------------------
# Eq. 1 sampling
# ---------------------------------------------------------------------------

def test_eq1_probabilities_form_distribution():
    for lam in (0.0, 0.2, 1.0):
        probs = lopt.sample_probabilities([1.0, 2.0, 10.0], lam)
        assert sum(probs) == pytest.approx(1.0)
        assert all(p > 0 for p in probs)


def test_eq1_prefers_cheap_plans():
    probs = lopt.sample_probabilities([0.1, 10.0], lam=0.2)
    assert probs[0] > probs[1]


def test_eq1_lambda_one_is_uniform():
    probs = lopt.sample_probabilities([0.1, 10.0, 5.0], lam=1.0)
    assert probs == pytest.approx([1 / 3] * 3)


# ---------------------------------------------------------------------------
# Logical optimizer
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def movie_small():
    return load_dataset("movie", max_rows=80)


def test_logical_optimizer_never_increases_cost(movie_small):
    table, oracle = movie_small
    backends = bk.make_backends(oracle)
    q = WORKLOADS["movie"][9]
    plan = q.plan_for(table)
    res = lopt.optimize(plan, table, backends,
                        cfg=lopt.LogicalOptConfig(n_iterations=4, seed=3))
    assert res.best_cost <= res.initial_cost
    for c in res.accepted_set[1:]:
        parent = res.candidates[c.parent]
        assert c.cost <= parent.cost
        assert c.acc >= 0.8


def test_logical_optimizer_finds_savings_on_large_query(movie_small):
    table, oracle = movie_small
    backends = bk.make_backends(oracle)
    q = WORKLOADS["movie"][9]
    plan = q.plan_for(table)
    best = min(lopt.optimize(
        plan, table, backends,
        cfg=lopt.LogicalOptConfig(n_iterations=6, seed=s)).best_cost
        for s in range(3))
    assert best < 0.7 * lopt.optimize(
        plan, table, backends,
        cfg=lopt.LogicalOptConfig(n_iterations=0)).initial_cost


def test_optimizer_meters_its_own_overhead(movie_small):
    table, oracle = movie_small
    backends = bk.make_backends(oracle)
    plan = WORKLOADS["movie"][9].plan_for(table)
    res = lopt.optimize(plan, table, backends,
                        cfg=lopt.LogicalOptConfig(n_iterations=3))
    assert res.meter.calls("rewriter") == 3
    assert res.meter.total.usd > 0
    assert res.opt_wall_s > 0


def test_beam_search_costs_more_than_random_walk(movie_small):
    table, oracle = movie_small
    backends = bk.make_backends(oracle)
    plan = WORKLOADS["movie"][9].plan_for(table)
    r1 = lopt.optimize(plan, table, backends,
                       cfg=lopt.LogicalOptConfig(n_iterations=3))
    r2 = lopt.optimize_beam(plan, table, backends,
                            cfg=lopt.LogicalOptConfig(n_iterations=3),
                            beam_width=2)
    assert r2.meter.calls("rewriter") >= r1.meter.calls("rewriter")


def test_judge_rejects_corrupted_rewrites_mostly(movie_small):
    table, oracle = movie_small
    backends = bk.make_backends(oracle)
    always_bad = rw.LLMSimRewriter(error_rate=1.0)
    rejected = total = 0
    for qi in (8, 9, 10):
        plan = WORKLOADS["movie"][qi].plan_for(table)
        res = lopt.optimize(plan, table, backends, rewriter=always_bad,
                            cfg=lopt.LogicalOptConfig(n_iterations=4,
                                                      seed=qi))
        for c in res.candidates[1:]:
            total += 1
            rejected += not c.accepted
    assert total > 0
    assert rejected / total >= 0.5


# ---------------------------------------------------------------------------
# Physical optimizer
# ---------------------------------------------------------------------------

def test_select_tier_margin_semantics():
    assert popt.select_tier({"m2": 0.05, "m3": 0.1, "m*": 0.15},
                            delta_min=0.2) == "m1"
    assert popt.select_tier({"m2": 0.25, "m3": 0.3, "m*": 0.32},
                            delta_min=0.2) == "m2"
    # marginal gains: m2 (+0.25) then m* (+0.3 over m2's 0.25)
    assert popt.select_tier({"m2": 0.25, "m3": 0.4, "m*": 0.55},
                            delta_min=0.2) == "m*"


def test_physical_optimizer_assigns_all_llm_ops(movie_small):
    table, oracle = movie_small
    backends = bk.make_backends(oracle)
    plan = WORKLOADS["movie"][8].plan_for(table)
    res = popt.optimize(plan, table, backends,
                        cfg=popt.PhysicalOptConfig(estimator="approx"))
    llm_idx = [i for i, o in enumerate(plan.ops) if o.is_llm]
    assert set(res.assignments) == set(llm_idx)
    for i in llm_idx:
        assert res.plan.ops[i].tier in DEFAULT_TIERS


def test_async_mode_faster_than_sync(movie_small):
    table, oracle = movie_small
    backends = bk.make_backends(oracle)
    plan = WORKLOADS["movie"][8].plan_for(table)
    sync = popt.optimize(plan, table, backends,
                         cfg=popt.PhysicalOptConfig(mode="sync"))
    asyn = popt.optimize(plan, table, backends,
                         cfg=popt.PhysicalOptConfig(mode="async",
                                                    concurrency=16))
    assert asyn.opt_wall_s < sync.opt_wall_s


def test_estimator_overhead_ordering(movie_small):
    """m*-invocation counts: approx < exact on an operator with real
    inter-tier disagreement (a hard map); plan level approx <= exact."""
    from repro.core import improvement as imp
    table, oracle = movie_small
    op = P.Operator(P.MAP, "According to the movie plot, extract the "
                    "genre(s) of each movie.", "Plot", "Genre")
    values = table.column("Plot")
    calls = {}
    for est in ("exact", "pushdown", "reuse", "approx"):
        backends = bk.make_backends(oracle)
        r = imp.improvement_scores(backends, op, values, method=est)
        calls[est] = r.meter.calls("m*")
    assert calls["approx"] < calls["exact"]
    assert calls["approx"] <= calls["reuse"] <= calls["pushdown"] \
        <= calls["exact"]

    backends = bk.make_backends(oracle)
    plan = WORKLOADS["movie"][8].plan_for(table)
    plan_calls = {}
    for est in ("exact", "approx"):
        res = popt.optimize(plan, table, backends,
                            cfg=popt.PhysicalOptConfig(estimator=est))
        plan_calls[est] = res.meter.calls("m*")
    assert plan_calls["approx"] <= plan_calls["exact"]


def test_smart_variants_run(movie_small):
    table, oracle = movie_small
    backends = bk.make_backends(oracle)
    op = P.Operator(P.FILTER, "The rating is higher than 9.", "IMDB_rating")
    values = table.column("IMDB_rating")[:40]
    for variant in ("exhaustive", "efficient", "multi-model"):
        tier, scores, meter = popt.smart_select(
            op, values, backends, delta_min=0.2, variant=variant)
        assert tier in DEFAULT_TIERS
        assert meter.calls("m*") > 0
