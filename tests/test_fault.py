"""Fault-tolerant serving suite (runtime.CallPolicy + chaos harness).

Covers the robustness tentpole: deterministic retry/deadline/breaker
enforcement inside both dispatch drivers, tier fallback on breaker trip,
shard kill + morsel requeue on the sharded dispatcher, and the
degradation contract for the tier-0 embedding cascade — all under the
seeded :class:`testing.FlakyBackend` fault plans, which are pure
functions of the logical call key and therefore driver-, shard-count-
and admission-order-invariant. The acceptance bar: a fixed fault plan at
10% transient failures leaves a 3-filter plan's results byte-identical
to the fault-free run, retried attempts bill under distinct logical
keys, and killing one shard of four mid-run requeues its morsels onto
the survivors without corrupting results or double-billing."""
import threading
import time

import pytest

from repro.core import backends as bk
from repro.core import cost as cost_mod
from repro.core import cascade as casc
from repro.core import executor as ex
from repro.core import plan as P
from repro.core import runtime as rt
from repro.core.backends import SimulatedBackend
from repro.core.cost import TierSpec
from repro.core.cost_model import CostModel
from repro.core.table import Table
from repro.launch.query_server import QueryServer
from repro.testing import (EmbeddingOracle, FlakyBackend, KindOracle,
                           SleepBackend, result_fingerprint, tagged_plan,
                           tagged_table)

BATCH = 4
MORSEL = 8


def _spec(name="m*", usd_in=2.0, usd_out=8.0):
    return TierSpec(name, 1.01, usd_in, usd_out, 0.01, 0.0)


def _backend(name="m*", flaky=None):
    b = SimulatedBackend(_spec(name), KindOracle(), violation_rate=0.0)
    if flaky is not None:
        b = FlakyBackend(b, **flaky)
    return b


def _filter3_plan(tag="fq3"):
    return P.LogicalPlan(tuple(
        P.Operator(P.FILTER, f"{tag} predicate {j}: keep", "v")
        for j in range(3)))


def _fingerprint_filter(res):
    return tuple(res.table.columns[ex.ROWID])


def _log_key(meter):
    """Byte-comparable merged call log: (logical key, tier, latency)."""
    return sorted(zip(meter.call_keys,
                      [t for t, _ in meter.call_log],
                      [round(l, 9) for _, l in meter.call_log]))


def _totals_key(meter):
    return {t: (u.calls, round(u.tok_in, 6), round(u.tok_out, 6),
                round(u.usd, 9), round(u.latency_s, 6))
            for t, u in sorted(meter.by_tier.items())}


def _run(plan, table, backends, policy=None, driver="simulated",
         shards=0, **kw):
    meter = bk.UsageMeter()
    res = ex.execute(plan, table, backends, default_tier="m*",
                     batch_size=BATCH, morsel_size=MORSEL, meter=meter,
                     call_policy=policy, driver=driver, shards=shards,
                     **kw)
    return res, meter


# ---------------------------------------------------------------------------
# Fail-fast default: byte-identity with the pre-policy runtime
# ---------------------------------------------------------------------------

def test_fault_free_default_policy_is_byte_identical():
    """An inactive CallPolicy() must leave the run byte-identical to no
    policy at all — same results, same call log, same logical key
    shapes (the fail-fast default costs nothing)."""
    plan, table = _filter3_plan(), tagged_table("fq3", 32)
    r0, m0 = _run(plan, table, {"m*": _backend()}, policy=None)
    r1, m1 = _run(plan, table, {"m*": _backend()},
                  policy=rt.CallPolicy())
    assert not rt.CallPolicy().active
    assert _fingerprint_filter(r1) == _fingerprint_filter(r0)
    assert list(m1.call_keys) == list(m0.call_keys)
    assert list(m1.call_log) == list(m0.call_log)
    assert _totals_key(m1) == _totals_key(m0)


# ---------------------------------------------------------------------------
# Retries: the acceptance-bar plan
# ---------------------------------------------------------------------------

def test_retry_recovers_seeded_faults_results_identical():
    """10% seeded transient failures + retries=2: the 3-filter plan's
    results are byte-identical to the fault-free run and faults really
    fired (the seed is chosen so the plan draws at least one)."""
    plan, table = _filter3_plan(), tagged_table("fq3", 48)
    r0, _ = _run(plan, table, {"m*": _backend()})
    flaky = _backend(flaky=dict(error_rate=0.10, seed=11))
    r1, m1 = _run(plan, table, {"m*": flaky},
                  policy=rt.CallPolicy(retries=3))
    assert flaky.faults_injected > 0
    assert _fingerprint_filter(r1) == _fingerprint_filter(r0)
    assert m1.total.calls > 0


def test_retry_attempts_bill_under_distinct_keys():
    """Every retried attempt lands in the call log under its own
    logical key (base key + (RETRY_KEY_MARK, attempt)) — billing stays
    per-attempt truthful and the merged log stays collision-free."""
    plan, table = _filter3_plan(), tagged_table("fq3", 48)
    flaky = _backend(flaky=dict(error_rate=0.25, seed=3))
    _, m = _run(plan, table, {"m*": flaky},
                policy=rt.CallPolicy(retries=4))
    keys = list(m.call_keys)
    assert all(k is not None for k in keys)
    assert len(keys) == len(set(keys))
    marked = [k for k in keys if rt.RETRY_KEY_MARK in k]
    assert len(marked) == flaky.faults_injected > 0


def test_same_fault_plan_same_policy_byte_identical_runs():
    """Two runs under the same seeded fault plan and the same policy are
    byte-identical: results, merged call log, spend totals."""
    runs = []
    plan, table = _filter3_plan(), tagged_table("fq3", 48)
    for _ in range(2):
        r, m = _run(plan, table,
                    {"m*": _backend(flaky=dict(error_rate=0.25, seed=3))},
                    policy=rt.CallPolicy(retries=4))
        runs.append((_fingerprint_filter(r), _log_key(m),
                     _totals_key(m)))
    assert runs[0] == runs[1]


def test_retry_driver_invariance():
    """The same seeded fault plan injects the same faults — and bills
    the same attempts — under both dispatch drivers: results, per-tier
    totals and key-sorted call logs all agree."""
    plan, table = _filter3_plan(), tagged_table("fq3", 48)
    pol = rt.CallPolicy(retries=4)
    ref = None
    for driver in rt.DRIVERS:
        flaky = _backend(flaky=dict(error_rate=0.25, seed=3))
        r, m = _run(plan, table, {"m*": flaky}, policy=pol,
                    driver=driver)
        assert flaky.faults_injected > 0, driver
        key = (_fingerprint_filter(r), _log_key(m), _totals_key(m))
        if ref is None:
            ref = key
        assert key == ref, driver


@pytest.mark.parametrize("driver", rt.DRIVERS)
def test_retry_shard_count_invariance(driver):
    """Sharding only moves calls between workers — a fixed fault plan
    with retries produces byte-identical merged logs at 1, 2 and 4
    shards."""
    plan, table = _filter3_plan(), tagged_table("fq3", 48)
    pol = rt.CallPolicy(retries=4)
    ref = None
    for shards in (1, 2, 4):
        flaky = _backend(flaky=dict(error_rate=0.25, seed=3))
        r, m = _run(plan, table, {"m*": flaky}, policy=pol,
                    driver=driver, shards=shards)
        key = (_fingerprint_filter(r), _log_key(m), _totals_key(m))
        if ref is None:
            ref = key
        assert key == ref, (driver, shards)


@pytest.mark.parametrize("driver", rt.DRIVERS)
def test_retry_through_coalesced_batches(driver):
    """Retries compose with the batch coalescer: coalesced cross-morsel
    batches recover from injected faults and match the fault-free
    coalesced run under both drivers."""
    plan, table = tagged_plan("fqc"), tagged_table("fqc", 48)
    r0, _ = _run(plan, table, {"m*": _backend()}, driver=driver,
                 coalesce=True)
    flaky = _backend(flaky=dict(error_rate=0.20, seed=5))
    r1, m1 = _run(plan, table, {"m*": flaky}, driver=driver,
                  coalesce=True, policy=rt.CallPolicy(retries=4))
    assert flaky.faults_injected > 0
    assert result_fingerprint(r1) == result_fingerprint(r0)


# ---------------------------------------------------------------------------
# Deadlines and retry budgets
# ---------------------------------------------------------------------------

def test_call_timeout_faults_retry_and_failfast_raises():
    """Injected timeouts honor the per-call deadline: with retries they
    recover (billing the deadline as the faulted attempt's latency);
    fail-fast surfaces CallTimeoutError as the query failure."""
    plan, table = _filter3_plan("fqt"), tagged_table("fqt", 32)
    r0, _ = _run(plan, table, {"m*": _backend()})
    flaky = _backend(flaky=dict(timeout_rate=0.25, seed=9))
    r1, m1 = _run(plan, table, {"m*": flaky},
                  policy=rt.CallPolicy(retries=4, call_timeout_s=0.5))
    assert flaky.faults_injected > 0
    assert _fingerprint_filter(r1) == _fingerprint_filter(r0)
    # each faulted attempt billed exactly the deadline it burned
    assert any(lat == 0.5 for _, lat in m1.call_log)
    with pytest.raises(rt.CallTimeoutError):
        _run(plan, table,
             {"m*": _backend(flaky=dict(timeout_rate=0.25, seed=9))},
             policy=rt.CallPolicy(call_timeout_s=0.5))


def test_retry_budget_exhaustion_fails_query():
    """retry_budget=0 turns retries off globally: the first injected
    fault exhausts the call and the denial is counted."""
    plan, table = _filter3_plan(), tagged_table("fq3", 48)
    ctx = rt.ExecutionContext(
        backends={"m*": _backend(flaky=dict(error_rate=0.25, seed=3))},
        default_tier="m*", batch_size=BATCH, morsel_size=MORSEL,
        call_policy=rt.CallPolicy(retries=4, retry_budget=0))
    disp = ctx.make_dispatcher()
    try:
        with pytest.raises(rt.TransientCallError):
            ex.execute(plan, table, ctx, dispatcher=disp)
        stats = disp.fault_stats()
        assert stats["budget_denied"] > 0
        assert stats["retries"] == 0
    finally:
        disp.close()


# ---------------------------------------------------------------------------
# Circuit breaker + tier fallback
# ---------------------------------------------------------------------------

def _two_tier(primary_error=1.0, seed=0):
    return {"m*": _backend(flaky=dict(error_rate=primary_error,
                                      seed=seed)),
            "m3": SimulatedBackend(_spec("m3", 0.4, 1.6), KindOracle(),
                                   violation_rate=0.0)}


def test_breaker_trips_and_degrades_to_fallback_tier():
    """A dead primary tier trips the breaker after the configured run of
    consecutive exhaustions; every later call short-circuits to the
    fallback tier and the query completes with the fallback tier's
    answers — graceful degradation, not failure."""
    plan, table = tagged_plan("fbk"), tagged_table("fbk", 32)
    pol = rt.CallPolicy(retries=1, breaker_threshold=3,
                        fallback_tier="m3")
    bs = _two_tier()
    ctx = rt.ExecutionContext(backends=bs, default_tier="m*",
                              batch_size=BATCH, morsel_size=MORSEL,
                              meter=bk.UsageMeter(), call_policy=pol)
    disp = ctx.make_dispatcher()
    try:
        res = ex.execute(tagged_plan("fbk"), table, ctx, dispatcher=disp)
        stats = disp.fault_stats()
    finally:
        disp.close()
    base = ex.execute(tagged_plan("fbk"), table,
                      {"m3": SimulatedBackend(_spec("m3", 0.4, 1.6),
                                              KindOracle(),
                                              violation_rate=0.0)},
                      default_tier="m3", batch_size=BATCH,
                      morsel_size=MORSEL)
    assert result_fingerprint(res) == result_fingerprint(base)
    assert stats["breaker_trips"] >= 1
    assert ("m*", 0) in stats["open_breakers"]
    assert stats["fallback_calls"] > 0
    m = ctx.meter
    assert m.calls("m3") > 0
    fkeys = [k for k in m.call_keys if k and rt.FALLBACK_KEY_MARK in k]
    assert len(fkeys) == stats["fallback_calls"]


def test_breaker_stops_hammering_doomed_primary():
    """After the trip, the primary tier sees no further attempts: its
    observed call count equals threshold * (retries + 1)."""
    plan, table = tagged_plan("fbk2"), tagged_table("fbk2", 32)
    pol = rt.CallPolicy(retries=1, breaker_threshold=3,
                        fallback_tier="m3")
    bs = _two_tier(seed=1)
    res, _ = _run(plan, table, bs, policy=pol)
    assert res.table.n_rows > 0
    assert bs["m*"].calls_seen == 3 * (pol.retries + 1)


def test_breaker_without_fallback_fails_query():
    """breaker_threshold set but no fallback tier: exhausted calls (and
    breaker-open short-circuits) surface the failure instead."""
    plan, table = tagged_plan("fbk3"), tagged_table("fbk3", 16)
    with pytest.raises(rt.TransientCallError):
        _run(plan, table, {"m*": _backend(flaky=dict(error_rate=1.0))},
             policy=rt.CallPolicy(retries=1, breaker_threshold=2))


def test_breaker_fallback_observes_costs_under_serving_tier():
    """CostModel calibration follows the tier that actually served: a
    degraded run records m3 observations (and none under the faulted
    attempts, which bill op_kind=None)."""
    plan, table = tagged_plan("fbk4"), tagged_table("fbk4", 32)
    cm = CostModel()
    pol = rt.CallPolicy(retries=1, breaker_threshold=2,
                        fallback_tier="m3")
    ctx = rt.ExecutionContext(backends=_two_tier(seed=2),
                              default_tier="m*", batch_size=BATCH,
                              morsel_size=MORSEL, cost_model=cm,
                              call_policy=pol)
    with ctx:
        res = ex.execute(plan, table, ctx, dispatcher=ctx.dispatcher())
    assert res.table.n_rows > 0
    snap = cm.calibration_state()
    assert any(tier == "m3" for _, tier in snap)
    assert all(tier != "m*" for _, tier in snap)


# ---------------------------------------------------------------------------
# Shard failure: kill + requeue
# ---------------------------------------------------------------------------

class KillerBackend:
    """Kills one shard of the ambient dispatcher after ``kill_after``
    observed calls — deterministic mid-run shard loss."""

    def __init__(self, inner, kill_after=4, shard=2):
        self.inner = inner
        self.tier = inner.tier
        self.kill_after = kill_after
        self.shard = shard
        self.disp = None
        self._n = 0
        self._lock = threading.Lock()

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def run_values(self, op, values, meter=None, batch_size=1):
        with self._lock:
            self._n += 1
            fire = self._n == self.kill_after
        if fire and self.disp is not None:
            self.disp.kill_shard(self.shard)
        return self.inner.run_values(op, values, meter=meter,
                                     batch_size=batch_size)


@pytest.mark.parametrize("driver", rt.DRIVERS)
def test_shard_kill_requeues_morsels_query_completes(driver):
    """Killing one shard of four mid-run reroutes its pending morsels
    onto the survivors: the query completes, results match the healthy
    run, and billing stays exactly-once (same total call count)."""
    plan, table = tagged_plan("skl"), tagged_table("skl", 48)
    r0, m0 = _run(plan, table, {"m*": _backend()}, driver=driver)
    kb = KillerBackend(_backend())
    ctx = rt.ExecutionContext(backends={"m*": kb}, default_tier="m*",
                              batch_size=BATCH, morsel_size=MORSEL,
                              driver=driver, shards=4,
                              meter=bk.UsageMeter())
    disp = ctx.make_dispatcher()
    kb.disp = disp
    try:
        res = ex.execute(plan, table, ctx, dispatcher=disp)
        assert disp.is_dead(2)
        assert disp.live_shards() == [0, 1, 3]
    finally:
        disp.close()
    assert result_fingerprint(res) == result_fingerprint(r0)
    assert ctx.meter.total.calls == m0.total.calls
    assert _totals_key(ctx.meter) == _totals_key(m0)


def test_shard_kill_merged_log_matches_healthy_run():
    """Under the simulated driver the requeued run's merged call log is
    byte-identical to the healthy run: logical keys don't encode the
    shard, so rerouting is invisible to the bill."""
    plan, table = tagged_plan("skl2"), tagged_table("skl2", 48)
    _, m0 = _run(plan, table, {"m*": _backend()})
    kb = KillerBackend(_backend(), kill_after=3, shard=1)
    ctx = rt.ExecutionContext(backends={"m*": kb}, default_tier="m*",
                              batch_size=BATCH, morsel_size=MORSEL,
                              shards=4, meter=bk.UsageMeter())
    disp = ctx.make_dispatcher()
    kb.disp = disp
    try:
        ex.execute(plan, table, ctx, dispatcher=disp)
    finally:
        disp.close()
    assert _log_key(ctx.meter) == _log_key(m0)


def test_shard_kill_last_live_shard_is_refused():
    ctx = rt.ExecutionContext(backends={"m*": _backend()},
                              default_tier="m*", shards=2)
    disp = ctx.make_dispatcher()
    try:
        disp.kill_shard(0)
        with pytest.raises(ValueError, match="last live shard"):
            disp.kill_shard(1)
        with pytest.raises(ValueError):
            disp.kill_shard(7)
    finally:
        disp.close()


def test_shard_failure_threshold_marks_shard_dead():
    """shard_failure_threshold: enough consecutive call failures on one
    shard retire it automatically (liveness detection without an
    explicit kill), and the query still fails-fast its own error."""
    plan, table = tagged_plan("sft"), tagged_table("sft", 48)
    pol = rt.CallPolicy(shard_failure_threshold=2)
    assert not pol.active      # detection alone doesn't re-key billing
    bs = {"m*": _backend(flaky=dict(error_rate=1.0, seed=4))}
    ctx = rt.ExecutionContext(backends=bs, default_tier="m*",
                              batch_size=BATCH, morsel_size=MORSEL,
                              shards=4, call_policy=pol)
    disp = ctx.make_dispatcher()
    try:
        with pytest.raises(rt.TransientCallError):
            ex.execute(plan, table, ctx, dispatcher=disp)
        assert len(disp.live_shards()) < 4
    finally:
        disp.close()


# ---------------------------------------------------------------------------
# Satellite 1: coalescer poison unwinds in-flight batches
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("driver", rt.DRIVERS)
def test_coalescer_poison_completes_inflight_batches(driver):
    """A morsel that fails after the coalescer accepted its rows must
    not strand sibling rows sharing its batches: the run raises the
    poison promptly (no deadlock) under both drivers."""
    plan, table = tagged_plan("cpo"), tagged_table("cpo", 48)
    bs = {"m*": _backend(flaky=dict(poison_values=["cpo-13"]))}
    t0 = time.perf_counter()
    with pytest.raises(rt.TransientCallError, match="poisoned"):
        _run(plan, table, bs, driver=driver, coalesce=True)
    assert time.perf_counter() - t0 < 30.0


def test_coalescer_poison_is_deterministic_under_simulated():
    """Two poisoned coalesced runs bill identically before failing: the
    unwind path is deterministic, not a race."""
    plan, table = tagged_plan("cpo2"), tagged_table("cpo2", 48)
    logs = []
    for _ in range(2):
        m = bk.UsageMeter()
        with pytest.raises(rt.TransientCallError):
            ex.execute(plan, table,
                       {"m*": _backend(flaky=dict(
                           poison_values=["cpo2-13"]))},
                       default_tier="m*", batch_size=BATCH,
                       morsel_size=MORSEL, meter=m, coalesce=True)
        logs.append(_log_key(m))
    assert logs[0] == logs[1]


# ---------------------------------------------------------------------------
# Satellite 3: cascade embed faults degrade, never fail
# ---------------------------------------------------------------------------

def _cascade_router(oracle, error_rate, seed=0):
    embed = FlakyBackend(
        casc.EmbeddingBackend(encoder=EmbeddingOracle(oracle)),
        error_rate=error_rate, seed=seed)
    return casc.CascadeRouter(embed,
                              default_bands=casc.CascadeBands(lo=-2.0,
                                                              hi=2.0))


@pytest.mark.parametrize("driver", rt.DRIVERS)
@pytest.mark.parametrize("rate", (0.5, 1.0))
def test_cascade_embed_fault_sweep_degrades_not_fails(driver, rate):
    """FlakyBackend-injected embedding failures at any rate degrade the
    affected morsels to plain LLM escalation: the query completes and
    results equal the no-cascade run (all-escalate bands make the
    healthy cascade path equivalent too)."""
    plan, table = tagged_plan("cef"), tagged_table("cef", 48)
    r0, _ = _run(plan, table, {"m*": _backend()}, driver=driver)
    router = _cascade_router(KindOracle(), error_rate=rate)
    res, _ = _run(plan, table, {"m*": _backend()}, driver=driver,
                  cascade=router)
    assert result_fingerprint(res) == result_fingerprint(r0)
    assert res.cascade_stats["embed_failures"] > 0
    if rate >= 1.0:
        assert res.cascade_stats["embed_calls"] == 0


def test_cascade_embed_total_fault_matches_no_cascade_billing():
    """error_rate=1.0 on the embed tier: every morsel degrades, so the
    LLM tier sees exactly the un-cascaded workload."""
    plan, table = tagged_plan("cef2"), tagged_table("cef2", 48)
    _, m0 = _run(plan, table, {"m*": _backend()})
    router = _cascade_router(KindOracle(), error_rate=1.0)
    res, m1 = _run(plan, table, {"m*": _backend()}, cascade=router)
    assert m1.calls("m*") == m0.calls("m*")
    assert res.cascade_stats["embed_failures"] > 0


# ---------------------------------------------------------------------------
# Satellite 2: drain deadline
# ---------------------------------------------------------------------------

def test_server_drain_respects_shared_deadline_fault():
    """drain(timeout=) is ONE deadline across all handles: with slow
    in-flight queries it raises TimeoutError within the budget instead
    of overshooting per-handle."""
    backend = SleepBackend(KindOracle(), delay_s=0.12)
    ctx = rt.ExecutionContext(backends={"m*": backend},
                              default_tier="m*", driver="threads",
                              concurrency=2, morsel_size=8)
    server = QueryServer(ctx)
    try:
        handles = [server.submit(tagged_plan(f"dr{i}"),
                                 tagged_table(f"dr{i}", 16))
                   for i in range(3)]
        t0 = time.perf_counter()
        with pytest.raises(TimeoutError):
            server.drain(timeout=0.15)
        # full completion of the in-flight queries would take several
        # seconds; anything under 1.5s proves drain honored the deadline
        assert time.perf_counter() - t0 < 1.5
        for h in handles:
            h.result(timeout=30)
    finally:
        server.close()


# ---------------------------------------------------------------------------
# Serving surface: policy on the server, stats, CLI knobs
# ---------------------------------------------------------------------------

def test_server_retries_faults_across_tenants():
    """A CallPolicy on the server context covers every admitted query:
    under a seeded 10%+ fault plan all queries succeed with solo
    fault-free results, and the server's stats() reports the fault
    counters."""
    specs = [("sva", False), ("svb", True)]
    want = {}
    for tag, tail in specs:
        r, _ = _run(tagged_plan(tag, tail), tagged_table(tag, 24),
                    {"m*": _backend()}, driver="threads")
        want[tag] = result_fingerprint(r)
    flaky = _backend(flaky=dict(error_rate=0.15, seed=2))
    ctx = rt.ExecutionContext(backends={"m*": flaky}, default_tier="m*",
                              batch_size=BATCH, morsel_size=MORSEL,
                              driver="threads", shards=2,
                              call_policy=rt.CallPolicy(retries=4))
    with QueryServer(ctx) as server:
        handles = {tag: server.submit(tagged_plan(tag, tail),
                                      tagged_table(tag, 24), name=tag)
                   for tag, tail in specs}
        got = {tag: result_fingerprint(h.result(timeout=30))
               for tag, h in handles.items()}
        stats = server.stats()
    assert got == want
    assert flaky.faults_injected > 0
    assert stats["faults"]["retries"] > 0
    assert stats["faults"]["attempts"] > 0


def test_server_stats_omit_faults_when_failfast():
    ctx = rt.ExecutionContext(backends={"m*": _backend()},
                              default_tier="m*", driver="simulated")
    with QueryServer(ctx) as server:
        server.submit(tagged_plan("nf"), tagged_table("nf", 8)) \
              .result(timeout=30)
        assert "faults" not in server.stats()


def test_serve_cli_exposes_fault_knobs():
    from repro.launch.serve import build_parser
    args = build_parser().parse_args(
        ["--semantic", "movie", "--retries", "2", "--call-timeout",
         "1.5", "--breaker-threshold", "4", "--fallback-tier", "m3"])
    assert args.retries == 2 and args.call_timeout == 1.5
    assert args.breaker_threshold == 4 and args.fallback_tier == "m3"
    d = build_parser().parse_args([])
    assert d.retries == 0 and d.call_timeout is None
    assert d.breaker_threshold == 0 and d.fallback_tier is None


# ---------------------------------------------------------------------------
# Calibration integrity under faults
# ---------------------------------------------------------------------------

def test_cost_model_calibration_unaffected_by_retried_faults():
    """Faulted attempts bill op_kind=None, so CostModel.observe folds a
    faulted-but-recovered run into the same calibration state as the
    fault-free run (same observation count per tier)."""
    plan, table = _filter3_plan("fcm"), tagged_table("fcm", 48)

    def observed(backends, policy):
        cm = CostModel()
        ctx = rt.ExecutionContext(backends=backends, default_tier="m*",
                                  batch_size=BATCH, morsel_size=MORSEL,
                                  cost_model=cm, call_policy=policy)
        with ctx:
            ex.execute(plan, table, ctx, dispatcher=ctx.dispatcher())
        return cm.calibration_state()

    clean = observed({"m*": _backend()}, None)
    faulted = observed(
        {"m*": _backend(flaky=dict(error_rate=0.25, seed=3))},
        rt.CallPolicy(retries=4))
    assert clean == faulted
