"""Tier-0 embedding cascade suite (core.cascade).

Covers the tentpole contract: cascade-enabled execution keeps the three
invariance guarantees (driver, shard count, admission order) over results
AND meter totals; only escalated rows bill under the LLM tier while device
passes bill under ``tier0-embed``; band edge cases (all-pass,
all-escalate) behave; an embedding-pass failure poisons only its morsels;
the physical optimizer calibrates bands from the capability sample and
adopts the cascade through the improvement-score gate; and the cost model
prices a cascaded operator as one kernel pass + ceil(escalated/batch) LLM
calls."""
import math
import time

import pytest

from repro.core import backends as bk
from repro.core import cascade as casc
from repro.core import cost as cost_mod
from repro.core import executor as ex
from repro.core import improvement as imp
from repro.core import physical_optimizer as po
from repro.core import plan as P
from repro.core import runtime as rt
from repro.core.table import Table
from repro.testing import EmbeddingOracle, result_fingerprint

SHARD_COUNTS = (1, 2, 4)
BATCH = 8


class SelOracle:
    """Deterministic ~55%-selective filters, echo maps, numeric ranks."""

    def answer(self, op, value):
        if op.kind == P.FILTER:
            return bk._unit_hash("truth", op.instruction, value) < 0.55
        if op.kind == P.RANK:
            return round(1.0 + 9.0 * bk._unit_hash("score", op.instruction,
                                                   value), 3)
        return f"A:{value}"

    def answer_reduce(self, op, values):
        return len(list(values))


def _table(n=160, tag="casc"):
    return Table({"v": [f"{tag}-row-{i:03d}" for i in range(n)]}, name=tag)


def _filter_plan(k=2, tag="casc"):
    return P.LogicalPlan(tuple(
        P.Operator(P.FILTER, f"{tag} predicate {j}: keep interesting", "v")
        for j in range(k)))


def _router(oracle, backends, plan, tier="m*", batch_size=BATCH):
    """Bands from the EmbeddingOracle: every on-device resolution targets
    a record ``tier`` answers correctly (violation_rate must be 0)."""
    emb = EmbeddingOracle(oracle)
    router = casc.CascadeRouter(casc.EmbeddingBackend(encoder=emb))
    for op in plan.ops:
        if op.kind in router.KINDS:
            router.set_bands(op, emb.bands_for(op, backends[tier],
                                               batch_size=batch_size))
    return router


def _meter_key(meter):
    return {t: (u.calls, round(u.tok_in, 6), round(u.tok_out, 6),
                round(u.usd, 9), round(u.latency_s, 6))
            for t, u in sorted(meter.by_tier.items())}


def _llm_calls(meter):
    return sum(u.calls for t, u in meter.by_tier.items()
               if t != cost_mod.EMBED_TIER_NAME)


def _backends(oracle):
    # violation_rate=0: resolved-band correctness relies on nested
    # correctness, so cascade/no-cascade equality is exact
    return bk.make_backends(oracle, violation_rate=0.0)


# ---------------------------------------------------------------------------
# Equal results, fewer calls
# ---------------------------------------------------------------------------

def test_cascade_matches_no_cascade_with_fewer_llm_calls():
    oracle = SelOracle()
    table, plan = _table(), _filter_plan()
    backends = _backends(oracle)
    router = _router(oracle, backends, plan)

    m0, m1 = bk.UsageMeter(), bk.UsageMeter()
    base = ex.execute(plan, table, backends, default_tier="m*",
                      batch_size=BATCH, morsel_size=32, meter=m0)
    cas = ex.execute(plan, table, _backends(oracle), default_tier="m*",
                     batch_size=BATCH, morsel_size=32, meter=m1,
                     cascade=router)
    assert result_fingerprint_filter(base) == result_fingerprint_filter(cas)
    assert cas.cascade_stats["escalated"] > 0          # band is live
    assert cas.cascade_stats["passed"] + cas.cascade_stats["dropped"] > 0
    assert _llm_calls(m1) < _llm_calls(m0)
    assert m1.calls(cost_mod.EMBED_TIER_NAME) == \
        cas.cascade_stats["embed_calls"] > 0


def result_fingerprint_filter(res):
    """Fingerprint for filter-only plans (no mapped column)."""
    return tuple(res.table.columns[ex.ROWID])


# ---------------------------------------------------------------------------
# Invariance: drivers x shards with cascade enabled
# ---------------------------------------------------------------------------

def test_cascade_invariance_across_drivers_and_shards():
    oracle = SelOracle()
    table = _table()
    plan = P.LogicalPlan(_filter_plan().ops + (
        P.Operator(P.MAP, "casc annotate", "v", "a"),))
    backends = _backends(oracle)
    router = _router(oracle, backends, plan)
    ref = None
    for driver in rt.DRIVERS:
        for shards in SHARD_COUNTS:
            meter = bk.UsageMeter()
            res = ex.execute(plan, table, _backends(oracle),
                             default_tier="m*", batch_size=BATCH,
                             morsel_size=16, driver=driver, shards=shards,
                             meter=meter, cascade=router)
            key = (result_fingerprint(res), res.rows_processed,
                   tuple(sorted(res.cascade_stats.items())),
                   _meter_key(meter))
            if ref is None:
                ref = key
            assert key == ref, (driver, shards)


def test_cascade_rank_invariance_across_drivers_and_shards():
    oracle = SelOracle()
    table = _table(96)
    plan = P.LogicalPlan((
        P.Operator(P.RANK, "casc order by interest", "v", "rank"),))
    backends = _backends(oracle)
    router = _router(oracle, backends, plan, batch_size=BATCH)
    ref = None
    for driver in rt.DRIVERS:
        for shards in SHARD_COUNTS:
            meter = bk.UsageMeter()
            res = ex.execute(plan, table, _backends(oracle),
                             default_tier="m*", batch_size=BATCH,
                             morsel_size=16, driver=driver, shards=shards,
                             meter=meter, cascade=router)
            key = (tuple(res.table.columns["rank"]),
                   tuple(sorted(res.cascade_stats.items())),
                   _meter_key(meter))
            if ref is None:
                ref = key
            assert key == ref, (driver, shards)
    assert ref[1][1][1] > 0        # ("embed_calls", > 0)


# ---------------------------------------------------------------------------
# Billing: escalated rows only under the LLM tier
# ---------------------------------------------------------------------------

def test_cascade_bills_only_escalated_rows_to_llm_tier():
    oracle = SelOracle()
    table, plan = _table(), _filter_plan(k=1)
    backends = _backends(oracle)
    router = _router(oracle, backends, plan)
    meter = bk.UsageMeter()
    res = ex.execute(plan, table, backends, default_tier="m*",
                     batch_size=BATCH, morsel_size=32, meter=meter,
                     cascade=router)
    esc = res.cascade_stats["escalated"]
    assert 0 < esc < table.n_rows
    # coalesced formation is global: escalated rows across morsels pack
    # into ceil(esc/batch) LLM calls; nothing else reaches the LLM tier
    assert meter.calls("m*") == math.ceil(esc / BATCH)
    assert res.rows_processed == esc
    # the device passes: one metered call per morsel, modeled latency in
    # the per-tier totals (driver-invariant), measured in the call log
    n_morsels = math.ceil(table.n_rows / 32)
    u = meter.by_tier[cost_mod.EMBED_TIER_NAME]
    assert u.calls == n_morsels
    assert u.usd > 0.0
    modeled = n_morsels * cost_mod.EMBED_TIER.latency_call_s \
        + table.n_rows * cost_mod.EMBED_ROW_S
    assert u.latency_s == pytest.approx(modeled)
    embed_logged = [lat for t, lat in meter.call_log
                    if t == cost_mod.EMBED_TIER_NAME]
    assert len(embed_logged) == n_morsels
    assert all(lat >= 0.0 for lat in embed_logged)


# ---------------------------------------------------------------------------
# Band edge cases
# ---------------------------------------------------------------------------

def test_cascade_all_pass_band_skips_llm_entirely():
    oracle = SelOracle()
    table, plan = _table(64), _filter_plan(k=1)
    router = casc.CascadeRouter(
        casc.EmbeddingBackend(encoder=EmbeddingOracle(oracle)),
        default_bands=casc.CascadeBands(lo=-2.0, hi=-2.0))
    meter = bk.UsageMeter()
    res = ex.execute(plan, table, _backends(oracle), default_tier="m*",
                     batch_size=BATCH, morsel_size=16, meter=meter,
                     cascade=router)
    assert res.table.n_rows == table.n_rows       # every row passed
    assert res.cascade_stats["passed"] == table.n_rows
    assert res.cascade_stats["escalated"] == 0
    assert _llm_calls(meter) == 0
    assert meter.calls(cost_mod.EMBED_TIER_NAME) > 0


def test_cascade_all_escalate_band_reproduces_no_cascade_billing():
    oracle = SelOracle()
    table, plan = _table(64), _filter_plan(k=1)
    backends = _backends(oracle)
    m0 = bk.UsageMeter()
    base = ex.execute(plan, table, backends, default_tier="m*",
                      batch_size=BATCH, morsel_size=16, meter=m0)
    router = casc.CascadeRouter(
        casc.EmbeddingBackend(encoder=EmbeddingOracle(oracle)),
        default_bands=casc.CascadeBands(lo=-2.0, hi=2.0))
    m1 = bk.UsageMeter()
    cas = ex.execute(plan, table, _backends(oracle), default_tier="m*",
                     batch_size=BATCH, morsel_size=16, meter=m1,
                     cascade=router)
    assert result_fingerprint_filter(base) == result_fingerprint_filter(cas)
    assert cas.cascade_stats["escalated"] == table.n_rows
    assert cas.cascade_stats["passed"] == cas.cascade_stats["dropped"] == 0
    # the LLM tier sees exactly the un-cascaded workload...
    assert m1.calls("m*") == m0.calls("m*")
    assert m1.by_tier["m*"].tok_in == pytest.approx(m0.by_tier["m*"].tok_in)
    # ...plus the (wasted) device passes on top
    assert m1.calls(cost_mod.EMBED_TIER_NAME) > 0


def test_cascade_bands_validate():
    with pytest.raises(ValueError):
        casc.CascadeBands(lo=0.5, hi=-0.5)


# ---------------------------------------------------------------------------
# Failure isolation: a broken embedding pass degrades, never poisons
# ---------------------------------------------------------------------------

class _BoomEncoder(EmbeddingOracle):
    def encode_values(self, op, values):
        if any("BOOM" in str(v) for v in values):
            raise RuntimeError("encoder down")
        return super().encode_values(op, values)


def test_cascade_embed_failure_degrades_to_llm_escalation():
    """The cascade is an *optimization*, so an embedding-pass failure
    must never fail the query: the affected morsel degrades to the plain
    all-escalate LLM path (same results as running without a cascade),
    the failure is counted in ``cascade_stats["embed_failures"]``, and
    every other morsel keeps cascading."""
    oracle = SelOracle()
    table = Table({"v": [f"x{i:02d}" if i < 24 else f"BOOM{i:02d}"
                         for i in range(32)]}, name="boom")
    plan = P.LogicalPlan((
        P.Operator(P.FILTER, "boom keep", "v"),
        P.Operator(P.MAP, "boom annotate", "v", "a"),
    ))
    base = {d: ex.execute(plan, table, _backends(oracle),
                          default_tier="m*", batch_size=BATCH,
                          morsel_size=8, driver=d)
            for d in rt.DRIVERS}
    router = casc.CascadeRouter(
        casc.EmbeddingBackend(encoder=_BoomEncoder(oracle)),
        default_bands=casc.CascadeBands(lo=-2.0, hi=2.0))
    for driver in rt.DRIVERS:
        t0 = time.perf_counter()
        res = ex.execute(plan, table, _backends(oracle), default_tier="m*",
                         batch_size=BATCH, morsel_size=8, driver=driver,
                         cascade=router)
        assert time.perf_counter() - t0 < 30.0       # degraded, not hung
        assert result_fingerprint(res) == result_fingerprint(base[driver])
        assert res.cascade_stats["embed_failures"] > 0
        # the healthy morsels still ran their device passes
        assert res.cascade_stats["embed_calls"] > 0


# ---------------------------------------------------------------------------
# Optimizer: cascade as a calibrated candidate assignment
# ---------------------------------------------------------------------------

def test_optimizer_calibrates_and_adopts_cascade_bands():
    oracle = SelOracle()
    table, plan = _table(128), _filter_plan(k=2)
    backends = _backends(oracle)
    router = casc.CascadeRouter(
        casc.EmbeddingBackend(encoder=EmbeddingOracle(oracle)))
    assert not router.active_for(plan.ops[0])        # no bands yet
    ctx = rt.ExecutionContext(backends=backends, default_tier="m*",
                              batch_size=BATCH, cascade=router)
    res = po.optimize(plan, table, ctx,
                      po.PhysicalOptConfig(sample_min=24, sample_max=24))
    assert res.cascades, "no operator adopted a cascade"
    for k, rec in res.cascades.items():
        lo, hi = rec["bands"]
        assert lo <= hi
        assert rec["resolved"] > 0.0
        assert rec["agree"] == pytest.approx(1.0)    # conservative bands
        assert router.active_for(plan.ops[k])
    # calibration overhead billed under tier0-embed in the optimizer meter
    assert res.meter.calls(cost_mod.EMBED_TIER_NAME) >= len(res.cascades)
    # the calibrated router drives a real execution end to end
    meter = bk.UsageMeter()
    out = ex.execute(res.plan, table, backends, batch_size=BATCH,
                     morsel_size=32, meter=meter, cascade=router)
    assert out.cascade_stats["passed"] + out.cascade_stats["dropped"] > 0


def test_improvement_cascade_scores_resolved_and_escalated():
    oracle = SelOracle()
    op = P.Operator(P.FILTER, "casc predicate 0: keep interesting", "v")
    values = [f"casc-row-{i:03d}" for i in range(24)]
    backends = _backends(oracle)
    store = imp.OutputStore(backends, op, values)
    truth = [bool(oracle.answer(op, v)) for v in values]
    # perfect decisions on half the sample -> agree == 1, resolved == 0.5
    decisions = {i: truth[i] for i in range(0, len(values), 2)}
    stats = imp.improvement_cascade(store, "m*", decisions)
    assert stats["resolved"] == pytest.approx(0.5)
    assert stats["agree"] == pytest.approx(1.0)
    assert 0.0 <= stats["improvement"] <= 1.0
    # empty decisions: pure escalation == the proxy tier's own improvement
    none_resolved = imp.improvement_cascade(store, "m*", {})
    i1s = sum(not store.eq("m1", "m*", i)
              for i in range(len(values))) / len(values)
    assert none_resolved["improvement"] == pytest.approx(i1s)
    assert none_resolved["resolved"] == 0.0


def test_calibrate_bands_filter_separates_sample_classes():
    scores = [0.8, 0.7, 0.6, -0.5, -0.6, -0.7]
    ref_outs = [True, True, True, False, False, False]
    bands = casc.calibrate_bands(scores, ref_outs, P.FILTER, margin=0.02)
    # separable sample: the bands collapse to the midpoint, nothing in the
    # sample escalates and nothing is misrouted
    assert bands.lo == bands.hi
    assert -0.5 < bands.lo < 0.6
    overlapping = casc.calibrate_bands([0.5, -0.1, 0.4, 0.1],
                                       [True, True, False, False],
                                       P.FILTER, margin=0.02)
    # overlapping classes widen the escalation band around the overlap
    assert overlapping.lo < overlapping.hi
    assert overlapping.lo <= -0.1 + 0.02
    assert overlapping.hi >= 0.4 - 0.02
    # one-class samples never auto-answer the unseen class
    no_pos = casc.calibrate_bands([-0.5, -0.2], [False, False], P.FILTER)
    assert no_pos.hi == 2.0
    no_neg = casc.calibrate_bands([0.5, 0.2], [True, True], P.FILTER)
    assert no_neg.lo == -2.0
    assert casc.calibrate_bands([], [], P.FILTER) is None


# ---------------------------------------------------------------------------
# Rank partition semantics
# ---------------------------------------------------------------------------

def test_rank_cascade_partition_orders_pass_escalate_drop():
    op = P.Operator(P.RANK, "order", "v", "rank")
    resolved = [casc._RANK_PASS_OFFSET + 0.9, None,
                casc._RANK_DROP_OFFSET - 0.9, None]
    part = casc.CascadePartition(op, list(resolved), escalate=[1, 3],
                                 n_pass=1, n_drop=1, finish=0.0)
    # LLM ranks row 3 above row 1
    full = part.merge(["2", "9"])
    assert full[0] > full[3] > full[1] > full[2]
    # escalated scores normalize into (0, 1): between both offset bands
    assert 0.0 < full[1] < full[3] < 1.0
    with pytest.raises(ValueError):
        part.merge(["only-one"])


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------

def test_cost_model_prices_cascade_escalation():
    op = P.Operator(P.FILTER, "keep the interesting rows", "v")
    spec = cost_mod.DEFAULT_TIERS["m1"]
    base = cost_mod.op_cost(op, 1000.0, spec, batch_size=8)
    cas = cost_mod.op_cost(op, 1000.0, spec, batch_size=8,
                           cascade_escalate=0.1)
    assert cas.llm_calls == math.ceil(1000.0 * 0.1 / 8)
    assert cas.llm_calls < base.llm_calls
    assert cas.tok_in < base.tok_in
    assert cas.usd < base.usd                     # embed pass ~free vs m1
    # the embed pass is priced in: more than a pure 10% LLM slice
    pure = cost_mod.op_cost(op, 100.0, spec, batch_size=8)
    assert cas.usd > pure.usd
    # rows_out (selectivity flow) is unchanged by the cascade
    assert cas.rows_out == base.rows_out


def test_plan_cost_counts_escalated_rows_only():
    plan = P.LogicalPlan((
        P.Operator(P.FILTER, "keep", "v"),
        P.Operator(P.MAP, "annotate", "v", "a"),
    ))
    base = cost_mod.plan_cost(plan, 1000, batch_size=8)
    cas = cost_mod.plan_cost(plan, 1000, batch_size=8, cascade={0: 0.1})
    # filter rows: 1000 -> 100 escalated; map (uncascaded) sees 500 either
    # way (selectivity flow is unchanged)
    assert base.rows_processed == pytest.approx(1500.0)
    assert cas.rows_processed == pytest.approx(600.0)
    assert cas.llm_calls < base.llm_calls
    assert cas.usd < base.usd


# ---------------------------------------------------------------------------
# Serving surface
# ---------------------------------------------------------------------------

def test_query_server_runs_cascade_per_query():
    """A cascade on the server's context applies to every admitted query
    (ctx.fork carries it), and per-query meters bill the device passes."""
    from repro.launch.query_server import QueryServer
    oracle = SelOracle()
    backends = _backends(oracle)
    tags = ("srv-a", "srv-b")
    queries, solos = {}, {}
    router = casc.CascadeRouter(
        casc.EmbeddingBackend(encoder=EmbeddingOracle(oracle)))
    emb = EmbeddingOracle(oracle)
    for tag in tags:
        table, plan = _table(96, tag=tag), _filter_plan(k=1, tag=tag)
        router.set_bands(plan.ops[0],
                         emb.bands_for(plan.ops[0], backends["m*"],
                                       batch_size=BATCH))
        queries[tag] = (plan, table)
    for tag, (plan, table) in queries.items():
        meter = bk.UsageMeter()
        res = ex.execute(plan, table, backends, default_tier="m*",
                         batch_size=BATCH, morsel_size=16, meter=meter,
                         cascade=router)
        solos[tag] = (res, meter)
    ctx = rt.ExecutionContext(backends=backends, default_tier="m*",
                              batch_size=BATCH, morsel_size=16,
                              driver="simulated", cascade=router)
    with QueryServer(ctx) as server:
        handles = {tag: server.submit(plan, table, name=tag)
                   for tag, (plan, table) in queries.items()}
        server.drain()
    for tag, h in handles.items():
        solo, solo_meter = solos[tag]
        res = h.result()
        assert result_fingerprint_filter(res) == \
            result_fingerprint_filter(solo)
        assert h.meter.calls(cost_mod.EMBED_TIER_NAME) == \
            solo_meter.calls(cost_mod.EMBED_TIER_NAME) > 0
        assert h.meter.calls("m*") == solo_meter.calls("m*")


def test_serve_cli_exposes_cascade_knobs():
    from repro.launch.serve import build_parser
    args = build_parser().parse_args(
        ["--semantic", "movie", "--cascade", "--cascade-lo", "-0.2",
         "--cascade-hi", "0.4"])
    assert args.cascade and args.cascade_lo == -0.2 \
        and args.cascade_hi == 0.4
    assert not build_parser().parse_args([]).cascade
