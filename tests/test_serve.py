"""Streaming semantic serve suite: continuous query admission onto one
shared dispatcher (launch.query_server.QueryServer) — admission-order
invariance of per-query results and meter totals vs solo runs, failure
isolation per handle, server-lifetime meter accounting, cross-tenant
serving quotas, per-query round-robin shard cursors, and the long-lived
shutdown paths (ExecutionContext.close, OutputCache.close, linger-ticker
stop)."""
import threading
import time

import pytest

from repro.core import backends as bk
from repro.core import executor as ex
from repro.core import plan as P
from repro.core import runtime as rt
from repro.distributed.morsel_shards import ShardedDispatcher
from repro.launch.query_server import QueryServer
from repro.testing import (KindOracle, SleepBackend, result_fingerprint,
                           tagged_plan, tagged_table)

SERVE_SHARDS = (1, 2)

# shared with benchmarks/bench_serve.py (one definition in repro.testing):
# per-query plans carry distinct instructions, so queries sharing the
# server cache never overlap on cache keys — their billing is then
# independent of co-tenants, which is what solo-identity asserts
_table = tagged_table
_plan = tagged_plan
_result_key = result_fingerprint


def _meter_key(meter):
    return {t: (u.calls, round(u.tok_in, 6), round(u.tok_out, 6),
                round(u.usd, 9), round(u.latency_s, 6))
            for t, u in sorted(meter.by_tier.items())}


def _ctx(shards: int = 1, delay_s: float = 0.004, **kw):
    backend = SleepBackend(KindOracle(), delay_s=delay_s)
    defaults = dict(backends={"m*": backend}, default_tier="m*",
                    concurrency=4, morsel_size=8, driver="threads",
                    shards=shards)
    defaults.update(kw)
    return rt.ExecutionContext(**defaults), backend


def _solo(plan, table, **kw):
    ctx, _ = _ctx(**kw)
    with ctx:
        meter = ctx.meter
        res = ex.execute(plan, table, ctx,
                         dispatcher=ctx.dispatcher())
    return res, meter


# ---------------------------------------------------------------------------
# Admission-order invariance: the serving isolation contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shards", SERVE_SHARDS)
def test_serve_concurrent_queries_match_solo_runs(shards):
    """Two queries admitted concurrently (threads driver) produce
    results AND per-query meter totals byte-identical to each query run
    solo — sharing the server's dispatcher/cache changes when calls run,
    never what they answer or bill."""
    specs = [("qa", False), ("qb", True)]
    want = {tag: (_result_key(r), _meter_key(m))
            for tag, tail in specs
            for r, m in [_solo(_plan(tag, tail), _table(tag),
                               shards=shards)]}
    ctx, _ = _ctx(shards=shards)
    with QueryServer(ctx) as server:
        handles = {tag: server.submit(_plan(tag, tail), _table(tag),
                                      name=tag)
                   for tag, tail in specs}
        got = {tag: (_result_key(h.result(timeout=30)),
                     _meter_key(h.meter))
               for tag, h in handles.items()}
    assert got == want


@pytest.mark.parametrize("shards", SERVE_SHARDS)
def test_serve_admission_order_is_invariant(shards):
    """Submitting [A, B] vs [B, A] yields identical per-query results
    and meter totals — nothing a query answers or bills depends on its
    admission position."""
    specs = [("qa", False), ("qb", True), ("qc", False)]
    runs = []
    for order in (specs, specs[::-1]):
        ctx, _ = _ctx(shards=shards)
        with QueryServer(ctx) as server:
            handles = [(tag, server.submit(_plan(tag, tail), _table(tag)))
                       for tag, tail in order]
            runs.append({tag: (_result_key(h.result(timeout=30)),
                               _meter_key(h.meter))
                         for tag, h in handles})
    assert runs[0] == runs[1]


def test_serve_per_query_logs_are_deterministic():
    """Each handle's finalized call log (entries + logical keys) is
    byte-identical across two server runs: per-query staging merges sort
    by the query-scoped logical key, not thread arrival order."""
    specs = [("qa", False), ("qb", True)]
    runs = []
    for _ in range(2):
        ctx, _ = _ctx(shards=2)
        with QueryServer(ctx) as server:
            handles = [(tag, server.submit(_plan(tag, tail), _table(tag)))
                       for tag, tail in specs]
            for _, h in handles:
                h.result(timeout=30)
            runs.append({tag: (list(h.meter.call_log),
                               list(h.meter.call_keys))
                         for tag, h in handles})
    assert runs[0] == runs[1]
    for log, keys in runs[0].values():
        assert log and all(k is not None for k in keys)


def test_serve_batched_coalesced_queries_match_solo():
    """Coalesced batch formation stays query-scoped on a shared server:
    with batch_size > 1 each query still pays ceil(survivors/batch)
    calls, and its outputs match the solo run."""
    specs = [("qa", False), ("qb", False)]
    want = {tag: (_result_key(r), _meter_key(m))
            for tag, tail in specs
            for r, m in [_solo(_plan(tag, tail), _table(tag),
                               batch_size=8)]}
    ctx, backend = _ctx(batch_size=8)
    with QueryServer(ctx) as server:
        handles = {tag: server.submit(_plan(tag, tail), _table(tag))
                   for tag, tail in specs}
        got = {tag: (_result_key(h.result(timeout=30)),
                     _meter_key(h.meter))
               for tag, h in handles.items()}
    assert got == want
    # 32 rows / batch 8 = 4 calls per op per query; nothing cross-filled
    assert all(h.meter.total.calls == 8 for h in handles.values())


def test_serve_simulated_driver_queries_match_solo():
    """The server also runs the simulated driver (inline execution, one
    shared lock-protected event scheduler): per-query results and meter
    totals still match solo runs."""
    specs = [("qa", True), ("qb", False)]
    want = {tag: (_result_key(r), _meter_key(m))
            for tag, tail in specs
            for r, m in [_solo(_plan(tag, tail), _table(tag),
                               driver="simulated", delay_s=0.0)]}
    ctx, _ = _ctx(driver="simulated", delay_s=0.0)
    with QueryServer(ctx) as server:
        handles = {tag: server.submit(_plan(tag, tail), _table(tag))
                   for tag, tail in specs}
        got = {tag: (_result_key(h.result(timeout=30)),
                     _meter_key(h.meter))
               for tag, h in handles.items()}
    assert got == want


# ---------------------------------------------------------------------------
# Failure isolation
# ---------------------------------------------------------------------------

class _BoomOracle(KindOracle):
    def answer(self, op, value):
        if "BOOM" in str(value):
            raise RuntimeError("backend down for this tenant")
        return super().answer(op, value)


@pytest.mark.parametrize("shards", SERVE_SHARDS)
def test_serve_failure_poisons_only_its_own_handle(shards):
    """One query's backend failure fails that query's handle; the other
    in-flight query completes correctly, and the server keeps admitting
    new queries afterwards."""
    backend = SleepBackend(_BoomOracle(), delay_s=0.002)
    ctx = rt.ExecutionContext(backends={"m*": backend}, default_tier="m*",
                              concurrency=4, morsel_size=8,
                              driver="threads", shards=shards)
    with QueryServer(ctx) as server:
        good = server.submit(_plan("ok"), _table("ok"))
        bad = server.submit(_plan("bad"), _table("BOOM"))
        with pytest.raises(RuntimeError, match="backend down"):
            bad.result(timeout=30)
        assert bad.failed()
        res = good.result(timeout=30)
        assert not good.failed()
        assert res.table.columns["a"] == [f"A:ok-{i}" for i in range(32)]
        # the server survives a tenant failure: admit another query
        after = server.submit(_plan("after"), _table("after"))
        assert after.result(timeout=30).table.n_rows == 32
        stats = server.stats()
    assert stats == {**stats, "admitted": 3, "completed": 2, "failed": 1}


def test_serve_failed_query_bills_all_straggler_calls():
    """Per-query cleanup waits for the failed query's sibling morsels
    and sibling fanout chunks: every backend call the query made lands
    in its handle meter (and therefore the lifetime bill) — none escape
    into staging that would only surface at dispatcher close — and the
    sharded round-robin cursor retains no entry for the dead query."""
    from repro.core.table import Table
    backend = SleepBackend(_BoomOracle(), delay_s=0.01)
    ctx = rt.ExecutionContext(backends={"m*": backend}, default_tier="m*",
                              concurrency=4, morsel_size=8,
                              driver="threads", shards=2)
    # morsel 0 is poison; morsels 1..3 are clean and still in flight
    # when morsel 0's failure surfaces
    table = Table({"v": [f"BOOM{i}" if i < 8 else f"x{i}"
                         for i in range(32)]}, name="mixed")
    with QueryServer(ctx) as server:
        h = server.submit(_plan("mixed"), table)
        with pytest.raises(RuntimeError, match="backend down"):
            h.result(timeout=30)
        # a failing call raises before it meters, so the billed calls
        # are exactly the backend's completed ones — equality proves no
        # straggler billed after the per-query staging was finalized
        assert h.meter.total.calls == backend.calls_made > 0
        assert ctx.meter.total.calls == h.meter.total.calls
        assert server._disp._query_base == {}     # released, not regrown


# ---------------------------------------------------------------------------
# Server-lifetime accounting + shared capacity
# ---------------------------------------------------------------------------

def test_serve_server_meter_accumulates_lifetime_totals():
    """The server context's meter absorbs every finished query's meter:
    lifetime totals equal the sum of per-query totals (failed queries
    included for whatever they billed)."""
    ctx, _ = _ctx()
    with QueryServer(ctx) as server:
        handles = [server.submit(_plan(t), _table(t))
                   for t in ("qa", "qb", "qc")]
        for h in handles:
            h.result(timeout=30)
        total = ctx.meter.total
        assert total.calls == sum(h.meter.total.calls for h in handles)
        assert total.usd == pytest.approx(
            sum(h.meter.total.usd for h in handles))
        assert len(ctx.meter.call_log) \
            == sum(len(h.meter.call_log) for h in handles)


def test_serve_per_tier_quota_caps_across_tenants():
    """per_tier_concurrency is a serving quota ACROSS queries: two
    in-flight queries' calls against one tier never exceed the cap."""
    from tests.test_shard import _PeakBackend
    backend = _PeakBackend(KindOracle(), delay_s=0.01)
    ctx = rt.ExecutionContext(backends={"m*": backend}, default_tier="m*",
                              concurrency=16, morsel_size=4,
                              per_tier_concurrency={"m*": 3},
                              driver="threads")
    with QueryServer(ctx) as server:
        handles = [server.submit(_plan(t), _table(t))
                   for t in ("qa", "qb")]
        for h in handles:
            h.result(timeout=30)
    assert backend.peak <= 3


def test_serve_concurrent_admission_overlaps_queries():
    """Two admitted queries interleave on the shared pools: the
    concurrent makespan beats back-to-back execution of the same two
    queries on an identical fresh server. The queries deliberately
    under-fill capacity solo (8-row morsels + a reduce barrier on a
    16-wide pool) — co-tenants fill the idle slots, which is the whole
    point of serving-level continuous batching."""
    def run(concurrent: bool) -> float:
        best = float("inf")
        for _ in range(3):
            ctx, _ = _ctx(delay_s=0.04, concurrency=16)
            with QueryServer(ctx) as server:
                t0 = time.perf_counter()
                if concurrent:
                    hs = [server.submit(_plan(t, reduce_tail=True),
                                        _table(t, 8))
                          for t in ("qa", "qb")]
                    for h in hs:
                        h.result(timeout=30)
                else:
                    for t in ("qa", "qb"):
                        server.submit(_plan(t, reduce_tail=True),
                                      _table(t, 8)).result(timeout=30)
                best = min(best, time.perf_counter() - t0)
        return best

    sequential, concurrent = run(False), run(True)
    assert concurrent < sequential * 0.85


# ---------------------------------------------------------------------------
# Per-query shard cursors
# ---------------------------------------------------------------------------

def test_serve_round_robin_cursor_is_per_query():
    """Each admitted query gets its own rotated shard cursor (so
    co-tenant queries spread over shards instead of piling on shard 0),
    and release_query drops the offset."""
    disp = ShardedDispatcher(shards=2, driver="threads", concurrency=2)
    try:
        # keyless callers (solo executions) keep plain round-robin
        assert [disp.shard_of(i) for i in range(4)] == [0, 1, 0, 1]
        assert [disp.shard_of(i, query=7) for i in range(4)] == [0, 1, 0, 1]
        assert [disp.shard_of(i, query=8) for i in range(4)] == [1, 0, 1, 0]
        disp.release_query(7)
        disp.release_query(7)                       # idempotent
        assert disp.shard_of(0, query=9) == 0       # freed base reused
    finally:
        disp.close()


# ---------------------------------------------------------------------------
# Long-lived shutdown paths
# ---------------------------------------------------------------------------

def test_serve_context_close_is_idempotent_and_terminal():
    ctx, _ = _ctx()
    disp = ctx.dispatcher()
    assert ctx.dispatcher() is disp          # cached, not rebuilt per call
    ctx.close()
    ctx.close()                              # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        ctx.dispatcher()
    # the dispatcher's pools are really shut down
    with pytest.raises(RuntimeError):
        disp.defer(disp.done(None), lambda v, r: (v, r))


def test_serve_context_manager_closes_and_forks_stay_independent():
    ctx, _ = _ctx()
    fork = ctx.fork(meter=bk.UsageMeter())
    with ctx:
        assert ctx.dispatcher() is not None
    with pytest.raises(RuntimeError):
        ctx.dispatcher()
    fdisp = fork.dispatcher()                # fork unaffected by close()
    fork.close()
    with pytest.raises(RuntimeError):
        fork.dispatcher()
    del fdisp


def test_serve_output_cache_close_unblocks_waiters():
    """A drained server must not leave threads blocked on cache keys
    whose owner will never publish: close() releases every reservation
    (idempotently) and waiters recompute solo."""
    cache = rt.OutputCache()
    key = ("k",)
    token = object()
    assert cache.claim([key], token)[0][0] == "own"
    state, event = cache.claim([key], object())[0]
    assert state == "wait"
    got = {}

    def wait():
        got["v"] = cache.wait_value(key, event)

    t = threading.Thread(target=wait)
    t.start()
    time.sleep(0.05)
    assert t.is_alive()                      # genuinely blocked
    cache.close()
    t.join(timeout=5)
    assert not t.is_alive()
    assert got["v"] == (False, None)         # unblocked, recomputes solo
    cache.close()                            # idempotent
    assert cache.closed


def test_serve_linger_ticker_stop_joins_daemon():
    """_LingerTicker.stop() is a deterministic shutdown: the daemon
    exits, and a later register starts a fresh one."""
    disp = rt.ThreadPoolDispatcher(concurrency=2)
    coal = rt.BatchCoalescer(disp, bk.UsageMeter(), batch_size=8,
                             linger_s=0.02)
    backend = SleepBackend(KindOracle(), delay_s=0.0)
    op = P.Operator(P.MAP, "annotate", "v", "a")
    try:
        group = coal.open(op, backend, "m*", expected=2)
        fut = group.submit(0, ["x"], 0.0)
        fut.result(timeout=5)                # linger flush fired
        assert rt._LINGER_TICKER.n_threads() == 1
        rt._LINGER_TICKER.stop()
        assert rt._LINGER_TICKER.n_threads() == 0
        rt._LINGER_TICKER.stop()             # idempotent
        # a fresh registration restarts the daemon
        coal2 = rt.BatchCoalescer(disp, bk.UsageMeter(), batch_size=8,
                                  linger_s=0.02)
        g2 = coal2.open(op, backend, "m*", expected=2)
        f2 = g2.submit(0, ["y"], 0.0)
        f2.result(timeout=5)
        assert rt._LINGER_TICKER.n_threads() == 1
        coal2.close()
    finally:
        coal.close()
        disp.close()


# ---------------------------------------------------------------------------
# Serve launcher surface
# ---------------------------------------------------------------------------

def test_serve_parser_and_stagger_offsets():
    from repro.launch import serve
    ap = serve.build_parser()
    args = ap.parse_args([])
    assert args.serve == 0 and args.stagger == 0.0
    args = ap.parse_args(["--semantic", "movie", "--serve", "4",
                          "--stagger", "0.2"])
    assert args.serve == 4 and args.stagger == pytest.approx(0.2)
    offs = serve.stagger_offsets(4, 0.2, seed=1)
    assert offs[0] == 0.0 and offs == sorted(offs) and len(offs) == 4
    assert serve.stagger_offsets(4, 0.2, seed=1) == offs   # deterministic
    assert serve.stagger_offsets(3, 0.0) == [0.0, 0.0, 0.0]


def test_serve_submit_after_close_is_rejected():
    ctx, _ = _ctx()
    server = QueryServer(ctx)
    h = server.submit(_plan("qa"), _table("qa"))
    server.close()
    assert h.done()
    with pytest.raises(RuntimeError, match="closed"):
        server.submit(_plan("qb"), _table("qb"))
