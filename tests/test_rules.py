"""Transformation rules: every rewrite must preserve semantics under a
perfect (oracle) backend; the corruption harness must break them."""
import random

import pytest

from repro.core import executor as ex
from repro.core import plan as P
from repro.core import rules
from repro.data import WORKLOADS, load_dataset

from conftest import perfect_backends


def _result_equal(a, b):
    va, vb = a.value(), b.value()
    if isinstance(va, ex.Table) != isinstance(vb, ex.Table):
        return False                      # scalar vs table: never equal
    if isinstance(va, ex.Table):
        ra = set(va.columns.get(ex.ROWID, [])) if va is not None else None
        rb = set(vb.columns.get(ex.ROWID, [])) if vb is not None else None
        return ra == rb
    if isinstance(va, float) and isinstance(vb, float):
        return va == pytest.approx(vb)
    return va == vb


@pytest.mark.parametrize("dataset", ["movie", "estate"])
def test_every_rewrite_is_semantics_preserving(dataset):
    table, oracle = load_dataset(dataset, max_rows=60)
    backends = perfect_backends(oracle)
    checked = 0
    for q in WORKLOADS[dataset]:
        plan = q.plan_for(table)
        base = ex.execute(plan, table, backends, default_tier="m*")
        for cand in rules.all_candidates(plan):
            new_plan = cand.apply()
            new_plan.validate()
            got = ex.execute(new_plan, table, backends, default_tier="m*")
            assert _result_equal(base, got), (
                q.qid, cand.rule, cand.description)
            checked += 1
    assert checked >= 10  # the workloads must actually exercise the rules


def test_corruption_changes_semantics_somewhere():
    table, oracle = load_dataset("movie", max_rows=120)
    backends = perfect_backends(oracle)
    rng = random.Random(0)
    broke = 0
    total = 0
    for q in WORKLOADS["movie"]:
        plan = q.plan_for(table)
        base = ex.execute(plan, table, backends, default_tier="m*")
        for cand in rules.all_candidates(plan)[:3]:
            bad = rules.corrupt(cand, plan, rng)
            assert not bad.correct
            got = ex.execute(bad.apply(), table, backends,
                             default_tier="m*")
            total += 1
            broke += not _result_equal(base, got)
    assert total >= 5
    assert broke / total > 0.5     # corruptions usually change results


def test_filter_pushdown_moves_before_expensive_map():
    q = WORKLOADS["movie"][8]      # q9: map, 3 filters, reduce
    table, _ = load_dataset("movie", max_rows=10)
    plan = q.plan_for(table)
    cands = rules.filter_pushdown_candidates(plan)
    assert cands, "rating filters should be hoistable above the genre map"
    new = cands[0].apply()
    assert new.ops[0].kind == P.FILTER


def test_fusion_merges_same_column_filters():
    q = WORKLOADS["movie"][8]
    table, _ = load_dataset("movie", max_rows=10)
    plan = q.plan_for(table)
    cands = rules.operator_fusion_candidates(plan)
    assert cands
    fused_plan = cands[0].apply()
    assert len(fused_plan.ops) == len(plan.ops) - 1
    fused = [o for o in fused_plan.ops if o.fused_from == 2]
    assert fused and " and " in fused[0].instruction


def test_non_llm_replacement_sets_udf():
    q = WORKLOADS["movie"][1]      # q2: directed by Nolan
    table, _ = load_dataset("movie", max_rows=10)
    plan = q.plan_for(table)
    cands = rules.non_llm_candidates(plan)
    assert cands
    new = cands[0].apply()
    assert new.ops[0].udf is not None
    assert new.n_llm_ops == 0


def test_semantic_vs_basic_rule_split():
    assert set(rules.SEMANTIC_RULES) | set(rules.BASIC_RULES) \
        == set(rules.RULES)


def test_no_candidates_on_single_udf_plan():
    plan = P.LogicalPlan((P.Operator(P.FILTER, "x > 1", "c",
                                     udf="lambda x: True"),))
    assert rules.all_candidates(plan) == []
