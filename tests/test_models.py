"""Per-arch smoke tests (assignment requirement) + decode-path consistency.

Each assigned architecture instantiates its REDUCED same-family config and
runs one forward/train step on CPU asserting output shapes + no NaNs; the
serving path is validated by teacher-forced prefill/decode consistency.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import (ARCH_IDS, SHAPES, InputShape, get_config,
                           reduced)
from repro.models import registry


def make_batch(bundle, shape, key):
    specs = bundle.batch_specs(shape)
    out = {}
    for k, s in specs.items():
        if jnp.issubdtype(s.dtype, jnp.floating):
            out[k] = jax.random.normal(key, s.shape, s.dtype)
        else:
            out[k] = jax.random.randint(key, s.shape, 1,
                                        bundle.cfg.vocab_size)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = reduced(get_config(arch))
    bundle = registry.build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    batch = make_batch(bundle, InputShape("t", 64, 2, "train"),
                       jax.random.PRNGKey(1))
    loss = bundle.loss_fn(params, batch, remat=True)
    assert loss.shape == ()
    assert jnp.isfinite(loss)
    grads = jax.grad(lambda p: bundle.loss_fn(p, batch, remat=False))(params)
    flat = jax.tree.leaves(grads)
    assert all(jnp.all(jnp.isfinite(g)) for g in flat)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_smoke(arch):
    cfg = reduced(get_config(arch))
    bundle = registry.build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    batch = make_batch(bundle, InputShape("p", 32, 2, "prefill"),
                       jax.random.PRNGKey(1))
    logits, cache = bundle.prefill(params, batch, max_len=40)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert not jnp.isnan(logits).any()
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    for _ in range(3):
        logits, cache = bundle.decode_step(params, cache, tok)
        assert logits.shape == (2, 1, cfg.vocab_size)
        assert not jnp.isnan(logits).any()
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "minicpm3-4b",
                                  "mamba2-1.3b", "hymba-1.5b",
                                  "granite-moe-1b-a400m"])
def test_decode_matches_teacher_forcing(arch):
    """Greedy decode logits must match the full-forward logits at each
    position (KV-cache correctness across GQA / MLA / SSM / hybrid /MoE)."""
    cfg = reduced(get_config(arch))
    bundle = registry.build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(7)
    prompt = jax.random.randint(key, (1, 12), 1, cfg.vocab_size)

    from repro.models import transformer
    n_extra = 4
    logits_p, cache = bundle.prefill(params, {"tokens": prompt},
                                     max_len=prompt.shape[1] + n_extra,
                                     dtype=jnp.float32)
    toks = [int(jnp.argmax(logits_p[0, -1]))]
    dec_logits = []
    for _ in range(n_extra - 1):
        lg, cache = bundle.decode_step(
            params, cache, jnp.asarray([[toks[-1]]], jnp.int32),
            dtype=jnp.float32)
        dec_logits.append(lg[0, 0])
        toks.append(int(jnp.argmax(lg[0, 0])))

    # teacher-forced full forward over prompt + generated tokens
    full = jnp.concatenate(
        [prompt, jnp.asarray([toks[:-1]], jnp.int32)], axis=1)
    logits_full = transformer.forward(params, cfg, full, dtype=jnp.float32)
    for i, lg in enumerate(dec_logits):
        want = logits_full[0, prompt.shape[1] + i]
        np.testing.assert_allclose(np.asarray(lg), np.asarray(want),
                                   atol=2e-3, rtol=2e-3)


def test_per_slot_pos_decode_matches_scalar_pos():
    """Vector-position decode (continuous batching) must agree with the
    scalar-position path when all slots share a depth."""
    cfg = reduced(get_config("qwen2-0.5b"))
    bundle = registry.build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 1,
                                cfg.vocab_size)
    _, cache_s = bundle.prefill(params, {"tokens": prompt}, max_len=12,
                                dtype=jnp.float32)
    from repro.models import common as cm
    cache_v = dict(cache_s)
    cache_v["pos"] = cm.Param(jnp.full((2,), cache_s["pos"].value),
                              ("batch",))
    tok = jnp.asarray([[5], [9]], jnp.int32)
    lg_s, _ = bundle.decode_step(params, cache_s, tok, dtype=jnp.float32)
    lg_v, _ = bundle.decode_step(params, cache_v, tok, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_v),
                               atol=1e-5)


def test_full_configs_have_published_dims():
    cq = get_config("codeqwen1.5-7b")
    assert (cq.n_layers, cq.d_model, cq.n_heads, cq.d_ff,
            cq.vocab_size) == (32, 4096, 32, 13440, 92416)
    ds = get_config("deepseek-67b")
    assert (ds.n_layers, ds.d_model, ds.n_heads, ds.n_kv_heads) \
        == (95, 8192, 64, 8)
    gm = get_config("granite-moe-1b-a400m")
    assert gm.moe.num_experts == 32 and gm.moe.top_k == 8
    l4 = get_config("llama4-scout-17b-a16e")
    assert l4.moe.num_experts == 16 and l4.moe.top_k == 1
    mc = get_config("minicpm3-4b")
    assert mc.mla is not None and mc.n_layers == 62
    mb = get_config("mamba2-1.3b")
    assert mb.ssm.d_state == 128 and mb.n_heads == 0
    hy = get_config("hymba-1.5b")
    assert hy.ssm is not None and hy.n_heads == 25
    sm = get_config("seamless-m4t-large-v2")
    assert sm.is_encoder_decoder and sm.vocab_size == 256206
    iv = get_config("internvl2-76b")
    assert iv.n_prefix_embeds > 0 and iv.d_ff == 28672
    q2 = get_config("qwen2-0.5b")
    assert q2.qkv_bias and q2.n_kv_heads == 2


def test_param_counts_near_published():
    """Sanity: derived parameter counts land near the advertised sizes."""
    approx = {
        "codeqwen1.5-7b": (7e9, 0.2), "qwen2-0.5b": (0.5e9, 0.3),
        "deepseek-67b": (67e9, 0.15), "minicpm3-4b": (4e9, 0.3),
        "mamba2-1.3b": (1.3e9, 0.3), "hymba-1.5b": (1.5e9, 0.35),
    }
    for arch, (want, tol) in approx.items():
        got = get_config(arch).param_count()
        assert abs(got - want) / want < tol, (arch, got)


def test_int8_kv_cache_close_to_fp():
    """int8-quantized KV cache (§Perf pair C) stays within quantization
    tolerance of the fp cache over a multi-step decode."""
    import jax.numpy as jnp
    cfg = reduced(get_config("qwen2-0.5b"))
    bundle = registry.build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(3), (2, 10), 1,
                                cfg.vocab_size)
    cache_q8 = bundle.init_cache(2, 16, dtype=jnp.float32,
                                 kv_dtype=jnp.int8)
    cache_fp = bundle.init_cache(2, 16, dtype=jnp.float32)
    for t in range(10):
        tok = prompt[:, t:t + 1]
        lg_q8, cache_q8 = bundle.decode_step(params, cache_q8, tok,
                                             dtype=jnp.float32)
        lg_fp, cache_fp = bundle.decode_step(params, cache_fp, tok,
                                             dtype=jnp.float32)
    rel = float(jnp.max(jnp.abs(lg_q8 - lg_fp))
                / jnp.max(jnp.abs(lg_fp)))
    assert rel < 0.05
