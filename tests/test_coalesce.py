"""Cross-morsel batch coalescing suite: result + meter identity against
whole-table batching, the ceil(survivors/batch) call bound, event-time and
wall-time linger flushes, reorder-buffer determinism, thread-safety under
the threads driver, and the batch-aware cost model / optimizer pricing."""
import random
import threading
import time

import pytest

from repro.core import backends as bk
from repro.core import cost as cost_mod
from repro.core import executor as ex
from repro.core import physical_optimizer as popt
from repro.core import plan as P
from repro.core import runtime as rt
from repro.core.table import Table
from repro.data import load_dataset
from repro.testing import EchoOracle, SleepBackend


@pytest.fixture(scope="module")
def movie_small():
    return load_dataset("movie", max_rows=48)


def _chain_plan():
    return P.LogicalPlan((
        P.Operator(P.FILTER, "The rating is higher than 8.", "IMDB_rating"),
        P.Operator(P.MAP, "According to the movie plot, extract the "
                   "genre(s) of each movie.", "Plot", "Genre"),
        P.Operator(P.FILTER, "The movie is directed by Christopher "
                   "Nolan.", "Director"),
    ))


def _assert_meters_equal(ma, mb):
    assert set(ma.by_tier) == set(mb.by_tier)
    for tier in ma.by_tier:
        ua, ub = ma.by_tier[tier], mb.by_tier[tier]
        assert ua.calls == ub.calls, tier
        assert ua.tok_in == pytest.approx(ub.tok_in)
        assert ua.tok_out == pytest.approx(ub.tok_out)
        assert ua.usd == pytest.approx(ub.usd)
        assert ua.latency_s == pytest.approx(ub.latency_s)


# ---------------------------------------------------------------------------
# Identity and call-count bounds
# ---------------------------------------------------------------------------

def test_coalesce_result_and_meter_identity_across_modes(movie_small):
    """Coalesced morsel execution must reproduce whole-table (barrier)
    batching exactly — results byte-identical, meters identical — while
    per-morsel batching pays ragged-remainder extra calls."""
    table, oracle = movie_small
    plan = _chain_plan()
    for batch in (4, 8):
        runs, meters = {}, {}
        for mode, kw in (("barrier", dict(morsel_size=0, coalesce=False)),
                         ("morsel", dict(morsel_size=8, coalesce=False)),
                         ("coalesced", dict(morsel_size=8, coalesce=True))):
            meters[mode] = bk.UsageMeter()
            runs[mode] = ex.execute(plan, table, bk.make_backends(oracle),
                                    default_tier="m*", batch_size=batch,
                                    meter=meters[mode], **kw)
        for mode in ("morsel", "coalesced"):
            assert runs[mode].table.columns[ex.ROWID] \
                == runs["barrier"].table.columns[ex.ROWID]
            assert runs[mode].table.columns["Genre"] \
                == runs["barrier"].table.columns["Genre"]
        _assert_meters_equal(meters["coalesced"], meters["barrier"])
        assert meters["morsel"].total.calls > meters["coalesced"].total.calls
        assert runs["coalesced"].coalesce_stats["rows"] > 0
        assert runs["morsel"].coalesce_stats is None


def test_coalesce_call_count_is_ceil_of_survivors(movie_small):
    """Watermark-only flushing packs each operator into exactly
    ceil(survivors/batch) calls — the whole-table bound, and the upper
    bound ceil(survivors/batch) + n_partial_flushes holds by construction."""
    table, oracle = movie_small
    batch = 8
    plan = _chain_plan()
    meter = bk.UsageMeter()
    res = ex.execute(plan, table, bk.make_backends(oracle),
                     default_tier="m*", batch_size=batch, morsel_size=8,
                     meter=meter, coalesce=True)
    # replay the survivor counts through the same backends via barrier mode
    sizes, cur = [], table
    barrier = ex.execute(plan, cur, bk.make_backends(oracle),
                         default_tier="m*", batch_size=batch, morsel_size=0,
                         coalesce=False)
    # per-op survivor cardinalities: full table -> after f1 -> after f1
    # (map preserves) -> the exact calls are ceil(n_i/batch) summed
    n0 = table.n_rows
    n1 = len(barrier.table.columns[ex.ROWID])  # after the whole chain
    # recompute intermediate survivor count with a 2-op prefix
    prefix = ex.execute(P.LogicalPlan(plan.ops[:1]), table,
                        bk.make_backends(oracle), default_tier="m*",
                        batch_size=batch, morsel_size=0, coalesce=False)
    s1 = prefix.table.n_rows
    expect = -(-n0 // batch) + 2 * -(-s1 // batch)   # f1 + map + f2 inputs
    assert meter.total.calls == expect
    stats = res.coalesce_stats
    assert meter.total.calls <= \
        expect + stats["partial_flushes"]
    assert stats["flushes"] == meter.total.calls
    assert n1 <= s1


def test_coalesce_reduction_meets_perf_target(movie_small):
    """The ISSUE-3 acceptance bar: on the selective filter->map->filter
    pipeline at batch_size=8, coalescing cuts LLM calls by >= 30% vs
    per-morsel batching with identical results."""
    table, oracle = movie_small
    plan = _chain_plan()
    calls = {}
    for coalesce in (False, True):
        meter = bk.UsageMeter()
        res = ex.execute(plan, table, bk.make_backends(oracle),
                         default_tier="m*", batch_size=8, morsel_size=8,
                         meter=meter, coalesce=coalesce)
        calls[coalesce] = (meter.total.calls,
                           res.table.columns[ex.ROWID],
                           res.table.columns["Genre"])
    assert calls[True][1:] == calls[False][1:]       # identical answers
    assert calls[True][0] <= 0.7 * calls[False][0]


def test_coalesce_disabled_restores_per_morsel_batching(movie_small):
    """The --coalesce knob: off = PR-2 per-morsel grouping, morsel-local
    ceil call counts."""
    table, oracle = movie_small
    op = P.Operator(P.FILTER, "The rating is higher than 8.", "IMDB_rating")
    plan = P.LogicalPlan((
        op, P.Operator(P.MAP, "According to the movie plot, extract the "
                       "genre(s) of each movie.", "Plot", "Genre")))
    meter = bk.UsageMeter()
    ex.execute(plan, table, bk.make_backends(oracle), default_tier="m*",
               batch_size=4, morsel_size=8, meter=meter, coalesce=False)
    # per-morsel: each 8-row filter morsel is 2 calls; map pays one ragged
    # ceil per surviving morsel (survivors = what the imperfect backend
    # actually passed, not the oracle truth)
    fres = ex.execute(P.LogicalPlan((op,)), table, bk.make_backends(oracle),
                      default_tier="m*", batch_size=4, morsel_size=0,
                      coalesce=False)
    kept = set(fres.table.columns[ex.ROWID])
    mask = [i in kept for i in range(table.n_rows)]
    morsel_survivors = [sum(mask[i:i + 8]) for i in range(0, len(mask), 8)]
    expect = 2 * len(morsel_survivors) + sum(
        -(-s // 4) for s in morsel_survivors if s)
    assert meter.total.calls == expect


def test_coalesce_empty_morsels_still_advance_watermark(movie_small):
    """A filter that empties most morsels must not stall the accumulation
    queue (empty submissions advance the watermark) and maps must still
    define their output column."""
    table, oracle = movie_small
    plan = P.LogicalPlan((
        P.Operator(P.FILTER, "The movie is directed by Christopher "
                   "Nolan.", "Director"),
        P.Operator(P.MAP, "According to the movie plot, extract the "
                   "genre(s) of each movie.", "Plot", "Genre"),
    ))
    for driver in rt.DRIVERS:
        res = ex.execute(plan, table, bk.make_backends(oracle),
                         default_tier="m*", batch_size=8, morsel_size=8,
                         driver=driver, coalesce=True)
        assert "Genre" in res.table.columns
        want = ex.execute(plan, table, bk.make_backends(oracle),
                          default_tier="m*", batch_size=8, morsel_size=0,
                          coalesce=False)
        assert res.table.columns[ex.ROWID] == want.table.columns[ex.ROWID]


# ---------------------------------------------------------------------------
# Determinism
# ---------------------------------------------------------------------------

def test_coalesce_simulated_runs_are_deterministic(movie_small):
    """Acceptance: two simulated coalesced runs produce identical
    UsageMeter.call_log (same calls, same order, same latencies)."""
    table, oracle = movie_small
    plan = _chain_plan()
    logs = []
    for _ in range(2):
        meter = bk.UsageMeter()
        res = ex.execute(plan, table, bk.make_backends(oracle),
                         default_tier="m*", batch_size=8, morsel_size=8,
                         meter=meter, coalesce=True, driver="simulated")
        logs.append((list(meter.call_log), res.wall_s,
                     res.table.columns[ex.ROWID]))
    assert logs[0] == logs[1]


def test_coalesce_reorder_buffer_forms_logical_order_batches():
    """Morsels submitted in arbitrary thread order must form the same
    logical-row-order batches whole-table batching would — the reorder
    buffer admits submissions strictly by morsel index."""
    backend = SleepBackend(EchoOracle(), delay_s=1.0, sleep_s=0.0)
    meter = bk.UsageMeter()
    disp = rt.ThreadPoolDispatcher(concurrency=8)
    coal = rt.BatchCoalescer(disp, meter, batch_size=4)
    op = P.Operator(P.MAP, "annotate", "v", "a")
    n_morsels, rows = 12, 3
    group = coal.open(op, backend, "m*", expected=n_morsels)
    order = list(range(n_morsels))
    random.Random(7).shuffle(order)
    futs = {}
    threads = []

    def submit(idx):
        futs[idx] = group.submit(
            idx, [f"m{idx}r{j}" for j in range(rows)], 0.0)

    for idx in order:
        t = threading.Thread(target=submit, args=(idx,))
        threads.append(t)
        t.start()
        time.sleep(0.002)
    for t in threads:
        t.join()
    flat = [f"m{i}r{j}" for i in range(n_morsels) for j in range(rows)]
    want_groups = [tuple(flat[i:i + 4]) for i in range(0, len(flat), 4)]
    # batch *formation* is deterministic (logical row order, full batches);
    # arrival order at the backend is not — one submission can cut several
    # batches and _execute runs them concurrently on the threaded driver
    assert sorted(backend.groups) == sorted(want_groups)
    for idx in range(n_morsels):
        outs, _ = futs[idx].result(timeout=5)
        assert outs == [f"A:m{idx}r{j}" for j in range(rows)]
    coal.close()
    disp.close()


# ---------------------------------------------------------------------------
# Linger flushes
# ---------------------------------------------------------------------------

def test_coalesce_linger_flush_fires_under_event_scheduler():
    """Simulated driver, event-time linger: a partial batch whose next
    contributor arrives after the linger deadline flushes at the deadline
    (one extra call, earlier downstream start) instead of waiting."""
    op = P.Operator(P.MAP, "annotate", "v", "a")

    def run(linger):
        backend = SleepBackend(EchoOracle(), delay_s=1.0, sleep_s=0.0)
        meter = bk.UsageMeter()
        disp = rt.SimulatedDispatcher(rt.EventScheduler(concurrency=4))
        coal = rt.BatchCoalescer(disp, meter, batch_size=8, linger_s=linger)
        group = coal.open(op, backend, "m*", expected=2)
        f0 = group.submit(0, ["a", "b", "c"], 0.0)
        f1 = group.submit(1, ["d", "e"], 10.0)     # arrives at t=10
        coal.close()
        return (meter.total.calls, f0.result()[1], f1.result()[1],
                dict(coal.stats))

    calls, fin0, fin1, stats = run(linger=2.0)
    assert calls == 2                    # linger partial + watermark partial
    assert fin0 == pytest.approx(3.0)    # launched at 0 + linger 2, 1s call
    assert fin1 == pytest.approx(11.0)
    assert stats["partial_flushes"] == 2

    calls, fin0, fin1, stats = run(linger=None)
    assert calls == 1                    # one watermark batch at t=10
    assert fin0 == fin1 == pytest.approx(11.0)
    assert stats["partial_flushes"] == 1


def test_coalesce_linger_deadline_does_not_slide():
    """The linger deadline anchors to the *oldest* queued row: arrivals
    each within linger of the previous one must not extend the wait
    indefinitely (the t=0 rows flush at t=linger, not at the watermark)."""
    op = P.Operator(P.MAP, "annotate", "v", "a")
    backend = SleepBackend(EchoOracle(), delay_s=1.0, sleep_s=0.0)
    meter = bk.UsageMeter()
    disp = rt.SimulatedDispatcher(rt.EventScheduler(concurrency=4))
    coal = rt.BatchCoalescer(disp, meter, batch_size=8, linger_s=2.0)
    group = coal.open(op, backend, "m*", expected=4)
    futs = [group.submit(0, ["a"], 0.0),
            group.submit(1, ["b"], 1.5),    # within linger of row "a"...
            group.submit(2, ["c"], 3.0),    # ...but past a+linger=2.0
            group.submit(3, ["d"], 4.5)]
    coal.close()
    # [a, b] flush at the t=0 row's deadline 2.0 (not at 4.5's watermark);
    # [c, d] flush at the watermark, launched at their max ready 4.5
    assert meter.total.calls == 2
    assert futs[0].result()[1] == pytest.approx(3.0)   # 2.0 + 1s call
    assert futs[1].result()[1] == pytest.approx(3.0)
    assert futs[2].result()[1] == pytest.approx(5.5)
    assert futs[3].result()[1] == pytest.approx(5.5)


def test_coalesce_linger_timer_flushes_under_threads_driver():
    """Threads driver, wall-time linger: a partial batch flushes after
    linger_s even though the watermark contributor never arrives yet."""
    backend = SleepBackend(EchoOracle(), delay_s=0.0)
    meter = bk.UsageMeter()
    disp = rt.ThreadPoolDispatcher(concurrency=4)
    coal = rt.BatchCoalescer(disp, meter, batch_size=8, linger_s=0.05)
    op = P.Operator(P.MAP, "annotate", "v", "a")
    group = coal.open(op, backend, "m*", expected=2)
    fut = group.submit(0, ["a", "b"], 0.0)
    outs, _ = fut.result(timeout=5)      # resolved by the linger timer
    assert outs == ["A:a", "A:b"]
    assert meter.total.calls == 1
    group.submit(1, ["c"], 0.0).result(timeout=5)
    assert meter.total.calls == 2
    coal.close()
    disp.close()


# ---------------------------------------------------------------------------
# Threads driver: safety + equivalence
# ---------------------------------------------------------------------------

def test_coalesce_threads_matches_simulated_many_morsels():
    """Thread-safety under load: 24 morsels racing through a coalesced
    two-op chain give byte-identical results and accounting on both
    drivers (and the run terminates — no deadlock)."""
    oracle = EchoOracle()
    table = Table({"v": [f"x{i}" for i in range(96)]}, name="wide")
    plan = P.LogicalPlan((
        P.Operator(P.FILTER, "keep", "v"),
        P.Operator(P.MAP, "annotate", "v", "a"),
    ))

    class KeepOracle(EchoOracle):
        def answer(self, op, value):
            if op.kind == P.FILTER:
                return int(str(value)[1:]) % 3 != 0    # selective-ish
            return f"A:{value}"

    stats = {}
    for d in rt.DRIVERS:
        backend = SleepBackend(KeepOracle(), delay_s=1.0, sleep_s=0.002)
        meter = bk.UsageMeter()
        res = ex.execute(plan, table, {"m*": backend}, default_tier="m*",
                         batch_size=8, morsel_size=4, meter=meter,
                         driver=d, coalesce=True)
        stats[d] = (meter.total.calls, res.table.columns["a"],
                    res.table.columns[ex.ROWID])
    assert stats["threads"] == stats["simulated"]


def test_coalesce_backend_failure_raises_instead_of_hanging():
    """A backend failure in one morsel's batch must propagate as an
    exception, not deadlock: failed chains poison downstream steps, which
    still advance their accumulation queues' watermarks (empty
    submissions) so every other morsel's future resolves."""
    class BoomOracle(EchoOracle):
        def answer(self, op, value):
            if "BOOM" in str(value):
                raise RuntimeError("backend down")
            return True if op.kind == P.FILTER else f"A:{value}"

    table = Table({"v": [f"x{i}" if i < 8 else f"BOOM{i}"
                         for i in range(16)]}, name="boom")
    plan = P.LogicalPlan((
        P.Operator(P.FILTER, "keep", "v"),
        P.Operator(P.MAP, "annotate", "v", "a"),
    ))
    for d in rt.DRIVERS:
        backend = SleepBackend(BoomOracle(), delay_s=0.0)
        t0 = time.perf_counter()
        with pytest.raises(RuntimeError, match="backend down"):
            ex.execute(plan, table, {"m*": backend}, default_tier="m*",
                       batch_size=8, morsel_size=8, driver=d,
                       coalesce=True)
        assert time.perf_counter() - t0 < 30.0       # raised, not hung


def test_coalesce_threads_wall_does_not_regress(movie_small):
    """Acceptance: measured threads wall with coalescing stays at or below
    per-morsel batching on the bench pipeline (fewer, fuller calls)."""
    table, oracle = movie_small
    plan = _chain_plan()
    walls = {}
    for coalesce in (False, True):
        best = float("inf")
        for _ in range(3):
            backend = SleepBackend(oracle, delay_s=0.03)
            res = ex.execute(plan, table, {"m*": backend},
                             default_tier="m*", batch_size=8, morsel_size=8,
                             concurrency=8, driver="threads",
                             coalesce=coalesce)
            best = min(best, res.wall_s)
        walls[coalesce] = best
    assert walls[True] <= walls[False] * 1.10 + 0.02


# ---------------------------------------------------------------------------
# Batch-aware cost model + optimizer pricing
# ---------------------------------------------------------------------------

def test_coalesce_cost_model_prices_ceil_batches():
    op = P.Operator(P.FILTER, "keep the good ones", "v")
    tier = cost_mod.DEFAULT_TIERS["m1"]
    c1 = cost_mod.op_cost(op, 100, tier, batch_size=1)
    c8 = cost_mod.op_cost(op, 100, tier, batch_size=8)
    assert c1.llm_calls == 100
    assert c8.llm_calls == 13                       # ceil(100/8)
    assert c8.usd < c1.usd                          # shared instruction
    assert c8.tok_in == pytest.approx(
        13 * cost_mod.text_tokens(op.instruction) + 100 * 60.0)
    plan = P.LogicalPlan((op,))
    p1 = cost_mod.plan_cost(plan, 100, batch_size=1)
    p8 = cost_mod.plan_cost(plan, 100, batch_size=8)
    assert p8.llm_calls == 13 and p1.llm_calls == 100
    assert p8.usd < p1.usd


def test_coalesce_physical_optimizer_scoring_is_batch_priced(movie_small):
    """With ctx.batch_size > 1 the physical optimizer's scoring sweeps run
    batched: ceil(sample/batch) calls per tier sweep — strictly fewer
    optimizer-phase calls than per-record scoring, tier choices intact."""
    table, oracle = movie_small
    plan = P.LogicalPlan(_chain_plan().ops[:2])
    meters = {}
    for batch in (1, 8):
        ctx = rt.ExecutionContext(backends=bk.make_backends(oracle),
                                  default_tier="m*", batch_size=batch)
        pres = popt.optimize(plan, table, ctx)
        meters[batch] = pres.meter.total.calls
        assert set(pres.assignments) == {0, 1}
    assert meters[8] < meters[1]
