"""Serving engine: continuous batching correctness + scheduler behaviour."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.data.tokenizer import ByteTokenizer
from repro.engine import ContinuousBatcher, GenerationEngine
from repro.models import registry


@pytest.fixture(scope="module")
def served():
    cfg = reduced(get_config("qwen2-0.5b"))
    b = registry.build(cfg)
    params = b.init(jax.random.PRNGKey(0))
    return cfg, b, params


def gen_sequential(bundle, params, prompt, max_new, max_len=96):
    """Reference: single-request engine (n_slots=1)."""
    eng = GenerationEngine(bundle, params, max_len=max_len, n_slots=1)
    cb = ContinuousBatcher(eng)
    rid = cb.submit(prompt, max_new_tokens=max_new)
    return cb.run()[rid].output_ids


def test_continuous_batching_matches_sequential(served):
    _, bundle, params = served
    prompts = [f"semantic query number {i} about movies" for i in range(5)]
    want = [gen_sequential(bundle, params, p, 8) for p in prompts]

    eng = GenerationEngine(bundle, params, max_len=96, n_slots=3)
    cb = ContinuousBatcher(eng)
    rids = [cb.submit(p, max_new_tokens=8) for p in prompts]
    got = cb.run()
    for rid, w in zip(rids, want):
        assert got[rid].output_ids == w, rid


def test_more_requests_than_slots(served):
    _, bundle, params = served
    eng = GenerationEngine(bundle, params, max_len=64, n_slots=2)
    cb = ContinuousBatcher(eng)
    rids = [cb.submit(f"req {i}", max_new_tokens=5) for i in range(9)]
    finished = cb.run()
    assert len(finished) == 9
    assert all(len(finished[r].output_ids) == 5 for r in rids)
    assert eng.stats["prefills"] == 9


def test_occupancy_improves_with_load(served):
    _, bundle, params = served
    eng1 = GenerationEngine(bundle, params, max_len=64, n_slots=4)
    cb1 = ContinuousBatcher(eng1)
    cb1.submit("only one request", max_new_tokens=6)
    cb1.run()
    eng2 = GenerationEngine(bundle, params, max_len=64, n_slots=4)
    cb2 = ContinuousBatcher(eng2)
    for i in range(12):
        cb2.submit(f"request {i}", max_new_tokens=6)
    cb2.run()
    assert eng2.occupancy > eng1.occupancy


def test_max_len_respected(served):
    _, bundle, params = served
    eng = GenerationEngine(bundle, params, max_len=48, n_slots=1)
    cb = ContinuousBatcher(eng)
    rid = cb.submit("x" * 200, max_new_tokens=64)    # prompt+gen > max_len
    req = cb.run()[rid]
    assert len(req.prompt_ids) + len(req.output_ids) <= 48


def test_temperature_sampling_differs(served):
    _, bundle, params = served
    eng = GenerationEngine(bundle, params, max_len=64, n_slots=1)
    cb = ContinuousBatcher(eng)
    r1 = cb.submit("hello", max_new_tokens=12, temperature=1.5)
    out1 = cb.run(key=jax.random.PRNGKey(0))[r1].output_ids
    eng2 = GenerationEngine(bundle, params, max_len=64, n_slots=1)
    cb2 = ContinuousBatcher(eng2)
    r2 = cb2.submit("hello", max_new_tokens=12, temperature=1.5)
    out2 = cb2.run(key=jax.random.PRNGKey(9))[r2].output_ids
    assert out1 != out2


def test_tokenizer_roundtrip():
    tok = ByteTokenizer()
    s = "Nirvana: semantic ops über tables 🎬"
    assert tok.decode(tok.encode(s, bos=True, eos=True)) == s
    batch = tok.pad_batch([[1, 2], [3, 4, 5]], align=8)
    assert batch.shape == (2, 8)
    assert batch[0, 2] == tok.pad_id


def test_jax_backend_through_executor(served):
    from repro.core import executor as ex
    from repro.core import plan as P
    from repro.core.backends import UsageMeter
    from repro.core.cost import DEFAULT_TIERS
    from repro.engine import JAXBackend
    _, bundle, params = served
    eng = GenerationEngine(bundle, params, max_len=128, n_slots=2)
    be = JAXBackend(DEFAULT_TIERS["m1"], eng, max_new_tokens=4)
    plan = P.LogicalPlan((P.Operator(P.FILTER, "Is it big?", "col"),))
    from repro.core.table import Table
    table = Table({"col": ["tiny", "huge", "medium"]})
    meter = UsageMeter()
    res = ex.execute(plan, table, {"m*": be}, default_tier="m*",
                     meter=meter)
    assert meter.calls("m1") == 3
    assert meter.total.latency_s > 0
    assert res.table is not None
