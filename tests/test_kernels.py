"""Pallas kernels vs pure-jnp oracles: shape / dtype / flag sweeps.

Kernels run in interpret mode on CPU — the kernel bodies execute exactly
as they would on TPU (same BlockSpec tiling, same scratch carries)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def randn(*shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape) * 0.5, dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,hq,hkv,d", [
    (1, 64, 4, 4, 32),      # MHA
    (2, 128, 8, 2, 32),     # GQA 4x
    (1, 96, 8, 1, 64),      # MQA, non-pow2 seq
    (2, 40, 4, 2, 16),      # needs padding (40 % 32 != 0)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, s, hq, hkv, d, dtype):
    q = randn(b, s, hq, d, dtype=dtype)
    k = randn(b, s, hkv, d, dtype=dtype)
    v = randn(b, s, hkv, d, dtype=dtype)
    got = ops.flash_attention(q, k, v, causal=True, bq=32, bk=32)
    want = ref.attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("window", [8, 24, 64])
def test_flash_attention_sliding_window(window):
    q = randn(1, 96, 4, 32)
    k = randn(1, 96, 2, 32)
    v = randn(1, 96, 2, 32)
    got = ops.flash_attention(q, k, v, causal=True, window=window,
                              bq=32, bk=32)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_attention_noncausal():
    q = randn(1, 64, 4, 32)
    k = randn(1, 64, 4, 32)
    v = randn(1, 64, 4, 32)
    got = ops.flash_attention(q, k, v, causal=False, bq=32, bk=32)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_attention_matches_model_chunked_attention():
    """The XLA chunked path (models/attention.py) and the Pallas kernel
    must be interchangeable."""
    from repro.models.attention import chunked_attention
    q = randn(2, 64, 8, 32)
    k = randn(2, 64, 2, 32)
    v = randn(2, 64, 2, 32)
    a = chunked_attention(q, k, v, causal=True)
    b = ops.flash_attention(q, k, v, causal=True, bq=32, bk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,hq,hkv,d,s", [
    (1, 4, 4, 32, 128),
    (3, 8, 2, 64, 256),
    (2, 4, 1, 32, 100),     # padding (100 % 64)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, hq, hkv, d, s, dtype):
    q = randn(b, 1, hq, d, dtype=dtype)
    kc = randn(b, s, hkv, d, dtype=dtype)
    vc = randn(b, s, hkv, d, dtype=dtype)
    lens = jnp.asarray(RNG.integers(1, s + 1, size=b), jnp.int32)
    got = ops.decode_attention(q, kc, vc, lens, bk=64)
    want = ref.decode_attention_ref(q, kc, vc, lens)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_decode_attention_scalar_len():
    q = randn(2, 1, 4, 32)
    kc = randn(2, 128, 2, 32)
    vc = randn(2, 128, 2, 32)
    got = ops.decode_attention(q, kc, vc, 77)
    want = ref.decode_attention_ref(q, kc, vc, jnp.full((2,), 77))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
    (1, 64, 2, 16, 1, 8, 16),
    (2, 128, 4, 32, 2, 16, 32),
    (1, 96, 8, 16, 4, 8, 48),
])
def test_ssd_scan_sweep(b, s, h, p, g, n, chunk):
    dx = randn(b, s, h, p)
    dA = -jnp.abs(randn(b, s, h)) * 0.2
    B = randn(b, s, g, n)
    C = randn(b, s, g, n)
    y, st = ops.ssd_scan(dx, dA, B, C, chunk=chunk)
    y_ref, st_ref = ref.ssd_ref(dx, dA, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=3e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               atol=3e-4)


def test_ssd_scan_initial_state_continuation():
    """Scanning [first half] then [second half from the carried state] must
    equal one full scan — the prefill-continuation invariant."""
    b, s, h, p, g, n = 1, 64, 2, 8, 1, 8
    dx = randn(b, s, h, p)
    dA = -jnp.abs(randn(b, s, h)) * 0.2
    B = randn(b, s, g, n)
    C = randn(b, s, g, n)
    y_full, st_full = ops.ssd_scan(dx, dA, B, C, chunk=16)
    y1, st1 = ops.ssd_scan(dx[:, :32], dA[:, :32], B[:, :32], C[:, :32],
                           chunk=16)
    y2, st2 = ops.ssd_scan(dx[:, 32:], dA[:, 32:], B[:, 32:], C[:, 32:],
                           initial_state=st1, chunk=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=3e-4)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               atol=3e-4)


def test_ssd_kernel_matches_model_chunked_path():
    """kernels.ssd_scan and models.ssm.ssd_chunked implement one schedule."""
    from repro.models.ssm import ssd_chunked
    b, s, h, p, g, n = 2, 64, 4, 16, 2, 8
    dx = randn(b, s, h, p)
    dA = -jnp.abs(randn(b, s, h)) * 0.2
    B = randn(b, s, g, n)
    C = randn(b, s, g, n)
    y1, st1 = ops.ssd_scan(dx, dA, B, C, chunk=16)
    y2, st2 = ssd_chunked(dx, dA, B, C, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=3e-4)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2), atol=3e-4)


# ---------------------------------------------------------------------------
# similarity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,d", [(128, 128, 256), (130, 70, 256),
                                   (16, 16, 64)])
def test_cosine_matrix_sweep(m, n, d):
    a = RNG.normal(size=(m, d)).astype(np.float32)
    b = RNG.normal(size=(n, d)).astype(np.float32)
    a /= np.linalg.norm(a, axis=1, keepdims=True)
    b /= np.linalg.norm(b, axis=1, keepdims=True)
    got = ops.cosine_matrix(a, b)
    np.testing.assert_allclose(got, np.asarray(ref.cosine_matrix_ref(a, b)),
                               atol=1e-5)


def test_rowwise_cosine():
    a = RNG.normal(size=(133, 256)).astype(np.float32)
    b = RNG.normal(size=(133, 256)).astype(np.float32)
    got = ops.rowwise_cosine(a, b)
    np.testing.assert_allclose(got,
                               np.asarray(ref.rowwise_cosine_ref(a, b)),
                               atol=1e-5)


# the similarity module itself (not the padding ops wrappers) must accept
# arbitrary M/N — morsels and cascade batches are rarely block multiples
@pytest.mark.parametrize("m", [1, 127, 129])
def test_cosine_matrix_arbitrary_rows(m):
    from repro.kernels import similarity as sim
    a = RNG.normal(size=(m, 256)).astype(np.float32)
    b = RNG.normal(size=(67, 256)).astype(np.float32)
    a /= np.linalg.norm(a, axis=1, keepdims=True)
    b /= np.linalg.norm(b, axis=1, keepdims=True)
    got = sim.cosine_matrix(a, b, interpret=True)
    assert got.shape == (m, 67)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.cosine_matrix_ref(a, b)),
                               atol=1e-5)


@pytest.mark.parametrize("m", [1, 127, 129])
def test_rowwise_cosine_arbitrary_rows(m):
    from repro.kernels import similarity as sim
    a = RNG.normal(size=(m, 256)).astype(np.float32)
    b = RNG.normal(size=(m, 256)).astype(np.float32)
    got = sim.rowwise_cosine(a, b, interpret=True)
    assert got.shape == (m,)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.rowwise_cosine_ref(a, b)),
                               atol=1e-5)


def test_semhash_uses_kernel_path():
    from repro.core import semhash
    xs = ["the quick brown fox", "a crime story", "N250m"]
    ys = ["the quick brown fox", "a thriller tale", "250 million naira"]
    eq = semhash.semantic_equal_batch(xs, ys, use_kernel=True)
    eq2 = semhash.semantic_equal_batch(xs, ys, use_kernel=False)
    assert list(eq) == list(eq2)
    assert eq[0]          # identical strings
