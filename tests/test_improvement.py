"""Improvement-score estimators (Eqs. 2-8): algebraic identities as
property tests over arbitrary joint output distributions.

The estimators consume only model *outputs*, so we drive them with a
scripted backend whose outputs per (tier, record) come from
hypothesis-generated response patterns. Invariants:

  * pushdown == exact  ALWAYS (Eq. 3 is a pure conditional factorization)
  * reuse    == exact  under the binary response model (one canonical wrong
                       answer per record — the paper's Fig. 5 world)
  * approx   == exact  when Hypothesis 2 holds (nested correctness)
  * m*-invocation counts: approx <= reuse <= pushdown <= exact
"""
import dataclasses

import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep "
    "(pip install .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import backends as bk
from repro.core import cost as cost_mod
from repro.core import improvement as imp
from repro.core import plan as P

OP = P.Operator(P.FILTER, "test predicate", "col")
TIERS = ("m1", "m2", "m3", "m*")


@dataclasses.dataclass
class ScriptedBackend:
    """Outputs fixed per (tier, record index): outputs[tier][i]."""
    tier: cost_mod.TierSpec
    outputs: dict

    def run_values(self, op, values, meter=None, batch_size=1):
        outs = [self.outputs[self.tier.name][int(v)] for v in values]
        if meter:
            meter.record(self.tier.name,
                         bk.Usage(calls=len(values), tok_in=len(values),
                                  tok_out=len(values), usd=0.0,
                                  latency_s=0.0))
        return outs


def make_backends(outputs):
    return {t: ScriptedBackend(cost_mod.DEFAULT_TIERS[t], outputs)
            for t in TIERS}


def run_all(outputs):
    n = len(outputs["m1"])
    values = list(range(n))
    res = {}
    for method in imp.ESTIMATORS:
        backends = make_backends(outputs)
        res[method] = imp.improvement_scores(backends, OP, values,
                                             method=method)
    return res


# --------------------------------------------------------------------------
# binary response model: each record has a truth and ONE wrong answer;
# tiers either emit the truth or the wrong answer
# --------------------------------------------------------------------------

@st.composite
def binary_response_patterns(draw):
    n = draw(st.integers(2, 24))
    # per record: which tiers are correct (m* always correct => proxy truth)
    pats = []
    for i in range(n):
        correct = {t: draw(st.booleans()) for t in ("m1", "m2", "m3")}
        correct["m*"] = True
        pats.append(correct)
    outputs = {t: [] for t in TIERS}
    for i, correct in enumerate(pats):
        for t in TIERS:
            outputs[t].append(bool(i % 2) if correct[t]
                              else (not bool(i % 2)))
    return outputs


@st.composite
def nested_patterns(draw):
    """Hypothesis-2 world: correctness sets nested m1 ⊆ m2 ⊆ m3 ⊆ m*."""
    n = draw(st.integers(2, 24))
    outputs = {t: [] for t in TIERS}
    for i in range(n):
        # strength threshold: tiers >= k are correct
        k = draw(st.integers(0, 3))
        for j, t in enumerate(TIERS):
            correct = j >= k
            outputs[t].append(bool(i % 2) if correct else (not bool(i % 2)))
    return outputs


@settings(max_examples=60, deadline=None)
@given(binary_response_patterns())
def test_pushdown_equals_exact_always(outputs):
    res = run_all(outputs)
    for tier in ("m2", "m3", "m*"):
        assert res["pushdown"].scores[tier] == pytest.approx(
            res["exact"].scores[tier], abs=1e-12)


@settings(max_examples=60, deadline=None)
@given(nested_patterns())
def test_reuse_equals_exact_under_hypothesis2(outputs):
    """Eq. 4's substitution Pr(m2=m*, m1!=m2, m2=m3) = I12 needs nested
    correctness (m2 right => m3 right), NOT just the binary response model.
    The paper presents Eq. 4 as a pure total-probability identity; property
    testing pins the actual assumption boundary (EXPERIMENTS.md
    §Repro-validation)."""
    res = run_all(outputs)
    for tier in ("m2", "m3", "m*"):
        assert res["reuse"].scores[tier] == pytest.approx(
            res["exact"].scores[tier], abs=1e-12)


def test_reuse_deviates_without_hypothesis2():
    """Regression: the hypothesis-found counterexample where m2 is right
    but m3 is wrong (violating nesting) makes Eq. 4 underestimate I13."""
    outputs = {"m1": [True, False], "m2": [False, False],
               "m3": [True, False], "m*": [False, True]}
    res = run_all(outputs)
    assert res["reuse"].scores["m3"] != pytest.approx(
        res["exact"].scores["m3"], abs=1e-12)


@settings(max_examples=60, deadline=None)
@given(nested_patterns())
def test_approx_equals_exact_under_hypothesis2(outputs):
    res = run_all(outputs)
    for tier in ("m2", "m3", "m*"):
        assert res["approx"].scores[tier] == pytest.approx(
            res["exact"].scores[tier], abs=1e-12)


@settings(max_examples=40, deadline=None)
@given(nested_patterns())
def test_mstar_invocation_ordering(outputs):
    n = len(outputs["m1"])
    values = list(range(n))
    calls = {}
    for method in ("exact", "pushdown", "reuse", "approx"):
        backends = make_backends(outputs)
        r = imp.improvement_scores(backends, OP, values, method=method)
        calls[method] = r.meter.calls("m*")
    assert calls["approx"] <= calls["reuse"] <= calls["pushdown"] \
        <= calls["exact"]
    assert calls["exact"] == n


def test_scores_bounded_01():
    outputs = {t: [True] * 8 for t in TIERS}
    for method, res in run_all(outputs).items():
        for tier, s in res.scores.items():
            assert 0.0 <= s <= 1.0, (method, tier, s)


def test_all_agree_means_zero_improvement():
    outputs = {t: ["same"] * 10 for t in TIERS}
    res = run_all(outputs)
    for method in res:
        assert res[method].scores["m2"] == 0.0
        assert res[method].scores["m3"] == 0.0
        assert res[method].scores["m*"] == 0.0


def test_simulated_backend_estimators_close():
    """End-to-end: with the calibrated simulator (violations on), approx
    stays within sampling tolerance of exact."""
    from repro.core.backends import make_backends as mk
    from repro.core.backends import UDFOracle
    op = P.Operator(P.FILTER, "The rating is higher than 5.", "col")
    values = [str(v / 10.0) for v in range(200)]
    backends = mk(UDFOracle(), violation_rate=0.03)
    exact = imp.improvement_scores(backends, op, values, method="exact")
    backends = mk(UDFOracle(), violation_rate=0.03)
    approx = imp.improvement_scores(backends, op, values, method="approx")
    for tier in ("m2", "m3", "m*"):
        assert approx.scores[tier] == pytest.approx(
            exact.scores[tier], abs=0.08)
