"""Event-driven runtime: scheduler semantics, morsel pipelining, context
threading, cache behaviour under pipelining."""
import pytest

from repro.core import backends as bk
from repro.core import executor as ex
from repro.core import judge as judge_mod
from repro.core import logical_optimizer as lopt
from repro.core import physical_optimizer as popt
from repro.core import plan as P
from repro.core import runtime as rt
from repro.core.cost import TierSpec
from repro.data import load_dataset

from conftest import perfect_backends


@pytest.fixture(scope="module")
def movie_small():
    return load_dataset("movie", max_rows=48)


def unit_latency_backends(oracle):
    """Always-correct two-tier cascade where every call takes exactly 1s
    (latency_call_s=1, latency_tok_s=0) — makes schedules hand-computable."""
    return {
        "m1": bk.SimulatedBackend(TierSpec("m1", 1.01, 0.1, 0.4, 1.0, 0.0),
                                  oracle, violation_rate=0.0),
        "m*": bk.SimulatedBackend(TierSpec("m*", 1.01, 2.0, 8.0, 1.0, 0.0),
                                  oracle, violation_rate=0.0),
    }


# ---------------------------------------------------------------------------
# EventScheduler
# ---------------------------------------------------------------------------

def test_scheduler_hand_computed_schedule():
    s = rt.EventScheduler(concurrency=2)
    assert s.submit("t", 3.0) == 3.0        # worker 1: [0, 3]
    assert s.submit("t", 1.0) == 1.0        # worker 2: [0, 1]
    assert s.submit("t", 1.0) == 2.0        # worker 2: [1, 2]
    assert s.submit("t", 1.0) == 3.0        # worker 2: [2, 3]
    assert s.makespan == 3.0
    # ready time delays the start past the free worker
    assert s.submit("t", 2.0, ready_s=4.0) == 6.0
    assert s.makespan == 6.0


def test_scheduler_per_tier_pools_are_independent():
    s = rt.EventScheduler(concurrency=4)
    for _ in range(4):
        s.submit("a", 1.0)
    for _ in range(4):
        s.submit("b", 1.0)
    # different tiers do not contend: both finish in one wave
    assert s.makespan == 1.0


def test_scheduler_per_tier_concurrency_caps():
    s = rt.EventScheduler(concurrency=4, per_tier={"m*": 1})
    for _ in range(4):
        s.submit("m1", 1.0)
    assert s.makespan == 1.0                # m1: 4 workers
    for _ in range(4):
        s.submit("m*", 1.0)
    assert s.makespan == 4.0                # m*: capped at 1 worker


def test_scheduler_sync_mode_is_sequential_sum():
    s = rt.EventScheduler(concurrency=16, mode="sync")
    for tier, d in (("a", 1.0), ("b", 2.0), ("a", 3.0)):
        s.submit(tier, d)
    assert s.makespan == 6.0                # one global worker


def test_scheduler_barrier_floors_later_jobs():
    s = rt.EventScheduler(concurrency=4)
    s.submit("t", 2.0)
    s.barrier()
    assert s.submit("t", 1.0) == 3.0        # cannot start before 2.0


def test_scheduler_drains_meter_call_log(movie_small):
    table, oracle = movie_small
    backends = unit_latency_backends(oracle)
    meter = bk.UsageMeter()
    op = P.Operator(P.FILTER, "The rating is higher than 8.", "IMDB_rating")
    backends["m1"].run_values(op, table.column("IMDB_rating")[:6],
                              meter=meter)
    assert len(meter.call_log) == 6
    assert all(t == "m1" and lat == pytest.approx(1.0)
               for t, lat in meter.call_log)
    s = rt.EventScheduler(concurrency=3)
    cursor, finish = s.drain(meter, 0)
    assert cursor == 6 and finish == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# Morsel-driven execution
# ---------------------------------------------------------------------------

def _chain_plan(filter_tier=None, map_tier=None):
    return P.LogicalPlan((
        P.Operator(P.FILTER, "The rating is higher than 1.", "IMDB_rating",
                   tier=filter_tier),
        P.Operator(P.MAP, "According to the movie plot, extract the "
                   "genre(s) of each movie.", "Plot", "Genre",
                   tier=map_tier),
    ))


def test_morsel_results_and_meter_match_barrier(movie_small):
    table, oracle = movie_small
    plan = P.LogicalPlan((
        P.Operator(P.FILTER, "The rating is higher than 8.", "IMDB_rating"),
        P.Operator(P.MAP, "According to the movie plot, extract the "
                   "genre(s) of each movie.", "Plot", "Genre"),
        P.Operator(P.REDUCE, "Count the number of movies.", "Title"),
    ))
    runs = {}
    for name, morsel in (("barrier", 0), ("morsel", 8)):
        backends = bk.make_backends(oracle)
        runs[name] = ex.execute(plan, table, backends, default_tier="m*",
                                morsel_size=morsel)
    a, b = runs["barrier"], runs["morsel"]
    assert a.scalar == b.scalar
    assert a.rows_processed == b.rows_processed
    ta, tb = a.meter.total, b.meter.total
    assert ta.calls == tb.calls
    assert ta.tok_in == pytest.approx(tb.tok_in)
    assert ta.tok_out == pytest.approx(tb.tok_out)
    assert ta.usd == pytest.approx(tb.usd)
    assert ta.latency_s == pytest.approx(tb.latency_s)


def test_morsel_table_outputs_match_barrier(movie_small):
    table, oracle = movie_small
    plan = _chain_plan()
    backends = bk.make_backends(oracle)
    a = ex.execute(plan, table, backends, morsel_size=0)
    b = ex.execute(plan, table, backends, morsel_size=8)
    assert a.table.columns[ex.ROWID] == b.table.columns[ex.ROWID]
    assert a.table.columns["Genre"] == b.table.columns["Genre"]


def test_filter_map_chain_pipelines_below_barrier(movie_small):
    """The ISSUE-1 acceptance schedule: filter (m1) -> map (m*) over 48
    rows, 4 workers per tier, 1s calls. Barrier: 12s filter + 12s map =
    24s. Morsels of 8: map morsel k starts as soon as filter morsel k is
    done (2k seconds), so the chain drains at 14s."""
    table, oracle = movie_small
    backends = unit_latency_backends(oracle)
    plan = _chain_plan(filter_tier="m1", map_tier="m*")

    barrier = ex.execute(plan, table, backends, concurrency=4,
                         morsel_size=0)
    morsel = ex.execute(plan, table, backends, concurrency=4,
                        morsel_size=8)
    assert barrier.wall_s == pytest.approx(24.0)
    assert morsel.wall_s == pytest.approx(14.0)
    assert morsel.wall_s < barrier.wall_s
    # identical answers either way
    assert morsel.table.columns["Genre"] == barrier.table.columns["Genre"]


def test_same_tier_chain_never_slower_than_barrier(movie_small):
    """With both operators contending for one tier's pool the pipeline is
    work-bound, but morsel scheduling must never lose to the barrier."""
    table, oracle = movie_small
    backends = unit_latency_backends(oracle)
    plan = _chain_plan(filter_tier="m*", map_tier="m*")
    for conc in (4, 5, 16):
        barrier = ex.execute(plan, table, backends, concurrency=conc,
                             morsel_size=0)
        morsel = ex.execute(plan, table, backends, concurrency=conc,
                            morsel_size=8)
        assert morsel.wall_s <= barrier.wall_s


def test_reduce_is_a_pipeline_barrier(movie_small):
    table, oracle = movie_small
    backends = perfect_backends(oracle)
    plan = P.LogicalPlan((
        P.Operator(P.FILTER, "The rating is higher than 8.", "IMDB_rating"),
        P.Operator(P.REDUCE, "Count the number of movies.", "Title"),
    ))
    got = ex.execute(plan, table, backends, morsel_size=8).value()
    want = sum(1 for r in table.column("IMDB_rating") if float(r) > 8)
    assert got == want


def test_cache_semantics_under_pipelining(movie_small):
    """Cache keys are per-value, so barrier and morsel runs share hits;
    a fully-cached pipelined run makes zero calls and has zero makespan."""
    table, oracle = movie_small
    backends = bk.make_backends(oracle)
    plan = _chain_plan()
    cache = rt.OutputCache()
    m1 = bk.UsageMeter()
    ex.execute(plan, table, backends, cache=cache, meter=m1, morsel_size=0)
    misses_after_first = cache.misses
    m2 = bk.UsageMeter()
    r2 = ex.execute(plan, table, backends, cache=cache, meter=m2,
                    morsel_size=8)
    assert m2.total.calls == 0
    assert r2.wall_s == 0.0
    assert cache.misses == misses_after_first
    assert cache.hits >= table.n_rows


def test_batch_prompting_call_counts_survive_morselling(movie_small):
    """Full morsels are multiples of the batch size, so batched call
    counts match the barrier executor: sum(ceil(s_i/b)) == ceil(n/b)."""
    table, oracle = movie_small
    op = P.Operator(P.FILTER, "The movie is directed by Christopher "
                    "Nolan.", "Director")
    plan = P.LogicalPlan((op,))
    for batch in (3, 4):
        counts = {}
        for name, morsel in (("barrier", 0), ("morsel", 8)):
            backends = bk.make_backends(oracle)
            meter = bk.UsageMeter()
            ex.execute(plan, table, backends, batch_size=batch,
                       meter=meter, morsel_size=morsel)
            counts[name] = meter.total.calls
        assert counts["morsel"] == counts["barrier"] \
            == -(-table.n_rows // batch)


# ---------------------------------------------------------------------------
# ExecutionContext threading
# ---------------------------------------------------------------------------

def test_context_threads_executor_judge_and_optimizers(movie_small):
    table, oracle = movie_small
    ctx = rt.ExecutionContext(backends=perfect_backends(oracle),
                              default_tier="m*", concurrency=8)
    plan = _chain_plan()
    res = ex.execute(plan, table, ctx)
    assert res.meter is ctx.meter
    assert res.table.n_rows == table.n_rows   # threshold-1 filter keeps all

    j = judge_mod.Judge(ctx)
    assert j.rate(plan, plan, table.sample(8)).rating == pytest.approx(1.0)

    # optimizers need the full four-tier cascade
    cascade = rt.ExecutionContext(backends=bk.make_backends(oracle),
                                  default_tier="m*", concurrency=8)
    pres = popt.optimize(plan, table, cascade,
                         cfg=popt.PhysicalOptConfig(estimator="approx"))
    assert set(pres.assignments) == {0, 1}
    assert pres.opt_wall_s > 0.0

    lres = lopt.optimize(plan, table, cascade,
                         cfg=lopt.LogicalOptConfig(n_iterations=1))
    assert lres.best_cost <= lres.initial_cost


def test_per_tier_concurrency_through_context(movie_small):
    table, oracle = movie_small
    backends = unit_latency_backends(oracle)
    plan = P.LogicalPlan((
        P.Operator(P.FILTER, "The rating is higher than 1.",
                   "IMDB_rating"),))
    wide = rt.ExecutionContext(backends=backends, concurrency=16)
    narrow = rt.ExecutionContext(backends=backends, concurrency=16,
                                 per_tier_concurrency={"m*": 1})
    w = ex.execute(plan, table, wide)
    n = ex.execute(plan, table, narrow)
    assert w.wall_s == pytest.approx(3.0)          # ceil(48/16) waves
    assert n.wall_s == pytest.approx(float(table.n_rows))


def test_sync_mode_context_matches_latency_sum(movie_small):
    table, oracle = movie_small
    backends = unit_latency_backends(oracle)
    plan = _chain_plan(filter_tier="m1", map_tier="m*")
    ctx = rt.ExecutionContext(backends=backends, mode="sync")
    res = ex.execute(plan, table, ctx)
    assert res.wall_s == pytest.approx(ctx.meter.total.latency_s)
