"""Property-based invariance harness: the three serving guarantees hold
for *randomly drawn* plans, fault seeds, and knob combinations — not
just the hand-picked cases in the per-feature suites.

Guarantees (ROADMAP north star), asserted per draw:

1. **driver-invariance** — ``driver="threads"`` and
   ``driver="simulated"`` produce byte-identical results and the same
   multiset of billed calls; fault-free draws also byte-compare spend
   totals and CostModel calibration state. Logical key *shapes* are
   driver-internal (the threads pipeline keys per-(morsel, chunk), the
   simulated driver numbers chunks globally), so keys — and therefore
   seeded fault *placement* — compare only within a driver;
2. **shard-count-invariance** — ``shards=N`` is byte-identical to
   ``shards=1``: results, merged call log with logical keys (modulo
   coalescer chunk shape), totals, calibration state — including the
   fault entries, since fault plans are pure functions of the
   shard-invariant logical keys;
3. **admission-order-invariance** — a query admitted to a shared
   ``QueryServer`` *through the AdmissionController* (random tenants,
   lanes, caps) is byte-identical to running it solo on a fresh
   context, fault entries included.

Faulty draws wrap the backend in a seeded :class:`FlakyBackend` with a
retrying :class:`CallPolicy`.

The harness runs through `hypothesis` when it is installed (CI installs
the ``test`` extra) and always through a deterministic seeded
parametrization, so the properties are exercised in every environment —
the container image does not ship hypothesis, and nothing may be
installed at test time.

The closing cross-feature matrix stress test turns every subsystem on
at once — tier-0 cascade, batch coalescing, 10% seeded faults with
retries, and 3-way sharding — and holds the stressed run byte-identical
to a healthy single-shard run on results and on the merged log filtered
to its successful (typed) entries with retry marks stripped: faulted
attempts bill extra ``op_kind=None`` entries by design, but the calls
that produced answers must be exactly the healthy run's calls.
"""
import random

import pytest

from repro.core import backends as bk
from repro.core import cascade as casc
from repro.core import executor as ex
from repro.core import plan as P
from repro.core import runtime as rt
from repro.core.cost_model import CostModel
from repro.launch.query_server import AdmissionController, QueryServer
from repro.testing import (EmbeddingOracle, FlakyBackend, KindOracle,
                           SleepBackend, tagged_table)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:        # container image: optional test extra absent
    HAVE_HYPOTHESIS = False

pytestmark = pytest.mark.qos

SEEDS = range(10)


# -- case generation (shared by hypothesis and seeded parametrization) ---

def draw_case(rng: random.Random) -> dict:
    """One random workload + knob combination. Everything derives from
    the ``rng``, so a seed pins the whole case."""
    tag = f"p{rng.randrange(1 << 30):08x}"
    ops = []
    for j in range(rng.randint(1, 3)):
        if rng.random() < 0.5:
            ops.append(P.Operator(P.FILTER, f"{tag}-keep-{j}", "v"))
        else:
            ops.append(P.Operator(P.MAP, f"{tag}-note-{j}", "v", f"a{j}"))
    if rng.random() < 0.4:
        ops.append(P.Operator(P.REDUCE, f"{tag}-count", "v"))
    faulty = rng.random() < 0.5
    return {
        "tag": tag,
        "plan": P.LogicalPlan(tuple(ops)),
        "n_rows": rng.choice((8, 13, 16, 24)),
        "batch_size": rng.choice((1, 2, 3)),
        "coalesce": rng.random() < 0.5,
        "morsel": rng.choice((4, 8, 16)),
        "shards": rng.choice((2, 3)),
        "concurrency": rng.choice((2, 4)),
        "faulty": faulty,
        "fault_seed": rng.randrange(10_000),
    }


def _backends(case) -> dict:
    be = SleepBackend(KindOracle(), delay_s=0.004, sleep_s=0.0)
    if case["faulty"]:
        # error_rate 0.05 with retries=4: P(exhaust) ~ 3e-7 per call, so
        # random draws never flake on an unlucky fault plan
        be = FlakyBackend(be, error_rate=0.05, seed=case["fault_seed"])
    return {"m*": be}


def _policy(case):
    return rt.CallPolicy(retries=4) if case["faulty"] else None


def _ctx(case, driver, shards, **kw):
    return rt.ExecutionContext(
        backends=_backends(case), default_tier="m*", driver=driver,
        shards=shards, concurrency=case["concurrency"],
        batch_size=case["batch_size"], coalesce=case["coalesce"],
        morsel_size=case["morsel"], call_policy=_policy(case),
        cost_model=CostModel(), **kw)


def run_config(case, driver, shards, query_key=None):
    """Execute the case solo under one (driver, shards) configuration."""
    ctx = _ctx(case, driver, shards)
    try:
        res = ex.execute(case["plan"], tagged_table(case["tag"],
                                                    case["n_rows"]),
                         ctx, query_key=query_key)
        return res, ctx.meter, ctx.cost_model
    finally:
        ctx.close()


# -- byte-comparable projections -----------------------------------------

def fingerprint(res):
    if res.is_reduce:
        return ("reduce", res.scalar)
    return ("table", {k: tuple(map(str, v))
                      for k, v in sorted(res.table.columns.items())})


def log_key(meter):
    """Order-insensitive merged call log: (logical key, tier, latency)."""
    return sorted(zip(meter.call_keys,
                      [t for t, _ in meter.call_log],
                      [round(l, 9) for _, l in meter.call_log]))


def totals_key(meter):
    return {t: (u.calls, round(u.tok_in, 6), round(u.tok_out, 6),
                round(u.usd, 9), round(u.latency_s, 6))
            for t, u in sorted(meter.by_tier.items())}


def assert_equivalent(got, want, *, keys=True):
    """Byte-equality on results, merged log, totals, calibration."""
    res_g, m_g, cm_g = got
    res_w, m_w, cm_w = want
    assert fingerprint(res_g) == fingerprint(res_w)
    if keys:
        assert log_key(m_g) == log_key(m_w)
    else:
        assert sorted((t, round(l, 9)) for t, l in m_g.call_log) == \
            sorted((t, round(l, 9)) for t, l in m_w.call_log)
    assert totals_key(m_g) == totals_key(m_w)
    assert cm_g.calibration_state() == cm_w.calibration_state()


# -- the three properties ------------------------------------------------

def check_driver_invariance(seed: int):
    """Results always match across drivers. Logical key *shapes* are
    driver-internal (the threads pipeline keys per-(morsel, chunk), the
    simulated driver numbers chunks globally), so the log compares as a
    (tier, latency) multiset; and since FlakyBackend draws its fault
    plan off those driver-internal keys, fault *placement* is only
    defined within a driver — fault-free draws byte-compare totals and
    calibration, faulty draws compare their successful calls."""
    case = draw_case(random.Random(seed))
    res_t, m_t, cm_t = run_config(case, "threads", 1)
    res_s, m_s, cm_s = run_config(case, "simulated", 1)
    assert fingerprint(res_t) == fingerprint(res_s)

    def typed_calls(meter):
        return sorted((t, round(l, 9))
                      for op, (t, l) in zip(meter.call_ops, meter.call_log)
                      if op is not None)
    assert typed_calls(m_t) == typed_calls(m_s)
    if not case["faulty"]:
        assert totals_key(m_t) == totals_key(m_s)
        assert cm_t.calibration_state() == cm_s.calibration_state()


def check_shard_invariance(seed: int):
    case = draw_case(random.Random(seed + 10_000))
    # chunk-level key shapes differ across shard counts only when the
    # coalescer is active (per-shard coalescers vs one global); billing
    # and results must match regardless
    coalescing = case["coalesce"] and case["batch_size"] > 1
    assert_equivalent(run_config(case, "threads", case["shards"]),
                      run_config(case, "threads", 1),
                      keys=not coalescing)


def check_admission_invariance(seed: int):
    rng = random.Random(seed + 20_000)
    env = draw_case(rng)
    cases = [env] + [draw_case(rng) for _ in range(2)]
    driver = rng.choice(("simulated", "threads"))
    shards = rng.choice((1, env["shards"]))
    lanes = [rng.choice(("interactive", "batch")) for _ in cases]
    ctl = AdmissionController(
        max_tenant_rows=rng.choice((None, 16, 48)),
        max_queue_depth=rng.choice((None, 8)),
        max_concurrent=rng.choice((1, 2, 3)))
    # env's knobs are server-wide; each case contributes its own plan
    ctx = _ctx(env, driver, shards)
    with QueryServer(ctx, admission=ctl) as srv:
        handles = [srv.submit(c["plan"], tagged_table(c["tag"],
                                                      c["n_rows"]),
                              tenant=f"t{i % 2}", lane=lanes[i])
                   for i, c in enumerate(cases)]
        srv.drain(60)
    for h, c in zip(handles, cases):
        solo_case = dict(c)
        # server-wide knobs override the case's own draw
        for k in ("batch_size", "coalesce", "morsel", "concurrency",
                  "faulty", "fault_seed"):
            solo_case[k] = env[k]
        res, meter, _ = run_config(solo_case, driver, shards,
                                   query_key=h.qid)
        assert fingerprint(h.result()) == fingerprint(res)
        assert log_key(h.meter) == log_key(meter)
        assert totals_key(h.meter) == totals_key(meter)


# -- always-on seeded parametrization ------------------------------------

@pytest.mark.parametrize("seed", SEEDS)
def test_property_driver_invariance(seed):
    check_driver_invariance(seed)


@pytest.mark.parametrize("seed", SEEDS)
def test_property_shard_invariance(seed):
    check_shard_invariance(seed)


@pytest.mark.parametrize("seed", SEEDS)
def test_property_admission_invariance(seed):
    check_admission_invariance(seed)


# -- hypothesis front-end (runs in CI, where the test extra installs) ----

if HAVE_HYPOTHESIS:
    _seeds = st.integers(min_value=0, max_value=2**31 - 1)

    @settings(max_examples=15, deadline=None)
    @given(seed=_seeds)
    def test_hypothesis_driver_invariance(seed):
        check_driver_invariance(seed)

    @settings(max_examples=15, deadline=None)
    @given(seed=_seeds)
    def test_hypothesis_shard_invariance(seed):
        check_shard_invariance(seed)

    @settings(max_examples=10, deadline=None)
    @given(seed=_seeds)
    def test_hypothesis_admission_invariance(seed):
        check_admission_invariance(seed)


# -- cross-feature matrix stress -----------------------------------------

def _strip_marks(key):
    """Logical key minus retry/fallback suffixes: the identity a
    recovered call shares with its never-faulted twin."""
    if key is None:
        return None
    for i, part in enumerate(key):
        if part in (rt.RETRY_KEY_MARK, rt.FALLBACK_KEY_MARK):
            return tuple(key[:i])
    return tuple(key)


def typed_log_key(meter):
    """The successful (typed) entries of the merged log, fault entries
    (op_kind=None) dropped, keyed by the op ordinal (chunk-level key
    shapes are legitimately different across shard counts when the
    coalescer is active). The embed tier's latency is the *measured*
    device-pass wall (not modeled), so embed entries compare on
    identity only."""
    from repro.core import cost as cost_mod
    return sorted((_strip_marks(k)[0], t,
                   None if t == cost_mod.EMBED_TIER_NAME else round(l, 9))
                  for k, op, (t, l) in zip(meter.call_keys, meter.call_ops,
                                           meter.call_log)
                  if op is not None)


def test_cross_feature_matrix_stress():
    """Everything on at once — cascade + coalescing + 10% seeded faults
    with retries + 3-way sharding — stays byte-identical to a healthy
    single-shard cascade run: same results, and the same successful
    calls in the merged log (the faulted attempts are extra op_kind=None
    entries on top, never substitutions)."""
    tag, n_rows, batch = "matrix", 96, 4
    plan = P.LogicalPlan((
        P.Operator(P.FILTER, f"{tag}-keep-0", "v"),
        P.Operator(P.FILTER, f"{tag}-keep-1", "v"),
        P.Operator(P.MAP, f"{tag}-note", "v", "a"),
    ))

    def run(faulty, shards):
        inner = SleepBackend(KindOracle(), delay_s=0.004, sleep_s=0.0)
        be = FlakyBackend(inner, error_rate=0.10, seed=7) if faulty \
            else inner
        emb = EmbeddingOracle(KindOracle())
        router = casc.CascadeRouter(casc.EmbeddingBackend(encoder=emb))
        for op in plan.ops:
            if op.kind in router.KINDS:
                router.set_bands(op, emb.bands_for(op, inner,
                                                   batch_size=batch))
        ctx = rt.ExecutionContext(
            backends={"m*": be}, default_tier="m*", driver="threads",
            shards=shards, concurrency=4, batch_size=batch,
            coalesce=True, morsel_size=16, cascade=router,
            call_policy=rt.CallPolicy(retries=4) if faulty else None,
            cost_model=CostModel())
        try:
            res = ex.execute(plan, tagged_table(tag, n_rows), ctx)
            return res, ctx.meter, be
        finally:
            ctx.close()

    res_h, m_h, _ = run(faulty=False, shards=1)
    res_s, m_s, flaky = run(faulty=True, shards=3)
    assert flaky.faults_injected > 0          # the chaos really fired
    assert fingerprint(res_s) == fingerprint(res_h)
    assert typed_log_key(m_s) == typed_log_key(m_h)
    # fault entries are additive: more calls billed, same calls answered
    assert m_s.total.calls == m_h.total.calls + flaky.faults_injected
