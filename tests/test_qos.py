"""Multi-tenant QoS: AdmissionController caps, lanes, and the
makespan-gated deadline check on the QueryServer.

Determinism strategy: ordering assertions never race real time — a
:class:`GateBackend` blocks every backend call on an explicit event, so
the test controls exactly when a running query can finish and what is
queued behind it when it does. Deadline-gate tests run on the simulated
driver with an empty server, where ``admission_estimate`` is a pure
function of (plan, rows, occupancy snapshot) and denial decisions are
bit-reproducible across fresh servers.
"""
import threading

import pytest

import repro.core.runtime as rt
from repro.analysis import qerror
from repro.core import plan as plan_ir
from repro.core.cost_model import CostModel
from repro.launch.query_server import (AdmissionController, AdmissionError,
                                       QueryServer)
from repro.launch.serve import parse_admission
from repro.testing import (KindOracle, SleepBackend, result_fingerprint,
                           tagged_plan, tagged_table)

pytestmark = pytest.mark.qos

DELAY = 0.004


class GateBackend:
    """SleepBackend wrapper whose calls block until :meth:`open` — lets a
    test pin a query in the 'running' state for as long as it needs."""

    def __init__(self, inner):
        self.inner = inner
        self.tier = inner.tier
        self._gate = threading.Event()

    def open(self):
        self._gate.set()

    def run_values(self, op, values, meter=None, batch_size=1):
        assert self._gate.wait(30.0), "gate never opened"
        return self.inner.run_values(op, values, meter=meter,
                                     batch_size=batch_size)


def _backends(delay_s=DELAY, gated=False):
    be = SleepBackend(KindOracle(), delay_s=delay_s)
    if gated:
        be = GateBackend(be)
    return {"m*": be}, be


def _ctx(backends, **kw):
    kw.setdefault("default_tier", "m*")
    kw.setdefault("driver", "threads")
    kw.setdefault("concurrency", 4)
    kw.setdefault("morsel_size", 64)
    return rt.ExecutionContext(backends=backends, **kw)


def _wait(pred, timeout_s=10.0):
    """Poll a condition instead of sleeping a guessed wall time."""
    import time
    deadline = time.perf_counter() + timeout_s
    while time.perf_counter() < deadline:
        if pred():
            return True
        time.sleep(0.002)
    return False


# -- baseline / stats shape ----------------------------------------------

def test_legacy_server_has_no_qos_key():
    backends, _ = _backends()
    with QueryServer(_ctx(backends)) as srv:
        h = srv.submit(tagged_plan("legacy"), tagged_table("legacy", 8))
        assert h.result(10).is_reduce is False
        stats = srv.stats()
    assert "qos" not in stats
    assert h.state == "completed"


def test_qos_stats_shape():
    backends, _ = _backends()
    ctl = AdmissionController(max_tenant_rows=64, max_queue_depth=4,
                              max_concurrent=2)
    with QueryServer(_ctx(backends), admission=ctl) as srv:
        srv.submit(tagged_plan("shape"), tagged_table("shape", 8),
                   tenant="t0", lane="interactive").result(10)
        qos = srv.stats()["qos"]
    assert qos["served_by_lane"] == {"interactive": 1, "batch": 0}
    assert qos["rejected_backpressure"] == 0
    assert qos["rejected_deadline"] == 0
    assert qos["max_tenant_rows"] == 64
    assert qos["running"] == 0 and qos["queued"] == {"interactive": 0,
                                                     "batch": 0}


def test_controller_binds_once():
    backends, _ = _backends()
    ctl = AdmissionController()
    with QueryServer(_ctx(backends), admission=ctl):
        with pytest.raises(RuntimeError, match="already bound"):
            QueryServer(_ctx(backends), admission=ctl)


def test_unknown_lane_rejected_eagerly():
    backends, _ = _backends()
    with QueryServer(_ctx(backends)) as srv:
        with pytest.raises(ValueError, match="unknown lane"):
            srv.submit(tagged_plan("x"), tagged_table("x", 4),
                       lane="sidechannel")
    with pytest.raises(ValueError, match="unknown lane"):
        AdmissionController(default_lane="express")


# -- tenant caps ---------------------------------------------------------

def test_tenant_row_cap_queues_second_query():
    backends, gate = _backends(gated=True)
    ctl = AdmissionController(max_tenant_rows=10, max_concurrent=4)
    with QueryServer(_ctx(backends), admission=ctl) as srv:
        a = srv.submit(tagged_plan("a"), tagged_table("a", 8), tenant="t")
        assert _wait(lambda: a.state == "running")
        b = srv.submit(tagged_plan("b"), tagged_table("b", 8), tenant="t")
        # 8 + 8 > 10: b must wait for a even though a slot is free
        assert b.state == "queued"
        gate.open()
        srv.drain(30)
    assert a.state == "completed" and b.state == "completed"
    assert b.started_s >= a.finished_s


def test_oversized_query_admitted_when_tenant_idle():
    backends, _ = _backends()
    ctl = AdmissionController(max_tenant_rows=4)
    with QueryServer(_ctx(backends), admission=ctl) as srv:
        h = srv.submit(tagged_plan("big"), tagged_table("big", 32),
                       tenant="t")
        assert h.result(10) is not None
    assert h.state == "completed"


def test_tenant_cap_does_not_block_other_tenant():
    backends, gate = _backends(gated=True)
    ctl = AdmissionController(max_tenant_rows=10, max_concurrent=4)
    with QueryServer(_ctx(backends), admission=ctl) as srv:
        a = srv.submit(tagged_plan("a"), tagged_table("a", 8), tenant="t")
        assert _wait(lambda: a.state == "running")
        b = srv.submit(tagged_plan("b"), tagged_table("b", 8), tenant="t")
        c = srv.submit(tagged_plan("c"), tagged_table("c", 8), tenant="u")
        # t is capped, u is not: c starts (blocked head yields the slot)
        assert _wait(lambda: c.state == "running")
        assert b.state == "queued"
        gate.open()
        srv.drain(30)
    assert {a.state, b.state, c.state} == {"completed"}


def test_queue_depth_backpressure_sheds_newest():
    backends, gate = _backends(gated=True)
    ctl = AdmissionController(max_concurrent=1, max_queue_depth=1)
    with QueryServer(_ctx(backends), admission=ctl) as srv:
        a = srv.submit(tagged_plan("a"), tagged_table("a", 4), tenant="t")
        assert _wait(lambda: a.state == "running")
        b = srv.submit(tagged_plan("b"), tagged_table("b", 4), tenant="t")
        c = srv.submit(tagged_plan("c"), tagged_table("c", 4), tenant="t")
        assert b.state == "queued"          # FIFO is sacred:
        assert c.rejected()                 # the NEW arrival is shed
        with pytest.raises(AdmissionError) as ei:
            c.result(1)
        assert ei.value.reason == "backpressure"
        gate.open()
        srv.drain(30)
        assert srv.stats()["qos"]["rejected_backpressure"] == 1
    assert a.state == "completed" and b.state == "completed"


def test_queue_depth_is_per_tenant():
    backends, gate = _backends(gated=True)
    ctl = AdmissionController(max_concurrent=1, max_queue_depth=1)
    with QueryServer(_ctx(backends), admission=ctl) as srv:
        a = srv.submit(tagged_plan("a"), tagged_table("a", 4), tenant="t")
        assert _wait(lambda: a.state == "running")
        srv.submit(tagged_plan("b"), tagged_table("b", 4), tenant="t")
        d = srv.submit(tagged_plan("d"), tagged_table("d", 4), tenant="u")
        # u's allowance is separate from t's spent one
        assert d.state == "queued" and not d.rejected()
        gate.open()
        srv.drain(30)
    assert d.state == "completed"


# -- priority lanes ------------------------------------------------------

def test_interactive_preempts_batch_at_dequeue():
    backends, gate = _backends(gated=True)
    ctl = AdmissionController(max_concurrent=1)
    with QueryServer(_ctx(backends), admission=ctl) as srv:
        a = srv.submit(tagged_plan("a"), tagged_table("a", 4), lane="batch")
        assert _wait(lambda: a.state == "running")
        b2 = srv.submit(tagged_plan("b2"), tagged_table("b2", 4),
                        lane="batch")
        i1 = srv.submit(tagged_plan("i1"), tagged_table("i1", 4),
                        lane="interactive")
        assert b2.state == "queued" and i1.state == "queued"
        gate.open()
        srv.drain(30)
    # i1 was submitted after b2 but starts first (lane preemption) —
    # and only once a finished (no mid-query preemption)
    assert i1.started_s >= a.finished_s
    assert b2.started_s >= i1.finished_s


def test_fifo_within_lane():
    backends, gate = _backends(gated=True)
    ctl = AdmissionController(max_concurrent=1)
    with QueryServer(_ctx(backends), admission=ctl) as srv:
        first = srv.submit(tagged_plan("q0"), tagged_table("q0", 4))
        assert _wait(lambda: first.state == "running")
        rest = [srv.submit(tagged_plan(f"q{i}"), tagged_table(f"q{i}", 4))
                for i in range(1, 5)]
        gate.open()
        srv.drain(30)
    starts = [h.started_s for h in [first] + rest]
    assert starts == sorted(starts)


def test_no_mid_morsel_preemption():
    backends, gate = _backends(gated=True)
    ctl = AdmissionController(max_concurrent=1)
    with QueryServer(_ctx(backends), admission=ctl) as srv:
        batch = srv.submit(tagged_plan("bg"), tagged_table("bg", 4),
                           lane="batch")
        assert _wait(lambda: batch.state == "running")
        inter = srv.submit(tagged_plan("fg"), tagged_table("fg", 4),
                           lane="interactive")
        # the running batch query is never interrupted: interactive
        # priority acts at dequeue time only
        assert inter.state == "queued"
        gate.open()
        srv.drain(30)
    assert inter.started_s >= batch.finished_s


# -- makespan gate -------------------------------------------------------

def _sim_ctx(**kw):
    backends, _ = _backends()
    kw.setdefault("driver", "simulated")
    kw.setdefault("cost_model", CostModel())
    return _ctx(backends, **kw)


def test_deadline_denial_is_deterministic():
    preds = []
    for _ in range(3):
        ctl = AdmissionController()
        with QueryServer(_sim_ctx(), admission=ctl) as srv:
            h = srv.submit(tagged_plan("dl"), tagged_table("dl", 256),
                           deadline_s=1e-9)
            assert h.rejected()
            with pytest.raises(AdmissionError) as ei:
                h.result(1)
            assert ei.value.reason == "deadline"
            preds.append(h.predicted_completion_s)
            assert srv.stats()["qos"]["rejected_deadline"] == 1
    # same plan, same empty server -> bit-identical prediction + decision
    assert preds[0] == preds[1] == preds[2]


def test_generous_deadline_admitted():
    ctl = AdmissionController()
    with QueryServer(_sim_ctx(), admission=ctl) as srv:
        h = srv.submit(tagged_plan("ok"), tagged_table("ok", 16),
                       deadline_s=3600.0)
        assert not h.rejected()
        h.result(10)
    assert h.state == "completed"
    assert h.predicted_makespan_s is not None
    assert h.predicted_completion_s == h.predicted_makespan_s  # empty queue


def test_deadline_ignored_without_cost_model():
    backends, _ = _backends()
    ctl = AdmissionController()
    with QueryServer(_ctx(backends), admission=ctl) as srv:
        h = srv.submit(tagged_plan("nm"), tagged_table("nm", 8),
                       deadline_s=1e-9)
        assert not h.rejected()
        h.result(10)
    assert h.predicted_makespan_s is None


def test_predicted_completion_includes_queue_wait():
    backends, gate = _backends(gated=True)
    ctl = AdmissionController(max_concurrent=1)
    ctx = _ctx(backends, cost_model=CostModel())
    with QueryServer(ctx, admission=ctl) as srv:
        a = srv.submit(tagged_plan("a"), tagged_table("a", 16))
        assert _wait(lambda: a.state == "running")
        b = srv.submit(tagged_plan("b"), tagged_table("b", 16))
        c = srv.submit(tagged_plan("c"), tagged_table("c", 16))
        assert b.state == "queued"
        # c's completion estimate carries b's queued makespan as wait
        assert c.predicted_completion_s > c.predicted_makespan_s
        gate.open()
        srv.drain(30)


def test_admission_estimate_grows_with_occupancy():
    model = CostModel()
    plan = tagged_plan("occ")
    idle = model.admission_estimate(plan, 32)
    busy = model.admission_estimate(plan, 32,
                                    occupancy={"m*": [5.0, 5.0, 5.0, 5.0]})
    assert busy > idle


def test_seed_occupancy_shifts_event_clock():
    sched = rt.EventScheduler(concurrency=2)
    sched.seed_occupancy({"m*": [1.0, 2.0]})
    sched.submit("m*", 0.5, 0.0)
    sched.barrier()
    # both seeded slots busy; the new job waits for the earlier one
    assert sched.makespan == pytest.approx(2.0)


# -- calibration feedback ------------------------------------------------

def test_observe_makespan_feedback_recorded():
    ctl = AdmissionController()
    ctx = _sim_ctx()
    with QueryServer(ctx, admission=ctl) as srv:
        for i in range(3):
            srv.submit(tagged_plan(f"f{i}"), tagged_table(f"f{i}", 16)
                       ).result(10)
    rep = ctx.cost_model.admission_report()
    assert rep["observations"] == 3
    assert rep["qerr_last"] >= 1.0 and rep["qerr_max"] >= rep["qerr_last"]


def test_observe_makespan_converges():
    # stationary workload: raw replay says 1.0s, reality says 2.5s.
    # the corrected prediction (raw * ratio) must converge on reality.
    model = CostModel()
    qerrs = []
    for _ in range(8):
        pred = 1.0 * model.admission_report()["ratio"]
        model.observe_makespan(pred, 2.5)
        qerrs.append(model.admission_report()["qerr_last"])
    assert qerrs[0] == pytest.approx(2.5)
    assert qerrs[-1] == pytest.approx(1.0, abs=0.05)
    assert model.admission_report()["ratio"] == pytest.approx(2.5, rel=0.1)


def test_observe_makespan_keeps_calibration_state():
    # the whole-plan admission EWMA must stay OUT of the per-(op, tier)
    # calibration state the invariance suites byte-compare
    model = CostModel()
    before = model.calibration_state()
    model.observe_makespan(1.0, 7.0)
    assert model.calibration_state() == before
    model.reset_calibration()
    assert model.admission_report()["observations"] == 0


def test_explain_cost_reports_admission_accuracy():
    ctx = _sim_ctx()
    ctl = AdmissionController()
    with QueryServer(ctx, admission=ctl) as srv:
        srv.submit(tagged_plan("xc"), tagged_table("xc", 16)).result(10)
    text = qerror.render_text(ctx.cost_model)
    assert "admission makespan: 1 observations" in text
    import json
    doc = json.loads(qerror.to_json(ctx.cost_model))
    assert doc["admission"]["observations"] == 1
    # and absent before any feedback
    assert "admission" not in json.loads(qerror.to_json(CostModel()))


# -- solo identity under admission ---------------------------------------

def _solo(plan, table, **ctx_kw):
    from repro.core import executor as ex
    backends, _ = _backends()
    ctx = _ctx(backends, **ctx_kw)
    try:
        return ex.execute(plan, table, ctx)
    finally:
        ctx.close()


def _meter_key(meter):
    return {t: (u.calls, round(u.tok_in, 6), round(u.tok_out, 6),
                round(u.usd, 9), round(u.latency_s, 6))
            for t, u in sorted(meter.by_tier.items())}


@pytest.mark.parametrize("driver", ["simulated", "threads"])
@pytest.mark.parametrize("shards", [1, 2])
def test_admitted_queries_identical_to_solo(driver, shards):
    backends, _ = _backends()
    ctl = AdmissionController(max_tenant_rows=24, max_queue_depth=8,
                              max_concurrent=2)
    specs = [(f"s{i}", "t0" if i % 2 else "t1",
              "interactive" if i % 3 == 0 else "batch", i % 2 == 0)
             for i in range(6)]
    ctx = _ctx(backends, driver=driver, shards=shards, morsel_size=8,
               cost_model=CostModel())
    with QueryServer(ctx, admission=ctl) as srv:
        handles = [srv.submit(tagged_plan(tag, reduce_tail=red),
                              tagged_table(tag, 16), tenant=ten, lane=lane)
                   for tag, ten, lane, red in specs]
        srv.drain(60)
    for h, (tag, _, _, red) in zip(handles, specs):
        solo = _solo(tagged_plan(tag, reduce_tail=red),
                     tagged_table(tag, 16), driver=driver, shards=shards,
                     morsel_size=8)
        assert result_fingerprint(h.result()) == result_fingerprint(solo)
        assert _meter_key(h.meter) == _meter_key(solo.meter)


# -- lifecycle under load ------------------------------------------------

def test_drain_waits_for_queued_queries():
    backends, _ = _backends()
    ctl = AdmissionController(max_concurrent=1)
    with QueryServer(_ctx(backends), admission=ctl) as srv:
        hs = [srv.submit(tagged_plan(f"d{i}"), tagged_table(f"d{i}", 8))
              for i in range(5)]
        srv.drain(60)
        assert all(h.state == "completed" for h in hs)


def test_close_completes_queued_queries():
    backends, _ = _backends()
    ctl = AdmissionController(max_concurrent=1)
    srv = QueryServer(_ctx(backends), admission=ctl)
    hs = [srv.submit(tagged_plan(f"c{i}"), tagged_table(f"c{i}", 8))
          for i in range(4)]
    srv.close()
    assert all(h.state == "completed" for h in hs)


def test_failure_releases_capacity():
    class SelectiveBoomOracle:
        """KindOracle that explodes on values carrying the 'bad' tag —
        one query fails, co-tenant queries are untouched."""

        def answer(self, op, value):
            if "bad" in str(value):
                raise RuntimeError("boom")
            return True if op.kind == plan_ir.FILTER else f"A:{value}"

        def answer_reduce(self, op, values):
            return len(list(values))

    backends = {"m*": SleepBackend(SelectiveBoomOracle(), delay_s=DELAY)}
    ctl = AdmissionController(max_concurrent=1, max_tenant_rows=8)
    with QueryServer(_ctx(backends), admission=ctl) as srv:
        h_bad = srv.submit(tagged_plan("bad"), tagged_table("bad", 4),
                           tenant="t")
        with pytest.raises(RuntimeError, match="boom"):
            h_bad.result(10)
        h_ok = srv.submit(tagged_plan("ok2"), tagged_table("ok2", 4),
                          tenant="t")
        assert h_ok.result(10) is not None
        qos = srv.stats()["qos"]
    assert h_bad.state == "failed" and h_ok.state == "completed"
    assert qos["running"] == 0 and qos["tenant_rows"] == {}


# -- serve launcher plumbing ---------------------------------------------

def test_parse_admission_specs():
    assert parse_admission("") is None
    ctl = parse_admission("on")
    assert isinstance(ctl, AdmissionController)
    assert ctl.max_tenant_rows is None
    ctl = parse_admission("rows=64,depth=4,conc=3")
    assert (ctl.max_tenant_rows, ctl.max_queue_depth,
            ctl.max_concurrent) == (64, 4, 3)
    with pytest.raises(ValueError, match="bad --admission"):
        parse_admission("turbo=9")
