"""Process shard workers (`distributed.process_workers`): the `procs`
driver's invariance, death-ladder, and serialization contracts.

Naming: every test here matches `-k proc` (the CI proc-smoke job).
"""
import os
import pickle
import signal
import threading
import time

import pytest

from repro import testing as tg
from repro.core import backends as bk
from repro.core import executor as ex
from repro.core import plan as plan_ir
from repro.core import runtime as rt
from repro.distributed.morsel_shards import ShardedDispatcher, _compose
from repro.distributed.process_workers import (ProcessShardDispatcher,
                                               shippable_backends)

pytestmark = pytest.mark.proc

MORSEL = 8


def _totals(meter):
    return {t: (u.calls, round(u.tok_in, 6), round(u.tok_out, 6),
                round(u.usd, 9), round(u.latency_s, 6))
            for t, u in sorted(meter.by_tier.items())}


def _log_key(meter):
    return sorted(zip(meter.call_keys,
                      [t for t, _ in meter.call_log],
                      [round(l, 9) for _, l in meter.call_log]))


def _run_inproc(plan, table, backend, driver, **kw):
    meter = bk.UsageMeter()
    res = ex.execute(plan, table, {"m*": backend}, default_tier="m*",
                     batch_size=1, morsel_size=MORSEL, meter=meter,
                     driver=driver, **kw)
    return res, meter


def _run_procs(plan, table, backend, n, cache=None, **disp_kw):
    meter = bk.UsageMeter()
    disp = ShardedDispatcher(shards=n, driver="procs", concurrency=4,
                             backends={"m*": backend}, **disp_kw)
    try:
        res = ex.execute(plan, table, {"m*": backend}, default_tier="m*",
                         batch_size=1, morsel_size=MORSEL, meter=meter,
                         cache=cache, dispatcher=disp)
        live = disp.live_shards()
        stats = [d.client.stats.copy() for d in disp._inner]
    finally:
        disp.close()
    return res, meter, live, stats


# -- invariance ------------------------------------------------------------

def test_proc_shard_count_invariance_results_and_meters():
    """procs in {1, 2, 4}: results and per-tier totals byte-identical to
    both in-process drivers; merged logical-key call logs byte-identical
    to the threads driver (same chunked key shapes)."""
    table, plan = tg.tagged_table("pi", 32), tg.tagged_plan("pi")

    def mk():
        return tg.SleepBackend(tg.KindOracle(), delay_s=0.01, sleep_s=0.0)

    res_sim, m_sim = _run_inproc(plan, table, mk(), "simulated")
    res_thr, m_thr = _run_inproc(plan, table, mk(), "threads")
    ref_fp = tg.result_fingerprint(res_sim)
    assert tg.result_fingerprint(res_thr) == ref_fp
    assert _totals(m_thr) == _totals(m_sim)
    for n in (1, 2, 4):
        res, m, live, _ = _run_procs(plan, table, mk(), n)
        assert tg.result_fingerprint(res) == ref_fp
        assert live == list(range(n))
        assert _totals(m) == _totals(m_sim)
        assert _log_key(m) == _log_key(m_thr)


def test_proc_udf_steps_run_in_worker_processes():
    """A compiled-UDF operator executes over the wire (client udf stats
    move) and produces the in-process results/meters byte-for-byte."""
    table = tg.tagged_table("pu", 32)
    plan = plan_ir.LogicalPlan((
        plan_ir.Operator(plan_ir.FILTER, "keep-pu", "v"),
        plan_ir.Operator(plan_ir.MAP, "annotate-pu", "v", "a"),
        plan_ir.Operator(plan_ir.MAP, "shout", "a", "b",
                         udf="lambda x: str(x).upper()"),
    ))

    def fp(res):
        return (tuple(res.table.columns[ex.ROWID]),
                tuple(map(str, res.table.columns["b"])))

    res_thr, m_thr = _run_inproc(
        plan, table,
        tg.SleepBackend(tg.KindOracle(), delay_s=0.01, sleep_s=0.0),
        "threads")
    res, m, _, stats = _run_procs(
        plan, table,
        tg.SleepBackend(tg.KindOracle(), delay_s=0.01, sleep_s=0.0), 2)
    assert fp(res) == fp(res_thr)
    assert _totals(m) == _totals(m_thr)
    assert _log_key(m) == _log_key(m_thr)
    assert sum(s["udf"] for s in stats) >= 4      # one per UDF morsel
    assert sum(s["llm"] for s in stats) > 0


# -- death ladder ----------------------------------------------------------

class SuicideBackend(tg.SleepBackend):
    """SIGKILLs its own *worker* process the first time it sees the
    trigger value (one-shot via a flag file, so the survivor's retry of
    the same logical call proceeds; never fires in the coordinator)."""

    def __init__(self, oracle, flag_path, parent_pid, trigger, **kw):
        super().__init__(oracle, **kw)
        self.flag_path = flag_path
        self.parent_pid = parent_pid
        self.trigger = trigger

    def run_values(self, op, values, meter=None, batch_size=1):
        if (os.getpid() != self.parent_pid
                and any(str(v) == self.trigger for v in values)
                and not os.path.exists(self.flag_path)):
            open(self.flag_path, "w").close()
            os.kill(os.getpid(), signal.SIGKILL)
        return super().run_values(op, values, meter=meter,
                                  batch_size=batch_size)


def test_proc_worker_sigkill_requeues_and_bills_exactly_once(tmp_path):
    """A SIGKILLed worker surfaces as the PR 8 contract: its shard goes
    dead, pending morsels requeue onto the survivor, and with the shared
    single-flight cache the merged totals and logical-key log are
    byte-identical to a healthy run — in-flight calls that died unbilled
    bill once on retry, completed chunks resolve as cache hits."""
    table, plan = tg.tagged_table("pk", 32), tg.tagged_plan("pk")
    healthy = tg.SleepBackend(tg.KindOracle(), delay_s=0.01, sleep_s=0.0)
    res_h, m_h, live_h, _ = _run_procs(plan, table, healthy, 2,
                                       cache=rt.OutputCache())
    assert live_h == [0, 1]

    sb = SuicideBackend(tg.KindOracle(), str(tmp_path / "boom"),
                        os.getpid(), "pk-17", delay_s=0.01, sleep_s=0.0)
    res_k, m_k, live_k, _ = _run_procs(plan, table, sb, 2,
                                       cache=rt.OutputCache())
    assert len(live_k) == 1                       # one worker died
    assert tg.result_fingerprint(res_k) == tg.result_fingerprint(res_h)
    assert _totals(m_k) == _totals(m_h)           # exactly-once billing
    assert _log_key(m_k) == _log_key(m_h)


def test_proc_missed_heartbeat_declares_shard_dead():
    """SIGSTOP freezes a worker without closing its pipe: only the
    heartbeat ladder can catch it. The monitor declares the shard dead,
    SIGKILLs the stopped process, and execution completes on the
    survivor."""
    table, plan = tg.tagged_table("ph", 32), tg.tagged_plan("ph")
    backend = tg.SleepBackend(tg.KindOracle(), delay_s=0.01, sleep_s=0.0)
    meter = bk.UsageMeter()
    disp = ShardedDispatcher(shards=2, driver="procs", concurrency=4,
                             backends={"m*": backend},
                             heartbeat_s=0.05, heartbeat_timeout_s=0.5)
    try:
        os.kill(disp._inner[0].client.pid, signal.SIGSTOP)
        res = ex.execute(plan, table, {"m*": backend}, default_tier="m*",
                         batch_size=1, morsel_size=MORSEL, meter=meter,
                         cache=rt.OutputCache(), dispatcher=disp)
        deadline = time.perf_counter() + 10.0
        while not disp.is_dead(0) and time.perf_counter() < deadline:
            time.sleep(0.05)
        assert disp.is_dead(0)
        assert disp.live_shards() == [1]
    finally:
        disp.close()
    ref, m_ref = _run_inproc(plan, table, backend, "simulated")
    assert tg.result_fingerprint(res) == tg.result_fingerprint(ref)
    assert _totals(meter) == _totals(m_ref)


def test_proc_graceful_close_terminates_workers():
    disp = ShardedDispatcher(shards=2, driver="procs", concurrency=4,
                             backends={"m*": tg.SleepBackend(
                                 tg.KindOracle(), delay_s=0.0)})
    procs = [d.client._proc for d in disp._inner]
    assert all(p.is_alive() for p in procs)
    disp.close()
    assert all(not p.is_alive() for p in procs)
    disp.close()                                  # idempotent


# -- chaos over the wire ---------------------------------------------------

def test_proc_chaos_run_matches_in_process_chaos():
    """FlakyBackend fault plans key off content-hashed logical identity,
    so a pickled copy in a worker draws the same plan: a retried chaos
    run over procs produces the threads driver's results, totals, and
    merged log byte-for-byte (the CallPolicy stays coordinator-side)."""
    table, plan = tg.tagged_table("pc", 32), tg.tagged_plan("pc")
    policy = rt.CallPolicy(retries=3)

    def mk():
        return tg.FlakyBackend(
            tg.SleepBackend(tg.KindOracle(), delay_s=0.01, sleep_s=0.0),
            error_rate=0.2, seed=7)

    res_thr, m_thr = _run_inproc(plan, table, mk(), "threads",
                                 call_policy=policy)
    meter = bk.UsageMeter()
    backend = mk()
    ctx = rt.ExecutionContext(backends={"m*": backend}, default_tier="m*",
                              batch_size=1, morsel_size=MORSEL,
                              meter=meter, procs=2, call_policy=policy)
    disp = ctx.make_dispatcher()
    try:
        res = ex.execute(plan, table, ctx, dispatcher=disp)
    finally:
        disp.close()
    assert tg.result_fingerprint(res) == tg.result_fingerprint(res_thr)
    assert _totals(meter) == _totals(m_thr)
    assert _log_key(meter) == _log_key(m_thr)


# -- serialization boundary ------------------------------------------------

def test_proc_fakes_pickle_roundtrip_and_seed_stability():
    oracle = tg.KindOracle()
    op = plan_ir.Operator(plan_ir.MAP, "annotate", "v", "a")
    sb = tg.SleepBackend(oracle, delay_s=0.01, sleep_s=0.0)
    sb2 = pickle.loads(pickle.dumps(sb))
    assert sb2.run_values(op, ["x"]) == sb.run_values(op, ["x"])

    gb = tg.GilBoundBackend(oracle, work_s=0.0)
    gb2 = pickle.loads(pickle.dumps(gb))
    assert gb2.run_values(op, ["x"]) == gb.run_values(op, ["x"])

    fb = tg.FlakyBackend(sb, error_rate=0.5, seed=3)
    fb2 = pickle.loads(pickle.dumps(fb))

    def draws(b):
        out = []
        for i in range(16):
            m = bk.UsageMeter()
            with m.keyed((0, i)):
                try:
                    b.run_values(op, [f"v{i}"], meter=m)
                    out.append("ok")
                except rt.TransientCallError:
                    out.append("err")
        return out

    assert draws(fb2) == draws(fb)                # same fault plan
    assert "err" in draws(fb) and "ok" in draws(fb)

    eo = tg.EmbeddingOracle(oracle, seed=5)
    eo2 = pickle.loads(pickle.dumps(eo))
    import numpy as np
    np.testing.assert_array_equal(eo2.encode_values(op, ["a", "b"]),
                                  eo.encode_values(op, ["a", "b"]))


def test_proc_usage_meter_pickles_with_logs_and_keys():
    m = bk.UsageMeter()
    with m.keyed((1, 2)):
        m.record("m*", bk.Usage(calls=2, tok_in=16.0, tok_out=8.0,
                                usd=0.01, latency_s=0.2),
                 per_call_latency_s=[0.1, 0.1], op_kind=plan_ir.MAP)
    m2 = pickle.loads(pickle.dumps(m))
    assert _totals(m2) == _totals(m)
    assert m2.call_log == m.call_log
    assert m2.call_keys == m.call_keys
    assert m2.call_ops == m.call_ops
    # lock and thread-local state are rebuilt per process
    with m2.keyed((9,)):
        m2.record("m*", bk.Usage(calls=1, latency_s=0.1))
    assert m2.call_keys[-1] == (9, 0)


def test_proc_unpicklable_backends_stay_coordinator_side():
    """A backend that cannot pickle (e.g. engine-backed) is not shipped;
    its calls run in-process through the inherited threads path, and the
    run still completes with correct results."""
    class Unpicklable(tg.SleepBackend):
        def __init__(self, *a, **kw):
            super().__init__(*a, **kw)
            self.hostage = lambda: None           # defeats pickling

        def __getstate__(self):
            raise TypeError("cannot pickle engine state")

    backend = Unpicklable(tg.KindOracle(), delay_s=0.01, sleep_s=0.0)
    assert shippable_backends({"m*": backend}) == {}
    table, plan = tg.tagged_table("px", 16), tg.tagged_plan("px")
    res_ref, m_ref = _run_inproc(plan, table, backend, "simulated")
    res, m, _, stats = _run_procs(plan, table, backend, 2)
    assert tg.result_fingerprint(res) == tg.result_fingerprint(res_ref)
    assert _totals(m) == _totals(m_ref)
    assert sum(s["llm"] for s in stats) == 0      # nothing went remote


# -- occupancy (satellite bugfix) ------------------------------------------

def test_proc_sharded_simulated_occupancy_merges_base_tiers():
    disp = ShardedDispatcher(shards=2, driver="simulated", concurrency=4)
    try:
        assert disp.occupancy() == {}
        disp._sched.submit(_compose(0, "m*"), 5.0)
        disp._sched.submit(_compose(1, "m*"), 3.0)
        disp._sched.submit(_compose(0, "m2"), 1.0)
        occ = disp.occupancy()
        assert occ["m*"] == [pytest.approx(3.0), pytest.approx(5.0)]
        assert occ["m2"] == [pytest.approx(1.0)]
    finally:
        disp.close()


def test_proc_threads_occupancy_tracks_inflight_calls():
    disp = rt.ThreadPoolDispatcher(concurrency=4)
    release = threading.Event()
    started = threading.Event()

    def thunk():
        started.set()
        release.wait(5.0)
        return []

    try:
        assert disp.occupancy() == {}
        fan = disp.fanout("m*")
        runner = threading.Thread(target=fan, args=([thunk],))
        runner.start()
        assert started.wait(5.0)
        occ = disp.occupancy()
        assert list(occ) == ["m*"] and len(occ["m*"]) == 1
        assert occ["m*"][0] > 0.0
        release.set()
        runner.join(5.0)
        assert disp.occupancy() == {}
    finally:
        release.set()
        disp.close()


# -- wiring ----------------------------------------------------------------

def test_proc_serve_parser_and_context_wiring():
    from repro.launch import serve
    ap = serve.build_parser()
    assert ap.parse_args([]).procs == 0
    assert ap.parse_args(["--procs", "4"]).procs == 4

    with pytest.raises(ValueError, match="mutually exclusive"):
        rt.ExecutionContext(backends={}, procs=2, shards=2) \
            .make_dispatcher()

    backend = tg.SleepBackend(tg.KindOracle(), delay_s=0.0)
    ctx = rt.ExecutionContext(backends={"m*": backend}, procs=3,
                              per_tier_concurrency={"m*": 7})
    disp = ctx.make_dispatcher()
    try:
        assert isinstance(disp, ShardedDispatcher)
        assert disp.n_shards == 3 and disp.kind == "procs"
        assert all(isinstance(d, ProcessShardDispatcher)
                   for d in disp._inner)
        assert [disp.shard_of(i) for i in range(5)] == [0, 1, 2, 0, 1]
        assert [disp.shard_quota("m*", s) for s in range(3)] == [3, 2, 2]
    finally:
        disp.close()
