"""Training substrate: optimization behaviour, grad accumulation,
compression, fault-tolerant supervision, straggler detection, sharding
rules, roofline HLO parsing, semhash invariances."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import common as cm
from repro.models import registry
from repro.training import compression, optimizer as opt_mod, train_loop


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced(get_config("qwen2-0.5b"))
    b = registry.build(cfg)
    state = train_loop.init_train_state(b, jax.random.PRNGKey(0))
    return cfg, b, state


def batch_of(cfg, step, bsz=4, seq=32):
    k = jax.random.PRNGKey(step)
    return {"tokens": jax.random.randint(k, (bsz, seq), 0, cfg.vocab_size)}


def test_loss_decreases(tiny):
    cfg, b, state = tiny
    step = jax.jit(train_loop.make_train_step(
        b, opt_mod.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=30)))
    fixed = batch_of(cfg, 0)
    losses = []
    for i in range(12):
        state, m = step(state, fixed)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9


def test_grad_accumulation_equivalence(tiny):
    """microbatches=2 must equal microbatches=1 on the same global batch."""
    cfg, b, state = tiny
    cfgo = opt_mod.AdamWConfig(warmup_steps=1, total_steps=10)
    s1 = jax.jit(train_loop.make_train_step(b, cfgo, microbatches=1,
                                            dtype=jnp.float32))
    s2 = jax.jit(train_loop.make_train_step(b, cfgo, microbatches=2,
                                            dtype=jnp.float32))
    batch = batch_of(cfg, 5, bsz=4)
    st1, m1 = s1(state, batch)
    st2, m2 = s2(state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    pa = jax.tree.leaves(st1["params"], is_leaf=cm.is_param)
    pb = jax.tree.leaves(st2["params"], is_leaf=cm.is_param)
    for x, y in zip(pa, pb):
        np.testing.assert_allclose(np.asarray(x.value, np.float32),
                                   np.asarray(y.value, np.float32),
                                   atol=1e-5)


def test_lr_schedule_shape():
    cfg = opt_mod.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                              min_lr_ratio=0.1)
    lrs = [float(opt_mod.schedule(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, abs=1e-6)


def test_gradient_clipping():
    cfg = opt_mod.AdamWConfig(clip_norm=1.0, warmup_steps=0, total_steps=10)
    params = {"w": cm.Param(jnp.zeros((4,)), ("embed",))}
    grads = {"w": cm.Param(jnp.full((4,), 100.0), ("embed",))}
    opt = opt_mod.init_state(params)
    _, _, metrics = opt_mod.apply_updates(
        cfg, cm.values(params), cm.values(grads),
        jax.tree.map(lambda p: p.value if cm.is_param(p) else p, opt,
                     is_leaf=cm.is_param))
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_int8_compression_error_bounded():
    g = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0
    out = compression.compress_decompress({"g": g})["g"]
    err = jnp.max(jnp.abs(out - g))
    scale = jnp.max(jnp.abs(g)) / 127.0
    assert float(err) <= float(scale) * 1.01


def test_compression_roundtrip_shapes():
    for shape in [(7,), (3, 5), (2, 3, 4)]:
        g = jax.random.normal(jax.random.PRNGKey(1), shape)
        out = compression.compress_decompress({"g": g})["g"]
        assert out.shape == shape


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------

def test_restart_determinism(tmp_path, tiny):
    from repro.distributed.fault_tolerance import (SupervisorConfig,
                                                   TrainSupervisor)
    cfg, b, state = tiny
    step = jax.jit(train_loop.make_train_step(
        b, opt_mod.AdamWConfig(warmup_steps=1, total_steps=20)))
    bf = lambda s: batch_of(cfg, 100 + s)

    sup = TrainSupervisor(step, bf, SupervisorConfig(
        ckpt_dir=str(tmp_path / "a"), ckpt_every=4))
    s1, logs, restarts = sup.run_with_restarts(state, 12, fail_at={6})
    assert restarts == 1

    sup2 = TrainSupervisor(step, bf, SupervisorConfig(
        ckpt_dir=str(tmp_path / "b"), ckpt_every=4))
    s2, _ = sup2.run(state, 12)
    for x, y in zip(jax.tree.leaves(s1["params"], is_leaf=cm.is_param),
                    jax.tree.leaves(s2["params"], is_leaf=cm.is_param)):
        np.testing.assert_array_equal(np.asarray(x.value),
                                      np.asarray(y.value))


def test_straggler_detection():
    from repro.distributed.fault_tolerance import StragglerStats
    st = StragglerStats(deadline_factor=3.0)
    for i in range(10):
        st.observe(i, 0.1)
    assert st.observe(10, 1.0)          # 10x median
    assert not st.observe(11, 0.12)
    assert st.flagged == [10]


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------

def test_spec_fallback_for_indivisible_dims():
    from repro.distributed import sharding as shd
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # pretend model axis is 16: simulate with a fake mesh dict via spec_for
    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")
    rules = {"heads": "model", "embed": "data", "vocab": "model"}
    # 14 heads don't divide 16 -> replicated
    spec = shd.spec_for((14, 64), ("heads", None), rules, FakeMesh)
    assert spec == jax.sharding.PartitionSpec(None, None)
    # 32 heads divide -> sharded
    spec = shd.spec_for((32, 64), ("heads", None), rules, FakeMesh)
    assert spec == jax.sharding.PartitionSpec("model", None)
    # same mesh axis cannot be used twice
    spec = shd.spec_for((32, 32), ("heads", "vocab"), rules, FakeMesh)
    assert spec == jax.sharding.PartitionSpec("model", None)


# ---------------------------------------------------------------------------
# Roofline HLO parsing
# ---------------------------------------------------------------------------

HLO = """
HloModule test
%body (p: f32[128,256]) -> f32[128,256] {
  %ar = f32[128,256] all-reduce(f32[128,256] %x), replica_groups=[2,16]
  ROOT %t = f32[128,256] copy(%ar)
}
%cond (p: f32[128,256]) -> pred[] {
  %c = s32[] constant(32)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}
ENTRY %main (a: f32[128,256]) -> f32[128,256] {
  %w = f32[128,256] while(%init), condition=%cond, body=%body
  %ag = f32[64,512] all-gather(f32[64,32] %y), replica_groups=[1,16]
  ROOT %r = f32[128,256] add(%w, %w)
}
"""


def test_parse_collective_bytes_trip_counts():
    from repro.analysis import roofline as rl
    st = rl.parse_collective_bytes(HLO)
    # target accounting counts floats at bf16 width (2B) — the CPU backend
    # legalizes bf16 to f32 carriers; raw keeps the compiled width (4B)
    ar_bytes = 128 * 256 * 2 * 2 * 15 / 16 * 32      # all-reduce x32 trips
    ag_bytes = 64 * 512 * 2 * 15 / 16                # all-gather once
    assert st.counts["all-reduce"] == 32
    assert st.counts["all-gather"] == 1
    assert st.bytes_per_chip == pytest.approx(ar_bytes + ag_bytes)
    assert st.bytes_per_chip_raw == pytest.approx(2 * (ar_bytes + ag_bytes))


def test_roofline_terms():
    from repro.analysis import roofline as rl
    coll = rl.CollectiveStats(bytes_per_chip=50e9)
    # 'bytes accessed' is divided by MEM_DTYPE_FACTOR's inverse (the CPU
    # backend's f32 carriers measure 2x the bf16 target traffic)
    r = rl.compute_roofline(
        {"flops": 197e12, "bytes accessed": 819e9 / rl.MEM_DTYPE_FACTOR},
        coll, chips=256, model_flops=197e12 * 256)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(1.0)
    assert r.collective_s == pytest.approx(1.0)
    assert r.roofline_fraction == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# semhash
# ---------------------------------------------------------------------------

def test_semhash_invariances():
    from repro.core import semhash
    assert semhash.semantic_equal("250 USD", "250 usd")
    assert semhash.semantic_equal(True, True)
    assert not semhash.semantic_equal(True, False)
    assert semhash.semantic_equal(100.0, 101.0)       # 1% numeric tolerance
    assert not semhash.semantic_equal(100.0, 150.0)
    assert not semhash.semantic_equal(
        "crime", "No relevant information found.")
    v = semhash.embed_one("hello world")
    assert np.linalg.norm(v) == pytest.approx(1.0)
