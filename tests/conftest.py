import os
import sys

# tests must see 1 CPU device (the dry-run sets 512 for itself only)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest  # noqa: E402


@pytest.fixture(scope="session")
def movie():
    from repro.data import load_dataset
    return load_dataset("movie")


@pytest.fixture(scope="session")
def estate():
    from repro.data import load_dataset
    return load_dataset("estate")


@pytest.fixture(scope="session")
def game_small():
    from repro.data import load_dataset
    return load_dataset("game", max_rows=400)


def perfect_backends(oracle):
    """Single-tier oracle cascade: capability > 1 => always correct."""
    from repro.core.backends import SimulatedBackend
    from repro.core.cost import TierSpec
    spec = TierSpec("m*", 1.01, 0.0, 0.0, 0.0, 0.0)
    return {"m*": SimulatedBackend(spec, oracle, violation_rate=0.0)}


@pytest.fixture(scope="session")
def tiny_bundle():
    import jax
    from repro.configs import get_config, reduced
    from repro.models import registry
    cfg = reduced(get_config("qwen2-0.5b"))
    b = registry.build(cfg)
    params = b.init(jax.random.PRNGKey(0))
    return cfg, b, params
