"""Checkpointing: atomicity, integrity, GC, async, restart, elastic."""
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as ck
from repro.models import common as cm


def tiny_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {
            "w": cm.Param(jax.random.normal(k, (8, 16)), ("embed", "mlp")),
            "b": cm.Param(jnp.zeros((16,)), ("mlp",)),
        },
        "opt": {"step": cm.Param(jnp.asarray(7, jnp.int32), ())},
    }


def assert_state_equal(a, b):
    la = jax.tree.leaves(a, is_leaf=cm.is_param)
    lb = jax.tree.leaves(b, is_leaf=cm.is_param)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x.value),
                                      np.asarray(y.value))
        assert x.axes == y.axes


def test_save_restore_roundtrip(tmp_path):
    s = tiny_state()
    ck.save(str(tmp_path), 3, s)
    step, got = ck.restore(str(tmp_path))
    assert step == 3
    assert_state_equal(s, got)


def test_atomicity_tmp_dirs_invisible(tmp_path):
    s = tiny_state()
    ck.save(str(tmp_path), 1, s)
    # simulate a crashed writer: uncommitted tmp dir with higher step
    bad = tmp_path / "step_00000009.tmp-dead"
    bad.mkdir()
    (bad / "manifest.json").write_text("{}")
    assert ck.latest_step(str(tmp_path)) == 1
    step, _ = ck.restore(str(tmp_path))
    assert step == 1


def test_keep_last_k_gc(tmp_path):
    s = tiny_state()
    for i in range(6):
        ck.save(str(tmp_path), i, s, keep_last=2)
    assert ck.committed_steps(str(tmp_path)) == [4, 5]


def test_checksum_detects_corruption(tmp_path):
    s = tiny_state()
    d = ck.save(str(tmp_path), 2, s)
    # flip bytes in one leaf
    leaf = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    p = os.path.join(d, leaf)
    raw = bytearray(open(p, "rb").read())
    raw[-1] ^= 0xFF
    open(p, "wb").write(bytes(raw))
    with pytest.raises(IOError):
        ck.restore(str(tmp_path))
    step, _ = ck.restore(str(tmp_path), verify=False)
    assert step == 2


def test_async_checkpointer(tmp_path):
    s = tiny_state()
    ac = ck.AsyncCheckpointer(str(tmp_path), keep_last=2)
    for i in range(4):
        ac.save(i, s)
    ac.close()
    assert ck.committed_steps(str(tmp_path)) == [2, 3]
    _, got = ck.restore(str(tmp_path))
    assert_state_equal(s, got)


def test_restore_with_mesh_resharding(tmp_path):
    """Elastic path: restore onto a (1,1) mesh with sharding rules."""
    from repro.distributed import sharding as shd
    s = tiny_state()
    ck.save(str(tmp_path), 0, s)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = {"embed": "data", "mlp": "model"}
    step, got = ck.restore(str(tmp_path), mesh=mesh, rules=rules)
    assert_state_equal(s, got)
    w = got["params"]["w"].value
    assert w.sharding.mesh.shape == {"data": 1, "model": 1}


def test_plan_remesh_factorings():
    from repro.distributed.elastic import plan_remesh
    assert plan_remesh(512) == (32, 16)
    assert plan_remesh(256) == (16, 16)
    assert plan_remesh(48) == (3, 16)
    assert plan_remesh(24) == (3, 8)
    assert plan_remesh(512, model_parallel=8) == (64, 8)
    with pytest.raises(ValueError):
        plan_remesh(10, model_parallel=4)


def test_manifest_contents(tmp_path):
    s = tiny_state()
    d = ck.save(str(tmp_path), 5, s, extra_meta={"mesh": "2x16x16"})
    m = json.load(open(os.path.join(d, "manifest.json")))
    assert m["step"] == 5
    assert m["meta"]["mesh"] == "2x16x16"
    assert m["leaves"]["params/w"]["axes"] == ["embed", "mlp"]
    assert m["leaves"]["params/w"]["shape"] == [8, 16]
