"""End-to-end system behaviour: the full Nirvana pipeline on the paper's
workloads — optimization must cut cost without destroying answers."""
import pytest

from repro.core import SemanticDataFrame, execute, make_backends
from repro.core import semhash
from repro.data import WORKLOADS, load_dataset

from conftest import perfect_backends


def answer_correct(got, want, table_truth=None):
    if want is None:
        return got is None
    if isinstance(want, (int, float)) and isinstance(got, (int, float)):
        scale = max(abs(float(want)), 1e-9)
        return abs(float(got) - float(want)) / scale < 0.05
    if hasattr(want, "columns"):          # table: row-set F1
        if not hasattr(got, "columns"):
            return False
        from repro.core.executor import ROWID
        a = set(got.columns.get(ROWID, []))
        b = set(want.columns.get(ROWID, []))
        if not b:
            return not a
        f1 = 2 * len(a & b) / max(1, len(a) + len(b))
        return f1 > 0.9
    return semhash.semantic_equal(got, want)


@pytest.fixture(scope="module")
def movie_env():
    table, oracle = load_dataset("movie")
    return table, make_backends(oracle), perfect_backends(oracle)


def test_full_pipeline_reduces_cost_preserves_answer(movie_env):
    table, backends, perfect = movie_env
    correct_opt = correct_base = 0
    cost_opt = cost_base = 0.0
    qs = [WORKLOADS["movie"][i] for i in (7, 8, 9, 10)]
    for q in qs:
        plan = q.plan_for(table)
        truth = execute(plan, table, perfect, default_tier="m*").value()
        df = SemanticDataFrame(table)
        df._ops = plan.ops
        rep = df.execute(backends)
        base = df.execute(backends, logical=False, physical=False)
        correct_opt += answer_correct(rep.result, truth)
        correct_base += answer_correct(base.result, truth)
        cost_opt += rep.total_usd
        cost_base += base.total_usd
    assert cost_opt < cost_base                 # optimization saves money
    assert correct_opt >= correct_base - 1     # quality preserved (±1)
    assert correct_opt >= len(qs) // 2


def test_queries_of_all_sizes_run(movie_env):
    table, backends, _ = movie_env
    for q in (WORKLOADS["movie"][0], WORKLOADS["movie"][5],
              WORKLOADS["movie"][11]):
        df = SemanticDataFrame(table)
        df._ops = q.plan_for(table).ops
        rep = df.execute(backends)
        assert rep.result is not None
        assert rep.total_usd > 0


def test_phase_breakdown_accounts_everything(movie_env):
    table, backends, _ = movie_env
    df = SemanticDataFrame(table)
    df._ops = WORKLOADS["movie"][9].plan_for(table).ops
    rep = df.execute(backends)
    pb = rep.phase_breakdown()
    assert set(pb) == {"execution", "logical_opt", "physical_opt"}
    assert rep.total_usd == pytest.approx(sum(d["usd"] for d in pb.values()))
    assert rep.total_wall_s == pytest.approx(
        sum(d["wall_s"] for d in pb.values()))


def test_listing1_api_shape(movie_env):
    """The Table-1 operator API builds the plan the paper's Listing 1
    describes."""
    table, _, _ = movie_env
    df = (SemanticDataFrame(table)
          .semantic_map("extract genre", "Plot", "Genre")
          .semantic_filter("rating > 8.5", "IMDB_rating")
          .semantic_rank("rank by rating", "IMDB_rating", "r")
          .semantic_reduce("count", "Title"))
    plan = df.plan()
    assert [o.kind for o in plan.ops] == ["map", "filter", "rank", "reduce"]
    plan.validate()
