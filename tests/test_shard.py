"""Morsel-parallel sharded execution suite: shard-count invariance of
results / call counts / per-tier meter totals under both drivers (incl.
the batch>1 + shared cache + cross-shard duplicates corner), per-shard
serving-quota bounds, deterministic merged call logs (UsageMeter.merge),
shard-worker failure isolation, the shared linger ticker, the shard-aware
cost model, and the serve.py --shards surface."""
import random
import threading
import time

import pytest

from repro.core import backends as bk
from repro.core import cost as cost_mod
from repro.core import executor as ex
from repro.core import plan as P
from repro.core import runtime as rt
from repro.core.table import Table
from repro.data import load_dataset
from repro.distributed.morsel_shards import (ShardedDispatcher,
                                             ShardEventScheduler,
                                             split_quota)
from repro.testing import EchoOracle, SleepBackend

SHARD_COUNTS = (1, 2, 4)


@pytest.fixture(scope="module")
def movie_small():
    return load_dataset("movie", max_rows=48)


def _chain_plan():
    return P.LogicalPlan((
        P.Operator(P.FILTER, "The rating is higher than 1.", "IMDB_rating"),
        P.Operator(P.MAP, "According to the movie plot, extract the "
                   "genre(s) of each movie.", "Plot", "Genre"),
        P.Operator(P.REDUCE, "Count the number of movies.", "Title"),
    ))


def _meter_key(meter):
    return {t: (u.calls, round(u.tok_in, 6), round(u.tok_out, 6),
                round(u.usd, 9), round(u.latency_s, 6))
            for t, u in sorted(meter.by_tier.items())}


# ---------------------------------------------------------------------------
# Shard-count invariance: the tentpole contract
# ---------------------------------------------------------------------------

def test_shard_invariance_results_and_meters(movie_small):
    """Results, call counts, and per-tier meter totals must be identical
    for shards in {1, 2, 4} under both drivers (the acceptance bar)."""
    table, oracle = movie_small
    plan = _chain_plan()
    ref = None
    for driver in rt.DRIVERS:
        for shards in SHARD_COUNTS:
            meter = bk.UsageMeter()
            res = ex.execute(plan, table, bk.make_backends(oracle),
                             default_tier="m*", morsel_size=8,
                             driver=driver, shards=shards, meter=meter)
            key = (res.scalar, res.is_reduce, res.rows_processed,
                   meter.total.calls, _meter_key(meter))
            if ref is None:
                ref = key
            assert key == ref, (driver, shards)


def test_shard_invariance_table_outputs(movie_small):
    table, oracle = movie_small
    plan = P.LogicalPlan(_chain_plan().ops[:2])     # filter -> map
    ref = None
    for driver in rt.DRIVERS:
        for shards in SHARD_COUNTS:
            res = ex.execute(plan, table, bk.make_backends(oracle),
                             default_tier="m*", morsel_size=8,
                             driver=driver, shards=shards)
            key = (res.table.columns[ex.ROWID], res.table.columns["Genre"])
            if ref is None:
                ref = key
            assert key == ref, (driver, shards)


def test_shard_invariance_batched_shared_cache_duplicates():
    """The PR 2/3 corner under sharding: batch_size > 1 + shared cache +
    duplicate values split across morsels that land on *different shards*
    must produce identical call grouping, billing, and outputs for every
    shard count and driver — batch formation stays global and the shared
    single-flight cache bills cross-shard duplicates once."""
    oracle = EchoOracle()
    table = Table({"v": [str(i % 8) for i in range(32)]}, name="dups")
    plan = P.LogicalPlan((P.Operator(P.MAP, "annotate", "v", "a"),))
    ref = None
    for driver in rt.DRIVERS:
        for shards in SHARD_COUNTS:
            backend = SleepBackend(oracle, delay_s=0.003)
            cache = rt.OutputCache()
            meter = bk.UsageMeter()
            res = ex.execute(plan, table, {"m*": backend},
                             default_tier="m*", batch_size=4,
                             morsel_size=8, cache=cache, meter=meter,
                             driver=driver, shards=shards)
            key = (sorted(backend.groups), backend.calls_made,
                   cache.misses, cache.hits, meter.total.calls,
                   res.table.columns["a"])
            if ref is None:
                ref = key
            assert key == ref, (driver, shards)
    groups, calls, misses, hits, metered, _ = ref
    # 8 unique values dedupe into exactly two full batches of 4, shard-
    # count invariant (the 1-shard grouping test_driver already enforces)
    assert calls == metered == 2
    assert groups == [("0", "1", "2", "3"), ("4", "5", "6", "7")]
    assert misses == 8 and hits == 24


def test_shard_coalesced_matches_barrier_batching(movie_small):
    """Sharded coalesced execution still reproduces whole-table batching
    exactly: ceil(survivors/batch) calls, byte-identical results."""
    table, oracle = movie_small
    plan = P.LogicalPlan((
        P.Operator(P.FILTER, "The rating is higher than 8.", "IMDB_rating"),
        P.Operator(P.MAP, "According to the movie plot, extract the "
                   "genre(s) of each movie.", "Plot", "Genre"),
    ))
    want_meter = bk.UsageMeter()
    want = ex.execute(plan, table, bk.make_backends(oracle),
                      default_tier="m*", batch_size=8, morsel_size=0,
                      coalesce=False, meter=want_meter)
    for driver in rt.DRIVERS:
        for shards in (2, 4):
            meter = bk.UsageMeter()
            res = ex.execute(plan, table, bk.make_backends(oracle),
                             default_tier="m*", batch_size=8,
                             morsel_size=8, driver=driver, shards=shards,
                             meter=meter)
            assert res.table.columns[ex.ROWID] \
                == want.table.columns[ex.ROWID], (driver, shards)
            assert res.table.columns["Genre"] \
                == want.table.columns["Genre"], (driver, shards)
            assert _meter_key(meter) == _meter_key(want_meter), \
                (driver, shards)


# ---------------------------------------------------------------------------
# Quotas: per-tier caps become per-shard serving quotas
# ---------------------------------------------------------------------------

def test_shard_quota_split_remainder_to_shard_zero():
    assert split_quota(8, 4) == [2, 2, 2, 2]
    assert split_quota(7, 4) == [4, 1, 1, 1]     # remainder to shard 0
    assert split_quota(2, 4) == [2, 1, 1, 1]     # floor of one worker
    assert split_quota(16, 1) == [16]


class _PeakBackend(SleepBackend):
    """SleepBackend that tracks the peak number of concurrent calls."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.inflight = 0
        self.peak = 0

    def run_values(self, op, values, meter=None, batch_size=1):
        with self._lock:
            self.inflight += 1
            self.peak = max(self.peak, self.inflight)
        try:
            return super().run_values(op, values, meter=meter,
                                      batch_size=batch_size)
        finally:
            with self._lock:
                self.inflight -= 1


def test_shard_quota_bound_never_exceeded(movie_small):
    """An explicit per-tier cap is a *global* serving quota: split across
    shards, the total in-flight calls never exceed it, and each shard's
    share really serializes (4 shards x quota 4 => 1 worker each, so the
    measured wall shows per-shard serialization, not 32-wide dispatch)."""
    table, oracle = movie_small
    plan = P.LogicalPlan((
        P.Operator(P.FILTER, "The rating is higher than 1.",
                   "IMDB_rating"),))
    backend = _PeakBackend(oracle, delay_s=0.03)
    ctx = rt.ExecutionContext(
        backends={"m*": backend}, default_tier="m*", concurrency=16,
        morsel_size=4, per_tier_concurrency={"m*": 4}, driver="threads",
        shards=4)
    res = ex.execute(plan, table, ctx)
    assert res.table.n_rows > 0
    assert backend.peak <= 4                     # the global quota
    # 48 calls over a 4-wide total quota, 0.03s each: wall >= 0.36s * 0.8
    assert res.wall_s > 48 / 4 * 0.03 * 0.8
    # dispatcher-level view of the same split
    disp = ctx.make_dispatcher()
    try:
        assert [disp.shard_quota("m*", s) for s in range(4)] == [1, 1, 1, 1]
        assert disp.shard_quota("other", 2) == 16   # un-quota'd: replica
    finally:
        disp.close()


def test_shard_threads_wall_shows_replica_speedup(movie_small):
    """Un-quota'd tiers scale with the shard count (each shard worker is
    its own replica): 4 shards must beat 1 shard on a really-sleeping
    backend with identical results. Loose 1.3x bound here (CI jitter);
    benchmarks/bench_shard.py enforces the 1.5x acceptance bar."""
    table, oracle = movie_small
    plan = P.LogicalPlan((
        P.Operator(P.FILTER, "The rating is higher than 1.",
                   "IMDB_rating"),))
    walls, rowids = {}, {}
    for shards in (1, 4):
        best = float("inf")
        for _ in range(3):
            backend = SleepBackend(oracle, delay_s=0.04)
            res = ex.execute(plan, table, {"m*": backend},
                             default_tier="m*", concurrency=4,
                             morsel_size=8, driver="threads",
                             shards=shards)
            best = min(best, res.wall_s)
            rowids[shards] = res.table.columns[ex.ROWID]
        walls[shards] = best
    assert rowids[4] == rowids[1]
    assert walls[4] < walls[1] / 1.3


# ---------------------------------------------------------------------------
# UsageMeter.merge: deterministic combined call logs
# ---------------------------------------------------------------------------

def test_shard_usage_meter_merge_orders_by_logical_key():
    """Merged call_log ordering sorts by logical (morsel, call) key, not
    arrival time: shuffled per-shard arrival orders always merge to the
    same log."""
    u = bk.Usage(calls=1, tok_in=8.0, tok_out=4.0, usd=0.001, latency_s=0.05)
    entries = [((oi, mi), f"m{oi}") for oi in range(2) for mi in range(6)]
    logs = []
    for seed in range(3):
        order = entries[:]
        random.Random(seed).shuffle(order)
        meters = [bk.UsageMeter(), bk.UsageMeter()]
        for key, tier in order:
            meters[key[1] % 2].record(tier, u, key=key)
        merged = bk.UsageMeter.merge(meters)
        logs.append((list(merged.call_log), list(merged.call_keys)))
        assert merged.total.calls == len(entries)
        assert merged.by_tier["m0"].calls == 6
        assert merged.by_tier["m1"].calls == 6
    assert logs[0] == logs[1] == logs[2]
    keys = logs[0][1]
    assert keys == sorted(keys)          # logical order, per-call index last
    assert keys[0] == (0, 0, 0)


def test_shard_usage_meter_merge_keeps_unkeyed_entries_and_absorb():
    a, b = bk.UsageMeter(), bk.UsageMeter()
    u = bk.Usage(calls=1, tok_in=1.0, tok_out=1.0, usd=0.0, latency_s=0.01)
    a.record("t", u, key=(0, 1))
    b.record("t", u)                      # no key: ordered after keyed ones
    b.record("t", u, key=(0, 0))
    merged = bk.UsageMeter.merge([a, b])
    assert merged.call_keys == [(0, 0, 0), (0, 1, 0), None]
    assert merged.total.calls == 3
    # absorb adds into an existing meter without mutating the source
    target = bk.UsageMeter()
    target.record("t", u, key=(9, 9))
    target.absorb(merged)
    assert target.total.calls == 4
    assert merged.total.calls == 3
    assert a.by_tier["t"].calls == 1


def test_shard_threads_merged_log_is_deterministic():
    """Two threaded sharded runs of the same pipeline report identical
    merged call logs (keys make the order logical, not arrival-based)."""
    oracle = EchoOracle()
    table = Table({"v": [f"x{i}" for i in range(64)]}, name="wide")
    plan = P.LogicalPlan((P.Operator(P.MAP, "annotate", "v", "a"),))
    logs = []
    for _ in range(2):
        meter = bk.UsageMeter()
        ex.execute(plan, table, {"m*": SleepBackend(oracle, delay_s=0.002)},
                   default_tier="m*", morsel_size=8, driver="threads",
                   shards=4, meter=meter)
        logs.append((list(meter.call_log), list(meter.call_keys)))
    assert logs[0] == logs[1]
    assert all(k is not None for k in logs[0][1])


# ---------------------------------------------------------------------------
# Failure isolation
# ---------------------------------------------------------------------------

class _BoomOracle(EchoOracle):
    def answer(self, op, value):
        if "BOOM" in str(value):
            raise RuntimeError("shard backend down")
        return True if op.kind == P.FILTER else f"A:{value}"


def test_shard_worker_failure_poisons_only_its_morsels():
    """A backend failure inside one shard's morsels must raise (not hang):
    the poisoned morsel keeps downstream watermarks moving, every other
    shard's morsels complete, and the error surfaces at the merge."""
    # rows 8..15 form morsel 1 -> shard 1 of 2; everything else is clean
    table = Table({"v": [f"BOOM{i}" if 8 <= i < 16 else f"x{i}"
                         for i in range(32)]}, name="boom")
    plan = P.LogicalPlan((
        P.Operator(P.FILTER, "keep", "v"),
        P.Operator(P.MAP, "annotate", "v", "a"),
    ))
    for driver in rt.DRIVERS:
        for shards in (2, 4):
            backend = SleepBackend(_BoomOracle(), delay_s=0.0)
            t0 = time.perf_counter()
            with pytest.raises(RuntimeError, match="shard backend down"):
                ex.execute(plan, table, {"m*": backend}, default_tier="m*",
                           batch_size=8, morsel_size=8, driver=driver,
                           shards=shards, coalesce=True)
            assert time.perf_counter() - t0 < 30.0   # raised, not starved
            # the healthy shards' morsels were still dispatched
            flat = [v for g in backend.groups for v in g]
            assert any(v.startswith("x") for v in flat)


# ---------------------------------------------------------------------------
# Shared linger ticker
# ---------------------------------------------------------------------------

def test_shard_linger_ticker_thread_is_shared():
    """Multiple coalescers with wall-time lingers (e.g. shards x ops)
    share ONE coalesce-linger daemon instead of one thread each."""
    disp = rt.ThreadPoolDispatcher(concurrency=4)
    backend = SleepBackend(EchoOracle(), delay_s=0.0)
    op = P.Operator(P.MAP, "annotate", "v", "a")
    coals = [rt.BatchCoalescer(disp, bk.UsageMeter(), batch_size=8,
                               linger_s=0.05) for _ in range(4)]
    futs = []
    try:
        for i, coal in enumerate(coals):
            g = coal.open(op, backend, "m*", expected=2)
            futs.append(g.submit(0, [f"c{i}a", f"c{i}b"], 0.0))
        names = [t.name for t in threading.enumerate()
                 if t.name == "coalesce-linger"]
        assert len(names) == 1               # one ticker for all four
        for i, fut in enumerate(futs):       # lingers still fire per-coal
            outs, _ = fut.result(timeout=5)
            assert outs == [f"A:c{i}a", f"A:c{i}b"]
    finally:
        for coal in coals:
            coal.close()
        disp.close()


# ---------------------------------------------------------------------------
# Simulated driver: one event timeline, per-(shard, tier) pools
# ---------------------------------------------------------------------------

def test_shard_event_scheduler_pools_split_quota():
    sched = ShardEventScheduler(4, concurrency=16, per_tier={"m*": 8})
    from repro.distributed.morsel_shards import _compose
    assert sched.workers(_compose(0, "m*")) == 2
    assert sched.workers(_compose(3, "m*")) == 2
    assert sched.workers(_compose(1, "other")) == 16   # replica width
    assert sched.workers(rt.HOST_TIER) == 1            # host never shards
    sync = ShardEventScheduler(4, concurrency=16, mode="sync")
    assert sync.workers(_compose(2, "m*")) == 1


def test_shard_simulated_runs_are_deterministic(movie_small):
    """Two simulated sharded runs produce identical call logs, walls, and
    results (Table-9 accounting stays one deterministic event replay)."""
    table, oracle = movie_small
    plan = _chain_plan()
    runs = []
    for _ in range(2):
        meter = bk.UsageMeter()
        res = ex.execute(plan, table, bk.make_backends(oracle),
                         default_tier="m*", batch_size=8, morsel_size=8,
                         meter=meter, driver="simulated", shards=4)
        runs.append((list(meter.call_log), list(meter.call_keys),
                     res.wall_s, res.scalar))
    assert runs[0] == runs[1]


# ---------------------------------------------------------------------------
# Shard-local cache option
# ---------------------------------------------------------------------------

def test_shard_local_cache_trades_invariance_for_isolation():
    """ctx.shard_cache="local": each shard memoizes independently, so
    cross-shard duplicates bill per shard (more calls than the default
    shared cache, which is why "shared" is the default)."""
    oracle = EchoOracle()
    table = Table({"v": [str(i % 8) for i in range(32)]}, name="dups")
    plan = P.LogicalPlan((P.Operator(P.MAP, "annotate", "v", "a"),))
    calls = {}
    for mode in ("shared", "local"):
        backend = SleepBackend(oracle, delay_s=0.0)
        res = ex.execute(plan, table, {"m*": backend}, default_tier="m*",
                         morsel_size=8, driver="threads", shards=2,
                         cache=rt.OutputCache(), shard_cache=mode)
        calls[mode] = backend.calls_made
        assert res.table.columns["a"] == [f"A:{i % 8}" for i in range(32)]
    assert calls["shared"] == 8          # one bill per unique value
    # local: each shard bills its own copy of the 8 unique values once
    assert calls["local"] == 16


# ---------------------------------------------------------------------------
# Cost model + serve surface
# ---------------------------------------------------------------------------

def test_shard_cost_model_scales_width_not_calls():
    plan = P.LogicalPlan((
        P.Operator(P.FILTER, "keep the good ones", "v"),))
    c1 = cost_mod.plan_cost(plan, 128, concurrency=4, shards=1)
    c4 = cost_mod.plan_cost(plan, 128, concurrency=4, shards=4)
    assert c4.llm_calls == c1.llm_calls      # sharding never changes calls
    assert c4.usd == pytest.approx(c1.usd)
    assert c4.latency_s == pytest.approx(c1.latency_s / 4)


def test_shard_serve_parser_and_dispatcher_wiring():
    from repro.launch import serve
    ap = serve.build_parser()
    assert ap.parse_args([]).shards == 1
    assert ap.parse_args(["--shards", "4"]).shards == 4
    ctx = rt.ExecutionContext(backends={}, shards=3, driver="threads",
                              per_tier_concurrency={"m*": 7})
    disp = ctx.make_dispatcher()
    try:
        assert isinstance(disp, ShardedDispatcher)
        assert disp.n_shards == 3 and disp.kind == "threads"
        assert [disp.shard_of(i) for i in range(5)] == [0, 1, 2, 0, 1]
        assert [disp.shard_quota("m*", s) for s in range(3)] == [3, 2, 2]
    finally:
        disp.close()
    assert isinstance(rt.ExecutionContext(backends={}).make_dispatcher(),
                      rt.SimulatedDispatcher)
