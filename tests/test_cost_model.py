"""CostModel surface: delegate-compat of the deprecated ``core.cost``
free functions, online q-error calibration (convergence, monotone
improvement, determinism), the ``latency_weight=0`` tier-choice identity,
and the three invariance guarantees with calibration enabled."""
import dataclasses

import pytest

from repro.core import backends as bk
from repro.core import cost as cost_mod
from repro.core import executor as ex
from repro.core import physical_optimizer as popt
from repro.core import plan as P
from repro.core import runtime as rt
from repro.core.cost_model import DEFAULT_MODEL, CostModel
from repro.analysis import qerror
from repro.testing import KindOracle, result_fingerprint, tagged_plan, \
    tagged_table


def _plans():
    yield P.LogicalPlan((
        P.Operator(P.FILTER, "keep the good ones", "v"),
        P.Operator(P.MAP, "annotate sentiment", "v", "a"),
        P.Operator(P.REDUCE, "count them", "v"),
    ))
    yield P.LogicalPlan((
        P.Operator(P.MAP, "upper", "v", "u", udf="upper"),
        P.Operator(P.RANK, "rank by relevance", "v"),
    ))


def _scaled_tiers(factor: float):
    """DEFAULT_TIERS with every latency term scaled: the simulated backend
    bills exactly ``tier.latency(out_tokens)`` per call, so these tiers'
    measured latencies are exactly ``factor``x the default-model priors."""
    return {name: dataclasses.replace(spec,
                                      latency_call_s=spec.latency_call_s
                                      * factor,
                                      latency_tok_s=spec.latency_tok_s
                                      * factor)
            for name, spec in cost_mod.DEFAULT_TIERS.items()}


def _calibration_env(factor: float = 3.0, n_rows: int = 48):
    table = tagged_table("cal", n=n_rows)
    backends = bk.make_backends(KindOracle(), tiers=_scaled_tiers(factor),
                                violation_rate=0.0)
    return table, backends


# ---------------------------------------------------------------------------
# Delegate compat: the deprecated free functions == the default model
# ---------------------------------------------------------------------------

def test_cost_free_functions_match_default_model():
    fresh = CostModel()
    for text in ("", "abcd", "a longer instruction string", 1234):
        assert cost_mod.text_tokens(text) == fresh.text_tokens(text)
    assert [t.name for t in cost_mod.tier_list()] \
        == [t.name for t in fresh.tier_list()]
    for plan in _plans():
        for n_rows in (1, 17, 1000):
            a = cost_mod.plan_cost(plan, n_rows, batch_size=4, shards=2)
            b = fresh.plan_cost(plan, n_rows, batch_size=4, shards=2)
            assert a.usd == b.usd
            assert a.latency_s == b.latency_s
            assert a.llm_calls == b.llm_calls
            assert a.tok_in == b.tok_in and a.tok_out == b.tok_out
            assert a.rows_processed == b.rows_processed
            # the logical optimizer's scalar: objective == .cost at the
            # default latency_weight=0
            assert fresh.objective(b) == a.cost == a.usd
        for op in plan.ops:
            tier = cost_mod.DEFAULT_TIERS["m2"]
            oa = cost_mod.op_cost(op, 100, tier, cascade_escalate=0.25)
            ob = fresh.op_cost(op, 100, tier, cascade_escalate=0.25)
            assert oa == ob


def test_cost_default_model_is_never_calibrated_by_execution():
    table, backends = _calibration_env()
    ctx = rt.ExecutionContext(backends=backends, default_tier="m1")
    ex.execute(tagged_plan("cal"), table, ctx)   # no ctx.cost_model
    assert DEFAULT_MODEL.calibration_state() == {}


# ---------------------------------------------------------------------------
# Online calibration: convergence + monotone improvement
# ---------------------------------------------------------------------------

def test_cost_calibration_converges_on_3x_shifted_backend():
    """Acceptance criterion: true latencies 3x the priors -> after one
    run with calibration on, median per-(op, tier) q-error drops below
    1.5, from >= 3 uncalibrated."""
    table, backends = _calibration_env(factor=3.0)
    model = CostModel()
    ctx = rt.ExecutionContext(backends=backends, default_tier="m2",
                              cost_model=model)
    ex.execute(tagged_plan("cal", reduce_tail=True), table, ctx)
    rows = qerror.report_rows(model)
    assert rows, "execution should have fed the model typed calls"
    assert qerror.median_qerror(rows, "prior_qerror") >= 3.0 - 1e-9
    assert qerror.median_qerror(rows, "qerror") < 1.5
    # the calibrated estimates now price with measured latencies
    for r in rows:
        assert r["pred_latency_s"] == pytest.approx(r["meas_latency_s"])


def test_cost_qerror_improves_monotonically_across_observes():
    table, backends = _calibration_env(factor=3.0)
    model = CostModel()
    ctx = rt.ExecutionContext(backends=backends, default_tier="m2",
                              cost_model=model)
    ex.execute(tagged_plan("cal"), table, ctx)
    first = {(r["op"], r["tier"]): r["qerror"]
             for r in qerror.report_rows(model)}
    assert first
    # a second identical run: measurements are stationary, so the EWMA
    # stays put and the live q-error never degrades
    ex.execute(tagged_plan("cal2"), table, ctx)
    second = {(r["op"], r["tier"]): r["qerror"]
              for r in qerror.report_rows(model)}
    for k, q1 in first.items():
        assert second[k] <= q1 + 1e-12
    # observing the same meter again is a no-op (per-meter cursor)
    state = model.calibration_state()
    assert model.observe(ctx.meter) == 0
    assert model.calibration_state() == state


def test_cost_qerror_report_renders_text_and_json():
    table, backends = _calibration_env(factor=3.0, n_rows=16)
    model = CostModel()
    ctx = rt.ExecutionContext(backends=backends, default_tier="m1",
                              cost_model=model)
    ex.execute(tagged_plan("cal", reduce_tail=True), table, ctx)
    text = qerror.render_text(model)
    assert "median q-error" in text and "m1" in text
    import json
    doc = json.loads(qerror.to_json(model))
    assert doc["rows"] and doc["median_qerror"] >= 1.0
    assert doc["median_prior_qerror"] >= 3.0 - 1e-9
    empty = qerror.render_text(CostModel())
    assert "no calibration data" in empty


# ---------------------------------------------------------------------------
# latency_weight=0 identity: tier selections byte-identical to pre-refactor
# ---------------------------------------------------------------------------

# pre-refactor physical-optimizer assignments, captured on the seed code
# (movie dataset, max_rows=80, approx estimator, seed 0, delta_min=0.1 --
# tier-diverse on these queries, so drift in either the improvement
# scoring or the selection walk shows up as a mismatch)
_GOLDEN_MOVIE_ASSIGNMENTS = {
    7: {0: "m*", 1: "m1", 2: "m1"},
    10: {0: "m1", 1: "m*", 2: "m1", 3: "m1"},
}


@pytest.mark.parametrize("with_model", [False, True],
                         ids=["no-model", "weight0-model"])
def test_cost_latency_weight_zero_tier_choices_identical(with_model):
    from repro.data import WORKLOADS, load_dataset
    table, oracle = load_dataset("movie", max_rows=80)
    for qi, want in _GOLDEN_MOVIE_ASSIGNMENTS.items():
        backends = bk.make_backends(oracle)
        ctx = rt.ExecutionContext(
            backends=backends, default_tier="m*",
            cost_model=CostModel(latency_weight=0.0) if with_model
            else None)
        plan = WORKLOADS["movie"][qi].plan_for(table)
        res = popt.optimize(plan, table, ctx,
                            cfg=popt.PhysicalOptConfig(
                                estimator="approx", seed=0, delta_min=0.1))
        assert res.assignments == want, f"movie q{qi}"


def test_cost_select_tier_penalty_none_is_classic_walk():
    scores = {"m2": 0.25, "m3": 0.30, "m*": 0.55}
    assert popt.select_tier(scores, 0.20) == "m*"
    assert popt.select_tier(scores, 0.20, penalty=None) == "m*"
    assert popt.select_tier(scores, 0.20,
                            penalty={m: 0.0 for m in scores}) == "m*"
    # a real penalty can veto an upgrade the margin alone would take
    assert popt.select_tier(scores, 0.20,
                            penalty={"m1": 0.0, "m2": 0.0, "m3": 0.0,
                                     "m*": 0.2}) == "m2"


def test_cost_positive_latency_weight_computes_makespan():
    model = CostModel(latency_weight=1.0)
    plan = next(_plans())
    pc = model.plan_cost(plan, 200, concurrency=4)
    assert pc.makespan_s > 0.0
    assert model.objective(pc) > pc.usd
    # weight 0 never pays for the replay
    pc0 = CostModel().plan_cost(plan, 200, concurrency=4)
    assert pc0.makespan_s == 0.0
    # a busier pool can only push the estimate out
    occ = {"m*": [5.0] * 4}
    busy = model.plan_cost(plan, 200, concurrency=4, occupancy=occ)
    assert busy.makespan_s >= pc.makespan_s


# ---------------------------------------------------------------------------
# Invariance with calibration enabled + deterministic calibration state
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("driver", ["simulated", "threads"])
@pytest.mark.parametrize("shards", [1, 2])
def test_cost_invariance_sweep_with_calibration(driver, shards):
    table, backends = _calibration_env(factor=3.0, n_rows=32)
    model = CostModel()
    ctx = rt.ExecutionContext(backends=backends, default_tier="m2",
                              driver=driver, shards=shards,
                              cost_model=model)
    res = ex.execute(tagged_plan("inv", reduce_tail=True), table, ctx)

    base_table, base_backends = _calibration_env(factor=3.0, n_rows=32)
    base_model = CostModel()
    base_ctx = rt.ExecutionContext(backends=base_backends,
                                   default_tier="m2",
                                   cost_model=base_model)
    base = ex.execute(tagged_plan("inv", reduce_tail=True), base_table,
                      base_ctx)

    assert result_fingerprint(res) == result_fingerprint(base)
    assert {t: u.calls for t, u in res.meter.by_tier.items()} \
        == {t: u.calls for t, u in base.meter.by_tier.items()}
    # calibration folds in logical-key order, so the model's state is
    # driver- and shard-count-invariant too
    assert model.calibration_state() == base_model.calibration_state()


def test_cost_calibration_state_deterministic_across_threaded_runs():
    states = []
    for _ in range(2):
        table, backends = _calibration_env(factor=3.0, n_rows=32)
        model = CostModel()
        ctx = rt.ExecutionContext(backends=backends, default_tier="m2",
                                  driver="threads", concurrency=8,
                                  cost_model=model)
        ex.execute(tagged_plan("det", reduce_tail=True), table, ctx)
        states.append(model.calibration_state())
    assert states[0] == states[1] and states[0]
