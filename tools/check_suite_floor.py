"""Assert the test-suite floor: pytest must report at least FLOOR_PASSED
passing tests and at most CEIL_SKIPPED skips.

    python -m pytest -q | tee pytest.out
    python tools/check_suite_floor.py pytest.out

Guards against silent shrinkage: a refactor that deletes or deselects
tests keeps a green exit code, but the floor check fails the build. The
floor is the local no-hypothesis count; environments with hypothesis
installed collect extra property-test front-ends and clear it with room
to spare. Bump FLOOR_PASSED when a PR adds tests.
"""
from __future__ import annotations

import re
import sys

FLOOR_PASSED = 393
CEIL_SKIPPED = 1


def check(text: str) -> str:
    """Return an error message, or '' if the floor holds."""
    # the summary tail looks like: "393 passed, 1 skipped in 312.44s"
    m_pass = re.search(r"(\d+) passed", text)
    if not m_pass:
        return "no 'N passed' summary found in pytest output"
    passed = int(m_pass.group(1))
    m_skip = re.search(r"(\d+) skipped", text)
    skipped = int(m_skip.group(1)) if m_skip else 0
    m_fail = re.search(r"(\d+) (?:failed|error)", text)
    if m_fail:
        return f"{m_fail.group(0)} — suite is red"
    if passed < FLOOR_PASSED:
        return (f"{passed} passed < floor {FLOOR_PASSED} — "
                f"tests were lost or deselected")
    if skipped > CEIL_SKIPPED:
        return (f"{skipped} skipped > ceiling {CEIL_SKIPPED} — "
                f"tests are being silently skipped")
    return ""


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    if argv:
        with open(argv[0]) as f:
            text = f.read()
    else:
        text = sys.stdin.read()
    err = check(text)
    if err:
        print(f"[suite-floor] FAIL: {err}", file=sys.stderr)
        return 1
    print(f"[suite-floor] ok (floor {FLOOR_PASSED} passed / "
          f"<= {CEIL_SKIPPED} skipped)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
