"""End-to-end driver: semantic analytics served by REAL JAX models.

The full Nirvana pipeline — logical optimization, physical optimization,
execution — with the m1 tier backed by an actual model from the zoo running
through the continuous-batching serving engine (prefill + decode + KV cache
on this machine), in oracle-echo mode so answers stay meaningful while
latency and token accounting come from genuine serving:

    PYTHONPATH=src python examples/serve_analytics.py
"""
import jax

from repro.core import make_backends
from repro.core.dataframe import SemanticDataFrame
from repro.core.cost import DEFAULT_TIERS
from repro.data import load_dataset, WORKLOADS
from repro.configs import get_config, reduced
from repro.engine import GenerationEngine, JAXBackend
from repro.models import registry


def main():
    table, oracle = load_dataset("estate", max_rows=96)
    backends = make_backends(oracle)

    # back the m1 tier with a real served model (reduced same-family config
    # of the tier's assigned arch — qwen2-0.5b)
    tier = DEFAULT_TIERS["m1"]
    cfg = reduced(get_config(tier.arch))
    bundle = registry.build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    engine = GenerationEngine(bundle, params, max_len=192, n_slots=4)
    backends["m1"] = JAXBackend(tier, engine, oracle=oracle)
    print(f"[m1] serving {cfg.name}: {cfg.param_count()/1e6:.2f}M params, "
          f"4 slots, continuous batching")

    q = WORKLOADS["estate"][4]  # q5 (medium)
    print(f"\nQuery {q.qid}: {q.question}")
    df = SemanticDataFrame(table)
    df._ops = q.plan_for(table).ops

    report = df.execute(backends)
    print("\n=== optimized plan ===")
    print(report.plan.describe())
    res = report.result
    print("\nresult:", repr(res)[:160])
    print(f"\nreal serving stats: {engine.stats['prefills']} prefills, "
          f"{engine.stats['decode_steps']} decode ticks, "
          f"occupancy={engine.occupancy:.2f}")
    for tier_name, u in report.execution.meter.by_tier.items():
        print(f"  exec[{tier_name}]: calls={u.calls} "
              f"tok_in={u.tok_in:.0f} usd=${u.usd:.4f}")


if __name__ == "__main__":
    main()
