"""Quickstart — the paper's Listing-1 experience.

Build a semantic query over the multi-modal Movie table with the
programmable operators, then let Nirvana optimize it:

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import SemanticDataFrame, make_backends
from repro.data import load_dataset


def main():
    table, oracle = load_dataset("movie")
    backends = make_backends(oracle)

    df = SemanticDataFrame(table)
    df = (df.semantic_map(
              "According to the movie plot, extract the genre(s) of each "
              "movie.", input_column="Plot", output_column="Genre")
            .semantic_filter("The rating is higher than 8.5.",
                             input_column="IMDB_rating")
            .semantic_filter("The rating is lower than 9.",
                             input_column="IMDB_rating")
            .semantic_filter("The movie belongs to crime movies.",
                             input_column="Genre")
            .semantic_reduce("Summarize the common characteristics of "
                             "these crime movies.", input_column="Plot"))

    print("=== initial logical plan ===")
    print(df.plan().describe())

    report = df.execute(backends)

    print("\n=== optimized physical plan ===")
    print(report.plan.describe())
    print("\n=== result ===")
    print(repr(report.result)[:200])
    print("\n=== cost breakdown (simulated latency / USD) ===")
    for phase, d in report.phase_breakdown().items():
        print(f"  {phase:14s} wall={d['wall_s']:8.2f}s  usd=${d['usd']:.4f}")
    print(f"  {'TOTAL':14s} wall={report.total_wall_s:8.2f}s  "
          f"usd=${report.total_usd:.4f}")

    base = df.execute(backends, logical=False, physical=False)
    print(f"\nunoptimized: wall={base.total_wall_s:8.2f}s  "
          f"usd=${base.total_usd:.4f}")
    print(f"savings: {100 * (1 - report.total_wall_s / base.total_wall_s):.0f}%"
          f" time, {100 * (1 - report.total_usd / base.total_usd):.0f}% cost")


if __name__ == "__main__":
    main()
