"""Train the local rewrite model (paper §3.3).

Pipeline exactly as the paper describes:
  1. data collection — compile the workloads' analytical queries into
     logical plans, enumerate candidate rewrites, and label each plan with
     the greedy rule-teacher's choice (the "LLM with transformation rules");
  2. fine-tune a small LM (reduced same-family config of qwen2-0.5b) to
     score (plan, candidate) pairs: input "plan \\x1f candidate", binary
     Y/N readout at the last position;
  3. plug the trained policy in as the LocalModelRewriter and run the
     logical optimizer with NO cloud-rewriter calls — compare end-to-end
     cost/latency vs the LLM rewriter.

    PYTHONPATH=src python examples/train_rewriter.py --steps 300
"""
import argparse
import random

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import make_backends
from repro.core import logical_optimizer as lopt
from repro.core import rewriter as rw
from repro.core import rules as rules_mod
from repro.data import WORKLOADS, load_dataset
from repro.data.tokenizer import ByteTokenizer
from repro.models import registry, transformer
from repro.training import optimizer as opt_mod

MAXLEN = 384


def collect_dataset():
    """(plan_json, candidate_desc, label) triples from the rule teacher."""
    rows = []
    for ds in ("movie", "estate", "game"):
        table, _ = load_dataset(ds, max_rows=4)
        plans = [q.plan_for(table) for q in WORKLOADS[ds]]
        for rec in rw.training_pairs(plans):
            cands = rec["candidates"]
            for i, c in enumerate(cands):
                rows.append((rec["plan_json"], c, 1 if i == rec["label"]
                             else 0))
    return rows


def encode_pair(tok, plan_json, cand, maxlen=MAXLEN):
    text = plan_json[-(maxlen - len(cand) - 24):] + "\x1f" + cand
    ids = tok.encode(text)[:maxlen - 1]
    return ids


def make_model():
    cfg = reduced(get_config("qwen2-0.5b"), n_layers=2, d_model=128,
                  vocab=512)
    bundle = registry.build(cfg)
    return cfg, bundle


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    tok = ByteTokenizer()
    rows = collect_dataset()
    rng = random.Random(args.seed)
    rng.shuffle(rows)
    n_eval = max(8, len(rows) // 6)
    eval_rows, train_rows = rows[:n_eval], rows[n_eval:]
    print(f"[data] {len(train_rows)} train / {len(eval_rows)} eval pairs "
          f"(teacher = greedy rule rewriter)")

    cfg, bundle = make_model()
    params = bundle.init(jax.random.PRNGKey(args.seed))
    print(f"[model] {cfg.name}: {cfg.param_count()/1e6:.2f}M params")
    Y, N = tok.encode("Y", bos=False)[0], tok.encode("N", bos=False)[0]

    def logits_of(params, tokens, lengths):
        out = transformer.forward(params, cfg, tokens, dtype=jnp.float32,
                                  remat=False)
        idx = jnp.clip(lengths - 1, 0, tokens.shape[1] - 1)
        last = jnp.take_along_axis(
            out, idx[:, None, None].repeat(out.shape[-1], -1), axis=1)[:, 0]
        return last[:, jnp.array([N, Y])]            # (B, 2)

    def loss_fn(params, batch):
        lg = logits_of(params, batch["tokens"], batch["lengths"])
        return jnp.mean(
            -jax.nn.log_softmax(lg)[jnp.arange(lg.shape[0]),
                                    batch["labels"]])

    opt_cfg = opt_mod.AdamWConfig(lr=args.lr, warmup_steps=20,
                                  total_steps=args.steps, weight_decay=0.01)
    opt_state = opt_mod.init_state(params)

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, m = opt_mod.apply_updates(opt_cfg, params, grads,
                                                     opt_state)
        return params, opt_state, loss

    def make_batch(rows_sel):
        seqs = [encode_pair(tok, p, c) for p, c, _ in rows_sel]
        lengths = np.array([len(s) for s in seqs], np.int32)
        tokens = tok.pad_batch(seqs, length=MAXLEN)
        labels = np.array([l for _, _, l in rows_sel], np.int32)
        return {"tokens": jnp.asarray(tokens),
                "lengths": jnp.asarray(lengths),
                "labels": jnp.asarray(labels)}

    @jax.jit
    def eval_logits(params, tokens, lengths):
        return logits_of(params, tokens, lengths)

    def accuracy(rows_sel):
        b = make_batch(rows_sel)
        lg = eval_logits(params, b["tokens"], b["lengths"])
        pred = jnp.argmax(lg, -1)
        return float(jnp.mean(pred == b["labels"]))

    print(f"[train] initial eval acc={accuracy(eval_rows):.2f}")
    for i in range(args.steps):
        sel = [train_rows[rng.randrange(len(train_rows))]
               for _ in range(args.batch)]
        params, opt_state, loss = step(params, opt_state, make_batch(sel))
        if (i + 1) % max(1, args.steps // 5) == 0:
            print(f"[train] step {i+1:4d} loss={float(loss):.3f} "
                  f"eval_acc={accuracy(eval_rows):.2f}")

    # ---- deploy as the LocalModelRewriter --------------------------------
    def policy(plan_json, candidate_descriptions):
        seqs = [encode_pair(tok, plan_json, c)
                for c in candidate_descriptions]
        lengths = np.array([len(s) for s in seqs], np.int32)
        tokens = tok.pad_batch(seqs, length=MAXLEN)
        lg = eval_logits(params, jnp.asarray(tokens), jnp.asarray(lengths))
        score = jax.nn.log_softmax(lg)[:, 1]
        return int(jnp.argmax(score))

    local = rw.LocalModelRewriter(policy=policy)
    cloud = rw.LLMSimRewriter(error_rate=0.0)

    table, oracle = load_dataset("movie", max_rows=64)
    backends = make_backends(oracle)
    q = WORKLOADS["movie"][9]
    plan = q.plan_for(table)
    for name, rewriter in (("cloud LLM", cloud), ("local model", local)):
        res = lopt.optimize(plan, table, backends, rewriter=rewriter,
                            cfg=lopt.LogicalOptConfig(n_iterations=3))
        u = res.meter.by_tier.get("rewriter")
        print(f"[{name:11s}] plan cost ${res.initial_cost:.3f} -> "
              f"${res.best_cost:.3f}  rewriter: "
              f"{u.latency_s:.2f}s ${u.usd:.4f}")


if __name__ == "__main__":
    main()
