"""Shared benchmark substrate: environments, baseline-system analogs,
ground truth, grading.

Baseline systems are implemented as *strategy analogs* inside this
framework (the paper compares whole systems; we reproduce each system's
optimization strategy over the same substrate so differences are
attributable to strategy, not plumbing):

  gpt-direct    whole-table single prompt — fails on context length
  table-llava   table rendered to an image — fails on image size
  tablerag      retrieve k=50 rows, answer from the subset only; cannot
                aggregate beyond its retrieval scope
  palimpzest    deterministic reorder rules (pushdown/reorder, Cascades
                style, zero-cost optimizer) + strongest backend everywhere
  lotus         no logical rewriting; per-operator model cascade with the
                strongest model as final arbiter (proxy-style)
  nirvana       this paper: agentic logical optimizer + improvement-score
                physical optimizer
"""
from __future__ import annotations

import dataclasses
import json
import os
import random
import sys
from typing import Any, Dict, List, Optional

from repro.core import backends as bk
from repro.core import cost_model as cm
from repro.core import executor as ex
from repro.core import logical_optimizer as lopt
from repro.core import physical_optimizer as popt
from repro.core import plan as plan_ir
from repro.core import rewriter as rw
from repro.core import runtime as rt
from repro.core import semhash
from repro.core.cost import DEFAULT_TIERS, TierSpec
from repro.core.backends import SimulatedBackend
from repro.data import WORKLOADS, load_dataset

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                       "bench")

# context windows for the failure-mode baselines (tokens / pixels)
GPT_CONTEXT_LIMIT = 128_000
LLAVA_PIXEL_LIMIT = 178_956_970

# execution driver for every system analog: "simulated" (event-model wall,
# deterministic — the default every table in the paper is reproduced with)
# or "threads" (real per-tier worker pools, measured wall).
# ``benchmarks.run --driver`` overrides it process-wide.
DRIVER = "simulated"

# cross-morsel batch coalescing for every system analog (only active with
# batch_size > 1). ``benchmarks.run --no-coalesce`` turns it off
# process-wide to measure the per-morsel ragged-batch baseline.
COALESCE = True

# morsel-parallel shard workers for every system analog (1 = unsharded;
# results/calls/meters are shard-count invariant, wall is not).
# ``benchmarks.run --shards`` overrides it process-wide.
SHARDS = 1

# tier-0 embedding cascade for the nirvana analog: when on, the execution
# context carries a ``core.cascade.CascadeRouter`` (hashing encoder) and
# the physical optimizer calibrates/adopts bands per operator from the
# capability sample — operators whose sample fails the improvement gate
# simply run un-cascaded. ``benchmarks.run --cascade`` turns it on
# process-wide.
CASCADE = False


def set_driver(name: str) -> None:
    global DRIVER
    if name not in rt.DRIVERS:
        raise ValueError(f"unknown driver {name!r} (expected {rt.DRIVERS})")
    DRIVER = name


def set_coalesce(flag: bool) -> None:
    global COALESCE
    COALESCE = bool(flag)


def set_shards(n: int) -> None:
    global SHARDS
    SHARDS = max(1, int(n))


def set_cascade(flag: bool) -> None:
    global CASCADE
    CASCADE = bool(flag)


def add_driver_arg(ap) -> None:
    import argparse
    ap.add_argument("--driver", choices=rt.DRIVERS, default=None,
                    help="execution driver for all system analogs "
                         "(default: simulated)")
    ap.add_argument("--coalesce", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="cross-morsel batch coalescing for batched runs "
                         "(default: on)")
    ap.add_argument("--shards", type=int, default=None,
                    help="morsel-parallel shard workers for all system "
                         "analogs (default: 1)")
    ap.add_argument("--cascade", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="tier-0 embedding cascade for the nirvana analog "
                         "(optimizer-calibrated bands; default: off)")


def env(dataset: str, max_rows: int = 0, violation_rate: float = 0.03,
        seed: int = 0):
    table, oracle = load_dataset(dataset, max_rows=max_rows)
    backends = bk.make_backends(oracle, violation_rate=violation_rate,
                                seed=seed)
    perfect = {"m*": SimulatedBackend(
        TierSpec("m*", 1.01, 0.0, 0.0, 0.0, 0.0), oracle,
        violation_rate=0.0)}
    return table, oracle, backends, perfect


def truth_of(plan, table, perfect):
    return ex.execute(plan, table, perfect, default_tier="m*").value()


def answer_correct(got, want) -> bool:
    if want is None:
        return got is None
    if isinstance(want, (int, float)) and isinstance(got, (int, float)):
        scale = max(abs(float(want)), 1e-9)
        return abs(float(got) - float(want)) / scale < 0.05
    if hasattr(want, "columns"):
        if not hasattr(got, "columns"):
            return False
        a = set(got.columns.get(ex.ROWID, []))
        b = set(want.columns.get(ex.ROWID, []))
        if not b:
            return not a
        return 2 * len(a & b) / max(1, len(a) + len(b)) > 0.9
    if got is None:
        return False
    return semhash.semantic_equal(got, want)


@dataclasses.dataclass
class RunResult:
    system: str
    dataset: str
    qid: str
    size: str
    wall_s: float
    usd: float
    correct: Optional[bool]
    opt_wall_s: float = 0.0
    opt_usd: float = 0.0
    exec_wall_s: float = 0.0
    exec_usd: float = 0.0
    detail: dict = dataclasses.field(default_factory=dict)


# ---------------------------------------------------------------------------
# System analogs
# ---------------------------------------------------------------------------

def run_nirvana(q, table, backends, perfect, *, logical=True, physical=True,
                rules=None, estimator="approx", n_iterations=3, seed=0,
                rewriter=None, batch_size=1, concurrency=16,
                driver=None, coalesce=None, linger=None,
                cascade=None, cost_model=None) -> RunResult:
    plan = q.plan_for(table)
    truth = truth_of(plan, table, perfect)
    router = None
    if CASCADE if cascade is None else cascade:
        from repro.core import cascade as casc
        router = casc.CascadeRouter(casc.EmbeddingBackend())
    # a fresh calibrated cost model per run unless the caller supplies one
    # to carry calibration across runs (latency_weight 0 = today's pure-USD
    # choices; the executor's finalize sync points feed it measurements)
    if cost_model is None:
        cost_model = cm.CostModel()
    # one ExecutionContext for the whole pipeline (optimizers meter their
    # own phases; the final execution bills into ctx.meter)
    ctx = rt.ExecutionContext(backends=backends, default_tier="m*",
                              concurrency=concurrency,
                              batch_size=batch_size,
                              driver=driver or DRIVER,
                              coalesce=COALESCE if coalesce is None
                              else coalesce,
                              linger_s=linger,
                              shards=SHARDS,
                              cascade=router,
                              cost_model=cost_model)
    opt_wall = opt_usd = 0.0
    lres = pres = None
    if logical:
        # configs inherit concurrency/tier from ctx
        cfg = lopt.LogicalOptConfig(n_iterations=n_iterations, seed=seed)
        rewr = rewriter
        if rewr is None and rules is not None:
            rewr = rw.LLMSimRewriter(rule_names=rules)
        lres = lopt.optimize(plan, table, ctx, rewriter=rewr, cfg=cfg)
        plan = lres.best
        opt_wall += lres.opt_wall_s
        opt_usd += lres.meter.total.usd
    if physical and plan.n_llm_ops:
        pres = popt.optimize(plan, table, ctx,
                             cfg=popt.PhysicalOptConfig(
                                 estimator=estimator, seed=seed))
        plan = pres.plan
        opt_wall += pres.opt_wall_s
        opt_usd += pres.meter.total.usd
    run = ex.execute(plan, table, ctx)
    name = "nirvana" if (logical and physical) else \
        ("nirvana-no-logical" if physical else
         ("nirvana-no-physical" if logical else "nirvana-no-opt"))
    return RunResult(
        system=name, dataset=table.name, qid=q.qid, size=q.size,
        wall_s=opt_wall + run.wall_s, usd=opt_usd + run.meter.total.usd,
        correct=answer_correct(run.value(), truth),
        opt_wall_s=opt_wall, opt_usd=opt_usd,
        exec_wall_s=run.wall_s, exec_usd=run.meter.total.usd,
        detail={"plan": plan.describe(),
                "rows_processed": run.rows_processed,
                "cascades": dict(pres.cascades) if pres is not None else {},
                "cascade_stats": run.cascade_stats,
                "exec_by_tier": {t: dataclasses.asdict(u) for t, u in
                                 run.meter.by_tier.items()}})


def run_palimpzest_analog(q, table, backends, perfect) -> RunResult:
    """Cascades-style: deterministic reorder rules, zero-cost optimizer,
    strongest backend for every operator."""
    plan = q.plan_for(table)
    truth = truth_of(plan, table, perfect)
    teacher = rw.GreedyRuleRewriter(
        rule_names=("filter_pushdown", "filter_reorder"),
        n_rows=table.n_rows)
    rng = random.Random(0)
    for _ in range(3):
        oc = teacher.rewrite(plan, rng)
        if oc.plan is None or oc.plan.signature() == plan.signature():
            break
        plan = oc.plan
    run = ex.execute(plan, table,
                     rt.ExecutionContext(backends=backends,
                                         default_tier="m*", driver=DRIVER,
                                         shards=SHARDS))
    return RunResult("palimpzest", table.name, q.qid, q.size,
                     run.wall_s, run.meter.total.usd,
                     answer_correct(run.value(), truth),
                     exec_wall_s=run.wall_s, exec_usd=run.meter.total.usd)


def run_lotus_analog(q, table, backends, perfect) -> RunResult:
    """No logical rewriting; proxy-cascade execution: the helper (m1) runs
    everything, the strongest model re-checks low-margin records — modeled
    as physical optimization with the exact estimator and no rewrites."""
    plan = q.plan_for(table)
    truth = truth_of(plan, table, perfect)
    ctx = rt.ExecutionContext(backends=backends, default_tier="m*",
                              driver=DRIVER, shards=SHARDS)
    pres = popt.optimize(plan, table, ctx,
                         cfg=popt.PhysicalOptConfig(estimator="exact"))
    run = ex.execute(pres.plan, table, ctx)
    return RunResult("lotus", table.name, q.qid, q.size,
                     pres.opt_wall_s + run.wall_s,
                     pres.meter.total.usd + run.meter.total.usd,
                     answer_correct(run.value(), truth),
                     opt_wall_s=pres.opt_wall_s,
                     opt_usd=pres.meter.total.usd,
                     exec_wall_s=run.wall_s, exec_usd=run.meter.total.usd)


def run_tablerag_analog(q, table, backends, perfect, k: int = 50
                        ) -> RunResult:
    """Retrieval-augmented: answers from a fixed k-row retrieval scope.
    Constant-ish cost; aggregations over the full table are out of scope
    (the paper measures 0% quality)."""
    plan = q.plan_for(table)
    truth = truth_of(plan, table, perfect)
    sub = table.head(k)
    run = ex.execute(plan, sub,
                     rt.ExecutionContext(backends=backends,
                                         default_tier="m1", driver=DRIVER,
                                         shards=SHARDS))
    got = run.value()
    correct = answer_correct(got, truth)
    return RunResult("tablerag", table.name, q.qid, q.size,
                     run.wall_s, run.meter.total.usd, correct,
                     exec_wall_s=run.wall_s, exec_usd=run.meter.total.usd)


def run_gpt_direct(q, table, backends, perfect) -> RunResult:
    """Whole-table-in-one-prompt: token count exceeds the context window on
    every benchmark table (the paper's X entries)."""
    tokens = sum(cm.DEFAULT_MODEL.text_tokens(v) for c in table.columns
                 for v in table.columns[c])
    ok = tokens < GPT_CONTEXT_LIMIT
    return RunResult("gpt-direct", table.name, q.qid, q.size,
                     0.0, 0.0, False if not ok else None,
                     detail={"prompt_tokens": tokens,
                             "context_limit": GPT_CONTEXT_LIMIT})


def run_table_llava(q, table, backends, perfect) -> RunResult:
    """Table-as-image: rendered pixel count exceeds the model limit beyond
    small tables (the paper's X entries for Estate/Game)."""
    px_per_cell = 120 * 28
    px = table.n_rows * len(table.columns) * px_per_cell
    ok = px < LLAVA_PIXEL_LIMIT
    return RunResult("table-llava", table.name, q.qid, q.size,
                     6.0 if ok else 0.0, 0.0, False,
                     detail={"pixels": px, "limit": LLAVA_PIXEL_LIMIT})


# ---------------------------------------------------------------------------
# Output helpers
# ---------------------------------------------------------------------------

def emit(name: str, rows: List[dict]) -> None:
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1, default=str)
    print(f"[{name}] wrote {len(rows)} rows -> {path}", file=sys.stderr)


# repo root, where benchmark modules drop their headline BENCH_*.json files
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TRAJECTORY = os.path.join(ROOT, "BENCH_trajectory.json")


def write_trajectory() -> dict:
    """Aggregate every root ``BENCH_*.json`` into one machine-readable
    ``BENCH_trajectory.json`` keyed by benchmark name, so the perf
    trajectory across PRs is a single document instead of a glob. Lives
    here (not ``benchmarks.run``) so a single benchmark module can
    refresh the trajectory without importing the whole aggregator."""
    import glob
    doc = {}
    for path in sorted(glob.glob(os.path.join(ROOT, "BENCH_*.json"))):
        name = os.path.basename(path)[len("BENCH_"):-len(".json")]
        if name == "trajectory":
            continue
        try:
            with open(path) as f:
                doc[name] = json.load(f)
        except (OSError, ValueError) as e:
            doc[name] = {"error": f"{type(e).__name__}: {e}"}
    with open(TRAJECTORY, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    print(f"[trajectory] {len(doc)} benchmark files -> {TRAJECTORY}",
          file=sys.stderr)
    return doc


def fmt_table(rows: List[dict], cols: List[str]) -> str:
    widths = {c: max(len(c), *(len(f"{r.get(c, '')}") for r in rows))
              for c in cols}
    out = ["  ".join(c.ljust(widths[c]) for c in cols)]
    for r in rows:
        out.append("  ".join(f"{r.get(c, '')}".ljust(widths[c])
                             for c in cols))
    return "\n".join(out)
