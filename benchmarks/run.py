"""Benchmark aggregator — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Prints each table and a final ``name,value,derived`` CSV summary, writing
per-benchmark JSON artifacts under artifacts/bench/.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import common
from benchmarks.common import ROOT, TRAJECTORY, write_trajectory
from benchmarks import (appendix_d_search, bench_cascade, bench_coalesce,
                        bench_fault, bench_qos, bench_serve, bench_shard,
                        fig9_fig10_breakdown,
                        fig13_cardinality, fig14_batch_prompting,
                        roofline_report, table2_capability,
                        table4_runtime_cost, table5_quality,
                        table6_optimizer_overhead, table7_judge,
                        table8_semantics_ablation, table9_smart)

BENCHES = [
    ("bench_coalesce", lambda q: bench_coalesce.run(
        max_rows=48 if q else 96)),
    ("bench_shard", lambda q: bench_shard.run(
        max_rows=48 if q else 96)),
    ("bench_serve", lambda q: bench_serve.run(
        sleep_s=0.03 if q else 0.05)),
    ("bench_qos", lambda q: bench_qos.run(
        delay_s=0.015 if q else 0.02, floods=4 if q else 6,
        probes=4 if q else 6)),
    ("bench_cascade", lambda q: bench_cascade.run(
        n_rows=128 if q else 256)),
    ("bench_fault", lambda q: bench_fault.run(
        n_queries=12 if q else 24, n_rows=24 if q else 32)),
    ("table2_capability", lambda q: table2_capability.run(
        n=200 if q else 500)),
    ("table4_runtime_cost", lambda q: table4_runtime_cost.run(
        datasets=("movie",) if q else ("movie", "estate", "game"))),
    ("table5_quality", lambda q: table5_quality.run(
        datasets=("movie",) if q else ("movie", "estate", "game"))),
    ("table6_optimizer_overhead", lambda q: table6_optimizer_overhead.run()),
    ("table7_judge", lambda q: table7_judge.run(
        datasets=("movie",) if q else ("movie", "estate", "game"))),
    ("table8_semantics_ablation", lambda q: table8_semantics_ablation.run(
        datasets=("movie",) if q else ("movie", "estate"))),
    ("table9_smart", lambda q: table9_smart.run()),
    ("fig9_fig10_breakdown", lambda q: fig9_fig10_breakdown.run(
        datasets=("movie",) if q else ("movie", "estate", "game"))),
    ("fig13_cardinality", lambda q: fig13_cardinality.run()),
    ("fig14_batch_prompting", lambda q: fig14_batch_prompting.run(
        datasets=("movie",) if q else ("movie", "estate"))),
    ("appendix_d_search", lambda q: appendix_d_search.run(
        datasets=("movie",) if q else ("movie", "estate"))),
    ("roofline_report", lambda q: roofline_report.run()),
]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller datasets / fewer samples")
    ap.add_argument("--only", default="",
                    help="run a single benchmark by name substring")
    common.add_driver_arg(ap)
    args = ap.parse_args(argv)
    if args.driver:
        common.set_driver(args.driver)
    if args.coalesce is not None:
        common.set_coalesce(args.coalesce)
    if args.shards is not None:
        common.set_shards(args.shards)
    if args.cascade is not None:
        common.set_cascade(args.cascade)

    summary = []
    n_fail = 0
    for name, fn in BENCHES:
        if args.only and args.only not in name:
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        try:
            fn(args.quick)
            status = "ok"
        except Exception as e:
            status = f"FAIL: {type(e).__name__}: {e}"
            traceback.print_exc(limit=4)
            n_fail += 1
        summary.append((name, round(time.time() - t0, 1), status))

    write_trajectory()

    print("\n===== summary (name,seconds,status) =====")
    for name, dt, status in summary:
        print(f"{name},{dt},{status}")
    if n_fail:
        sys.exit(1)


if __name__ == "__main__":
    main()
