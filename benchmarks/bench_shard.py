"""Morsel-parallel sharding benchmark — the repo's shard-scaling
perf trajectory.

A selective filter -> map -> filter pipeline runs at
``shards in {1, 2, 4}`` x ``batch_size in {1, 8}``:

* simulated driver: LLM calls, usd, and event-model wall per config, with
  byte-identical results checked across every shard count (the
  shard-count-invariance contract: sharding changes *where* morsels run,
  never what they answer or bill);
* threads driver: *measured* wall over a really-sleeping backend
  (``repro.testing.SleepBackend``) at 1 vs 4 shards — each shard worker
  is its own replica (``concurrency`` workers per (shard, tier) pool), so
  4 shards must deliver a >= 1.5x measured speedup with byte-identical
  results.

A third section locates the **GIL knee**: the same pipeline plus a
host-UDF tail over a ``testing.GilBoundBackend`` — every call holds a
process-global lock for its compute (the GIL model; see the fake's
docstring for why modeled rather than burned CPU). Thread shards cannot
scale this workload at any width (one interpreter, one lock); process
shard workers (``driver="procs"``) must deliver >= 1.8x measured wall at
4 workers vs 4 thread shards, with byte-identical results across both
substrates and all shard counts.

Writes ``artifacts/bench/BENCH_shard.json`` (one row per config) and a
repo-root ``BENCH_shard.json`` summary for the perf trajectory
(refreshed into ``BENCH_trajectory.json``).
"""
from __future__ import annotations

import json
import os
import time

from repro.core import backends as bk
from repro.core import executor as ex
from repro.core import plan as plan_ir
from repro.data import load_dataset
from repro import testing
from repro.distributed.morsel_shards import ShardedDispatcher
from repro.testing import GilBoundBackend, SleepBackend

from benchmarks import common

MORSEL = 8
SHARD_COUNTS = (1, 2, 4)
ROOT_SUMMARY = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_shard.json")


def _pipeline():
    return plan_ir.LogicalPlan((
        plan_ir.Operator(plan_ir.FILTER, "The rating is higher than 8.",
                         "IMDB_rating"),
        plan_ir.Operator(plan_ir.MAP, "According to the movie plot, "
                         "extract the genre(s) of each movie.", "Plot",
                         "Genre"),
        plan_ir.Operator(plan_ir.FILTER, "The movie is directed by "
                         "Christopher Nolan.", "Director"),
    ))


def _result_key(res):
    t = res.table
    return (tuple(t.columns[ex.ROWID]), tuple(map(str, t.columns["Genre"])))


def run(max_rows: int = 96, sleep_s: float = 0.02):
    table, oracle = load_dataset("movie", max_rows=max_rows)
    plan = _pipeline()
    rows = []

    # -- simulated driver: deterministic calls/usd/wall sweep -------------
    results = {}
    for batch in (1, 8):
        for shards in SHARD_COUNTS:
            meter = bk.UsageMeter()
            res = ex.execute(plan, table, bk.make_backends(oracle),
                             default_tier="m*", batch_size=batch,
                             morsel_size=MORSEL, meter=meter,
                             shards=shards, driver="simulated")
            results[(batch, shards)] = _result_key(res)
            rows.append({
                "driver": "simulated", "batch": batch, "shards": shards,
                "calls": meter.total.calls,
                "usd": round(meter.total.usd, 6),
                "wall_s": round(res.wall_s, 4)})
        for shards in SHARD_COUNTS[1:]:
            if results[(batch, shards)] != results[(batch, 1)]:
                raise AssertionError(
                    f"sharding changed the answer at batch={batch} "
                    f"shards={shards}")
        calls = {r["shards"]: r["calls"] for r in rows
                 if r["driver"] == "simulated" and r["batch"] == batch}
        if len(set(calls.values())) != 1:
            raise AssertionError(
                f"sharding changed call counts at batch={batch}: {calls}")

    # -- threads driver: measured wall over a really-sleeping backend -----
    threads_results = {}
    for shards in (1, 4):
        walls, meter, res = [], None, None
        for _ in range(3):          # median of 3: thread scheduling jitter
            backend = SleepBackend(oracle, delay_s=sleep_s)
            meter = bk.UsageMeter()
            res = ex.execute(plan, table, {"m*": backend},
                             default_tier="m*", batch_size=1,
                             morsel_size=MORSEL, meter=meter,
                             concurrency=4, shards=shards,
                             driver="threads")
            walls.append(res.wall_s)
        threads_results[shards] = _result_key(res)
        rows.append({
            "driver": "threads", "batch": 1, "shards": shards,
            "calls": meter.total.calls, "usd": round(meter.total.usd, 6),
            "wall_s": round(sorted(walls)[1], 4),
            "walls": [round(w, 4) for w in walls]})
    if threads_results[4] != threads_results[1]:
        raise AssertionError("threads sharding changed the answer")

    # -- GIL-bound workload: the thread-scaling knee vs process workers --
    # parse/host-UDF-heavy shape: every LLM call holds the GIL-model lock
    # for its compute, plus a host-UDF tail that crosses the process
    # boundary under the procs driver. Built from the picklable testing
    # fakes (KindOracle) — the dataset InstructionOracle registers local
    # closures and cannot ship to worker processes.
    gil_table = testing.tagged_table("gil", max_rows)
    gil_plan = plan_ir.LogicalPlan((
        plan_ir.Operator(plan_ir.FILTER, "keep-gil", "v"),
        plan_ir.Operator(plan_ir.MAP, "annotate-gil", "v", "a"),
        plan_ir.Operator(plan_ir.MAP, "canonicalize casing", "a", "b",
                         udf="lambda x: str(x).upper()"),))

    def gil_key(res):
        t = res.table
        return (tuple(t.columns[ex.ROWID]),
                tuple(map(str, t.columns["a"])),
                tuple(map(str, t.columns["b"])))

    gil_results, gil_walls = {}, {}
    for driver in ("threads", "procs"):
        for shards in SHARD_COUNTS:
            backend = GilBoundBackend(testing.KindOracle(), work_s=0.004)
            # dispatcher built outside the timed region: spawn cost is a
            # per-server startup price, not per-query wall
            disp = ShardedDispatcher(shards=shards, driver=driver,
                                     concurrency=4,
                                     backends={"m*": backend})
            walls, meter, res = [], None, None
            try:
                for _ in range(3):      # median of 3: scheduling jitter
                    meter = bk.UsageMeter()
                    t0 = time.perf_counter()
                    res = ex.execute(gil_plan, gil_table, {"m*": backend},
                                     default_tier="m*", batch_size=1,
                                     morsel_size=MORSEL, meter=meter,
                                     dispatcher=disp)
                    walls.append(time.perf_counter() - t0)
            finally:
                disp.close()
            gil_results[(driver, shards)] = gil_key(res)
            gil_walls[(driver, shards)] = sorted(walls)[1]
            rows.append({
                "driver": f"{driver}-gil", "batch": 1, "shards": shards,
                "calls": meter.total.calls,
                "usd": round(meter.total.usd, 6),
                "wall_s": round(sorted(walls)[1], 4),
                "walls": [round(w, 4) for w in walls]})
    if len(set(gil_results.values())) != 1:
        raise AssertionError(
            "GIL-bound results differ across substrates/shard counts")
    gil_speedup = gil_walls[("threads", 4)] / max(gil_walls[("procs", 4)],
                                                  1e-9)

    def row_of(driver, batch, shards):
        return next(r for r in rows if r["driver"] == driver
                    and r["batch"] == batch and r["shards"] == shards)

    t1 = row_of("threads", 1, 1)
    t4 = row_of("threads", 1, 4)
    speedup = t1["wall_s"] / max(t4["wall_s"], 1e-9)
    summary = {
        "driver": "summary", "batch": 1, "shards": 4,
        "calls": t4["calls"],
        "threads_wall_1shard_s": t1["wall_s"],
        "threads_wall_4shard_s": t4["wall_s"],
        "threads_speedup_4x_vs_1x": round(speedup, 3),
        "simulated_calls_batch1": row_of("simulated", 1, 1)["calls"],
        "simulated_calls_batch8": row_of("simulated", 8, 1)["calls"],
        "results_identical_across_shards": True,
        "gil_threads_walls_s": {s: round(gil_walls[("threads", s)], 4)
                                for s in SHARD_COUNTS},
        "gil_procs_walls_s": {s: round(gil_walls[("procs", s)], 4)
                              for s in SHARD_COUNTS},
        "gil_procs_speedup_4w_vs_4threads": round(gil_speedup, 3),
    }
    rows.append(summary)
    common.emit("BENCH_shard", rows)
    with open(ROOT_SUMMARY, "w") as f:
        json.dump(summary, f, indent=1)
    common.write_trajectory()
    print(common.fmt_table(
        [r for r in rows if r["driver"] != "summary"],
        ["driver", "batch", "shards", "calls", "usd", "wall_s"]))
    print(f"[bench_shard] threads wall {t1['wall_s']:.3f}s (1 shard) -> "
          f"{t4['wall_s']:.3f}s (4 shards): {speedup:.2f}x speedup, "
          f"byte-identical results")
    print(f"[bench_shard] GIL-bound: threads "
          f"{gil_walls[('threads', 1)]:.3f}s / "
          f"{gil_walls[('threads', 4)]:.3f}s (1 / 4 shards — the knee) vs "
          f"procs {gil_walls[('procs', 4)]:.3f}s (4 workers): "
          f"{gil_speedup:.2f}x past the knee")
    if speedup < 1.5:
        raise AssertionError(
            f"4-shard threads speedup {speedup:.2f}x < 1.5x target")
    if gil_speedup < 1.8:
        raise AssertionError(
            f"4-process-worker GIL-bound speedup {gil_speedup:.2f}x "
            f"< 1.8x target")
    return rows


if __name__ == "__main__":
    run()
