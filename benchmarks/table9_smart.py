"""Table 9 — physical optimization overhead: Smart variants vs Nirvana.

q3 on Estate (single filter), same candidate tiers. Smart exhaustive /
efficient / multi-model vs Nirvana's improvement-score optimizer in
synchronous and asynchronous modes. Optimization time, execution time, and
the optimization:execution ratio.
"""
from __future__ import annotations

from repro.core import executor as ex
from repro.core import physical_optimizer as popt
from repro.core.backends import UsageMeter
from repro.data import WORKLOADS
from benchmarks import common


def run():
    table, oracle, backends, perfect = common.env("estate")
    q = WORKLOADS["estate"][2]           # q3: single filter
    plan = q.plan_for(table)
    op = plan.ops[0]
    sample = table.sample(52, seed=0)    # 5% of 1041
    values = sample.resolve(op.input_column)
    rows = []

    for variant in ("exhaustive", "efficient", "multi-model"):
        meter = UsageMeter()
        tier, scores, meter = popt.smart_select(
            op, values, backends, delta_min=0.2, variant=variant,
            meter=meter)
        opt_lat = meter.total.latency_s           # Smart is sequential
        run_ex = ex.execute(plan.with_tiers({0: tier}), table, backends,
                            concurrency=1)        # non-parallel, as Smart
        rows.append({"system": f"smart ({variant})",
                     "opt_time_s": round(opt_lat, 2),
                     "exec_time_s": round(run_ex.wall_s, 2),
                     "ratio": f"{100 * opt_lat / max(run_ex.wall_s, 1e-9):.2f}%",
                     "tier": tier})

    for mode, conc in (("sync", 1), ("async", 16)):
        res = popt.optimize(plan, table, backends,
                            cfg=popt.PhysicalOptConfig(mode=mode,
                                                       concurrency=conc))
        run_ex = ex.execute(res.plan, table, backends, concurrency=conc)
        rows.append({"system": f"nirvana ({mode})",
                     "opt_time_s": round(res.opt_wall_s, 2),
                     "exec_time_s": round(run_ex.wall_s, 2),
                     "ratio": f"{100 * res.opt_wall_s / max(run_ex.wall_s, 1e-9):.2f}%",
                     "tier": res.assignments.get(0)})
    rows.append({"system": "paper: smart exhaustive 59.06s/626.77s; "
                 "nirvana sync 13.11/674.56, async 4.12/66.47",
                 "opt_time_s": "", "exec_time_s": "", "ratio": "",
                 "tier": ""})
    common.emit("table9_smart", rows)
    print(common.fmt_table(rows, ["system", "opt_time_s", "exec_time_s",
                                  "ratio", "tier"]))
    return rows


if __name__ == "__main__":
    run()
