"""Appendix C (Fig. 14) — batch prompting: cost savings vs quality.

Nirvana with batch sizes 1 / 3 / 4 on Movie and Estate.
"""
from __future__ import annotations

from repro.data import WORKLOADS
from benchmarks import common


def run(datasets=("movie", "estate")):
    rows = []
    for ds in datasets:
        table, oracle, backends, perfect = common.env(ds)
        for bsz in (1, 3, 4):
            usd = 0.0
            ok = 0
            n = 0
            for q in WORKLOADS[ds]:
                r = common.run_nirvana(q, table, backends, perfect,
                                       seed=hash(q.qid) % 61,
                                       batch_size=bsz)
                usd += r.usd
                ok += bool(r.correct)
                n += 1
            rows.append({"dataset": ds, "batch": bsz,
                         "total_usd": round(usd, 4),
                         "quality": f"{100 * ok / n:.1f}%"})
        base = next(r for r in rows if r["dataset"] == ds and r["batch"] == 1)
        for r in rows:
            if r["dataset"] == ds and r["batch"] > 1:
                r["usd_saving"] = round(base["total_usd"] - r["total_usd"],
                                        5)
    common.emit("fig14_batch_prompting", rows)
    print(common.fmt_table(rows, ["dataset", "batch", "total_usd",
                                  "usd_saving", "quality"]))
    return rows


if __name__ == "__main__":
    run()
