"""Table 4 — end-to-end runtime + monetary cost across systems.

Mean per-query wall-clock (simulated latency model, 16-way concurrency) and
USD for Table-LLaVA / TableRAG / Palimpzest / Lotus strategy-analogs vs
Nirvana, per dataset x workload size.
"""
from __future__ import annotations

import statistics

from repro.data import WORKLOADS
from benchmarks import common

GAME_ROWS = 3000   # game scaled for bench runtime; per-record costs scale
                   # linearly so Δ% columns are row-count invariant


def run(datasets=("movie", "estate", "game")):
    rows = []
    for ds in datasets:
        table, oracle, backends, perfect = common.env(
            ds, max_rows=GAME_ROWS if ds == "game" else 0)
        per_size = {}
        for q in WORKLOADS[ds]:
            runs = {
                "table-llava": common.run_table_llava(q, table, backends,
                                                      perfect),
                "tablerag": common.run_tablerag_analog(q, table, backends,
                                                       perfect),
                "palimpzest": common.run_palimpzest_analog(q, table,
                                                           backends,
                                                           perfect),
                "lotus": common.run_lotus_analog(q, table, backends,
                                                 perfect),
                "nirvana": common.run_nirvana(q, table, backends, perfect,
                                              seed=hash(q.qid) % 97),
            }
            per_size.setdefault(q.size, []).append(runs)
        for size, entries in per_size.items():
            row = {"dataset": ds, "workload": size}
            for sysname in ("table-llava", "tablerag", "palimpzest",
                            "lotus", "nirvana"):
                ws = [e[sysname].wall_s for e in entries]
                us = [e[sysname].usd for e in entries]
                row[f"{sysname}_time_s"] = round(statistics.mean(ws), 3)
                row[f"{sysname}_usd"] = round(statistics.mean(us), 4)
            best_other = min(row["palimpzest_time_s"], row["lotus_time_s"])
            best_cost = min(row["palimpzest_usd"], row["lotus_usd"])
            row["d_time_pct"] = round(
                100 * (1 - row["nirvana_time_s"] / best_other), 1) \
                if best_other else 0.0
            row["d_cost_pct"] = round(
                100 * (1 - row["nirvana_usd"] / best_cost), 1) \
                if best_cost else 0.0
            rows.append(row)
    common.emit("table4_runtime_cost", rows)
    print(common.fmt_table(rows, ["dataset", "workload",
                                  "tablerag_time_s", "palimpzest_time_s",
                                  "lotus_time_s", "nirvana_time_s",
                                  "palimpzest_usd", "lotus_usd",
                                  "nirvana_usd", "d_time_pct",
                                  "d_cost_pct"]))
    return rows


if __name__ == "__main__":
    run()
