"""Table 6 — logical-optimizer overhead vs a Cascades-style optimizer.

q10 on Estate: optimization time/cost + execution time/cost for Nirvana's
agentic optimizer vs the zero-cost deterministic Cascades analog
(Palimpzest strategy).
"""
from __future__ import annotations

from repro.data import WORKLOADS
from benchmarks import common


def run():
    table, oracle, backends, perfect = common.env("estate")
    q = WORKLOADS["estate"][9]          # q10
    pz = common.run_palimpzest_analog(q, table, backends, perfect)
    nv = common.run_nirvana(q, table, backends, perfect, physical=False,
                            n_iterations=6, seed=0)
    rows = [
        {"system": "palimpzest (Cascades)", "opt_time_s": 0.0,
         "opt_usd": 0.0, "exec_time_s": round(pz.exec_wall_s, 1),
         "exec_usd": round(pz.exec_usd, 4)},
        {"system": "nirvana (agentic)", "opt_time_s": round(nv.opt_wall_s, 1),
         "opt_usd": round(nv.opt_usd, 4),
         "exec_time_s": round(nv.exec_wall_s, 1),
         "exec_usd": round(nv.exec_usd, 4)},
    ]
    rows.append({
        "system": "paper reference", "opt_time_s": 9.8, "opt_usd": 0.0082,
        "exec_time_s": 99.1, "exec_usd": 0.038,
    })
    common.emit("table6_optimizer_overhead", rows)
    print(common.fmt_table(rows, ["system", "opt_time_s", "opt_usd",
                                  "exec_time_s", "exec_usd"]))
    return rows


if __name__ == "__main__":
    run()
