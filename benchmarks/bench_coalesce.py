"""Cross-morsel batch coalescing benchmark — the repo's perf trajectory.

A filter -> map -> filter pipeline (selective filters emit ragged morsels)
runs at ``batch_size in {1, 4, 8}`` with coalescing on and off:

* simulated driver: LLM calls, usd, and event-model wall per config, with
  byte-identical results checked between the coalesced and whole-table
  groupings;
* threads driver: *measured* wall over a real sleeping backend at
  ``batch_size=8``, coalesced vs per-morsel — coalescing must cut calls
  by >= 30% on this pipeline without regressing measured wall.

Writes ``artifacts/bench/BENCH_coalesce.json`` (one row per config).
"""
from __future__ import annotations

from repro.core import backends as bk
from repro.core import executor as ex
from repro.core import plan as plan_ir
from repro.data import load_dataset
from repro.testing import SleepBackend

from benchmarks import common

MORSEL = 8


def _pipeline():
    return plan_ir.LogicalPlan((
        plan_ir.Operator(plan_ir.FILTER, "The rating is higher than 8.",
                         "IMDB_rating"),
        plan_ir.Operator(plan_ir.MAP, "According to the movie plot, "
                         "extract the genre(s) of each movie.", "Plot",
                         "Genre"),
        plan_ir.Operator(plan_ir.FILTER, "The movie is directed by "
                         "Christopher Nolan.", "Director"),
    ))


def _result_key(res):
    t = res.table
    return (tuple(t.columns[ex.ROWID]), tuple(map(str, t.columns["Genre"])))


def run(max_rows: int = 96, sleep_s: float = 0.05):
    table, oracle = load_dataset("movie", max_rows=max_rows)
    plan = _pipeline()
    rows = []

    # -- simulated driver: deterministic calls/usd/wall sweep -------------
    results = {}
    for batch in (1, 4, 8):
        for coalesce in (False, True):
            meter = bk.UsageMeter()
            res = ex.execute(plan, table, bk.make_backends(oracle),
                             default_tier="m*", batch_size=batch,
                             morsel_size=MORSEL, meter=meter,
                             coalesce=coalesce, driver="simulated")
            results[(batch, coalesce)] = _result_key(res)
            rows.append({
                "driver": "simulated", "batch": batch,
                "coalesce": coalesce, "calls": meter.total.calls,
                "usd": round(meter.total.usd, 6),
                "wall_s": round(res.wall_s, 4),
                "stats": res.coalesce_stats})
        if results[(batch, True)] != results[(batch, False)]:
            raise AssertionError(
                f"coalescing changed the answer at batch={batch}")

    # -- threads driver: measured wall over a really-sleeping backend -----
    for coalesce in (False, True):
        walls, meter, res = [], None, None
        for _ in range(3):          # median of 3: thread scheduling jitter
            backend = SleepBackend(oracle, delay_s=sleep_s)
            meter = bk.UsageMeter()
            res = ex.execute(plan, table, {"m*": backend},
                             default_tier="m*", batch_size=8,
                             morsel_size=MORSEL, meter=meter,
                             concurrency=8, coalesce=coalesce,
                             driver="threads")
            walls.append(res.wall_s)
        rows.append({
            "driver": "threads", "batch": 8, "coalesce": coalesce,
            "calls": meter.total.calls, "usd": round(meter.total.usd, 6),
            "wall_s": round(sorted(walls)[1], 4),
            "walls": [round(w, 4) for w in walls],
            "stats": res.coalesce_stats})

    def row_of(driver, batch, coalesce):
        return next(r for r in rows if r["driver"] == driver
                    and r["batch"] == batch and r["coalesce"] == coalesce)

    base = row_of("simulated", 8, False)
    coal = row_of("simulated", 8, True)
    reduction = 1.0 - coal["calls"] / base["calls"]
    t_base = row_of("threads", 8, False)
    t_coal = row_of("threads", 8, True)
    summary = {
        "driver": "summary", "batch": 8, "coalesce": True,
        "calls": coal["calls"],
        "call_reduction_vs_per_morsel": round(reduction, 4),
        "threads_wall_base_s": t_base["wall_s"],
        "threads_wall_coalesced_s": t_coal["wall_s"],
    }
    rows.append(summary)
    common.emit("BENCH_coalesce", rows)
    print(common.fmt_table(
        [r for r in rows if r["driver"] != "summary"],
        ["driver", "batch", "coalesce", "calls", "usd", "wall_s"]))
    print(f"[bench_coalesce] batch=8 call reduction vs per-morsel: "
          f"{100 * reduction:.1f}%  threads wall {t_base['wall_s']:.3f}s "
          f"-> {t_coal['wall_s']:.3f}s")
    if reduction < 0.30:
        raise AssertionError(
            f"coalescing reduced calls by only {100 * reduction:.1f}% "
            f"(target >= 30%)")
    return rows


if __name__ == "__main__":
    run()
