"""Table 2 — model-capability-hypothesis alignment statistics.

GPT-4.1 vs GPT-4.1-nano on amenity extraction over 500 Estate records:
#aligned / #misaligned / #strong-is-right / #weak-is-right.
"""
from __future__ import annotations

from repro.core import plan as P
from repro.core import semhash
from benchmarks import common


def run(n: int = 500):
    table, oracle, backends, perfect = common.env("estate")
    op = P.Operator(P.MAP, "Extract Amenities of the estate from the "
                    "estate details.", "Details", "Amenities")
    values = table.column("Details")[:n]
    strong = backends["m*"].run_values(op, values)
    weak = backends["m1"].run_values(op, values)
    truth = [oracle.answer(op, v) for v in values]

    aligned = misaligned = strong_right = weak_right = 0
    for s, w, t in zip(strong, weak, truth):
        if semhash.semantic_equal(s, w):
            aligned += 1
            continue
        misaligned += 1
        strong_right += semhash.semantic_equal(s, t)
        weak_right += semhash.semantic_equal(w, t)
    rows = [{
        "n": n, "aligned": aligned, "misaligned": misaligned,
        "strong_is_right": strong_right, "weak_is_right": weak_right,
        "hypothesis_holds_frac": (strong_right / misaligned
                                  if misaligned else 1.0),
        "paper_reference": "424 / 76 / 69 / 7 (hypothesis ~0.91)",
    }]
    common.emit("table2_capability", rows)
    print(common.fmt_table(rows, ["n", "aligned", "misaligned",
                                  "strong_is_right", "weak_is_right",
                                  "hypothesis_holds_frac"]))
    return rows


if __name__ == "__main__":
    run()
