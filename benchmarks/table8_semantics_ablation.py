"""Table 8 — optimizer w/ vs w/o semantic transformations vs 2-step.

w/ sem    all four rules (incl. non-LLM replacement)
w/o sem   basic rules only (pushdown / reorder / fusion)
2-step    basic rules first, then the semantic rule greedily
"""
from __future__ import annotations

import random
import statistics

from repro.core import executor as ex
from repro.core import logical_optimizer as lopt
from repro.core import rewriter as rw
from repro.core import rules as rules_mod
from repro.data import WORKLOADS
from benchmarks import common


def _two_step(q, table, backends, perfect, seed):
    """Basic random-walk phase, then greedy semantic replacement."""
    plan = q.plan_for(table)
    res = lopt.optimize(
        plan, table, backends,
        rewriter=rw.LLMSimRewriter(rule_names=rules_mod.BASIC_RULES),
        cfg=lopt.LogicalOptConfig(n_iterations=3, seed=seed))
    plan2 = res.best
    teacher = rw.GreedyRuleRewriter(rule_names=rules_mod.SEMANTIC_RULES,
                                    n_rows=table.n_rows)
    rng = random.Random(seed)
    opt_wall = res.opt_wall_s
    opt_usd = res.meter.total.usd
    for _ in range(4):
        oc = teacher.rewrite(plan2, rng)
        opt_wall += oc.usage.latency_s
        opt_usd += oc.usage.usd
        if oc.plan is None or oc.plan.signature() == plan2.signature():
            break
        plan2 = oc.plan
    run = ex.execute(plan2, table, backends, default_tier="m*")
    return opt_wall, opt_usd, run.wall_s, run.meter.total.usd


def run(datasets=("movie", "estate")):
    rows = []
    for ds in datasets:
        table, oracle, backends, perfect = common.env(ds)
        for size in ("S", "M", "L"):
            acc = {"w_sem": [], "wo_sem": [], "two_step": []}
            for q in [x for x in WORKLOADS[ds] if x.size == size]:
                seed = hash((ds, q.qid)) % 89
                w = common.run_nirvana(q, table, backends, perfect,
                                       physical=False, seed=seed)
                acc["w_sem"].append((w.opt_wall_s, w.opt_usd,
                                     w.exec_wall_s, w.exec_usd))
                wo = common.run_nirvana(q, table, backends, perfect,
                                        physical=False,
                                        rules=rules_mod.BASIC_RULES,
                                        seed=seed)
                acc["wo_sem"].append((wo.opt_wall_s, wo.opt_usd,
                                      wo.exec_wall_s, wo.exec_usd))
                acc["two_step"].append(_two_step(q, table, backends,
                                                 perfect, seed))
            row = {"dataset": ds, "size": size}
            for name, vals in acc.items():
                row[f"opt_time_{name}"] = round(
                    statistics.mean(v[0] for v in vals), 2)
                row[f"overall_time_{name}"] = round(
                    statistics.mean(v[0] + v[2] for v in vals), 2)
                row[f"opt_cost_{name}"] = round(
                    statistics.mean(v[1] for v in vals), 4)
                row[f"overall_cost_{name}"] = round(
                    statistics.mean(v[1] + v[3] for v in vals), 4)
            rows.append(row)
    common.emit("table8_semantics_ablation", rows)
    print(common.fmt_table(rows, ["dataset", "size",
                                  "opt_time_w_sem", "opt_time_wo_sem",
                                  "opt_time_two_step",
                                  "overall_time_w_sem",
                                  "overall_time_wo_sem",
                                  "overall_time_two_step",
                                  "overall_cost_w_sem",
                                  "overall_cost_wo_sem",
                                  "overall_cost_two_step"]))
    return rows


if __name__ == "__main__":
    run()
