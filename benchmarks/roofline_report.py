"""§Roofline report — aggregates launch/dryrun artifacts into the
per-(arch x shape x mesh) roofline table (compute/memory/collective terms,
dominant bottleneck, useful-FLOPs ratio, roofline fraction).

Run the dry-run first:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out artifacts/dryrun
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks import common

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                          "dryrun")


def load_records(pattern: str = "*.json"):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run():
    recs = load_records()
    rows = []
    for r in recs:
        if r.get("skipped"):
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "status": "SKIP (sub-quadratic "
                         "only)"})
            continue
        if not r.get("ok"):
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "status": "FAIL"})
            continue
        roof = r.get("roofline", {})
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "ok",
            "compute_s": f"{roof.get('compute_s', 0):.3e}",
            "memory_s": f"{roof.get('memory_s', 0):.3e}",
            "collective_s": f"{roof.get('collective_s', 0):.3e}",
            "dominant": roof.get("dominant", "-"),
            "useful_flops": f"{roof.get('useful_flops_ratio', 0):.2f}",
            "roofline_frac": f"{roof.get('roofline_fraction', 0):.3f}",
            "bytes_per_dev_gb": f"{r.get('bytes_per_device', 0) / 2**30:.1f}",
        })
    if rows:
        common.emit("roofline_report", rows)
        print(common.fmt_table(
            rows, ["arch", "shape", "mesh", "status", "compute_s",
                   "memory_s", "collective_s", "dominant", "useful_flops",
                   "roofline_frac", "bytes_per_dev_gb"]))
    else:
        print("no dry-run artifacts found; run repro.launch.dryrun first")
    return rows


if __name__ == "__main__":
    run()
