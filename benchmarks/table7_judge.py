"""Table 7 — LLM-as-a-judge reliability + verification cost.

Run the logical optimizer with an error-injecting rewriter over every
query; score the judge's accept/reject against the rewrites' known
correctness: success rate, precision, recall, cost per query.
"""
from __future__ import annotations

from repro.core import logical_optimizer as lopt
from repro.core import rewriter as rw
from repro.data import WORKLOADS
from benchmarks import common

GAME_ROWS = 2000


def run(datasets=("movie", "estate", "game"), error_rate: float = 0.3):
    rows = []
    for ds in datasets:
        table, oracle, backends, perfect = common.env(
            ds, max_rows=GAME_ROWS if ds == "game" else 0)
        tp = fp = fn = tn = 0
        usd = 0.0
        n_queries = 0
        for q in WORKLOADS[ds]:
            rewriter = rw.LLMSimRewriter(error_rate=error_rate)
            res = lopt.optimize(
                q.plan_for(table), table, backends, rewriter=rewriter,
                cfg=lopt.LogicalOptConfig(n_iterations=4,
                                          seed=hash(q.qid) % 31))
            n_queries += 1
            usd += sum(u.usd for t, u in res.meter.by_tier.items()
                       if t == "m*")     # the judge-rating calls
            for c in res.candidates[1:]:
                if c.rewrite_correct is None:
                    continue
                if c.rewrite_correct and c.acc >= 0.8:
                    tp += 1
                elif not c.rewrite_correct and c.acc >= 0.8:
                    fp += 1
                elif c.rewrite_correct and c.acc < 0.8:
                    fn += 1
                else:
                    tn += 1
        total = tp + fp + fn + tn
        rows.append({
            "dataset": ds, "rewrites": total,
            "success_rate": f"{100 * (tp + tn) / max(1, total):.1f}%",
            "precision": f"{100 * tp / max(1, tp + fp):.1f}%",
            "recall": f"{100 * tp / max(1, tp + fn):.1f}%",
            "judge_usd_per_query": round(usd / max(1, n_queries), 4),
            "paper_success": {"movie": "81.6%", "estate": "90.0%",
                              "game": "86.7%"}[ds],
        })
    common.emit("table7_judge", rows)
    print(common.fmt_table(rows, ["dataset", "rewrites", "success_rate",
                                  "precision", "recall",
                                  "judge_usd_per_query", "paper_success"]))
    return rows


if __name__ == "__main__":
    run()
