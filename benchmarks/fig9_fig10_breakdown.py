"""Figures 9 & 10 — phase breakdown + per-model-tier breakdown.

Fig. 9: runtime/cost split across logical optimizer / physical optimizer /
query executor, per dataset (share of optimization in total time).
Fig. 10: records processed and USD per backend tier per query.
"""
from __future__ import annotations

import statistics

from repro.data import WORKLOADS
from benchmarks import common

GAME_ROWS = 2000


def run(datasets=("movie", "estate", "game")):
    fig9_rows = []
    fig10_rows = []
    for ds in datasets:
        table, oracle, backends, perfect = common.env(
            ds, max_rows=GAME_ROWS if ds == "game" else 0)
        opt_share = []
        for q in WORKLOADS[ds]:
            r = common.run_nirvana(q, table, backends, perfect,
                                   seed=hash(q.qid) % 53)
            total = r.wall_s or 1e-9
            opt_share.append(r.opt_wall_s / total)
            tiers = r.detail["exec_by_tier"]
            row = {"dataset": ds, "qid": q.qid}
            for t in ("m1", "m2", "m3", "m*"):
                u = tiers.get(t, {})
                row[f"{t}_calls"] = int(u.get("calls", 0))
                row[f"{t}_usd"] = round(u.get("usd", 0.0), 4)
            fig10_rows.append(row)
        fig9_rows.append({
            "dataset": ds,
            "opt_share_of_total": f"{100 * statistics.mean(opt_share):.1f}%",
            "paper_reference": {"movie": "50.7%", "estate": "6.7%",
                                "game": "42.7%"}[ds],
        })
    common.emit("fig9_breakdown", fig9_rows)
    common.emit("fig10_model_breakdown", fig10_rows)
    print(common.fmt_table(fig9_rows, ["dataset", "opt_share_of_total",
                                       "paper_reference"]))
    print()
    print(common.fmt_table(fig10_rows[:12],
                           ["dataset", "qid", "m1_calls", "m2_calls",
                            "m3_calls", "m*_calls", "m1_usd", "m*_usd"]))
    return fig9_rows, fig10_rows


if __name__ == "__main__":
    run()
