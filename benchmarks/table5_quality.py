"""Table 5 — answer quality across systems + Nirvana ablations.

Fraction of queries answered correctly (graded against the oracle ground
truth: numerics within 5%, tables by row-set F1 > 0.9, text by semantic
equality).
"""
from __future__ import annotations

from repro.data import WORKLOADS
from benchmarks import common

GAME_ROWS = 2000


def run(datasets=("movie", "estate", "game")):
    rows = []
    for ds in datasets:
        table, oracle, backends, perfect = common.env(
            ds, max_rows=GAME_ROWS if ds == "game" else 0)
        counts = {}
        for q in WORKLOADS[ds]:
            seed = hash((ds, q.qid)) % 997
            entries = {
                "gpt-direct": common.run_gpt_direct(q, table, backends,
                                                    perfect),
                "table-llava": common.run_table_llava(q, table, backends,
                                                      perfect),
                "tablerag": common.run_tablerag_analog(q, table, backends,
                                                       perfect),
                "palimpzest": common.run_palimpzest_analog(
                    q, table, backends, perfect),
                "lotus": common.run_lotus_analog(q, table, backends,
                                                 perfect),
                "nirvana": common.run_nirvana(q, table, backends, perfect,
                                              seed=seed),
                "nirvana-no-logical": common.run_nirvana(
                    q, table, backends, perfect, logical=False, seed=seed),
                "nirvana-no-physical": common.run_nirvana(
                    q, table, backends, perfect, physical=False, seed=seed),
                "nirvana-no-opt": common.run_nirvana(
                    q, table, backends, perfect, logical=False,
                    physical=False, seed=seed),
            }
            for name, r in entries.items():
                c = counts.setdefault(name, [0, 0])
                c[1] += 1
                c[0] += bool(r.correct)
        row = {"dataset": ds}
        for name, (ok, n) in counts.items():
            row[name] = f"{100 * ok / n:.1f}%"
        rows.append(row)
    common.emit("table5_quality", rows)
    print(common.fmt_table(rows, ["dataset", "gpt-direct", "table-llava",
                                  "tablerag", "palimpzest", "lotus",
                                  "nirvana", "nirvana-no-logical",
                                  "nirvana-no-physical", "nirvana-no-opt"]))
    return rows


if __name__ == "__main__":
    run()
