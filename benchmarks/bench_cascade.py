"""Tier-0 embedding cascade benchmark — LLM-call reduction at matched
accuracy on a filter-heavy plan.

A three-filter conjunctive plan (the cascade's target shape: SEM_FILTER
dominates, the LLM is the bottleneck) runs twice over the same table and
capability-simulated backends at ``violation_rate=0``:

* **no-cascade**: every surviving row reaches the LLM tier through the
  coalescer — the baseline every PR before this one measured;
* **cascade**: one batched Pallas pass scores each morsel against the
  predicate anchor; confident rows resolve on-device and only the
  uncertain band escalates. Bands come from
  ``testing.EmbeddingOracle.bands_for`` (placed off the backend's
  effective batch capability), so every on-device resolution targets a
  record the LLM tier would have answered identically — the two runs
  return byte-identical tables.

Acceptance (raises AssertionError otherwise):

* final results byte-identical between cascade and no-cascade;
* >= 5x fewer LLM calls (``tier0-embed`` excluded) with the cascade;
* cascade results + per-tier meter totals byte-identical across
  drivers {simulated, threads} x shards {1, 2, 4}.

Writes ``artifacts/bench/BENCH_cascade.json`` (one row per mode) and a
repo-root ``BENCH_cascade.json`` summary for the perf trajectory.
"""
from __future__ import annotations

import json
import os
import time

from repro.core import backends as bk
from repro.core import cascade as casc
from repro.core import cost as cost_mod
from repro.core import executor as ex
from repro.core import plan as plan_ir
from repro.core import runtime as rt
from repro.core.table import Table
from repro.testing import EmbeddingOracle

from benchmarks import common

BATCH = 8
MORSEL = 32
ROOT_SUMMARY = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_cascade.json")


class _SelOracle:
    """Deterministic ~55%-selective filter truths (same recipe as the
    cascade test suite, so bench and tests exercise one band geometry)."""

    def answer(self, op, value):
        if op.kind == plan_ir.FILTER:
            return bk._unit_hash("truth", op.instruction, value) < 0.55
        return f"A:{value}"

    def answer_reduce(self, op, values):
        return len(list(values))


def _workload(n_rows: int):
    table = Table({"v": [f"bench-row-{i:04d}" for i in range(n_rows)]},
                  name="bench_cascade")
    plan = plan_ir.LogicalPlan(tuple(
        plan_ir.Operator(plan_ir.FILTER,
                         f"bench predicate {j}: keep interesting", "v")
        for j in range(3)))
    return table, plan


def _router(oracle, backends, plan):
    emb = EmbeddingOracle(oracle)
    router = casc.CascadeRouter(casc.EmbeddingBackend(encoder=emb))
    for op in plan.ops:
        router.set_bands(op, emb.bands_for(op, backends["m*"],
                                           batch_size=BATCH))
    return router


def _llm_calls(meter):
    return sum(u.calls for t, u in meter.by_tier.items()
               if t != cost_mod.EMBED_TIER_NAME)


def _meter_key(meter):
    return tuple(sorted(
        (t, u.calls, round(u.tok_in, 6), round(u.usd, 9),
         round(u.latency_s, 6)) for t, u in meter.by_tier.items()))


def _run_once(plan, table, oracle, *, cascade, driver, shards):
    meter = bk.UsageMeter()
    backends = bk.make_backends(oracle, violation_rate=0.0)
    router = _router(oracle, backends, plan) if cascade else None
    t0 = time.perf_counter()
    res = ex.execute(plan, table, backends, default_tier="m*",
                     batch_size=BATCH, morsel_size=MORSEL, driver=driver,
                     shards=shards, meter=meter, cascade=router)
    wall = time.perf_counter() - t0
    key = tuple(res.table.columns[ex.ROWID])
    return res, meter, wall, key


def run(n_rows: int = 256):
    oracle = _SelOracle()
    table, plan = _workload(n_rows)

    rows = []
    runs = {}
    for mode, cascade in (("no-cascade", False), ("cascade", True)):
        res, meter, wall, key = _run_once(plan, table, oracle,
                                          cascade=cascade,
                                          driver=common.DRIVER,
                                          shards=common.SHARDS)
        runs[mode] = (res, meter, key)
        row = {"mode": mode, "rows": n_rows,
               "llm_calls": _llm_calls(meter),
               "embed_calls": meter.calls(cost_mod.EMBED_TIER_NAME),
               "usd": round(meter.total.usd, 6),
               "event_wall_s": round(res.wall_s, 4),
               "wall_s": round(wall, 4),
               "rows_out": res.table.n_rows,
               "rows_processed": res.rows_processed}
        if res.cascade_stats:
            row.update({f"cascade_{k}": v
                        for k, v in sorted(res.cascade_stats.items())})
        rows.append(row)

    base_res, base_meter, base_key = runs["no-cascade"]
    cas_res, cas_meter, cas_key = runs["cascade"]
    if cas_key != base_key:
        raise AssertionError("cascade changed the query answer")

    # determinism sweep: cascade results and meter totals must be
    # invariant across drivers and shard counts
    ref = None
    for driver in rt.DRIVERS:
        for shards in (1, 2, 4):
            _, meter, _, key = _run_once(plan, table, oracle, cascade=True,
                                         driver=driver, shards=shards)
            k = (key, _meter_key(meter))
            if ref is None:
                ref = k
            elif k != ref:
                raise AssertionError(
                    f"cascade run diverged at driver={driver} "
                    f"shards={shards}")

    reduction = _llm_calls(base_meter) / max(1, _llm_calls(cas_meter))
    summary = {
        "mode": "summary", "rows": n_rows,
        "llm_calls_no_cascade": _llm_calls(base_meter),
        "llm_calls_cascade": _llm_calls(cas_meter),
        "embed_calls": cas_meter.calls(cost_mod.EMBED_TIER_NAME),
        "call_reduction_x": round(reduction, 2),
        "usd_no_cascade": round(base_meter.total.usd, 6),
        "usd_cascade": round(cas_meter.total.usd, 6),
        "event_wall_no_cascade_s": round(base_res.wall_s, 4),
        "event_wall_cascade_s": round(cas_res.wall_s, 4),
        "results_identical": True,
        "driver_shard_invariant": True,
    }
    rows.append(summary)
    common.emit("BENCH_cascade", rows)
    with open(ROOT_SUMMARY, "w") as f:
        json.dump(summary, f, indent=1)
    print(common.fmt_table(
        [r for r in rows if r["mode"] != "summary"],
        ["mode", "rows", "llm_calls", "embed_calls", "usd",
         "event_wall_s", "rows_out", "rows_processed"]))
    print(f"[bench_cascade] {summary['llm_calls_no_cascade']} -> "
          f"{summary['llm_calls_cascade']} LLM calls "
          f"({reduction:.1f}x fewer) at byte-identical results; "
          f"event wall {summary['event_wall_no_cascade_s']}s -> "
          f"{summary['event_wall_cascade_s']}s")
    if reduction < 5.0:
        raise AssertionError(
            f"cascade call reduction {reduction:.2f}x < 5x target")
    return rows


if __name__ == "__main__":
    run()
