"""Multi-tenant QoS benchmark — interactive tail latency under a batch
flood, with and without the admission controller.

Workload on one shared ``QueryServer`` (threads driver, really-sleeping
backend, 4-wide tier pool):

* a **batch flood**: long filter->map queries admitted all at once by a
  greedy batch tenant;
* **interactive probes**: small queries submitted one at a time while
  the flood is in flight — the latency-sensitive tenant.

Two modes:

* ``no-qos`` — the pre-admission server: every query starts
  immediately and the probes' backend calls queue behind the entire
  flood on the shared tier pool;
* ``qos`` — ``AdmissionController(max_concurrent=1)`` with the probes
  on the interactive lane: the flood executes one query at a time
  (same pool, same total work), and every freed slot is offered to the
  interactive lane first, so a probe waits for at most the query
  currently running — never the whole flood.

Acceptance (ISSUE 10): interactive p99 improves **>= 3x** under QoS,
while the flood's results stay byte-identical to running each query
solo on a fresh context (admission control changes *when* queries run,
never what they answer). The QoS run also feeds predicted-vs-actual
makespans back to ``CostModel.observe_makespan``; the summary reports
the resulting admission q-error so the trajectory tracks gate accuracy.

Writes ``artifacts/bench/BENCH_qos.json`` and a repo-root
``BENCH_qos.json`` summary for the perf trajectory.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import executor as ex
from repro.core import runtime as rt
from repro.core.cost_model import CostModel
from repro.launch.query_server import AdmissionController, QueryServer
from repro.testing import (KindOracle, SleepBackend, result_fingerprint,
                           tagged_plan, tagged_table)

from benchmarks import common

CONCURRENCY = 4
ROOT_SUMMARY = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_qos.json")


def _ctx(delay_s: float) -> rt.ExecutionContext:
    backend = SleepBackend(KindOracle(), delay_s=delay_s)
    return rt.ExecutionContext(backends={"m*": backend},
                               default_tier="m*", concurrency=CONCURRENCY,
                               morsel_size=8, driver="threads",
                               cost_model=CostModel())


def _serve(mode: str, delay_s: float, flood_specs, probe_specs,
           probe_gap_s: float):
    """One server run: flood admitted at t0, probes staggered while it
    drains. Returns (probe latencies, per-query fingerprints, admission
    report)."""
    ctl = None
    if mode == "qos":
        ctl = AdmissionController(max_concurrent=1)
    ctx = _ctx(delay_s)
    with QueryServer(ctx, max_inflight=16, admission=ctl) as server:
        floods = [(tag, server.submit(tagged_plan(tag), tagged_table(tag, n),
                                      name=tag, tenant="batch",
                                      lane="batch"))
                  for tag, n in flood_specs]
        probes = []
        for tag, n in probe_specs:
            time.sleep(probe_gap_s)
            probes.append((tag, server.submit(
                tagged_plan(tag), tagged_table(tag, n), name=tag,
                tenant="inter", lane="interactive")))
        server.drain(600)
        report = ctx.cost_model.admission_report()
    lats = [h.latency_s for _, h in probes]
    keys = {tag: result_fingerprint(h.result())
            for tag, h in floods + probes}
    return lats, keys, report


def run(delay_s: float = 0.02, floods: int = 6, probes: int = 6,
        flood_rows: int = 32, probe_rows: int = 8):
    flood_specs = [(f"fl{i}", flood_rows) for i in range(floods)]
    probe_specs = [(f"pr{i}", probe_rows) for i in range(probes)]
    # a probe lands every ~half flood-query so several arrive mid-flood
    solo_flood_s = flood_rows * 2 * delay_s / CONCURRENCY
    probe_gap_s = solo_flood_s / 2

    # solo reference: every query on its own fresh context
    solo = {}
    for tag, n in flood_specs + probe_specs:
        ctx = _ctx(delay_s)
        try:
            solo[tag] = result_fingerprint(
                ex.execute(tagged_plan(tag), tagged_table(tag, n), ctx))
        finally:
            ctx.close()

    rows, p99 = [], {}
    for mode in ("no-qos", "qos"):
        lats, keys, report = _serve(mode, delay_s, flood_specs,
                                    probe_specs, probe_gap_s)
        if keys != solo:
            raise AssertionError(
                f"{mode} serving changed a query's answer vs solo")
        p99[mode] = float(np.percentile(lats, 99))
        rows.append({
            "mode": mode, "floods": floods, "probes": probes,
            "probe_p50_s": round(float(np.percentile(lats, 50)), 4),
            "probe_p99_s": round(p99[mode], 4),
            "probe_max_s": round(max(lats), 4),
            "admission_observations": report["observations"],
            "admission_qerr_ewma": round(report["qerr_ewma"], 3),
        })

    improvement = p99["no-qos"] / max(p99["qos"], 1e-9)
    qos_row = next(r for r in rows if r["mode"] == "qos")
    summary = {
        "mode": "summary", "floods": floods, "probes": probes,
        "interactive_p99_noqos_s": round(p99["no-qos"], 4),
        "interactive_p99_qos_s": round(p99["qos"], 4),
        "qos_p99_improvement_x": round(improvement, 2),
        "batch_identical_to_solo": True,
        "admission_observations": qos_row["admission_observations"],
        "admission_qerr_ewma": qos_row["admission_qerr_ewma"],
    }
    rows.append(summary)
    common.emit("BENCH_qos", rows)
    with open(ROOT_SUMMARY, "w") as f:
        json.dump(summary, f, indent=1)
    print(common.fmt_table(
        [r for r in rows if r["mode"] != "summary"],
        ["mode", "floods", "probes", "probe_p50_s", "probe_p99_s",
         "probe_max_s"]))
    print(f"[bench_qos] interactive p99 under batch flood: "
          f"{p99['no-qos']:.3f}s (no QoS) -> {p99['qos']:.3f}s "
          f"(admission control): {improvement:.1f}x better tail, "
          f"batch results byte-identical to solo; admission gate "
          f"q-error ewma {qos_row['admission_qerr_ewma']} over "
          f"{qos_row['admission_observations']} queries")
    if improvement < 3.0:
        raise AssertionError(
            f"QoS interactive p99 improvement {improvement:.2f}x < 3x "
            f"target")
    return rows


if __name__ == "__main__":
    run()
