"""Streaming-serve benchmark — the repo's multi-tenant serving
perf trajectory.

Four filter -> map (-> reduce) queries with distinct instructions run on
one shared ``launch.query_server.QueryServer`` (threads driver, really-
sleeping backend) two ways:

* **sequential**: admitted back-to-back — submit, wait, submit — the
  "batch script" baseline every PR before this one measured;
* **concurrent**: all four admitted at once, interleaving on the same
  per-tier worker pools.

Each query deliberately under-fills the 16-wide tier pool solo (8-row
morsels + a reduce barrier on half the queries), so solo execution
leaves idle capacity; concurrent admission fills it. Acceptance:
concurrent admission is >= 1.5x faster than back-to-back at 4 in-flight
queries, and every query's result is byte-identical to running it solo
on a fresh context (the admission-order-invariance contract,
test-enforced in tests/test_serve.py).

Writes ``artifacts/bench/BENCH_serve.json`` (one row per mode) and a
repo-root ``BENCH_serve.json`` summary for the perf trajectory.
"""
from __future__ import annotations

import json
import os
import time

from repro.core import executor as ex
from repro.core import runtime as rt
from repro.launch.query_server import QueryServer
from repro.testing import (KindOracle, SleepBackend, result_fingerprint,
                           tagged_plan, tagged_table)

from benchmarks import common

N_QUERIES = 4
ROWS_PER_QUERY = 8
CONCURRENCY = 16
ROOT_SUMMARY = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_serve.json")


def _specs():
    """(tag, reduce_tail): distinct instructions per query (see
    repro.testing.tagged_plan), so sharing the server cache never
    cross-fills between tenants."""
    return [(f"q{i}", i % 2 == 1) for i in range(N_QUERIES)]


def _table(tag: str):
    return tagged_table(tag, ROWS_PER_QUERY)


_plan = tagged_plan
_result_key = result_fingerprint


def _ctx(sleep_s: float) -> rt.ExecutionContext:
    backend = SleepBackend(KindOracle(), delay_s=sleep_s)
    return rt.ExecutionContext(backends={"m*": backend},
                               default_tier="m*", concurrency=CONCURRENCY,
                               morsel_size=ROWS_PER_QUERY,
                               driver="threads")


def _serve_once(sleep_s: float, concurrent: bool):
    """One server run; returns (makespan, per-query result keys, calls)."""
    with QueryServer(_ctx(sleep_s)) as server:
        t0 = time.perf_counter()
        if concurrent:
            handles = [(tag, server.submit(_plan(tag, tail), _table(tag),
                                           name=tag))
                       for tag, tail in _specs()]
            for _, h in handles:
                h.result(timeout=60)
        else:
            handles = []
            for tag, tail in _specs():
                h = server.submit(_plan(tag, tail), _table(tag), name=tag)
                h.result(timeout=60)
                handles.append((tag, h))
        makespan = time.perf_counter() - t0
        calls = server.ctx.meter.total.calls
    return makespan, {tag: _result_key(h.result()) for tag, h in handles}, \
        calls


def run(sleep_s: float = 0.05):
    # solo reference: each query on its own fresh context
    solo = {}
    for tag, tail in _specs():
        res = ex.execute(_plan(tag, tail), _table(tag), _ctx(sleep_s))
        solo[tag] = _result_key(res)

    rows = []
    results = {}
    for mode, concurrent in (("sequential", False), ("concurrent", True)):
        walls, keys, calls = [], None, None
        for _ in range(3):          # median of 3: thread scheduling jitter
            wall, keys, calls = _serve_once(sleep_s, concurrent)
            walls.append(wall)
        results[mode] = keys
        rows.append({"mode": mode, "queries": N_QUERIES, "calls": calls,
                     "wall_s": round(sorted(walls)[1], 4),
                     "walls": [round(w, 4) for w in walls]})

    for mode, keys in results.items():
        if keys != solo:
            raise AssertionError(
                f"{mode} serving changed a query's answer vs solo")

    seq = next(r for r in rows if r["mode"] == "sequential")
    conc = next(r for r in rows if r["mode"] == "concurrent")
    speedup = seq["wall_s"] / max(conc["wall_s"], 1e-9)
    summary = {
        "mode": "summary", "queries": N_QUERIES, "calls": conc["calls"],
        "sequential_wall_s": seq["wall_s"],
        "concurrent_wall_s": conc["wall_s"],
        "serve_speedup_4_inflight": round(speedup, 3),
        "results_identical_to_solo": True,
    }
    rows.append(summary)
    common.emit("BENCH_serve", rows)
    with open(ROOT_SUMMARY, "w") as f:
        json.dump(summary, f, indent=1)
    print(common.fmt_table(
        [r for r in rows if r["mode"] != "summary"],
        ["mode", "queries", "calls", "wall_s"]))
    print(f"[bench_serve] threads wall {seq['wall_s']:.3f}s (back-to-back)"
          f" -> {conc['wall_s']:.3f}s (4 in-flight): {speedup:.2f}x "
          f"speedup, byte-identical results vs solo")
    if speedup < 1.5:
        raise AssertionError(
            f"4-in-flight serve speedup {speedup:.2f}x < 1.5x target")
    return rows


if __name__ == "__main__":
    run()
