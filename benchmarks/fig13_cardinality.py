"""Appendix B (Fig. 13) — semantic cardinality estimation upside.

For queries whose filter order the default optimizer cannot determine
(identical default selectivities), compare the default order against the
oracle-optimal order (enumerate permutations, measure true records
processed): records-processed and latency reduction.
"""
from __future__ import annotations

import itertools

from repro.core import executor as ex
from repro.core import plan as plan_ir
from repro.data import WORKLOADS
from benchmarks import common

CASES = [("estate", "q5"), ("estate", "q10"), ("game", "q8"),
         ("game", "q10")]
GAME_ROWS = 3000


def _legal_orders(plan):
    """All permutations of the ops preserving def-before-use + reduce last."""
    n = len(plan.ops)
    for perm in itertools.permutations(range(n)):
        ops = tuple(plan.ops[i] for i in perm)
        cand = plan_ir.LogicalPlan(ops, plan.source)
        try:
            cand.validate()
        except ValueError:
            continue
        ok = all(not (cand.ops[j].kind == plan_ir.REDUCE and j < n - 1)
                 for j in range(n))
        if ok:
            yield cand


def run():
    rows = []
    for ds, qid in CASES:
        table, oracle, backends, perfect = common.env(
            ds, max_rows=GAME_ROWS if ds == "game" else 0)
        q = next(x for x in WORKLOADS[ds] if x.qid == qid)
        plan = q.plan_for(table)
        base = ex.execute(plan, table, perfect, default_tier="m*")
        best = None
        for cand in _legal_orders(plan):
            r = ex.execute(cand, table, perfect, default_tier="m*")
            if best is None or r.rows_processed < best[1].rows_processed:
                best = (cand, r)
        # latency with the real (priced) backends under both orders
        lat_base = ex.execute(plan, table, backends,
                              default_tier="m*").wall_s
        lat_best = ex.execute(best[0], table, backends,
                              default_tier="m*").wall_s
        rows.append({
            "dataset": ds, "qid": qid,
            "records_default": int(base.rows_processed),
            "records_oracle": int(best[1].rows_processed),
            "records_reduction": f"{100 * (1 - best[1].rows_processed / max(base.rows_processed, 1)):.1f}%",
            "latency_reduction": f"{100 * (1 - lat_best / max(lat_base, 1e-9)):.1f}%",
        })
    common.emit("fig13_cardinality", rows)
    print(common.fmt_table(rows, ["dataset", "qid", "records_default",
                                  "records_oracle", "records_reduction",
                                  "latency_reduction"]))
    return rows


if __name__ == "__main__":
    run()
