"""Appendix D — lambda sensitivity (Eq. 1) + beam-search comparison."""
from __future__ import annotations

import statistics

from repro.core import logical_optimizer as lopt
from repro.data import WORKLOADS
from benchmarks import common


def run(datasets=("movie", "estate")):
    lam_rows = []
    beam_rows = []
    for ds in datasets:
        table, oracle, backends, perfect = common.env(ds)
        queries = [q for q in WORKLOADS[ds] if q.size == "L"]
        for lam in (0.0, 0.2, 0.4, 0.6, 0.8, 1.0):
            costs = []
            for q in queries:
                res = lopt.optimize(
                    q.plan_for(table), table, backends,
                    cfg=lopt.LogicalOptConfig(
                        n_iterations=3, lam=lam, seed=hash(q.qid) % 43))
                costs.append(res.best_cost / max(res.initial_cost, 1e-12))
            lam_rows.append({"dataset": ds, "lambda": lam,
                             "cost_ratio": round(statistics.mean(costs),
                                                 3)})
        opt_usd = {"ours": [], "beam": []}
        exec_usd = {"ours": [], "beam": []}
        for q in queries:
            seed = hash(q.qid) % 43
            a = lopt.optimize(q.plan_for(table), table, backends,
                              cfg=lopt.LogicalOptConfig(n_iterations=3,
                                                        seed=seed))
            b = lopt.optimize_beam(q.plan_for(table), table, backends,
                                   cfg=lopt.LogicalOptConfig(
                                       n_iterations=3, seed=seed),
                                   beam_width=2)
            opt_usd["ours"].append(a.meter.total.usd)
            opt_usd["beam"].append(b.meter.total.usd)
            exec_usd["ours"].append(a.best_cost)
            exec_usd["beam"].append(b.best_cost)
        beam_rows.append({
            "dataset": ds,
            "opt_usd_ours": round(statistics.mean(opt_usd["ours"]), 4),
            "opt_usd_beam": round(statistics.mean(opt_usd["beam"]), 4),
            "exec_usd_ours": round(statistics.mean(exec_usd["ours"]), 4),
            "exec_usd_beam": round(statistics.mean(exec_usd["beam"]), 4),
        })
    common.emit("appendix_d_lambda", lam_rows)
    common.emit("appendix_d_beam", beam_rows)
    print(common.fmt_table(lam_rows, ["dataset", "lambda", "cost_ratio"]))
    print()
    print(common.fmt_table(beam_rows, ["dataset", "opt_usd_ours",
                                       "opt_usd_beam", "exec_usd_ours",
                                       "exec_usd_beam"]))
    return lam_rows, beam_rows


if __name__ == "__main__":
    run()
