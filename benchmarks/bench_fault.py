"""Fault-tolerance benchmark — goodput under injected transient failures.

A fleet of independent queries runs three times over the same seeded
10%-transient-failure fault plan (``testing.FlakyBackend``, whose draws
are a pure function of the logical call key, so every mode sees the
*same* faults on the same calls):

* **fault-free**: no faults injected — the reference results and bill;
* **fail-fast**: faults on, no :class:`runtime.CallPolicy` — today's
  pre-policy behavior, where one transient error anywhere in a query
  poisons the whole query;
* **retry**: faults on, ``CallPolicy(retries=2)`` — the dispatcher
  retries faulted attempts under deterministic retry-marked logical
  keys.

Goodput = completed queries / admitted queries. Acceptance (raises
AssertionError otherwise):

* retry-mode goodput == 1.0 and every retried query's results are
  byte-identical to its fault-free run;
* fail-fast goodput < 1.0 on the same plan (the faults were real);
* retry-mode overhead is bounded: billed calls grow by exactly the
  number of faulted attempts (each fault = one extra logged call).

Writes ``artifacts/bench/BENCH_fault.json`` (one row per mode) and a
repo-root ``BENCH_fault.json`` summary for the perf trajectory.
"""
from __future__ import annotations

import json
import os
import time

from repro.core import backends as bk
from repro.core import executor as ex
from repro.core import runtime as rt
from repro.core.backends import SimulatedBackend
from repro.core.cost import TierSpec
from repro.testing import (FlakyBackend, KindOracle, result_fingerprint,
                           tagged_plan, tagged_table)

BATCH = 4
MORSEL = 8
ERROR_RATE = 0.10
SEED = 11
ROOT_SUMMARY = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_fault.json")


def _backend(error_rate: float = 0.0):
    spec = TierSpec("m*", 1.01, 2.0, 8.0, 0.01, 0.0)
    inner = SimulatedBackend(spec, KindOracle(), violation_rate=0.0)
    if error_rate <= 0.0:
        return inner
    return FlakyBackend(inner, error_rate=error_rate, seed=SEED)


def _queries(n_queries: int, n_rows: int):
    return [(f"fq{i:02d}", tagged_plan(f"fq{i:02d}", reduce_tail=i % 3 == 0),
             tagged_table(f"fq{i:02d}", n_rows)) for i in range(n_queries)]


def _run_mode(queries, *, error_rate: float, policy):
    """Run every query under one shared fault plan; returns per-query
    outcomes plus fleet-level accounting. ``query_key=tag`` scopes the
    logical meter keys per query, so each query draws its own slice of
    the fault plan (and the same slice in every mode)."""
    completed, failed, fingerprints = 0, 0, {}
    calls = usd = 0.0
    backend = _backend(error_rate)
    t0 = time.perf_counter()
    for tag, plan, table in queries:
        meter = bk.UsageMeter()
        try:
            res = ex.execute(plan, table, {"m*": backend},
                             default_tier="m*", batch_size=BATCH,
                             morsel_size=MORSEL, meter=meter,
                             call_policy=policy, query_key=tag)
        except rt.TransientCallError:
            failed += 1
            fingerprints[tag] = None
        else:
            completed += 1
            fingerprints[tag] = result_fingerprint(res)
        calls += meter.total.calls
        usd += meter.total.usd
    wall = time.perf_counter() - t0
    faults = getattr(backend, "faults_injected", 0)
    return {"completed": completed, "failed": failed,
            "goodput": completed / max(1, len(queries)),
            "calls": int(calls),
            "usd": round(usd, 6),
            "faults_injected": faults,
            "wall_s": round(wall, 4)}, fingerprints


def run(n_queries: int = 24, n_rows: int = 32):
    queries = _queries(n_queries, n_rows)
    modes = [
        ("fault-free", 0.0, None),
        ("fail-fast", ERROR_RATE, None),
        ("retry", ERROR_RATE, rt.CallPolicy(retries=2)),
    ]
    rows, prints = [], {}
    for mode, rate, policy in modes:
        stats, fps = _run_mode(queries, error_rate=rate, policy=policy)
        stats.update({"mode": mode, "error_rate": rate,
                      "queries": n_queries})
        rows.append(stats)
        prints[mode] = fps

    by_mode = {r["mode"]: r for r in rows}
    base, ff, retry = (by_mode["fault-free"], by_mode["fail-fast"],
                       by_mode["retry"])
    if retry["goodput"] != 1.0:
        raise AssertionError(
            f"retry goodput {retry['goodput']} != 1.0")
    if prints["retry"] != prints["fault-free"]:
        raise AssertionError("retried results diverged from fault-free")
    if ff["goodput"] >= 1.0:
        raise AssertionError(
            "fail-fast lost no queries: the fault plan injected nothing")
    # exactly-once billing + one extra logged call per faulted attempt
    if retry["calls"] != base["calls"] + retry["faults_injected"]:
        raise AssertionError(
            f"retry billed {retry['calls']} calls, expected "
            f"{base['calls']} + {retry['faults_injected']} faults")

    summary = {
        "mode": "summary", "queries": n_queries,
        "error_rate": ERROR_RATE,
        "goodput_fail_fast": round(ff["goodput"], 4),
        "goodput_retry": round(retry["goodput"], 4),
        "faults_injected": retry["faults_injected"],
        "usd_fault_free": base["usd"],
        "usd_retry": retry["usd"],
        "retry_usd_overhead_pct": round(
            100.0 * (retry["usd"] / base["usd"] - 1.0), 2)
        if base["usd"] else 0.0,
        "results_identical": True,
    }
    rows.append(summary)

    from benchmarks import common
    common.emit("BENCH_fault", rows)
    with open(ROOT_SUMMARY, "w") as f:
        json.dump(summary, f, indent=1)
    print(common.fmt_table(
        [r for r in rows if r["mode"] != "summary"],
        ["mode", "error_rate", "queries", "completed", "failed",
         "goodput", "calls", "faults_injected", "usd"]))
    print(f"[bench_fault] goodput at {ERROR_RATE:.0%} transient failures: "
          f"fail-fast {ff['goodput']:.2f} -> retry "
          f"{retry['goodput']:.2f} "
          f"(+{summary['retry_usd_overhead_pct']}% spend, results "
          f"byte-identical to fault-free)")
    return rows


if __name__ == "__main__":
    run()
