"""Process shard workers: GIL-free morsel execution behind the
``Dispatcher`` interface.

``ShardedDispatcher(driver="procs")`` builds one
:class:`ProcessShardDispatcher` per shard. Each is a regular
``runtime.ThreadPoolDispatcher`` — chain tasks, tier-pool quotas, the
shared single-flight ``OutputCache``, and the ``CallPolicy``
retry/breaker/fallback ladder all stay coordinator-side, unchanged —
except that every backend call and host-UDF step is serialized over a
pipe to a spawned worker subprocess and executed there, outside the
coordinator's GIL.

Serialization boundary
----------------------
A request ships ``(tier_key, op, values, batch_size, logical_key,
call_timeout)`` (or ``(op, table, values)`` for a UDF step) by pickle;
the reply carries the outputs (or the exception) plus a fresh
``UsageMeter`` holding exactly that call's entries. The worker re-enters
``meter.keyed(logical_key)`` and ``runtime._call_deadline(timeout)``
around the backend invocation, so the billed entries carry the same
logical keys — and fault harnesses draw the same fault plans — as an
in-process run. The coordinator ``absorb``\\ s the reply meter into the
call's per-shard staging meter verbatim (``absorb`` copies keys without
re-keying), and ``UsageMeter.merge``'s logical-key sort then produces a
byte-identical combined log: meter-merge determinism survives the wire
because the *keys* travel with the entries, and the merge order never
depended on arrival time in the first place.

Backends that do not survive a pickle round-trip (an engine-backed
``JAXBackend`` holding device buffers) are simply not shipped
(:func:`shippable_backends`); their calls run coordinator-side exactly
as under the threads driver. The coordinator-side cache + policy layer
is also the cross-process dedupe: duplicate values claim one cache key
*before* any request ships, so cross-process duplicates bill once.

Death ladder
------------
A worker death — crash, SIGKILL, or ``heartbeat_timeout_s`` of silence
(e.g. SIGSTOP) — is detected by the client's monitor/receiver threads
and surfaces as the exact PR 8 contract: the owning ``ShardedDispatcher``
``kill_shard``\\ s the shard (ring-next routing, morsel requeue onto
survivors), and every pending pipe call raises ``ShardDeadError`` so the
``run_llm``/``run_udf`` retry loops re-route. A call that died with the
worker never shipped its meter back, so the survivor's retry bills it
exactly once; replies already buffered in the pipe are drained before
pending futures are failed, so a completed call is never double-billed.
"""
from __future__ import annotations

import multiprocessing as mp
import os
import pickle
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Dict, Optional, Tuple

from repro.core import backends as bk
from repro.core import runtime as rt


def shippable_backends(backends: Dict[str, Any]) -> Dict[str, Any]:
    """The subset of ``backends`` that survives a pickle round-trip —
    these ship to the worker processes at spawn; the rest keep running
    coordinator-side (the threads-driver path, GIL and all)."""
    out = {}
    for k, b in (backends or {}).items():
        try:
            pickle.dumps(b)
        except Exception:
            continue
        out[k] = b
    return out


def _worker_main(conn, backends: Dict[str, Any], concurrency: int,
                 heartbeat_s: float) -> None:
    """Worker-process entry point: a request loop over the pipe.

    Requests fan out onto a local thread pool (remote callers block on
    their reply, so in-flight depth is bounded by the coordinator's tier
    pools); the main thread stays in ``recv`` so the pipe never wedges.
    Each request bills into a fresh meter that ships back with the reply.
    A heartbeat thread pings ``("hb",)`` every ``heartbeat_s`` so the
    coordinator can tell a stalled worker from a slow call."""
    send_lock = threading.Lock()
    stop = threading.Event()

    def send(msg) -> None:
        try:
            with send_lock:
                conn.send(msg)
        except Exception:
            stop.set()

    def heartbeat() -> None:
        while not stop.wait(heartbeat_s):
            send(("hb",))

    def handle(req_id: int, kind: str, payload) -> None:
        meter = bk.UsageMeter()
        try:
            if kind == "llm":
                tier_key, op, values, batch_size, key, timeout_s = payload
                backend = backends[tier_key]
                with rt._call_deadline(timeout_s):
                    if key is None:
                        outs = backend.run_values(op, values, meter=meter,
                                                  batch_size=batch_size)
                    else:
                        with meter.keyed(key):
                            outs = backend.run_values(
                                op, values, meter=meter,
                                batch_size=batch_size)
            elif kind == "udf":
                op, tbl, values = payload
                outs = rt.run_udf_op(op, tbl, values)
            else:
                raise RuntimeError(f"unknown request kind {kind!r}")
        except BaseException as e:
            try:
                pickle.dumps(e)
            except Exception:
                e = rt.TransientCallError(f"{type(e).__name__}: {e}")
            send(("err", req_id, e, meter))
            return
        send(("ok", req_id, outs, meter))

    threading.Thread(target=heartbeat, daemon=True).start()
    pool = ThreadPoolExecutor(max_workers=max(4, int(concurrency) * 4),
                              thread_name_prefix="proc-worker")
    send(("ready", os.getpid()))
    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError):
            break
        if msg[0] == "close":
            break
        _, req_id, kind, payload = msg
        pool.submit(handle, req_id, kind, payload)
    stop.set()
    pool.shutdown(wait=True)
    send(("bye",))
    conn.close()


class ProcessShardClient:
    """Coordinator-side handle on one spawned worker subprocess.

    Owns the duplex pipe, a receiver thread that demultiplexes replies
    onto per-request futures, and a monitor thread that declares the
    worker dead after ``heartbeat_timeout_s`` of pipe silence or on
    process exit. Exactly-once resolution: a request future is popped
    from ``_pending`` under the lock by whichever side settles it first
    (reply vs death), so a late reply for an already-failed request is
    dropped *with its meter* — the survivor's retry is the one billing.
    """

    def __init__(self, backends: Dict[str, Any], concurrency: int, *,
                 shard: int = 0,
                 on_death: Optional[Callable[[int], None]] = None,
                 heartbeat_s: float = 0.25,
                 heartbeat_timeout_s: float = 10.0):
        self.shard = shard
        self._on_death = on_death
        self._hb_s = max(0.01, float(heartbeat_s))
        self._hb_timeout = max(self._hb_s * 2, float(heartbeat_timeout_s))
        self._lock = threading.Lock()
        self._send_lock = threading.Lock()
        self._pending: Dict[int, Future] = {}
        self._next_id = 0
        self._dead = False
        self._closed = False
        self._death_reason = ""
        self._ready = threading.Event()
        self._last_recv = time.perf_counter()
        self.pid: Optional[int] = None
        self.stats = {"llm": 0, "udf": 0}
        ctx = mp.get_context("spawn")
        self._conn, child = ctx.Pipe(duplex=True)
        self._proc = ctx.Process(
            target=_worker_main,
            args=(child, backends, concurrency, self._hb_s),
            name=f"proc-shard-{shard}", daemon=True)
        self._proc.start()
        child.close()
        self._recv_thread = threading.Thread(
            target=self._recv_loop, name=f"proc-recv-{shard}", daemon=True)
        self._recv_thread.start()
        threading.Thread(target=self._monitor, name=f"proc-mon-{shard}",
                         daemon=True).start()

    # -- receive / liveness ----------------------------------------------
    def _recv_loop(self) -> None:
        while True:
            try:
                msg = self._conn.recv()
            except (EOFError, OSError):
                with self._lock:
                    closed = self._closed
                if not closed:
                    self._declare_dead("pipe closed")
                return
            self._last_recv = time.perf_counter()
            tag = msg[0]
            if tag == "hb" or tag == "bye":
                continue
            if tag == "ready":
                self.pid = msg[1]
                self._ready.set()
                continue
            _, req_id, payload, meter = msg
            with self._lock:
                fut = self._pending.pop(req_id, None)
            if fut is not None:
                fut.set_result((tag, payload, meter))

    def _monitor(self) -> None:
        # a cold spawn (interpreter boot + module imports) can exceed a
        # test-sized heartbeat timeout: don't start the silence clock
        # until the worker reported ready
        while not self._ready.wait(timeout=0.05):
            with self._lock:
                if self._dead or self._closed:
                    return
            if not self._proc.is_alive():
                self._declare_dead("worker exited before ready "
                                   f"(code {self._proc.exitcode})")
                return
        self._last_recv = time.perf_counter()
        interval = max(0.02, self._hb_s / 2.0)
        while True:
            with self._lock:
                if self._dead or self._closed:
                    return
            silent = time.perf_counter() - self._last_recv
            if silent >= self._hb_timeout:
                self._declare_dead(f"no heartbeat for {silent:.2f}s")
                return
            if not self._proc.is_alive():
                self._declare_dead("worker process exited "
                                   f"(code {self._proc.exitcode})")
                return
            time.sleep(interval)

    def _declare_dead(self, reason: str) -> None:
        """Unplanned death (crash / SIGKILL / missed heartbeat): kill the
        process, let the receiver drain any replies already buffered in
        the pipe (those calls completed — they must bill, not retry),
        notify the owner (``kill_shard`` marks the shard dead *before*
        any pending future raises, so ``_shard_died_under`` classifies
        the failures as requeue-able), then fail whatever is left."""
        with self._lock:
            if self._dead or self._closed:
                return
            self._dead = True
            self._death_reason = reason
        try:
            self._proc.kill()       # SIGKILL: also takes down a SIGSTOPped
        except Exception:           # worker (SIGTERM would stay pending)
            pass
        if threading.current_thread() is not self._recv_thread:
            self._recv_thread.join(timeout=2.0)
        self._ready.set()           # unblock wait_ready (it re-checks _dead)
        if self._on_death is not None:
            try:
                self._on_death(self.shard)
            except Exception:
                pass
        self._fail_pending(reason)

    def _fail_pending(self, reason: str) -> None:
        with self._lock:
            pending = list(self._pending.values())
            self._pending.clear()
        exc = rt.ShardDeadError(
            f"process shard {self.shard} died: {reason}")
        for fut in pending:
            fut.set_exception(exc)

    @property
    def is_dead(self) -> bool:
        with self._lock:
            return self._dead

    def kill(self) -> None:
        """Dispatcher-initiated teardown (``kill_shard``/``abandon``):
        same as a detected death but without the ``on_death`` callback —
        the dispatcher already knows. Idempotent."""
        with self._lock:
            if self._dead:
                return
            self._dead = True
            self._death_reason = "killed by dispatcher"
        try:
            self._proc.kill()
        except Exception:
            pass
        self._fail_pending("killed by dispatcher")

    # -- calls -----------------------------------------------------------
    def call(self, kind: str, payload
             ) -> Tuple[str, Any, Optional[bk.UsageMeter]]:
        """Ship one request, block for its reply. Raises
        ``ShardDeadError`` if the worker is (or dies) in between; raises
        the caller's own error (e.g. an unpicklable payload) unchanged."""
        fut: Future = Future()
        with self._lock:
            if self._dead or self._closed:
                raise rt.ShardDeadError(
                    f"process shard {self.shard} is dead: "
                    f"{self._death_reason or 'closed'}")
            req_id = self._next_id
            self._next_id += 1
            self._pending[req_id] = fut
            self.stats[kind] = self.stats.get(kind, 0) + 1
        try:
            with self._send_lock:
                self._conn.send(("req", req_id, kind, payload))
        except (OSError, ValueError, BrokenPipeError):
            with self._lock:
                self._pending.pop(req_id, None)
            self._declare_dead("send failed")
            raise rt.ShardDeadError(
                f"process shard {self.shard} died: send failed")
        except BaseException:
            # e.g. PicklingError: the request never left — a genuine
            # caller error, not a dead worker
            with self._lock:
                self._pending.pop(req_id, None)
            raise
        return fut.result()

    def wait_ready(self, timeout_s: float = 60.0) -> None:
        deadline = time.perf_counter() + timeout_s
        while not self._ready.wait(timeout=0.05):
            if time.perf_counter() > deadline:
                raise rt.ShardDeadError(
                    f"process shard {self.shard} not ready "
                    f"after {timeout_s}s")
        with self._lock:
            if self._dead:
                raise rt.ShardDeadError(
                    f"process shard {self.shard} died during spawn: "
                    f"{self._death_reason}")

    def close(self, timeout_s: float = 10.0) -> None:
        """Graceful drain: tell the worker to finish in-flight requests
        and exit, then join (SIGKILL fallback). Idempotent."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            was_dead = self._dead
        if not was_dead:
            try:
                with self._send_lock:
                    self._conn.send(("close",))
            except Exception:
                pass
        self._proc.join(timeout_s)
        if self._proc.is_alive():
            self._proc.kill()
            self._proc.join(timeout_s)
        self._fail_pending("closed")
        try:
            self._conn.close()
        except Exception:
            pass


class _RemoteBackend:
    """The ``Backend``-protocol proxy a :class:`ProcessShardDispatcher`
    swaps in for a shippable backend: one ``run_values`` = one pipe
    round-trip. The ambient logical key and the cooperative call deadline
    are captured *here*, on the coordinator thread where the policy layer
    installed them, and shipped explicitly; the reply meter is absorbed
    before any error re-raises, so faulted attempts bill exactly like
    in-process ones (retries are not free over the wire either)."""

    def __init__(self, client: ProcessShardClient, tier_key: str, tier):
        self._client = client
        self._tier_key = tier_key
        self.tier = tier

    def run_values(self, op, values, meter=None, batch_size: int = 1):
        key = meter.current_key() if meter is not None else None
        timeout_s = rt.current_call_timeout()
        tag, payload, rmeter = self._client.call(
            "llm",
            (self._tier_key, op, list(values), batch_size, key, timeout_s))
        if meter is not None and rmeter is not None:
            meter.absorb(rmeter)
        if tag == "err":
            raise payload
        return payload


class ProcessShardDispatcher(rt.ThreadPoolDispatcher):
    """One shard's inner dispatcher in ``procs`` mode: a
    ``ThreadPoolDispatcher`` whose backend calls and UDF steps execute in
    a spawned worker subprocess. Everything else — chain pool, tier-pool
    quotas, cache single-flight, policy retries/breakers/fallback, meter
    staging — is inherited unchanged, which is exactly what keeps the
    invariance guarantees: the coordinator still decides *what* runs;
    the worker only supplies GIL-free *where*."""

    kind = "procs"

    def __init__(self, concurrency: int = 16,
                 per_tier: Optional[Dict[str, int]] = None,
                 mode: str = "async",
                 host_lock: Optional[threading.Lock] = None,
                 policy: Optional[rt.FaultPolicyRuntime] = None, *,
                 backends: Dict[str, Any],
                 shard: int = 0,
                 on_death: Optional[Callable[[int], None]] = None,
                 heartbeat_s: float = 0.25,
                 heartbeat_timeout_s: float = 10.0):
        super().__init__(concurrency, per_tier=per_tier, mode=mode,
                         host_lock=host_lock, policy=policy)
        self.shard = shard
        self._by_id = {id(b): k for k, b in backends.items()}
        self._proxies: Dict[int, _RemoteBackend] = {}
        self.client = ProcessShardClient(
            backends, concurrency, shard=shard, on_death=on_death,
            heartbeat_s=heartbeat_s,
            heartbeat_timeout_s=heartbeat_timeout_s)

    def _remote(self, backend) -> Optional[_RemoteBackend]:
        key = self._by_id.get(id(backend))
        if key is None:
            return None       # unshipped (unpicklable/unknown): run local
        proxy = self._proxies.get(id(backend))
        if proxy is None:
            proxy = _RemoteBackend(self.client, key, backend.tier)
            self._proxies[id(backend)] = proxy
        return proxy

    def wait_ready(self, timeout_s: float = 60.0) -> None:
        """Block until the worker's request loop is up, then reset the
        measured-wall origin so ``wall_s`` excludes spawn cost."""
        self.client.wait_ready(timeout_s)
        now = time.perf_counter()
        with self._lock:
            self._t0 = now
            self._last = now

    def run_llm(self, op, values, backend, tier_name, meter, *,
                batch_size: int = 1,
                cache: Optional[rt.OutputCache] = None,
                ready_s: float = 0.0, shard: int = 0,
                key: Optional[tuple] = None):
        remote = self._remote(backend)
        return super().run_llm(
            op, values, backend if remote is None else remote, tier_name,
            meter, batch_size=batch_size, cache=cache, ready_s=ready_s,
            shard=shard, key=key)

    def run_udf(self, op, table, values, ready_s: float = 0.0,
                shard: int = 0):
        """Host-UDF steps ship to the worker too — they are the
        GIL-bound half of the workload. No host-lock serialization: each
        worker process is its own interpreter."""
        tag, payload, _ = self.client.call("udf",
                                           (op, table, list(values)))
        self._touch()
        if tag == "err":
            raise payload
        return payload, 0.0

    def abandon(self) -> None:
        super().abandon()
        self.client.kill()

    def close(self) -> None:
        # drain the coordinator pools FIRST: their tasks may be blocked
        # on pipe futures, which the still-running receiver resolves;
        # only then ask the worker to exit
        super().close()
        self.client.close()
