"""Fault-tolerant training supervision: checkpoint/restart, failure
injection, straggler detection.

At 1000+ nodes the mean time between node failures drops below the job
length, so the training loop must be a pure function of (checkpoint,
data-order) — restart-determinism is the invariant the tests pin down:
a run with injected failures restores from the last committed step and
reaches bit-identical state to an uninterrupted run.

Straggler mitigation: per-step wall times feed an online median tracker;
steps exceeding ``deadline_factor``x the running median are flagged. On a
real cluster the supervisor re-slices the batch away from the slow host
(or preempts it — the action is pluggable); here the detection logic and
the accounting are exercised under injected delays.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import jax

from repro.checkpoint import checkpoint as ckpt


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class StragglerStats:
    times: List[float] = dataclasses.field(default_factory=list)
    flagged: List[int] = dataclasses.field(default_factory=list)
    deadline_factor: float = 3.0

    def observe(self, step: int, dt: float) -> bool:
        """Record a step time; True if the step was a straggler."""
        med = sorted(self.times)[len(self.times) // 2] if self.times else dt
        self.times.append(dt)
        if len(self.times) >= 5 and dt > self.deadline_factor * med:
            self.flagged.append(step)
            return True
        return False


@dataclasses.dataclass
class SupervisorConfig:
    ckpt_dir: str
    ckpt_every: int = 10
    keep_last: int = 3
    async_save: bool = False
    deadline_factor: float = 3.0


class TrainSupervisor:
    """Runs `train_step(state, batch) -> (state, metrics)` under checkpoint/
    restart. ``fail_at`` injects a crash *after* the step executes but
    before its checkpoint commits — the worst-case window."""

    def __init__(self, train_step: Callable, batch_fn: Callable,
                 cfg: SupervisorConfig):
        self.train_step = train_step
        self.batch_fn = batch_fn      # step -> batch (deterministic!)
        self.cfg = cfg
        self.straggler = StragglerStats(deadline_factor=cfg.deadline_factor)
        self._async = (ckpt.AsyncCheckpointer(cfg.ckpt_dir, cfg.keep_last)
                       if cfg.async_save else None)

    def _save(self, step: int, state):
        if self._async:
            self._async.save(step, state)
        else:
            ckpt.save(self.cfg.ckpt_dir, step, state,
                      keep_last=self.cfg.keep_last)

    def run(self, init_state, n_steps: int,
            fail_at: Optional[set] = None,
            delay_steps: Optional[dict] = None):
        """Execute steps [resume..n_steps); returns (state, metrics_log).

        Restarts resume from the last committed checkpoint; `fail_at` steps
        raise InjectedFailure once each (the caller loops, as a cluster
        controller would). NOTE: `fail_at` is mutated (fired steps are
        discarded) so a controller re-invoking `run` shares the ledger."""
        fail_at = fail_at if fail_at is not None else set()
        delay_steps = delay_steps or {}
        last = ckpt.latest_step(self.cfg.ckpt_dir)
        if last is not None:
            _, state = ckpt.restore(self.cfg.ckpt_dir, last)
            start = last + 1
        else:
            state = init_state
            start = 0
            self._save(-1, state) if False else None
        log = []
        for step in range(start, n_steps):
            t0 = time.perf_counter()
            batch = self.batch_fn(step)
            state, metrics = self.train_step(state, batch)
            if step in delay_steps:
                time.sleep(delay_steps[step])
            jax.block_until_ready(jax.tree.leaves(metrics))
            dt = time.perf_counter() - t0
            self.straggler.observe(step, dt)
            log.append({"step": step,
                        **{k: float(v) for k, v in metrics.items()}})
            if step in fail_at:
                fail_at.discard(step)
                raise InjectedFailure(f"injected failure at step {step}")
            if (step + 1) % self.cfg.ckpt_every == 0:
                self._save(step, state)
        if self._async:
            self._async.wait()
        return state, log

    def run_with_restarts(self, init_state, n_steps: int,
                          fail_at: Optional[set] = None,
                          max_restarts: int = 8):
        """Cluster-controller loop: rerun after every injected failure."""
        fail_at = set(fail_at or ())
        logs = []
        restarts = 0
        while True:
            try:
                state, log = self.run(init_state, n_steps, fail_at=fail_at)
                logs.extend(log)
                return state, logs, restarts
            except InjectedFailure:
                restarts += 1
                logs.append({"event": "restart", "n": restarts})
                if restarts > max_restarts:
                    raise
