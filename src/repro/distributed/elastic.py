"""Elastic re-meshing: move a training state between meshes of different
sizes without retraining.

Checkpoints store *logical* arrays + axis names (never device layouts), so
scaling from N to M chips is: restore with the new mesh's sharding rules.
The only constraint is divisibility, and the sharding rules already fall
back to replication for non-dividing dims — so any (data, model) factoring
of the new chip count is a legal restore target.

``plan_remesh`` picks the new mesh shape for a chip budget; ``remesh``
re-materializes a live state tree onto a new mesh in-process (used when a
pod is drained but the job keeps running on the remainder).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax

from repro.distributed import sharding as shd
from repro.models import common as cm


def plan_remesh(n_chips: int, *, model_parallel: Optional[int] = None,
                prefer_model: int = 16) -> Tuple[int, int]:
    """(data, model) factoring for a chip budget. Keeps the model axis at
    the largest power-of-two divisor <= prefer_model so TP layouts survive
    scale-downs (e.g. 512 -> 256 chips keeps model=16, halves data)."""
    if model_parallel is not None:
        if n_chips % model_parallel:
            raise ValueError(f"{n_chips} chips not divisible by "
                             f"model={model_parallel}")
        return n_chips // model_parallel, model_parallel
    m = 1
    while m * 2 <= prefer_model and n_chips % (m * 2) == 0:
        m *= 2
    return n_chips // m, m


def remesh(state, old_mesh, new_mesh, rules_new: dict):
    """Reshard a live Param tree onto `new_mesh` under `rules_new`.

    Implementation: gather each leaf to host (at scale: all-gather only the
    shards that move; XLA's resharding transfer does this when both meshes
    are visible — on a single controller we route via host), then place
    with the new NamedSharding."""
    def leaf(p):
        arr = jax.device_get(p.value)
        sharding = shd.NamedSharding(
            new_mesh, shd.spec_for(arr.shape, p.axes, rules_new, new_mesh))
        return cm.Param(jax.device_put(arr, sharding), p.axes)
    return jax.tree.map(leaf, state, is_leaf=cm.is_param)
