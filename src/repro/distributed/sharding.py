"""Logical-axis -> mesh sharding rules (DP / FSDP / TP / EP / SP).

Every Param carries logical axis names; ``make_rules`` maps them to mesh
axes per (config, mode) and ``sharding_for_tree`` materializes
NamedShardings with automatic fallback: a dim whose size does not divide the
assigned mesh axes — or whose mesh axis is already taken by an earlier dim —
falls back to replication. This is what lets 14/25/40-head archs and
non-multiple-of-16 vocabs compile on a 16-way model axis (documented
baseline inefficiency; see EXPERIMENTS.md §Perf).

Modes:
  train  FSDP (embed dim over `data`) x TP (heads/mlp/vocab/expert over
         `model`); batch over (`pod`, `data`).
  serve  TP only; params replicated over `data`; decode KV cache sharded on
         kv_heads when divisible, else on the sequence dim (SP fallback).
"""
from __future__ import annotations

import contextlib
import contextvars
import math
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import common as cm

# ---------------------------------------------------------------------------
# Activation sharding context: model code calls ``constrain(x, axes)`` on hot
# intermediates; without an active context it is a no-op (CPU unit tests),
# with one (dry-run / launch scripts) it pins GSPMD propagation so batch/head
# dims stay sharded through scans and remat. See EXPERIMENTS.md §Perf.
# ---------------------------------------------------------------------------

_ACT = contextvars.ContextVar("activation_sharding", default=None)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules: dict):
    token = _ACT.set((mesh, rules))
    try:
        yield
    finally:
        _ACT.reset(token)


def constrain(x, axes: tuple):
    """Apply a sharding constraint by logical axis names (no-op w/o ctx)."""
    ctx = _ACT.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = spec_for(x.shape, axes, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_if(x, axes: tuple, key: str):
    """constrain(), but only when rule `key` is mapped — a constraint with
    an unmapped key would PIN the tensor replicated and override GSPMD's
    (often better) propagated choice."""
    ctx = _ACT.get()
    if ctx is None or ctx[1].get(key) is None:
        return x
    return constrain(x, axes)


def dp_axes(mesh: Mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def make_rules(cfg, mesh: Mesh, mode: str = "train",
               overrides: Optional[dict] = None) -> dict:
    """mode: train | prefill | serve (decode).

    Attention sharding policy (§Perf iteration 1): when kv/q heads do not
    divide the model axis, the old fallback sharded `kv_seq` — GSPMD then
    all-gathers the (.., q_chunk, kv_seq) score tensor inside every
    layer x chunk loop for the softmax (measured 54 TB/chip for
    deepseek-67b prefill_32k). Instead, shard the attention *q-chunk* dim
    over `model` ("attn_q") and replicate K/V: scores/softmax/AV all stay
    local, and the only added traffic is the per-chunk output gather
    (~MBs). Decode keeps kv_seq sharding — its q length is 1, and the
    sharded cache is what bounds per-chip HBM."""
    model_n = mesh.shape["model"]
    kv_shardable = cfg.n_kv_heads > 0 and cfg.n_kv_heads % model_n == 0
    heads_shardable = cfg.n_heads > 0 and cfg.n_heads % model_n == 0
    attn_fallback = cfg.n_heads > 0 and not (kv_shardable and
                                             heads_shardable)
    # §Perf iteration 4: prefill processes ~64k tokens/device, so
    # activation all-reduces (Megatron TP) cost ~2 x tokens x d_model per
    # layer (~7.5 GB for deepseek-67b) while the layer's weights are only
    # ~1.4 GB. Weight-gathered sequence parallelism (ZeRO-3 style: params
    # sharded over `data`, gathered per layer; activations sharded over
    # `model` along the sequence) is strictly cheaper whenever
    # tokens/device * d_model >> layer params. Attention-only archs use it
    # for prefill; SSM/hybrid keep TP (their prefill is not
    # collective-bound and the chunked scan dislikes seq sharding).
    zero3_prefill = (mode == "prefill" and cfg.n_heads > 0
                     and cfg.ssm is None)
    park = "data" if zero3_prefill else "model"
    rules = {
        "layer": None,
        "embed": "data" if mode == "train" else None,
        "embed2": park,
        "vocab": park,
        "heads": park,       # non-dividing head counts fall back to
        "kv_heads": park,    # replication in spec_for automatically
        "head_dim": None,
        "mlp": park,
        "expert": park,
        "q_lora": None,
        "kv_lora": None,
        "ssm_inner": "model",
        "ssm_conv_ch": "model",
        "ssm_heads": "model",
        "ssm_state": None,
        "conv": None,
        # activations / caches
        "batch": dp_axes(mesh),
        "seq": "model" if zero3_prefill else None,
        # train: shard scores on q-chunks ONLY when q heads shard but KV
        # heads don't (deepseek/internvl/granite class — measured 2-4x);
        # for heads-unshardable archs the backward pass of seq-sharded
        # attention costs more than it saves (measured regressions on
        # minicpm/hymba/qwen2) — they keep the kv_seq fallback.
        "attn_q": ("model" if (zero3_prefill or
                               (mode == "train" and heads_shardable
                                and not kv_shardable)) else None),
        "kv_seq": ("model" if (not kv_shardable and mode == "serve")
                   else ("model" if zero3_prefill else
                         ("model" if (mode == "train" and not kv_shardable
                                      and not heads_shardable) else None))),
        "enc_seq": None,
        "embed_act": None,   # activation d_model dim (never FSDP-sharded)
    }
    if overrides:
        rules.update(overrides)
    return rules


def spec_for(shape: tuple, axes: tuple, rules: dict, mesh: Mesh) -> P:
    used = set()
    parts = []
    for dim, ax in zip(shape, axes):
        assign = rules.get(ax)
        if assign is None:
            parts.append(None)
            continue
        assign_t = assign if isinstance(assign, tuple) else (assign,)
        size = math.prod(mesh.shape[a] for a in assign_t)
        if any(a in used for a in assign_t) or dim % size != 0:
            parts.append(None)
            continue
        used.update(assign_t)
        parts.append(assign_t if len(assign_t) > 1 else assign_t[0])
    return P(*parts)


def sharding_for_tree(tree, rules: dict, mesh: Mesh):
    """Param tree (values may be ShapeDtypeStructs) -> NamedSharding tree."""
    def leaf(p):
        return NamedSharding(mesh, spec_for(p.value.shape, p.axes, rules, mesh))
    return jax.tree.map(leaf, tree, is_leaf=cm.is_param)


def batch_sharding(specs: dict, rules: dict, mesh: Mesh):
    """Input batch dict (name -> ShapeDtypeStruct) -> shardings.

    Convention: dim 0 is batch, the rest replicated.
    """
    out = {}
    for name, sds in specs.items():
        axes = ("batch",) + (None,) * (len(sds.shape) - 1)
        out[name] = NamedSharding(mesh, spec_for(sds.shape, axes, rules, mesh))
    return out


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
