"""Morsel-parallel sharded execution: pool-per-(shard, tier) dispatch.

The single-host ``runtime.ThreadPoolDispatcher`` (PR 2) overlaps one
execution's backend calls on per-tier worker pools; this module
generalizes that shape to **N shard workers**: the executor's morsel
stream is partitioned round-robin by morsel index, each shard runs behind
the existing :class:`runtime.Dispatcher` interface with its *own* inner
dispatcher, and shard outputs merge back in logical morsel order
(``Table.concat`` in the executor) with per-shard staging meters combined
by ``UsageMeter.merge`` into one deterministic call log.

Concurrency semantics
---------------------
* Explicit ``per_tier_concurrency`` caps are **serving quotas** for a
  model tier — a global resource. They are *split* across shards
  (integer division, remainder to shard 0), so for any quota >= the
  shard count the total in-flight calls against that tier never exceed
  the cap (:func:`split_quota`). A quota *smaller* than the shard count
  cannot be honored exactly: every shard needs at least one worker to
  make progress, so the floor-of-1 deliberately over-subscribes by up to
  ``shards - quota`` calls rather than starving (and deadlocking)
  shards — use fewer shards if the quota is that tight.
* The default ``concurrency`` is a shard-local replica width: each shard
  worker models its own serving replica, so adding shards adds capacity
  for un-quota'd tiers. This is what the shard-scaling benchmark
  (``benchmarks/bench_shard.py``) measures.

Drivers
-------
* ``threads``: one ``ThreadPoolDispatcher`` per shard — a pool per
  (shard, tier) plus a per-shard chain pool; shard workers genuinely
  overlap and ``wall_s`` is measured. Host (UDF) compute still serializes
  process-wide through one shared lock.
* ``simulated``: one shard-aware :class:`ShardEventScheduler` shared by
  every shard (jobs land on composite ``(shard, tier)`` pools; host
  compute stays one global worker), driven through per-shard
  ``SimulatedDispatcher`` views — so Table-9 accounting stays a single
  deterministic event replay.
* ``procs``: one ``distributed.process_workers.ProcessShardDispatcher``
  per shard — the threads topology, but each shard's backend calls and
  host UDFs execute in a spawned worker *subprocess* (GIL-free; no
  shared host lock — each worker is its own interpreter). Worker death
  surfaces through :meth:`kill_shard` exactly like an explicit kill, so
  the requeue/exactly-once story below carries over verbatim. Requires
  ``backends`` so the picklable ones can ship to the workers at spawn.

Shard-count invariance
----------------------
Results, call counts, and per-tier meter totals are identical for any
shard count (test-enforced for shards in {1, 2, 4} under both drivers):
morsel boundaries don't depend on the shard count, batch formation in the
``BatchCoalescer`` stays *global* (one reorder buffer in morsel order —
only batch execution round-robins across shard pools), and the default
process-wide shared ``OutputCache`` bills cross-shard duplicates once
through the single-flight claim/publish protocol. ``shared_cache=False``
(``ctx.shard_cache = "local"``) opts into shard-local memoization —
cheaper coordination, but cross-shard duplicates then bill per shard, so
it deliberately trades the invariance guarantee away.

Metering
--------
Calls bill into per-(target meter, shard) staging meters; the executor
calls :meth:`ShardedDispatcher.finalize` once per execution, which merges
the staging meters into the target with ``UsageMeter.merge`` — entries
sort by their logical (operator, morsel/batch, chunk, call) key, so two
threaded sharded runs that made the same calls report byte-identical
combined logs regardless of thread arrival order.
"""
from __future__ import annotations

import threading
from concurrent.futures import CancelledError
from typing import Any, Dict, List, Optional, Tuple

from repro.core import backends as bk
from repro.core import runtime as rt

# composite tier-name encoding for the shared event scheduler's
# per-(shard, tier) pools
_SHARD_SEP = "\x1f"
_SHARD_MARK = "\x02"


def split_quota(total: int, shards: int) -> List[int]:
    """Split a per-tier serving quota into per-shard shares: integer
    division with the remainder to shard 0, and a floor of one worker per
    shard (a quota smaller than the shard count over-subscribes rather
    than starving shards)."""
    shards = max(1, int(shards))
    total = max(1, int(total))
    base, rem = divmod(total, shards)
    return [max(1, base + (rem if s == 0 else 0)) for s in range(shards)]


def _compose(shard: int, tier: str) -> str:
    if tier == rt.HOST_TIER:        # one Python process: host work is one
        return tier                 # global resource, never sharded
    return f"{_SHARD_MARK}{shard}{_SHARD_SEP}{tier}"


def _decompose(tier: str) -> Tuple[Optional[int], str]:
    if tier.startswith(_SHARD_MARK) and _SHARD_SEP in tier:
        shard, base = tier[1:].split(_SHARD_SEP, 1)
        return int(shard), base
    return None, tier


class ShardEventScheduler(rt.EventScheduler):
    """An :class:`runtime.EventScheduler` whose pools are keyed by
    composite (shard, tier) names: quota'd tiers get their split share
    per shard, un-quota'd tiers get the full default width per shard
    (each shard is its own replica). ``mode="sync"`` still collapses
    everything onto one worker — sequential accounting is shard-blind."""

    def __init__(self, shards: int, concurrency: int = 16,
                 per_tier: Optional[Dict[str, int]] = None,
                 mode: str = "async"):
        super().__init__(concurrency, per_tier=None, mode=mode)
        self.shards = max(1, int(shards))
        self._base_per_tier = dict(per_tier or {})

    def workers(self, tier: str) -> int:
        if self.mode == "sync" or tier == rt.HOST_TIER:
            return 1
        shard, base = _decompose(tier)
        quota = self._base_per_tier.get(base)
        if quota is not None:
            return split_quota(quota, self.shards)[shard or 0]
        return max(1, int(self.concurrency))


class _ShardSchedulerView:
    """The scheduler one shard's ``SimulatedDispatcher`` sees: submits
    land on the shared :class:`ShardEventScheduler` under composite
    (shard, tier) pool names, so every shard replays onto ONE event
    timeline (deterministic Table-9 accounting) while still respecting
    its own serving quota."""

    def __init__(self, sched: ShardEventScheduler, shard: int):
        self._sched = sched
        self._shard = shard

    def submit(self, tier: str, duration_s: float,
               ready_s: float = 0.0) -> float:
        return self._sched.submit(_compose(self._shard, tier), duration_s,
                                  ready_s=ready_s)

    def drain(self, meter: bk.UsageMeter, cursor: int,
              ready_s: float = 0.0) -> Tuple[int, float]:
        log = meter.call_log
        finish = ready_s
        for tier, lat in log[cursor:]:
            finish = max(finish, self.submit(tier, lat, ready_s))
        return len(log), finish

    def barrier(self) -> float:
        return self._sched.barrier()

    @property
    def makespan(self) -> float:
        return self._sched.makespan


class _ResilientTask:
    """A chain task that survives its shard dying while still queued.

    ``ThreadPoolDispatcher.abandon`` cancels queued (never-started) chain
    tasks; their futures raise ``CancelledError``. Since a cancelled task
    has no side effects, re-running its ``fn`` inline is exactly-once —
    and any backend calls the re-run makes route through the owning
    :class:`ShardedDispatcher`, which now sends them to surviving shards.
    Already-*running* tasks are untouched by ``abandon`` and complete
    normally (their calls bill exactly once into the dead shard's staging
    meter, which ``finalize`` still merges)."""

    __slots__ = ("_disp", "_up", "_fn", "_task")

    def __init__(self, disp: "ShardedDispatcher", task, fn, shard: int):
        self._disp = disp
        self._up = task
        self._fn = fn
        while True:
            s = disp._route(shard)
            try:
                self._task = disp._inner[s].defer(task, fn)
                return
            except RuntimeError:
                # raced a kill at submit time ("cannot schedule new
                # futures after shutdown"): re-route and try again
                if not disp.is_dead(s):
                    raise
                shard = s

    def result(self):
        try:
            return self._task.result()
        except CancelledError:
            value, ready = self._up.result()
            return self._fn(value, ready)


class ShardedDispatcher(rt.Dispatcher):
    """N shard workers behind the single ``Dispatcher`` interface.

    The executor routes every morsel task to ``shard_of(morsel_idx)``
    (round-robin); each shard's chains and backend calls run on that
    shard's inner dispatcher. ``kind`` reports the underlying driver so
    driver-conditional logic (coalescer linger mode, ephemeral flush
    threads) behaves identically to the unsharded dispatchers.

    Liveness under threads is the PR 2 chain-FIFO argument applied per
    shard: the executor defers tasks in operator-major order, so within
    every shard's FIFO a task's intra-shard dependency is earlier in the
    queue, and cross-shard waits (a coalesced batch needing another
    shard's submission, a cache follower awaiting another shard's
    publish) resolve on that *other* shard's pools, which progress
    independently.

    Failed shards: :meth:`kill_shard` marks a shard dead (explicitly, or
    automatically once ``failure_threshold`` consecutive backend-call
    failures land on it). Every entry point re-routes dead-shard work to
    the ring-next live shard; a threads shard's pools are ``abandon``\\ ed
    (running calls finish and bill once, queued tasks cancel), cancelled
    chains re-run via :class:`_ResilientTask`, and cancelled backend
    calls retry on a survivor. With the default shared cache the retried
    call's already-completed chunks resolve as cache hits, so call counts
    and the merged logical-key log stay exactly what an undisturbed run
    produces; the dead shard's staging meter still merges at
    ``finalize``, so no billed call is ever lost or double-counted."""

    def __init__(self, shards: int, driver: str = "threads",
                 concurrency: int = 16,
                 per_tier: Optional[Dict[str, int]] = None,
                 mode: str = "async", shared_cache: bool = True,
                 policy: Optional[rt.FaultPolicyRuntime] = None,
                 failure_threshold: Optional[int] = None,
                 backends: Optional[Dict[str, Any]] = None,
                 heartbeat_s: float = 0.25,
                 heartbeat_timeout_s: float = 10.0):
        if driver not in (*rt.DRIVERS, "procs"):
            raise ValueError(f"unknown driver {driver!r} "
                             f"(expected one of {(*rt.DRIVERS, 'procs')})")
        self.n_shards = max(1, int(shards))
        self.kind = driver
        self.concurrency = max(1, int(concurrency))
        self.per_tier = dict(per_tier or {})
        self.shared_cache = bool(shared_cache)
        self.policy = policy
        self._failure_threshold = failure_threshold
        self._dead: set = set()
        self._consec_fail: Dict[int, int] = {}
        self._lock = threading.Lock()
        self._local_caches: Dict[int, rt.OutputCache] = {}
        # per-query round-robin cursor offsets: concurrently admitted
        # queries each rotate their morsel->shard mapping by their own
        # base, so a multi-tenant server spreads queries across shards
        # instead of every query starting on shard 0
        self._query_base: Dict[object, int] = {}
        self._next_base = 0
        # target-meter id -> (target ref, per-shard staging meters)
        self._staging: Dict[int, Tuple[bk.UsageMeter,
                                       List[bk.UsageMeter]]] = {}
        self._sched: Optional[ShardEventScheduler] = None
        if driver == "simulated":
            self._sched = ShardEventScheduler(self.n_shards,
                                              self.concurrency,
                                              per_tier=self.per_tier,
                                              mode=mode)
            self._inner: List[rt.Dispatcher] = [
                rt.SimulatedDispatcher(_ShardSchedulerView(self._sched, s),
                                       policy=policy)
                for s in range(self.n_shards)]
        elif driver == "procs":
            # local import: process_workers builds on this module's deps
            from repro.distributed.process_workers import (
                ProcessShardDispatcher, shippable_backends)
            ship = shippable_backends(backends or {})
            self._inner = [
                ProcessShardDispatcher(
                    self.concurrency,
                    per_tier={t: split_quota(q, self.n_shards)[s]
                              for t, q in self.per_tier.items()},
                    mode=mode, policy=policy,
                    backends=ship, shard=s,
                    on_death=self._on_worker_death,
                    heartbeat_s=heartbeat_s,
                    heartbeat_timeout_s=heartbeat_timeout_s)
                for s in range(self.n_shards)]
            try:
                for d in self._inner:
                    d.wait_ready()
            except BaseException:
                for d in self._inner:
                    d.close()
                raise
        else:
            host_lock = threading.Lock()
            self._inner = [
                rt.ThreadPoolDispatcher(
                    self.concurrency,
                    per_tier={t: split_quota(q, self.n_shards)[s]
                              for t, q in self.per_tier.items()},
                    mode=mode, host_lock=host_lock, policy=policy)
                for s in range(self.n_shards)]

    # -- shard routing ---------------------------------------------------
    def shard_of(self, morsel_idx: int, query=None) -> int:
        """Round-robin by morsel index; a ``query`` id adds the query's
        own cursor offset (assigned round-robin at first sight). The
        offset only rotates *placement* — results, call counts, and
        meter totals are placement-invariant, so per-query offsets keep
        the shard-count-invariance contract intact."""
        if query is None or self.n_shards == 1:
            return morsel_idx % self.n_shards
        with self._lock:
            base = self._query_base.get(query)
            if base is None:
                base = self._next_base % self.n_shards
                self._query_base[query] = base
                self._next_base += 1
        return (morsel_idx + base) % self.n_shards

    def release_query(self, query) -> None:
        with self._lock:
            self._query_base.pop(query, None)

    # -- shard liveness --------------------------------------------------
    def _route(self, shard: int) -> int:
        """The physical shard that serves logical shard ``shard``: itself
        while alive, else the ring-next live shard (every caller of a
        dead shard deterministically agrees on the replacement)."""
        shard = shard % self.n_shards
        with self._lock:
            if shard not in self._dead:
                return shard
            for k in range(1, self.n_shards):
                s = (shard + k) % self.n_shards
                if s not in self._dead:
                    return s
        raise rt.ShardDeadError("no live shard available")

    def is_dead(self, shard: int) -> bool:
        with self._lock:
            return shard in self._dead

    def live_shards(self) -> List[int]:
        with self._lock:
            return [s for s in range(self.n_shards)
                    if s not in self._dead]

    def kill_shard(self, shard: int) -> None:
        """Declare one shard worker dead: subsequent work re-routes to
        survivors, queued chain tasks and backend calls on the dead
        shard's pools are cancelled (and requeued by the entry points
        that observe the cancellation), already-running calls complete
        and bill exactly once. Idempotent; killing the last live shard
        is refused — an execution with zero workers cannot finish."""
        if not (0 <= shard < self.n_shards):
            raise ValueError(f"shard {shard} out of range "
                             f"[0, {self.n_shards})")
        with self._lock:
            if shard in self._dead:
                return
            if len(self._dead) + 1 >= self.n_shards:
                raise ValueError("cannot kill the last live shard")
            self._dead.add(shard)
            self._consec_fail.pop(shard, None)
        abandon = getattr(self._inner[shard], "abandon", None)
        if abandon is not None:
            abandon()

    def _on_worker_death(self, shard: int) -> None:
        """Process-worker death callback (crash / SIGKILL / missed
        heartbeat), invoked by the ``ProcessShardClient`` monitor
        *before* it fails the shard's pending call futures — so by the
        time a caller sees ``ShardDeadError``, the shard is already
        marked dead and ``_shard_died_under`` routes the retry to a
        survivor. Losing the last live shard (or dying mid-construction)
        is not recoverable by requeue; those calls then fail with the
        worker's ``ShardDeadError``."""
        try:
            self.kill_shard(shard)
        except (ValueError, AttributeError):
            pass

    def _shard_died_under(self, shard: int, exc: BaseException) -> bool:
        """Whether ``exc`` means "this shard's pools were torn down",
        as opposed to a genuine backend failure."""
        if not self.is_dead(shard):
            return False
        if isinstance(exc, (CancelledError, rt.ShardDeadError)):
            return True
        return (isinstance(exc, RuntimeError)
                and "shutdown" in str(exc))

    def _note_call_result(self, shard: int, ok: bool) -> None:
        """Consecutive-failure shard liveness: ``failure_threshold``
        straight backend-call failures on one shard mark it dead (its
        pending work requeues onto survivors); any success resets the
        count. The failing call itself still raises — the threshold is a
        health signal for *future* routing, not a retry mechanism (the
        CallPolicy layer owns retries)."""
        th = self._failure_threshold
        if th is None or th <= 0:
            return
        with self._lock:
            if ok:
                self._consec_fail[shard] = 0
                return
            n = self._consec_fail.get(shard, 0) + 1
            self._consec_fail[shard] = n
            live = self.n_shards - len(self._dead)
            should_kill = (n >= th and shard not in self._dead
                           and live > 1)
        if should_kill:
            self.kill_shard(shard)

    def shard_quota(self, tier: str, shard: int) -> int:
        """The (shard, tier) pool width actually in force."""
        quota = self.per_tier.get(tier)
        if quota is not None:
            return split_quota(quota, self.n_shards)[shard]
        return self.concurrency

    # -- metering --------------------------------------------------------
    def meter_for(self, meter: bk.UsageMeter, shard: int) -> bk.UsageMeter:
        with self._lock:
            entry = self._staging.get(id(meter))
            if entry is None or entry[0] is not meter:
                entry = (meter, [bk.UsageMeter()
                                 for _ in range(self.n_shards)])
                self._staging[id(meter)] = entry
            return entry[1][shard]

    def finalize(self, meter: bk.UsageMeter) -> None:
        with self._lock:
            entry = self._staging.pop(id(meter), None)
        if entry is not None:
            meter.absorb(bk.UsageMeter.merge(entry[1]))

    def _cache_for(self, cache: Optional[rt.OutputCache],
                   shard: int) -> Optional[rt.OutputCache]:
        if cache is None or self.shared_cache:
            return cache
        with self._lock:
            local = self._local_caches.get(shard)
            if local is None:
                local = self._local_caches[shard] = rt.OutputCache()
            return local

    # -- Dispatcher interface --------------------------------------------
    def defer(self, task, fn, shard: int = 0):
        if self.kind == "simulated":
            # simulated defers execute fn inline at defer time; there is
            # no queue to cancel, so plain routing suffices
            return self._inner[self._route(shard)].defer(task, fn)
        return _ResilientTask(self, task, fn, shard)

    def fanout(self, tier_name: str):
        # non-sharded callers (optimizer sample flows) run on shard 0
        return self._inner[self._route(0)].fanout(tier_name)

    def run_llm(self, op, values, backend, tier_name, meter, *,
                batch_size: int = 1,
                cache: Optional[rt.OutputCache] = None,
                ready_s: float = 0.0, shard: int = 0,
                key: Optional[tuple] = None):
        while True:
            s = self._route(shard)
            try:
                outs = self._inner[s].run_llm(
                    op, values, backend, tier_name,
                    self.meter_for(meter, s),
                    batch_size=batch_size,
                    cache=self._cache_for(cache, s),
                    ready_s=ready_s, shard=s, key=key)
            except BaseException as e:
                if self._shard_died_under(s, e):
                    # the shard died with this call queued/cancelled:
                    # retry on a survivor. Chunks that completed before
                    # the kill already published to the (shared) cache,
                    # so the retry re-bills nothing it shouldn't.
                    shard = s
                    continue
                self._note_call_result(s, ok=False)
                raise
            self._note_call_result(s, ok=True)
            return outs

    def run_host(self, fn, n_rows: int, ready_s: float = 0.0,
                 shard: int = 0):
        return self._inner[self._route(shard)].run_host(
            fn, n_rows, ready_s=ready_s)

    def run_udf(self, op, table, values, ready_s: float = 0.0,
                shard: int = 0):
        """UDF steps route like backend calls — under ``procs`` they run
        in the shard's worker process, and a shard dying mid-step retries
        on the ring-next survivor (UDF steps are pure functions of their
        inputs, so a re-run is exactly-once by construction)."""
        while True:
            s = self._route(shard)
            try:
                return self._inner[s].run_udf(op, table, values,
                                              ready_s=ready_s, shard=s)
            except BaseException as e:
                if self._shard_died_under(s, e):
                    shard = s
                    continue
                raise

    def occupancy(self) -> Dict[str, List[float]]:
        """Merged per-tier busy offsets across all shard pools, under the
        tier's *base* name — a ``CostModel`` makespan replay seeds from
        one tier-wide slot list no matter the shard topology. (The base
        class returns ``{}``, which made occupancy-seeded cost estimates
        assume idle pools exactly on the sharded serving path.)"""
        out: Dict[str, List[float]] = {}
        if self._sched is not None:
            sched = self._sched
            with sched._elock:
                now = sched._floor
                for key, pool in sched._pools.items():
                    if key in (rt.HOST_TIER, "\x00sync"):
                        continue
                    _, base = _decompose(key)
                    busy = [t - now for t in pool if t > now]
                    if busy:
                        out.setdefault(base, []).extend(busy)
        else:
            for d in self._inner:
                for tier, busy in d.occupancy().items():
                    out.setdefault(tier, []).extend(busy)
        return {t: sorted(busy) for t, busy in out.items()}

    def checkpoint(self, meter: bk.UsageMeter, cursor: int) -> int:
        return self._inner[0].checkpoint(meter, cursor)

    @property
    def wall_s(self) -> float:
        if self._sched is not None:
            return self._sched.makespan
        return max(d.wall_s for d in self._inner)

    def close(self) -> None:
        # absorb any staging a caller never finalized so usage is not lost
        with self._lock:
            leftovers = list(self._staging.values())
            self._staging.clear()
        for target, stages in leftovers:
            target.absorb(bk.UsageMeter.merge(stages))
        for d in self._inner:
            d.close()
