"""Config system: model architectures, input shapes, mesh/run configs.

Every assigned architecture is a module ``repro.configs.<arch_id>`` exporting
``CONFIG`` (the exact published dims) built on :class:`ModelConfig`.
``get_config(arch_id)`` resolves ids (dashes or underscores accepted);
``reduced(cfg)`` shrinks any config to a CPU-smoke-testable size of the same
family.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Optional

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

ATTN_GQA = "gqa"        # grouped-query attention (covers MHA when kv == heads)
ATTN_MLA = "mla"        # multi-head latent attention (DeepSeek-V2 / MiniCPM3)
ATTN_NONE = "none"      # attention-free (pure SSM)

FAMILY_DENSE = "dense"
FAMILY_MOE = "moe"
FAMILY_VLM = "vlm"
FAMILY_AUDIO = "audio"  # encoder-decoder with audio-frame frontend stub
FAMILY_HYBRID = "hybrid"
FAMILY_SSM = "ssm"


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    # d_ff of each expert (MoE archs use ModelConfig.d_ff for the expert width)
    router_jitter: float = 0.0
    shared_expert_ff: int = 0  # width of optional always-on shared expert


@dataclass(frozen=True)
class SSMConfig:
    d_state: int
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256
    conv_width: int = 4
    n_groups: int = 1  # B/C shared across heads (GQA-analogue in SSD duality)


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int                     # query heads (0 for attn-free)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    attn_type: str = ATTN_GQA
    head_dim: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    sliding_window: int = 0          # 0 = full attention
    # hybrid archs: fraction of layers (or explicit ids) that use full attention
    full_attn_layers: tuple = ()
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    mla: Optional[MLAConfig] = None
    # enc-dec
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    # vlm / audio frontend stubs
    n_prefix_embeds: int = 0         # patch/frame embeddings prepended to text
    citation: str = ""

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ---- derived quantities -------------------------------------------------
    @property
    def d_head_total(self) -> int:
        return self.n_heads * self.head_dim

    def param_count(self) -> int:
        """Approximate total parameter count (embeddings included)."""
        c = self
        emb = c.vocab_size * c.d_model * (1 if c.tie_embeddings else 2)
        per_layer = self._params_per_layer()
        n_dec = c.n_layers
        total = emb + n_dec * per_layer
        if c.is_encoder_decoder:
            # encoder layers: self-attn + ffn; decoder already counted (adds cross-attn)
            enc_layer = self._attn_params() + 3 * c.d_model * c.d_ff + 2 * c.d_model
            total += c.n_encoder_layers * enc_layer
            total += n_dec * self._attn_params()  # cross attention
        return total

    def active_param_count(self) -> int:
        """Params used per token (MoE: only routed experts)."""
        c = self
        if c.moe is None:
            return self.param_count()
        emb = c.vocab_size * c.d_model * (1 if c.tie_embeddings else 2)
        attn = self._attn_params()
        expert = 3 * c.d_model * c.d_ff
        active_ffn = c.moe.top_k * expert + (3 * c.d_model * c.moe.shared_expert_ff)
        router = c.d_model * c.moe.num_experts
        per_layer = attn + active_ffn + router + 2 * c.d_model
        return emb + c.n_layers * per_layer

    def _attn_params(self) -> int:
        c = self
        if c.attn_type == ATTN_NONE:
            return self._ssm_params()
        if c.attn_type == ATTN_MLA:
            m = c.mla
            qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
            p = c.d_model * m.q_lora_rank + m.q_lora_rank * c.n_heads * qk_head
            p += c.d_model * (m.kv_lora_rank + m.qk_rope_head_dim)
            p += m.kv_lora_rank * c.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
            p += c.n_heads * m.v_head_dim * c.d_model
            return p
        qkv = c.d_model * (c.n_heads + 2 * c.n_kv_heads) * c.head_dim
        out = c.n_heads * c.head_dim * c.d_model
        p = qkv + out
        if c.family == FAMILY_HYBRID and c.ssm is not None:
            p += self._ssm_params()
        return p

    def _ssm_params(self) -> int:
        s = self.ssm
        d_inner = s.expand * self.d_model
        n_heads = d_inner // s.head_dim
        p = self.d_model * 2 * d_inner                  # in_proj (x, z)
        p += self.d_model * 2 * s.n_groups * s.d_state  # B, C projections (grouped)
        p += self.d_model * n_heads                     # dt proj
        p += n_heads + n_heads                          # A_log, D
        p += (d_inner + 2 * s.n_groups * s.d_state) * s.conv_width  # depthwise conv
        p += d_inner * self.d_model                     # out proj
        return p

    def _params_per_layer(self) -> int:
        c = self
        attn = self._attn_params()
        if c.moe is not None:
            ffn = c.moe.num_experts * 3 * c.d_model * c.d_ff
            ffn += c.d_model * c.moe.num_experts
            ffn += 3 * c.d_model * c.moe.shared_expert_ff
        elif c.family == FAMILY_SSM:
            ffn = 0
        else:
            ffn = 3 * c.d_model * c.d_ff
        return attn + ffn + 2 * c.d_model


# ---------------------------------------------------------------------------
# Input shapes (assigned): every LM arch is paired with all four
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

# archs able to run long_500k (sub-quadratic context path)
SUBQUADRATIC = ("hymba-1.5b", "mamba2-1.3b")

ARCH_IDS = (
    "codeqwen1.5-7b",
    "qwen2-0.5b",
    "deepseek-67b",
    "minicpm3-4b",
    "granite-moe-1b-a400m",
    "llama4-scout-17b-a16e",
    "internvl2-76b",
    "seamless-m4t-large-v2",
    "hymba-1.5b",
    "mamba2-1.3b",
)


def _mod_name(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str) -> ModelConfig:
    arch_id = arch_id.replace("_", "-")
    # tolerate '1.5' style ids translated both ways
    canon = None
    for a in ARCH_IDS:
        if a == arch_id or _mod_name(a) == _mod_name(arch_id):
            canon = a
            break
    if canon is None:
        raise KeyError(f"unknown arch '{arch_id}'; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_mod_name(canon)}")
    return mod.CONFIG


def list_configs() -> dict:
    return {a: get_config(a) for a in ARCH_IDS}


def cells(include_skipped: bool = True):
    """Yield (arch_id, shape_name, runnable) for all 40 assigned cells."""
    for a in ARCH_IDS:
        for s in SHAPES:
            runnable = not (s == "long_500k" and a not in SUBQUADRATIC)
            if runnable or include_skipped:
                yield a, s, runnable


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------

def reduced(cfg: ModelConfig, *, n_layers: int = 2, d_model: int = 64,
            vocab: int = 512) -> ModelConfig:
    """Shrink a config to a tiny same-family variant runnable on CPU."""
    n_heads = min(cfg.n_heads, 4) if cfg.n_heads else 0
    kv = 0
    if cfg.n_kv_heads:
        kv = max(1, min(cfg.n_kv_heads, n_heads))
        # preserve GQA-ness when the full config has it
        if cfg.n_heads and cfg.n_kv_heads < cfg.n_heads:
            kv = max(1, n_heads // 2)
    head_dim = d_model // n_heads if n_heads else 0
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=kv,
        d_ff=d_model * 2 if cfg.d_ff else 0,
        vocab_size=vocab,
        head_dim=head_dim,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        full_attn_layers=tuple(i for i in cfg.full_attn_layers if i < n_layers),
        n_encoder_layers=min(cfg.n_encoder_layers, n_layers),
        n_prefix_embeds=min(cfg.n_prefix_embeds, 8),
    )
    if cfg.moe is not None:
        # capacity_factor 8 => effectively dropless at smoke-test token
        # counts, so decode/teacher-forcing consistency is exact; the full
        # configs keep the production 1.25 (capacity drops are a training-
        # time throughput trade, not a correctness surface)
        kw["moe"] = replace(cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2),
                            capacity_factor=8.0,
                            shared_expert_ff=(d_model if cfg.moe.shared_expert_ff else 0))
        kw["d_ff"] = d_model  # tiny experts
    if cfg.ssm is not None:
        kw["ssm"] = replace(cfg.ssm, d_state=16, head_dim=16, chunk_size=32)
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                              qk_nope_head_dim=head_dim, qk_rope_head_dim=head_dim // 2,
                              v_head_dim=head_dim)
    return replace(cfg, **kw)
