"""SeamlessM4T-large-v2 — encoder-decoder, multimodal (audio frontend is a
STUB: input_specs() provides precomputed frame embeddings). [arXiv:2308.11596; hf]"""
from repro.configs import ModelConfig, FAMILY_AUDIO

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family=FAMILY_AUDIO,
    n_layers=24,             # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    is_encoder_decoder=True,
    n_encoder_layers=24,
    citation="arXiv:2308.11596",
)
