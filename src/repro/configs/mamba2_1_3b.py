"""Mamba2-1.3B — attention-free SSD (state-space duality). [arXiv:2405.21060; unverified]"""
from repro.configs import ModelConfig, SSMConfig, FAMILY_SSM, ATTN_NONE

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family=FAMILY_SSM,
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,                  # attn-free; no separate FFN (Mamba block is the mixer)
    vocab_size=50280,
    attn_type=ATTN_NONE,
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, chunk_size=256),
    citation="arXiv:2405.21060",
)
