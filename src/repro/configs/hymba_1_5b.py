"""Hymba-1.5B — hybrid: parallel attention + mamba heads in every layer;
sliding-window attention except 3 full-attention layers. [arXiv:2411.13676; hf]"""
from repro.configs import ModelConfig, SSMConfig, FAMILY_HYBRID

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family=FAMILY_HYBRID,
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    sliding_window=1024,
    full_attn_layers=(0, 15, 31),   # first/middle/last use global attention
    ssm=SSMConfig(d_state=16, expand=2, head_dim=64, chunk_size=256),
    citation="arXiv:2411.13676",
)
