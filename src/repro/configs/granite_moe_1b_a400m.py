"""Granite-3.0-1B-A400M — MoE 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.configs import ModelConfig, MoEConfig, FAMILY_MOE

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family=FAMILY_MOE,
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,                # per-expert width
    vocab_size=49155,
    tie_embeddings=True,
    moe=MoEConfig(num_experts=32, top_k=8),
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
