"""MiniCPM3-4B — MLA (multi-head latent attention). [hf:openbmb/MiniCPM3-4B; hf]"""
from repro.configs import ModelConfig, MLAConfig, FAMILY_DENSE, ATTN_MLA

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family=FAMILY_DENSE,
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    attn_type=ATTN_MLA,
    head_dim=96,  # qk_nope(64) + qk_rope(32)
    mla=MLAConfig(
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
    ),
    citation="hf:openbmb/MiniCPM3-4B",
)
