"""Qwen2-0.5B — GQA with QKV bias. [arXiv:2407.10671; hf]"""
from repro.configs import ModelConfig, FAMILY_DENSE

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family=FAMILY_DENSE,
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    citation="arXiv:2407.10671",
)
