"""InternVL2-76B backbone (InternViT frontend is a STUB: input_specs() provides
precomputed patch embeddings). LLM backbone dims. [arXiv:2404.16821; unverified]"""
from repro.configs import ModelConfig, FAMILY_VLM

CONFIG = ModelConfig(
    name="internvl2-76b",
    family=FAMILY_VLM,
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500000.0,
    n_prefix_embeds=256,     # precomputed ViT patch embeddings per example
    citation="arXiv:2404.16821",
)
