"""CodeQwen1.5-7B — Qwen1.5 architecture. [hf:Qwen/CodeQwen1.5-7B; hf]"""
from repro.configs import ModelConfig, FAMILY_DENSE

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family=FAMILY_DENSE,
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,           # GQA kv=32 (full MHA-style KV)
    d_ff=13440,
    vocab_size=92416,
    qkv_bias=True,           # Qwen1.5 uses QKV bias
    rope_theta=1_000_000.0,
    citation="hf:Qwen/CodeQwen1.5-7B",
)
