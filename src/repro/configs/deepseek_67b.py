"""DeepSeek-67B — llama-arch dense, GQA kv=8. [arXiv:2401.02954; hf]"""
from repro.configs import ModelConfig, FAMILY_DENSE

CONFIG = ModelConfig(
    name="deepseek-67b",
    family=FAMILY_DENSE,
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
    rope_theta=10000.0,
    citation="arXiv:2401.02954",
)
