"""Llama-4-Scout-17B-16E — MoE 16 experts top-1, shared expert, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs import ModelConfig, MoEConfig, FAMILY_MOE

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family=FAMILY_MOE,
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,               # per-expert width
    vocab_size=202048,
    rope_theta=500000.0,
    moe=MoEConfig(num_experts=16, top_k=1, shared_expert_ff=8192),
    citation="hf:meta-llama/Llama-4-Scout-17B-16E",
)
