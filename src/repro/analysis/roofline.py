"""Roofline-term extraction from compiled dry-run artifacts.

Conventions (validated empirically against controlled SPMD compilations on
this backend — see tests/test_roofline.py):

* ``compiled.cost_analysis()`` flops / "bytes accessed" are **per device**.
* ``compiled.memory_analysis()`` sizes are **per device**.
* Post-SPMD HLO shapes are per-device. Collective link traffic per chip is
  modeled from each collective's **result shape** and its replica-group size
  g with standard ring estimates:
      all-gather          (g-1)/g * result_bytes
      all-reduce        2*(g-1)/g * result_bytes
      reduce-scatter      (g-1)   * result_bytes   (input is g * result)
      all-to-all          (g-1)/g * result_bytes
      collective-permute            result_bytes
* Collectives inside `while` bodies (layer scans, remat loops) are multiplied
  by the loop trip count, recovered from the constant bound in the loop's
  condition computation.

Three roofline terms (seconds):
    compute    = flops_per_device / PEAK_FLOPS
    memory     = bytes_per_device / HBM_BW
    collective = link_bytes_per_device / LINK_BW
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

# TPU v5e-class hardware constants (per chip), per the assignment.
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_TY_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%[\w\.\-]+\s*=\s*(.*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
# computation headers are single lines "%name (params...) -> type {"; param
# lists may nest parens (tuple-typed while carries), so match greedily —
# instruction lines ("%x = ...") can't match because of the "=".
_COMP_RE = re.compile(
    r"^\s*(?:ENTRY\s+)?%([\w\.\-]+)\s+\(.*\)\s*->\s*\S.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


_FLOAT_DTYPES = {"bf16", "f16", "f32", "f64", "f8e4m3fn", "f8e5m2"}


def _shape_list_bytes(type_str: str, float_bytes: int = 0) -> int:
    """Total bytes of an HLO type list. float_bytes > 0 overrides the
    per-element size of floating dtypes — the CPU dry-run backend legalizes
    bf16 compute to f32 (entry params are bf16; every internal tensor and
    collective rides an f32 carrier), so TARGET-hardware accounting counts
    floating tensors at the model's compute dtype (bf16 = 2 bytes)."""
    total = 0
    for dt, dims in _TY_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        size = _DTYPE_BYTES.get(dt, 4)
        if float_bytes and dt in _FLOAT_DTYPES:
            size = min(size, float_bytes)
        total += n * size
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


_KIND_FACTOR = {
    "all-gather": lambda g: (g - 1) / g,
    "all-reduce": lambda g: 2 * (g - 1) / g,
    "reduce-scatter": lambda g: (g - 1),
    "all-to-all": lambda g: (g - 1) / g,
    "collective-permute": lambda g: 1.0,
}


@dataclasses.dataclass
class CollectiveStats:
    bytes_per_chip: float = 0.0          # target-dtype (bf16) accounting
    bytes_per_chip_raw: float = 0.0      # as-compiled (CPU f32 carriers)
    counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    bytes_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class HLOStats:
    collectives: CollectiveStats
    dot_flops: float = 0.0       # per-device MXU flops, trip-count-aware
    dot_count: int = 0


_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*([a-z0-9]+)\[([0-9,]*)\]")
_DOT_OPERANDS_RE = re.compile(r"\bdot\(([^)]*)\)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"(?:([a-z0-9]+)\[([0-9,]*)\][^%]*)?%([\w\.\-]+)")


def _dims(dim_str: str):
    return [int(d) for d in dim_str.split(",")] if dim_str else []


def _split_computations(hlo_text: str) -> Dict[str, list]:
    comps: Dict[str, list] = {"__toplevel__": []}
    cur = "__toplevel__"
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line)
        if m:
            cur = m.group(1)
            comps[cur] = []
        else:
            comps[cur].append(line)
    return comps


_CONST_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*s32\[\]\s+constant\((\d+)\)")
_COMPARE_RE = re.compile(r"compare\(([^)]*)\)")


def _cond_trip_bound(lines) -> int:
    """Loop bound from a while condition: the s32[] constant consumed by
    the comparison (NOT the max constant — conds can also contain unrelated
    literals). The comparison is either a literal ``compare(...)`` or a
    ``ROOT ... fusion(...)`` wrapping one; in both cases the bound constant
    appears among the instruction's operands."""
    consts = {}
    for ln in lines:
        m = _CONST_DEF_RE.match(ln)
        if m:
            consts[m.group(1)] = int(m.group(2))
    if not consts:
        return 0
    for ln in lines:
        if _COMPARE_RE.search(ln) or ("ROOT" in ln and "fusion(" in ln):
            for name in re.findall(r"%([\w\.\-]+)", ln):
                if name in consts:
                    return consts[name]
    return 0


def _body_multipliers(comps: Dict[str, list]) -> Dict[str, int]:
    """while-loop trip counts per computation (condition compare bound),
    propagated one nesting level (scan-in-scan, e.g. grad accumulation)."""
    cond_bound: Dict[str, int] = {}
    for name, lines in comps.items():
        b = _cond_trip_bound(lines)
        if b:
            cond_bound[name] = b

    body_mult: Dict[str, int] = {}
    for name, lines in comps.items():
        for ln in lines:
            m = _WHILE_RE.search(ln)
            if m:
                trips = max(cond_bound.get(m.group(1), 1), 1)
                body_mult[m.group(2)] = max(body_mult.get(m.group(2), 1),
                                            trips)
    for name, lines in comps.items():
        outer = body_mult.get(name, 1)
        if outer == 1:
            continue
        for ln in lines:
            m = _WHILE_RE.search(ln)
            if m:
                inner = max(cond_bound.get(m.group(1), 1), 1)
                body_mult[m.group(2)] = max(
                    body_mult.get(m.group(2), 1), inner * outer)
    return body_mult


def parse_hlo(hlo_text: str) -> HLOStats:
    """One pass over post-SPMD HLO: collective link bytes AND dot flops,
    both multiplied by enclosing while-loop trip counts.

    Why not ``cost_analysis()`` for flops: XLA's analysis visits each while
    body ONCE, so an L-layer lax.scan under-counts matmul flops by ~L x.
    The dot parser resolves operand shapes through a symbol table (operand
    types are not always inlined) and computes
    2 * prod(result_dims) * prod(lhs contracting dims) per dot.
    """
    comps = _split_computations(hlo_text)
    body_mult = _body_multipliers(comps)

    # symbol table: %name -> dims (definitions are unique module-wide)
    sym: Dict[str, list] = {}
    for lines in comps.values():
        for ln in lines:
            m = _DEF_RE.match(ln)
            if m:
                sym[m.group(1)] = _dims(m.group(3))

    stats = HLOStats(collectives=CollectiveStats())
    coll = stats.collectives
    for name, lines in comps.items():
        mult = body_mult.get(name, 1)
        for ln in lines:
            mc = _COLL_RE.match(ln)
            if mc:
                result_types, kind = mc.group(1), mc.group(2)
                b = _shape_list_bytes(result_types, float_bytes=2)
                b_raw = _shape_list_bytes(result_types)
                g = _group_size(ln)
                link_b = b * _KIND_FACTOR[kind](g) * mult
                coll.bytes_per_chip += link_b
                coll.bytes_per_chip_raw += b_raw * _KIND_FACTOR[kind](g) \
                    * mult
                coll.counts[kind] = coll.counts.get(kind, 0) + mult
                coll.bytes_by_kind[kind] = \
                    coll.bytes_by_kind.get(kind, 0.0) + link_b
                continue
            if " dot(" not in ln:
                continue
            md = _DEF_RE.match(ln)
            mo = _DOT_OPERANDS_RE.search(ln)
            mk = _LHS_CDIMS_RE.search(ln)
            if not (md and mo and mk):
                continue
            out_dims = _dims(md.group(3))
            first = mo.group(1).split(",")[0].strip()
            mop = _OPERAND_RE.search(first)
            if not mop:
                continue
            lhs_dims = _dims(mop.group(2)) if mop.group(2) is not None \
                else sym.get(mop.group(3))
            if lhs_dims is None:
                continue
            cdims = [int(i) for i in mk.group(1).split(",") if i != ""]
            k = 1
            for i in cdims:
                if i < len(lhs_dims):
                    k *= lhs_dims[i]
            out_n = 1
            for d in out_dims:
                out_n *= d
            stats.dot_flops += 2.0 * out_n * k * mult
            stats.dot_count += mult
    return stats


def parse_collective_bytes(hlo_text: str) -> CollectiveStats:
    return parse_hlo(hlo_text).collectives


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    link_bytes_per_device: float
    chips: int
    model_flops: float           # global useful flops (6ND / 2ND)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline-model step time = max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the roofline-limited step — the score
        hillclimbed in §Perf: (MODEL_FLOPS/chips/peak) / max(term)."""
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        return ideal / self.step_time_s if self.step_time_s else 0.0


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N_active*D train, 2*N_active*D forward-only."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.seq_len * shape.global_batch
    return 2.0 * n_active * shape.global_batch  # decode: 1 token / sequence


MEM_DTYPE_FACTOR = 0.5   # CPU legalizes bf16 -> f32; HBM traffic on the
                         # TPU target is ~half the measured bytes (caveat:
                         # genuinely-f32 paths like the SSM state are then
                         # under-counted ~2x — noted in EXPERIMENTS.md)


def compute_roofline(cost: dict, coll: CollectiveStats, chips: int,
                     model_flops: float,
                     flops_override: float = 0.0) -> Roofline:
    """flops_override: trip-count-aware dot flops from parse_hlo — XLA's
    cost_analysis visits while bodies once, so an L-layer scan under-counts
    by ~L x; we take max(cost_analysis, dot parser)."""
    flops = max(float(cost.get("flops", 0.0)), float(flops_override))
    byts = float(cost.get("bytes accessed", 0.0)) * MEM_DTYPE_FACTOR
    return Roofline(
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=coll.bytes_per_chip / LINK_BW,
        flops_per_device=flops,
        bytes_per_device=byts,
        link_bytes_per_device=coll.bytes_per_chip,
        chips=chips,
        model_flops=model_flops,
    )
