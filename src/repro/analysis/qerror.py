"""Per-(op kind, tier) q-error reporting for a calibrated CostModel.

The q-error ``max(pred/meas, meas/pred)`` is cardinality estimation's
standard symmetric error, applied here to per-call latency and output
tokens: 1.0 is perfect, 3.0 means the prediction is off by 3x in either
direction. The rows come from :meth:`CostModel.qerror_report` — EWMA
state the model accumulated at its observe sync points — rendered as an
aligned text table (``launch/serve.py --explain-cost``) or a JSON
document for tooling.
"""
from __future__ import annotations

import json
from typing import List, Optional

_COLUMNS = (
    ("op", "{}", 10),
    ("tier", "{}", 12),
    ("calls", "{:d}", 6),
    ("prior_latency_s", "{:.4f}", 9),
    ("pred_latency_s", "{:.4f}", 9),
    ("meas_latency_s", "{:.4f}", 9),
    ("qerror", "{:.3f}", 7),
    ("prior_qerror", "{:.3f}", 7),
    ("tok_qerror", "{:.3f}", 7),
)
_HEADERS = {"prior_latency_s": "prior", "pred_latency_s": "pred",
            "meas_latency_s": "meas", "prior_qerror": "q-prior",
            "tok_qerror": "q-tok", "qerror": "q-err"}


def report_rows(model) -> List[dict]:
    """The model's calibration table (sorted by (op, tier); empty until
    the model has observed at least one typed call)."""
    return model.qerror_report()


def median_qerror(rows: List[dict], field: str = "qerror"
                  ) -> Optional[float]:
    vals = sorted(r[field] for r in rows)
    if not vals:
        return None
    mid = len(vals) // 2
    if len(vals) % 2:
        return vals[mid]
    return 0.5 * (vals[mid - 1] + vals[mid])


def to_json(model, indent: int = 2) -> str:
    rows = report_rows(model)
    doc = {
        "rows": rows,
        "median_qerror": median_qerror(rows),
        "median_prior_qerror": median_qerror(rows, "prior_qerror"),
        "latency_weight": model.latency_weight,
        "ewma_alpha": model.ewma_alpha,
    }
    adm = model.admission_report()
    if adm["observations"] > 0:
        doc["admission"] = adm
    return json.dumps(doc, indent=indent, sort_keys=True)


def render_text(model) -> str:
    """Aligned per-(op, tier) table plus a median summary line."""
    rows = report_rows(model)
    if not rows:
        return ("cost model: no calibration data "
                "(no typed calls observed yet)")
    header = "  ".join(_HEADERS.get(name, name).rjust(width)
                       if name not in ("op", "tier")
                       else _HEADERS.get(name, name).ljust(width)
                       for name, _, width in _COLUMNS)
    lines = [header, "-" * len(header)]
    for r in rows:
        cells = []
        for name, fmt, width in _COLUMNS:
            s = fmt.format(r[name])
            cells.append(s.ljust(width) if name in ("op", "tier")
                         else s.rjust(width))
        lines.append("  ".join(cells))
    med = median_qerror(rows)
    med_prior = median_qerror(rows, "prior_qerror")
    lines.append(f"median q-error {med:.3f} (uncalibrated prior would be "
                 f"{med_prior:.3f})")
    # admission-gate accuracy: whole-plan makespan predictions the
    # QueryServer's controller fed back via observe_makespan
    adm = model.admission_report()
    if adm["observations"] > 0:
        lines.append(
            f"admission makespan: {adm['observations']} observations, "
            f"q-error ewma {adm['qerr_ewma']:.3f} "
            f"last {adm['qerr_last']:.3f} max {adm['qerr_max']:.3f} "
            f"(correction ratio {adm['ratio']:.4f})")
    return "\n".join(lines)
