"""Recompute roofline terms for saved dry-run artifacts from their .hlo
files (used whenever the analysis layer improves — the lower/compile work
is not repeated).

    PYTHONPATH=src python -m repro.analysis.recompute [dir...]
"""
from __future__ import annotations

import glob
import json
import os
import sys

from repro.analysis import roofline as rl
from repro.configs import SHAPES, get_config


def recompute_dir(d: str) -> int:
    n = 0
    for jp in sorted(glob.glob(os.path.join(d, "*.json"))):
        rec = json.load(open(jp))
        if rec.get("skipped") or not rec.get("ok"):
            continue
        hp = jp.replace(".json", ".hlo")
        if not os.path.exists(hp):
            continue
        hstats = rl.parse_hlo(open(hp).read())
        cost = rec.get("cost_analysis", {})
        mf = rl.model_flops_estimate(get_config(rec["arch"]),
                                     SHAPES[rec["shape"]])
        roof = rl.compute_roofline(cost, hstats.collectives, rec["chips"],
                                   mf, flops_override=hstats.dot_flops)
        rec["dot_flops_per_device"] = hstats.dot_flops
        rec["collectives"] = {
            "bytes_per_chip": hstats.collectives.bytes_per_chip,
            "bytes_per_chip_raw": hstats.collectives.bytes_per_chip_raw,
            "counts": hstats.collectives.counts,
            "bytes_by_kind": hstats.collectives.bytes_by_kind,
        }
        rec["roofline"] = {
            "compute_s": roof.compute_s, "memory_s": roof.memory_s,
            "collective_s": roof.collective_s, "dominant": roof.dominant,
            "model_flops": mf, "flops_per_device": roof.flops_per_device,
            "useful_flops_ratio": roof.useful_flops_ratio,
            "roofline_fraction": roof.roofline_fraction,
            "step_time_s": roof.step_time_s,
        }
        json.dump(rec, open(jp, "w"), indent=1)
        n += 1
    return n


if __name__ == "__main__":
    dirs = sys.argv[1:] or ["artifacts/dryrun", "artifacts/perf"]
    for d in dirs:
        print(f"{d}: recomputed {recompute_dir(d)} records")
