"""Backend model tiers: the execution engines physical plans assign to
operators (paper §4's M = {m1, m2, m3, m*}).

Two implementations of the :class:`Backend` protocol:

* :class:`SimulatedBackend` — the calibrated **capability simulator**. Each
  tier answers an operator on a record correctly iff the record's hidden
  difficulty draw falls below the tier's capability; difficulty draws are
  shared across tiers, so correctness sets are *nested* (Hypothesis 2 holds
  exactly) except on records flagged as violations at rate
  ``violation_rate`` — where a stronger tier fails a record a weaker tier
  gets right, reproducing Table-2-style statistics. Wrong answers follow
  the paper's Figure-5 **binary response model** by default (one canonical
  wrong answer per (op, record)); ``diverse_wrong=True`` makes wrong answers
  tier-specific, deliberately breaking that assumption for robustness tests.

* ``JAXBackend`` lives in ``repro.engine.jax_backend`` — it serves a real
  (reduced) model from the zoo through the prefill/decode engine; tiers map
  to architectures per ``cost.DEFAULT_TIERS``.

All backends report token/price/latency usage so optimizer overhead
accounting (Tables 6 & 9) includes *everything the optimizer spends*.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import threading
from typing import Any, Dict, List, Optional, Protocol, Sequence

from repro.core import cost as cost_mod
from repro.core import plan as plan_ir


# ---------------------------------------------------------------------------
# Usage accounting
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Usage:
    calls: int = 0
    tok_in: float = 0.0
    tok_out: float = 0.0
    usd: float = 0.0
    latency_s: float = 0.0     # sum of per-call latencies (sequential time)

    def add(self, other: "Usage"):
        self.calls += other.calls
        self.tok_in += other.tok_in
        self.tok_out += other.tok_out
        self.usd += other.usd
        self.latency_s += other.latency_s


class UsageMeter:
    """Per-tier usage accumulator; threaded through optimizers/executors so
    every experiment can report calls/usd/latency per model (Fig. 10).

    Besides the per-tier totals, the meter keeps ``call_log`` — one
    ``(tier, latency_s)`` entry per LLM call, in issue order. The
    event-driven scheduler (``runtime.EventScheduler``) consumes this log
    to place each call on a simulated worker, so wall-clock accounting is
    per-call rather than per-operator-wave. Backends that know their true
    per-call latencies pass them explicitly; otherwise the aggregate
    latency is split uniformly across the calls.

    ``record`` is lock-protected: under the threaded execution driver
    (``runtime.ThreadPoolDispatcher``) concurrent backend calls bill into
    one shared meter, and totals must match the sequential driver's.

    Calls can carry an optional **logical key** — a tuple like
    ``(op_index, morsel_index, chunk, call)`` identifying the call's place
    in the plan rather than its arrival time. Keys are attached either
    explicitly (``record(..., key=...)``) or ambiently via the
    :meth:`keyed` context manager, which the runtime wraps around backend
    invocations (the ambient form survives the hop onto a tier-pool
    thread because the runtime re-enters it inside the pool thunk).
    ``call_keys`` parallels ``call_log``; :meth:`merge` uses the keys to
    combine per-shard meters into one log with *deterministic* ordering —
    sorted by logical key, not by which shard's thread billed first."""

    def __init__(self):
        self.by_tier: Dict[str, Usage] = {}
        self.call_log: List[tuple] = []      # (tier_name, latency_s)
        self.call_keys: List[Optional[tuple]] = []   # parallel logical keys
        # parallel (op_kind, tok_out_per_call) — CostModel.observe's food;
        # call_log itself stays 2-tuples (the scheduler drain unpacks two)
        self.call_ops: List[Optional[tuple]] = []
        self._lock = threading.Lock()
        self._local = threading.local()

    def __getstate__(self):
        # meters cross process boundaries under the ``procs`` driver:
        # worker call logs ship back to the coordinator with their
        # logical keys attached. Lock and thread-local state is
        # per-process; only the billed data travels.
        with self._lock:
            return {"by_tier": {t: dataclasses.replace(u)
                                for t, u in self.by_tier.items()},
                    "call_log": list(self.call_log),
                    "call_keys": list(self.call_keys),
                    "call_ops": list(self.call_ops)}

    def __setstate__(self, state):
        self.__dict__.update(state)
        self._lock = threading.Lock()
        self._local = threading.local()

    @contextlib.contextmanager
    def keyed(self, key: Optional[tuple]):
        """Attach ``key`` to every call recorded in this thread inside the
        ``with`` block (per-call index appended per entry)."""
        prev = getattr(self._local, "key", None)
        self._local.key = key
        try:
            yield self
        finally:
            self._local.key = prev

    def current_key(self) -> Optional[tuple]:
        """The ambient logical key installed by :meth:`keyed` on this
        thread (None outside a keyed block). Fault-injection harnesses
        (``testing.FlakyBackend``) key their deterministic fault plans
        off it: the logical call identity is driver- and shard-invariant,
        so a seeded plan injects the same faults into the same logical
        calls no matter how execution is scheduled."""
        return getattr(self._local, "key", None)

    def record(self, tier_name: str, usage: Usage,
               per_call_latency_s: Optional[Sequence[float]] = None,
               key: Optional[tuple] = None,
               op_kind: Optional[str] = None):
        if key is None:
            key = getattr(self._local, "key", None)
        if per_call_latency_s is None and usage.calls > 0:
            per_call_latency_s = [usage.latency_s / usage.calls] \
                * usage.calls
        op_info = None
        if op_kind is not None and usage.calls > 0:
            op_info = (op_kind, usage.tok_out / usage.calls)
        with self._lock:
            self.by_tier.setdefault(tier_name, Usage()).add(usage)
            for i, lat in enumerate(per_call_latency_s or ()):
                self.call_log.append((tier_name, lat))
                self.call_keys.append(None if key is None
                                      else tuple(key) + (i,))
                self.call_ops.append(op_info)

    def absorb(self, other: "UsageMeter") -> "UsageMeter":
        """Add another meter's totals and call log into this one (shard
        merge target; also the judge's two-run accounting)."""
        with other._lock:
            tiers = {t: dataclasses.replace(u)
                     for t, u in other.by_tier.items()}
            log, keys = list(other.call_log), list(other.call_keys)
            ops = list(other.call_ops)
            ops += [None] * (len(log) - len(ops))
        with self._lock:
            for t, u in tiers.items():
                self.by_tier.setdefault(t, Usage()).add(u)
            self.call_log.extend(log)
            self.call_keys.extend(keys)
            self.call_ops.extend(ops)
        return self

    @staticmethod
    def merge(meters: Sequence["UsageMeter"]) -> "UsageMeter":
        """Combine meters (e.g. one per shard) into a new meter whose
        ``call_log`` ordering is **deterministic**: entries sort by their
        logical (morsel, call) key, not by arrival time — so two threaded
        sharded runs that made the same calls report identical logs.
        Un-keyed entries keep (meter position) order after the keyed ones."""
        out = UsageMeter()
        entries = []
        for mi, m in enumerate(meters):
            with m._lock:
                for tier, u in m.by_tier.items():
                    out.by_tier.setdefault(tier, Usage()).add(u)
                for pos, entry in enumerate(m.call_log):
                    k = m.call_keys[pos] if pos < len(m.call_keys) else None
                    o = m.call_ops[pos] if pos < len(m.call_ops) else None
                    sort_key = (0, k) if k is not None else (1, (mi, pos))
                    entries.append((sort_key, entry, k, o))
        entries.sort(key=lambda e: e[0])
        for _, entry, k, o in entries:
            out.call_log.append(entry)
            out.call_keys.append(k)
            out.call_ops.append(o)
        return out

    @property
    def total(self) -> Usage:
        t = Usage()
        with self._lock:
            for u in self.by_tier.values():
                t.add(u)
        return t

    def calls(self, tier_name: str) -> int:
        with self._lock:
            u = self.by_tier.get(tier_name)
            return u.calls if u is not None else 0

    def latency(self, tier_name: str) -> float:
        with self._lock:
            u = self.by_tier.get(tier_name)
            return u.latency_s if u is not None else 0.0


class Backend(Protocol):
    tier: cost_mod.TierSpec

    def run_values(self, op: plan_ir.Operator, values: Sequence[Any],
                   meter: Optional[UsageMeter] = None,
                   batch_size: int = 1) -> List[Any]:
        """Execute `op` on each value (reduce: one call over all values).
        batch_size > 1 = batch prompting (App. C): several records share one
        call — cheaper, but the per-record accuracy degrades."""
        ...


# ---------------------------------------------------------------------------
# Oracle protocol — ground truth provider (datasets implement it)
# ---------------------------------------------------------------------------

class Oracle(Protocol):
    def answer(self, op: plan_ir.Operator, value: Any) -> Any:
        """The true output of `op` for one record value."""
        ...

    def answer_reduce(self, op: plan_ir.Operator,
                      values: Sequence[Any]) -> Any:
        ...


class UDFOracle:
    """Fallback oracle: answers via the compiled-UDF grammar. Datasets wrap
    it with instruction-specific truth functions for non-computable ops."""

    def answer(self, op: plan_ir.Operator, value: Any):
        from repro.core import udf as udf_mod
        c = udf_mod.compile_udf(op)
        if c is None:
            raise KeyError(
                f"no oracle for instruction {op.instruction!r}")
        return c.fn(value)

    def answer_reduce(self, op: plan_ir.Operator, values: Sequence[Any]):
        from repro.core import udf as udf_mod
        c = udf_mod.compile_reduce(op.instruction)
        if c is None:
            raise KeyError(
                f"no reduce oracle for instruction {op.instruction!r}")
        return c.fn(list(values))


# ---------------------------------------------------------------------------
# Capability simulator
# ---------------------------------------------------------------------------

def _unit_hash(*parts) -> float:
    """Deterministic U[0,1) from content (stable across runs/processes)."""
    h = hashlib.blake2b("\x1f".join(map(str, parts)).encode(),
                        digest_size=8).digest()
    return int.from_bytes(h, "little") / 2.0 ** 64


_IMAGE_WORDS = ("picture", "image", "poster", "photo", "observed", "badge",
                "audio")


def op_hardness(op: plan_ir.Operator) -> float:
    """Structural instruction difficulty in [0.1, 1.8]."""
    base = {plan_ir.FILTER: 0.35, plan_ir.MAP: 0.85, plan_ir.REDUCE: 0.6,
            plan_ir.RANK: 1.0}[op.kind]
    h = base + min(0.4, len(op.instruction) / 400.0)
    ins = op.instruction.lower()
    if any(w in ins for w in _IMAGE_WORDS):
        h += 0.45
    h += 0.5 * (_unit_hash("hardness", op.kind, op.instruction) - 0.5)
    return max(0.1, min(1.8, h))


_WRONG_TOKENS = ("unclear from the data", "not specified", "mixed signals",
                 "requires manual review", "ambiguous entry")


def corrupt_value(truth: Any, salt: str) -> Any:
    """A canonical wrong answer for a record (binary response model). Wrong
    answers must be *semantically* wrong — they may not retain the truth's
    key content (else the embedding comparator correctly treats them as
    equal and they are not errors at all)."""
    if isinstance(truth, bool):
        return not truth
    if isinstance(truth, (int, float)):
        u = _unit_hash("corrupt", salt)
        delta = (0.07 + 0.5 * u) * (abs(float(truth)) + 1.0)
        return type(truth)(truth + delta if u > 0.5 else truth - delta)
    if truth is None:
        return "unknown"
    s = str(truth)
    u = _unit_hash("corrupt-mode", salt, s)
    if u < 0.34:
        return "No relevant information found."
    if u < 0.67:
        return _WRONG_TOKENS[int(u * 1e6) % len(_WRONG_TOKENS)]
    return "possibly " + s[::-1][: max(4, len(s) // 2)]


@dataclasses.dataclass
class SimulatedBackend:
    tier: cost_mod.TierSpec
    oracle: Oracle
    violation_rate: float = 0.03   # P(a record violates Hypothesis 2)
    diverse_wrong: bool = False    # break the binary response model
    batch_penalty: float = 0.012   # capability loss per extra batched record
    seed: int = 0

    # -- correctness model -------------------------------------------------
    def _capability(self, op: plan_ir.Operator, batch_size: int = 1) -> float:
        """Effective capability on this operator = capability^hardness.

        Hardness is a structural difficulty model: maps (open-ended
        generation) are harder than filters (binary); image/audio-grounded
        instructions are harder than text; long instructions are harder;
        plus a small per-instruction jitter. cap^h preserves the tier
        ordering (Hypothesis 2's nesting) while making weak tiers degrade
        faster on hard operators — the source of the per-operator tier
        diversity in Fig. 10."""
        h = op_hardness(op)
        cap = min(self.tier.capability, 1.0) ** h \
            if self.tier.capability <= 1.0 else self.tier.capability
        return cap - self.batch_penalty * (batch_size - 1)

    def _is_correct(self, op: plan_ir.Operator, value: Any,
                    batch_size: int = 1) -> bool:
        diff = _unit_hash("difficulty", self.seed, op.kind, op.instruction,
                          value)
        cap = self._capability(op, batch_size)
        if _unit_hash("violation", self.seed, op.instruction,
                      value) < self.violation_rate:
            # hypothesis-2 violation: the record has a capability *pivot* —
            # tiers at or below it answer correctly, stronger tiers
            # overthink and fail (the paper's Table-2 "nano is right"
            # cases). Shared pivot across tiers keeps the violation
            # record-consistent.
            pivot = 0.7 + 0.3 * _unit_hash("pivot", self.seed,
                                           op.instruction, value)
            return cap <= pivot
        return diff < cap

    _UNANSWERABLE = {plan_ir.FILTER: False, plan_ir.MAP: "n/a",
                     plan_ir.RANK: 0, plan_ir.REDUCE: None}

    def _output(self, op: plan_ir.Operator, value: Any,
                batch_size: int = 1) -> Any:
        try:
            truth = self.oracle.answer(op, value)
        except KeyError:
            # nonsense instruction (e.g. a corrupted rewrite dropped half a
            # conjunct): a real LLM answers *something*; the simulator
            # returns the kind's degenerate answer
            return self._UNANSWERABLE[op.kind]
        if self._is_correct(op, value, batch_size):
            return truth
        salt_parts = [op.instruction, str(value)]
        if self.diverse_wrong:
            salt_parts.append(self.tier.name)
        return corrupt_value(truth, "|".join(salt_parts))

    # -- protocol ------------------------------------------------------------
    def run_values(self, op: plan_ir.Operator, values: Sequence[Any],
                   meter: Optional[UsageMeter] = None,
                   batch_size: int = 1) -> List[Any]:
        if op.kind == plan_ir.REDUCE:
            try:
                truth = self.oracle.answer_reduce(op, values)
            except KeyError:
                truth = None            # unanswerable reduce instruction
            ok = self._is_correct(op, "\x1e".join(map(str, values))[:512])
            out = truth if ok else corrupt_value(
                truth, op.instruction + "|reduce")
            usage = self._usage(op, n_calls=max(1, (len(values) + 31) // 32),
                                values=values)
            if meter:
                meter.record(self.tier.name, usage,
                             per_call_latency_s=self._per_call(usage),
                             op_kind=op.kind)
            return [out]
        outs = [self._output(op, v, batch_size) for v in values]
        n_calls = max(1, (len(values) + batch_size - 1) // batch_size)
        usage = self._usage(op, n_calls=n_calls, values=values)
        if meter:
            meter.record(self.tier.name, usage,
                         per_call_latency_s=self._per_call(usage),
                         op_kind=op.kind)
        return outs

    @staticmethod
    def _per_call(usage: Usage) -> List[float]:
        """Per-call latency report: tier latency is homogeneous per op."""
        return [usage.latency_s / usage.calls] * usage.calls

    def _usage(self, op: plan_ir.Operator, n_calls: int,
               values: Sequence[Any]) -> Usage:
        ins_tok = cost_mod.text_tokens(op.instruction)
        val_tok = sum(cost_mod.text_tokens(v) for v in values)
        tok_in = n_calls * ins_tok + val_tok
        tok_out = n_calls * cost_mod.OUT_TOKENS[op.kind]
        per_call_out = tok_out / max(1, n_calls)
        return Usage(calls=n_calls, tok_in=tok_in, tok_out=tok_out,
                     usd=self.tier.usd(tok_in, tok_out),
                     latency_s=n_calls * self.tier.latency(per_call_out))


def make_backends(oracle: Oracle,
                  tiers: Optional[Dict[str, cost_mod.TierSpec]] = None,
                  violation_rate: float = 0.02,
                  diverse_wrong: bool = False,
                  seed: int = 0) -> Dict[str, Backend]:
    """The standard four-tier simulated cascade."""
    tiers = tiers or cost_mod.DEFAULT_TIERS
    return {name: SimulatedBackend(spec, oracle,
                                   violation_rate=violation_rate,
                                   diverse_wrong=diverse_wrong, seed=seed)
            for name, spec in tiers.items()}
