"""Deterministic text embedder for semantic-equality checks.

Plays the role Sentence-BERT plays in the paper (§4.2): map operator outputs
to vectors; two outputs are "semantically equal" when their cosine
similarity clears a threshold. Here the embedder is a character n-gram
feature hasher — deterministic, dependency-free, and order-insensitive
enough that reformatted-but-equal outputs ("250 USD" vs "USD 250.0") land
close while corrupted outputs land far.

The batched cosine(similarity-matrix) compute is the paper-specific hot
spot (every improvement-score evaluation and every judge call runs it over
sample batches); ``repro.kernels.similarity`` provides the Pallas TPU
kernel; this module's ``cosine_matrix`` is the pure-jnp path used on CPU
and as the kernel's oracle.
"""
from __future__ import annotations

import hashlib
import re
from typing import List, Sequence

import numpy as np

DIM = 256
_NGRAMS = (2, 3)


def _normalize_text(x) -> str:
    if isinstance(x, bool):
        return "true" if x else "false"
    if isinstance(x, float) and x == int(x):
        x = int(x)
    s = str(x).lower().strip()
    s = re.sub(r"[^\w\s\.]", " ", s)
    s = re.sub(r"\s+", " ", s)
    return s


def _h(token: str) -> int:
    return int.from_bytes(
        hashlib.blake2b(token.encode(), digest_size=4).digest(), "little")


def embed_one(x, dim: int = DIM) -> np.ndarray:
    """Hash word unigrams + char n-grams into a signed feature vector."""
    s = _normalize_text(x)
    v = np.zeros((dim,), np.float32)
    words = s.split()
    feats: List[str] = ["w:" + w for w in words]
    padded = "^" + s.replace(" ", "_") + "$"
    for n in _NGRAMS:
        feats.extend(padded[i:i + n] for i in range(len(padded) - n + 1))
    for f in feats:
        h = _h(f)
        v[h % dim] += 1.0 if (h >> 31) & 1 else -1.0
    n = np.linalg.norm(v)
    return v / n if n > 0 else v


def embed(xs: Sequence, dim: int = DIM) -> np.ndarray:
    return np.stack([embed_one(x, dim) for x in xs]) if len(xs) else \
        np.zeros((0, dim), np.float32)


def cosine_matrix(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Rows are already L2-normalized -> plain GEMM."""
    return a @ b.T


def pairwise_similarity(xs: Sequence, ys: Sequence) -> np.ndarray:
    """cos(x_i, y_i) for aligned pairs (the improvement-score compare)."""
    if len(xs) != len(ys):
        raise ValueError("pairwise_similarity needs aligned sequences")
    if not len(xs):
        return np.zeros((0,), np.float32)
    a, b = embed(xs), embed(ys)
    return np.sum(a * b, axis=1)


SEM_EQ_THRESHOLD = 0.80


def semantic_equal(x, y, threshold: float = SEM_EQ_THRESHOLD) -> bool:
    """Single-pair semantic equality (binary outputs compare directly)."""
    if isinstance(x, bool) or isinstance(y, bool):
        return bool(x) == bool(y)
    if isinstance(x, (int, float)) and isinstance(y, (int, float)):
        scale = max(abs(float(x)), abs(float(y)), 1e-9)
        return abs(float(x) - float(y)) / scale < 0.02
    if x is None or y is None:
        return x is y
    return float(np.dot(embed_one(x), embed_one(y))) >= threshold


def semantic_equal_batch(xs: Sequence, ys: Sequence,
                         threshold: float = SEM_EQ_THRESHOLD,
                         use_kernel: bool = True) -> np.ndarray:
    """Vectorized aligned-pair equality. Dispatches the cosine compute to
    the Pallas kernel when available (ops handles CPU interpret fallback)."""
    if len(xs) != len(ys):
        raise ValueError("aligned sequences required")
    if not len(xs):
        return np.zeros((0,), bool)
    fast = [i for i in range(len(xs))
            if isinstance(xs[i], (bool, int, float))
            or isinstance(ys[i], (bool, int, float))
            or xs[i] is None or ys[i] is None]
    out = np.zeros((len(xs),), bool)
    text_idx = [i for i in range(len(xs)) if i not in set(fast)]
    for i in fast:
        out[i] = semantic_equal(xs[i], ys[i], threshold)
    if text_idx:
        a = embed([xs[i] for i in text_idx])
        b = embed([ys[i] for i in text_idx])
        if use_kernel:
            try:
                from repro.kernels import ops as kops
                sims = np.asarray(kops.rowwise_cosine(a, b))
            except Exception:
                sims = np.sum(a * b, axis=1)
        else:
            sims = np.sum(a * b, axis=1)
        for j, i in enumerate(text_idx):
            out[i] = sims[j] >= threshold
    return out
