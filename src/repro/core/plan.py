"""Logical-plan IR (paper §2.2).

A user query compiles to *data lineage* — a DAG over semantic operators. The
paper's queries (App. F) are operator chains; the IR keeps them as an ordered
tuple with the DAG recovered from column def/use edges, which is what the
transformation rules need for legality checks (an operator may move past
another iff it does not consume its output and no reduce barrier intervenes).

Operators carry:
  kind           map | filter | reduce | rank
  instruction    the natural-language predicate / transformation
  input_column   column(s) read
  output_column  column written (map / rank), None for filter, result for reduce
  udf            python source of a compiled non-LLM implementation
                 (set by the non-LLM-replacement rule); None = LLM-executed
  selectivity    cost-model estimate of |out| / |in|
  fused_from     how many original operators were merged into this one
  tier           physical plan: backend model tier name (None = unassigned)
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional, Tuple

MAP, FILTER, REDUCE, RANK = "map", "filter", "reduce", "rank"
KINDS = (MAP, FILTER, REDUCE, RANK)

# paper defaults: filter 0.5, reduce 0 (many-to-one), map/rank 1
DEFAULT_SELECTIVITY = {MAP: 1.0, FILTER: 0.5, REDUCE: 0.0, RANK: 1.0}


@dataclasses.dataclass(frozen=True)
class Operator:
    kind: str
    instruction: str
    input_column: str
    output_column: Optional[str] = None
    udf: Optional[str] = None
    selectivity: Optional[float] = None
    fused_from: int = 1
    tier: Optional[str] = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown operator kind {self.kind!r}")
        if self.kind == MAP and self.output_column is None:
            raise ValueError("map requires output_column")
        if self.selectivity is None:
            sel = DEFAULT_SELECTIVITY[self.kind]
            if self.kind == FILTER and self.fused_from > 1:
                # paper §3.1: merged filter selectivity 0.5 -> 0.25 -> 1/6 ...
                sel = sel / self.fused_from
            object.__setattr__(self, "selectivity", sel)

    # ------------------------------------------------------------------
    @property
    def is_llm(self) -> bool:
        return self.udf is None

    @property
    def reads(self) -> Tuple[str, ...]:
        return tuple(c.strip() for c in self.input_column.split(","))

    @property
    def writes(self) -> Tuple[str, ...]:
        return (self.output_column,) if self.output_column else ()

    def with_(self, **kw) -> "Operator":
        return dataclasses.replace(self, **kw)

    def describe(self) -> str:
        exec_ = f"udf:{self.udf}" if self.udf else (self.tier or "llm")
        out = f" -> {self.output_column}" if self.output_column else ""
        return (f"{self.kind}[{self.input_column}{out}] "
                f"\"{self.instruction}\" ({exec_})")


@dataclasses.dataclass(frozen=True)
class LogicalPlan:
    ops: Tuple[Operator, ...]
    source: str = ""          # dataset / table name

    def __post_init__(self):
        object.__setattr__(self, "ops", tuple(self.ops))

    # ------------------------------------------------------------------
    # DAG structure
    # ------------------------------------------------------------------
    def depends_on(self, i: int, j: int) -> bool:
        """True if op i (later) consumes a column written by op j (earlier),
        or j is a reduce (a pipeline barrier: it collapses the table)."""
        if j >= i:
            return False
        oj, oi = self.ops[j], self.ops[i]
        if oj.kind == REDUCE:
            return True
        return any(w in oi.reads for w in oj.writes)

    def movable_before(self, i: int) -> int:
        """Earliest position op i can legally move to (paper's pushdown
        legality: 'does not rely on results of preceding operators')."""
        pos = i
        for j in range(i - 1, -1, -1):
            if self.depends_on(i, j):
                break
            pos = j
        return pos

    def validate(self) -> None:
        """Check def-before-use for every non-source column."""
        defined = set()
        for k, op in enumerate(self.ops):
            for w in op.writes:
                defined.add(w)
        # source columns are those read but never written before their read
        seen = set()
        for op in self.ops:
            for r in op.reads:
                if r in defined and r not in seen:
                    # must have been written already
                    raise ValueError(
                        f"plan reads {r} before it is produced: {self}")
            seen.update(op.writes)

    # ------------------------------------------------------------------
    # Rewrite helpers (used by transformation rules)
    # ------------------------------------------------------------------
    def replace_op(self, i: int, op: Operator) -> "LogicalPlan":
        ops = list(self.ops)
        ops[i] = op
        return dataclasses.replace(self, ops=tuple(ops))

    def move_op(self, i: int, to: int) -> "LogicalPlan":
        ops = list(self.ops)
        op = ops.pop(i)
        ops.insert(to, op)
        return dataclasses.replace(self, ops=tuple(ops))

    def fuse_ops(self, i: int, j: int, fused: Operator) -> "LogicalPlan":
        assert i < j
        ops = list(self.ops)
        ops[i] = fused
        ops.pop(j)
        return dataclasses.replace(self, ops=tuple(ops))

    def with_tiers(self, tiers) -> "LogicalPlan":
        """Assign a physical plan: tiers is a list (len == n LLM ops consumed
        in order) or a dict {op_index: tier}."""
        ops = list(self.ops)
        if isinstance(tiers, dict):
            for idx, t in tiers.items():
                ops[idx] = ops[idx].with_(tier=t)
        else:
            it = iter(tiers)
            for k, op in enumerate(ops):
                if op.is_llm:
                    ops[k] = op.with_(tier=next(it))
        return dataclasses.replace(self, ops=tuple(ops))

    # ------------------------------------------------------------------
    @property
    def n_llm_ops(self) -> int:
        return sum(1 for o in self.ops if o.is_llm)

    def signature(self) -> tuple:
        """Hashable identity used to dedupe candidate plans in the search."""
        return tuple((o.kind, o.instruction, o.input_column, o.output_column,
                      o.udf, o.fused_from) for o in self.ops)

    def describe(self) -> str:
        return "\n".join(f"  {k}: {op.describe()}"
                         for k, op in enumerate(self.ops))

    # ------------------------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "source": self.source,
            "ops": [dataclasses.asdict(o) for o in self.ops],
        }, indent=1)

    @staticmethod
    def from_json(text: str) -> "LogicalPlan":
        d = json.loads(text)
        return LogicalPlan(tuple(Operator(**o) for o in d["ops"]),
                           d.get("source", ""))
