"""Plan rewriters — the `m` in Algorithm 1's ``rewrite(p_i, m)``.

Three interchangeable rewriters:

* :class:`LLMSimRewriter` — models the paper's cloud-LLM rewriter: picks a
  random applicable (rule, site) candidate (LLM nondeterminism), emits a
  semantically-wrong rewrite at ``error_rate`` (hallucination; the judge's
  job is to catch these), and bills each rewrite as one LLM call whose
  prompt is the rules text + plan JSON (Tables 6/8 overhead accounting).
* :class:`GreedyRuleRewriter` — deterministic: applies the candidate with
  the largest estimated cost gain. Used by the "2-step" baseline (Table 8)
  and as the teacher when generating local-rewriter training data (§3.3).
* :class:`LocalModelRewriter` — the paper's §3.3 local rewrite model: a
  JAX-trained policy scores candidate rewrites and picks one; falls back to
  uniform when unsure. Training lives in ``examples/train_rewriter.py``;
  at inference the call is billed at local-serving latency (no network),
  which is the point of §3.3.
"""
from __future__ import annotations

import dataclasses
import json
import random
from typing import Callable, Optional, Sequence, Tuple

from repro.core import backends as bk
from repro.core import cost as cost_mod
from repro.core import cost_model as cm
from repro.core import plan as plan_ir
from repro.core import rules as rules_mod


@dataclasses.dataclass
class RewriteOutcome:
    rewrite: Optional[rules_mod.Rewrite]   # None = no applicable rule
    plan: Optional[plan_ir.LogicalPlan]
    usage: bk.Usage


def _rewrite_call_usage(plan: plan_ir.LogicalPlan, tier: cost_mod.TierSpec,
                        rule_names: Sequence[str]) -> bk.Usage:
    rules_text = " ".join(rules_mod.RULES[r][0] for r in rule_names)
    tok_in = cost_mod.text_tokens(rules_text) + cost_mod.text_tokens(
        plan.to_json())
    # the rewriter emits only the rewritten operator(s) — a diff, not the
    # whole plan (keeps per-rewrite latency in the paper's 1-3 s band)
    tok_out = 120.0
    return bk.Usage(calls=1, tok_in=tok_in, tok_out=tok_out,
                    usd=tier.usd(tok_in, tok_out),
                    latency_s=tier.latency(tok_out))


@dataclasses.dataclass
class LLMSimRewriter:
    rule_names: Tuple[str, ...] = tuple(rules_mod.RULES)
    error_rate: float = 0.12      # hallucinated (wrong) rewrites
    tier: cost_mod.TierSpec = dataclasses.field(
        default_factory=lambda: cost_mod.DEFAULT_TIERS["m*"])

    def rewrite(self, plan: plan_ir.LogicalPlan,
                rng: random.Random) -> RewriteOutcome:
        usage = _rewrite_call_usage(plan, self.tier, self.rule_names)
        cands = rules_mod.all_candidates(plan, self.rule_names)
        if not cands:
            return RewriteOutcome(None, None, usage)
        choice = rng.choice(cands)
        if rng.random() < self.error_rate:
            choice = rules_mod.corrupt(choice, plan, rng)
        return RewriteOutcome(choice, choice.apply(), usage)


@dataclasses.dataclass
class GreedyRuleRewriter:
    rule_names: Tuple[str, ...] = tuple(rules_mod.RULES)
    n_rows: int = 1000            # cost-model table size for gain estimates
    tier: cost_mod.TierSpec = dataclasses.field(
        default_factory=lambda: cost_mod.DEFAULT_TIERS["m*"])
    # gain estimates price through this model when set (e.g. a serve
    # loop's calibrated instance); None = the uncalibrated default
    cost_model: Optional[cm.CostModel] = None

    def rewrite(self, plan: plan_ir.LogicalPlan,
                rng: random.Random) -> RewriteOutcome:
        usage = _rewrite_call_usage(plan, self.tier, self.rule_names)
        cands = rules_mod.all_candidates(plan, self.rule_names)
        if not cands:
            return RewriteOutcome(None, None, usage)
        model = self.cost_model or cm.DEFAULT_MODEL
        base = model.objective(model.plan_cost(plan, self.n_rows))
        best, best_gain = None, -1e30
        for c in cands:
            try:
                gain = base - model.objective(
                    model.plan_cost(c.apply(), self.n_rows))
            except Exception:
                continue
            if gain > best_gain:
                best, best_gain = c, gain
        if best is None:
            return RewriteOutcome(None, None, usage)
        return RewriteOutcome(best, best.apply(), usage)


@dataclasses.dataclass
class LocalModelRewriter:
    """§3.3: replace the cloud rewriter with a locally-served model.

    ``policy(plan_json, candidate_descriptions) -> index`` is the trained
    scorer (see examples/train_rewriter.py, which distills the greedy rule
    teacher into a small JAX transformer). Local inference is billed at
    local latency — no network round trip, no per-token API price.
    """
    policy: Callable[[str, Sequence[str]], int]
    rule_names: Tuple[str, ...] = tuple(rules_mod.RULES)
    latency_s: float = 0.08      # local serving latency per rewrite

    def rewrite(self, plan: plan_ir.LogicalPlan,
                rng: random.Random) -> RewriteOutcome:
        usage = bk.Usage(calls=1, tok_in=0.0, tok_out=0.0, usd=0.0,
                         latency_s=self.latency_s)
        cands = rules_mod.all_candidates(plan, self.rule_names)
        if not cands:
            return RewriteOutcome(None, None, usage)
        try:
            idx = int(self.policy(plan.to_json(),
                                  [c.description for c in cands]))
            idx = max(0, min(idx, len(cands) - 1))
        except Exception:
            idx = rng.randrange(len(cands))
        choice = cands[idx]
        return RewriteOutcome(choice, choice.apply(), usage)


def training_pairs(plans: Sequence[plan_ir.LogicalPlan], n_rows: int = 1000,
                   rule_names: Tuple[str, ...] = tuple(rules_mod.RULES)):
    """§3.3 data collection: (un-optimized plan, teacher-chosen rewrite)
    pairs for fine-tuning the local rewriter."""
    teacher = GreedyRuleRewriter(rule_names=rule_names, n_rows=n_rows)
    rng = random.Random(0)
    out = []
    for p in plans:
        oc = teacher.rewrite(p, rng)
        if oc.rewrite is not None:
            cands = rules_mod.all_candidates(p, rule_names)
            label = [c.description for c in cands].index(
                oc.rewrite.description)
            out.append({"plan_json": p.to_json(),
                        "candidates": [c.description for c in cands],
                        "label": label})
    return out
