"""Deprecated shim over :mod:`repro.core.cost_model`.

All cost estimation lives on :class:`repro.core.cost_model.CostModel`
now — tier specs, token priors, ``op_cost``/``plan_cost``, plus the
online calibration and makespan estimation the free functions never had.
This module keeps the seed-era surface importable: the data structures
are re-exported and the free functions delegate to
:data:`cost_model.DEFAULT_MODEL` (which is never calibrated, so these
stay byte-stable). New code should take an explicit ``CostModel``
(usually ``ExecutionContext.cost_model``) instead of importing from here.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.core import plan as plan_ir
from repro.core.cost_model import (   # noqa: F401  (re-exported surface)
    DEFAULT_MODEL,
    DEFAULT_TIERS,
    EMBED_ROW_S,
    EMBED_TIER,
    EMBED_TIER_NAME,
    OUT_TOKENS,
    OpCost,
    PlanCost,
    TIER_ORDER,
    TOKENS_PER_CHAR,
    TierSpec,
    chip_seconds,
)


def text_tokens(text) -> float:
    return DEFAULT_MODEL.text_tokens(text)


def tier_list(tiers: Optional[Dict[str, TierSpec]] = None):
    return DEFAULT_MODEL.tier_list(tiers)


def op_cost(op: plan_ir.Operator, rows_in: float, tier: TierSpec,
            avg_value_tokens: float = 60.0,
            concurrency: int = 1, batch_size: int = 1,
            cascade_escalate: Optional[float] = None) -> OpCost:
    return DEFAULT_MODEL.op_cost(
        op, rows_in, tier, avg_value_tokens, concurrency=concurrency,
        batch_size=batch_size, cascade_escalate=cascade_escalate)


def plan_cost(plan: plan_ir.LogicalPlan, n_rows: int,
              tiers: Optional[Dict[str, TierSpec]] = None,
              default_tier: str = "m*",
              avg_value_tokens: float = 60.0,
              concurrency: int = 16, batch_size: int = 1,
              shards: int = 1,
              cascade: Optional[Dict[int, float]] = None) -> PlanCost:
    return DEFAULT_MODEL.plan_cost(
        plan, n_rows, tiers=tiers, default_tier=default_tier,
        avg_value_tokens=avg_value_tokens, concurrency=concurrency,
        batch_size=batch_size, shards=shards, cascade=cascade)
