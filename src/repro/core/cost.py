"""Selectivity-based plan cost model + backend tier table (paper §3.1, §4).

The paper's estimator "tracks the number of processed data items and prompt
lengths per operator"; total plan cost is the sum of operator costs, with
record counts flowing through per-operator selectivities (filter 0.5,
reduce 0, others 1; fused filters 0.5/k).

Two cost axes are reported everywhere:
  usd        monetary cost from per-tier token prices (mirrors the GPT-4.1
             family price card so Table-4-shaped numbers are reproducible)
  latency_s  simulated wall-clock: per-call overhead + per-token decode time,
             scheduled over `concurrency` parallel workers (the paper uses
             16 coroutines)

plus the hardware-grounded axis the paper cannot see:
  chip_s     FLOPs / (MFU * peak) for tiers backed by a JAX-served arch.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, Optional, Sequence

from repro.core import plan as plan_ir

TOKENS_PER_CHAR = 0.25   # ~4 chars/token


def text_tokens(text) -> float:
    return max(1.0, len(str(text)) * TOKENS_PER_CHAR)


# ---------------------------------------------------------------------------
# Backend tiers (m1 < m2 < m3 < m*) — §4's four-model setting
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TierSpec:
    name: str
    capability: float            # P(correct) scale for the simulator
    usd_per_m_in: float
    usd_per_m_out: float
    latency_call_s: float        # per-call overhead (network + queue)
    latency_tok_s: float         # per output token
    arch: Optional[str] = None   # JAX model zoo id backing this tier

    def usd(self, tok_in: float, tok_out: float) -> float:
        return (tok_in * self.usd_per_m_in
                + tok_out * self.usd_per_m_out) / 1e6

    def latency(self, tok_out: float) -> float:
        return self.latency_call_s + tok_out * self.latency_tok_s


# price card mirrors OpenAI's GPT-4.1 family (paper §5.1.4); capabilities are
# the simulator's knobs calibrated so Table-2-style alignment stats reproduce
# (misaligned fraction ~0.15 on a hard map; see benchmarks/table2).
DEFAULT_TIERS: Dict[str, TierSpec] = {
    "m1": TierSpec("m1", 0.88, 0.10, 0.40, 0.35, 0.004, arch="qwen2-0.5b"),
    "m2": TierSpec("m2", 0.92, 0.15, 0.60, 0.45, 0.006,
                   arch="granite-moe-1b-a400m"),
    "m3": TierSpec("m3", 0.96, 0.40, 1.60, 0.60, 0.010, arch="minicpm3-4b"),
    "m*": TierSpec("m*", 0.99, 2.00, 8.00, 0.90, 0.022,
                   arch="codeqwen1.5-7b"),
}
TIER_ORDER = ("m1", "m2", "m3", "m*")

# tier-0 embedding pass (core.cascade): one batched Pallas kernel launch
# scores a whole morsel, so the per-row price is ~1000x below m1's and the
# "per-call" latency is a kernel launch, not a network round trip. Not part
# of TIER_ORDER — it cannot answer an operator alone; it only *routes*
# (cascade bands decide pass/drop, the uncertain band escalates to an LLM
# tier), so improvement-score tier selection never assigns it directly.
EMBED_TIER_NAME = "tier0-embed"
EMBED_ROW_S = 2e-6              # modeled per-row device time
EMBED_TIER = TierSpec(EMBED_TIER_NAME, 0.0, 0.0001, 0.0, 0.002, 0.0)


def tier_list(tiers: Optional[Dict[str, TierSpec]] = None):
    t = tiers or DEFAULT_TIERS
    return [t[k] for k in TIER_ORDER if k in t]


# output length model per operator kind (tokens per record)
OUT_TOKENS = {plan_ir.FILTER: 2.0, plan_ir.MAP: 24.0, plan_ir.REDUCE: 16.0,
              plan_ir.RANK: 6.0}


# ---------------------------------------------------------------------------
# Cost records
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class OpCost:
    llm_calls: float = 0.0
    tok_in: float = 0.0
    tok_out: float = 0.0
    usd: float = 0.0
    latency_s: float = 0.0       # sequential latency of this op's calls
    rows_in: float = 0.0
    rows_out: float = 0.0


@dataclasses.dataclass
class PlanCost:
    per_op: list
    llm_calls: float = 0.0
    tok_in: float = 0.0
    tok_out: float = 0.0
    usd: float = 0.0
    latency_s: float = 0.0       # wall-clock under `concurrency`
    rows_processed: float = 0.0  # paper Fig. 10/13 metric

    @property
    def cost(self) -> float:
        """The scalar the logical optimizer minimizes (Alg. 1 line 9)."""
        return self.usd

    def describe(self) -> str:
        return (f"calls={self.llm_calls:.0f} tok_in={self.tok_in:.0f} "
                f"usd={self.usd:.4f} latency={self.latency_s:.1f}s "
                f"rows={self.rows_processed:.0f}")


def op_cost(op: plan_ir.Operator, rows_in: float, tier: TierSpec,
            avg_value_tokens: float = 60.0,
            concurrency: int = 1, batch_size: int = 1,
            cascade_escalate: Optional[float] = None) -> OpCost:
    """Cost of one operator over `rows_in` records.

    LLM ops: ``ceil(rows / batch_size)`` calls — the executor's batch
    coalescer packs surviving rows across morsel boundaries, so the model
    prices whole-table batching, not per-morsel ragged ceilings. Batched
    records share the instruction prompt and the call's output budget.
    (Reduce: hierarchical tree over batches of ~32 values per call.)
    UDF ops: zero LLM cost, negligible latency.

    ``cascade_escalate`` prices a tier-0 embedding cascade on this
    operator (``core.cascade``): one batched kernel pass scores every row
    (EMBED_TIER prices + a launch latency), and only the escalated
    fraction reaches the LLM tier — ``ceil(rows * frac / batch)`` calls
    instead of ``ceil(rows / batch)``.
    """
    rows_out = rows_in * op.selectivity if op.kind == plan_ir.FILTER \
        else (1.0 if op.kind == plan_ir.REDUCE else rows_in)
    c = OpCost(rows_in=rows_in, rows_out=rows_out)
    if not op.is_llm:
        c.latency_s = rows_in * 2e-6
        return c
    ins_tok = text_tokens(op.instruction)
    if op.kind == plan_ir.REDUCE:
        batch = 32.0
        calls = 0.0
        level = rows_in
        while level > 1.0:
            level = math.ceil(level / batch)
            calls += level
        calls = max(calls, 1.0)
        c.llm_calls = calls
        c.tok_in = calls * (ins_tok + batch * avg_value_tokens * 0.5)
        c.tok_out = calls * OUT_TOKENS[op.kind]
    else:
        b = max(1, int(batch_size))
        llm_rows = rows_in
        if cascade_escalate is not None:
            llm_rows = rows_in * min(max(cascade_escalate, 0.0), 1.0)
        calls = math.ceil(llm_rows / b) if llm_rows > 0 else 0.0
        c.llm_calls = float(calls)
        c.tok_in = calls * ins_tok + llm_rows * avg_value_tokens
        c.tok_out = calls * OUT_TOKENS[op.kind]
    c.usd = tier.usd(c.tok_in, c.tok_out)
    per_call_out = c.tok_out / max(c.llm_calls, 1.0)
    c.latency_s = c.llm_calls * tier.latency(per_call_out)
    if cascade_escalate is not None and op.kind != plan_ir.REDUCE:
        # the device pass itself: every row is embedded and scored in one
        # batched kernel launch, billed under the tier-0 price card
        c.usd += EMBED_TIER.usd(rows_in * avg_value_tokens, 0.0)
        c.latency_s += EMBED_TIER.latency_call_s + rows_in * EMBED_ROW_S
    return c


def plan_cost(plan: plan_ir.LogicalPlan, n_rows: int,
              tiers: Optional[Dict[str, TierSpec]] = None,
              default_tier: str = "m*",
              avg_value_tokens: float = 60.0,
              concurrency: int = 16, batch_size: int = 1,
              shards: int = 1,
              cascade: Optional[Dict[int, float]] = None) -> PlanCost:
    """Estimate a full plan: record counts flow through selectivities.

    ``concurrency`` is one shard worker's replica width; ``shards``
    multiplies it (morsel-parallel sharded execution runs a
    pool-per-(shard, tier), so un-quota'd effective width is
    ``concurrency * shards`` — matching ``ShardedDispatcher``).

    ``cascade`` maps op index -> expected escalation fraction for
    operators running behind a tier-0 embedding cascade (see ``op_cost``);
    ``rows_processed`` then counts only the escalated (LLM-seen) rows —
    the Fig. 13 metric the cascade is built to shrink."""
    tiers = tiers or DEFAULT_TIERS
    rows = float(n_rows)
    total = PlanCost(per_op=[])
    width = max(1, int(concurrency)) * max(1, int(shards))
    for k, op in enumerate(plan.ops):
        tier = tiers[op.tier or default_tier]
        esc = None if cascade is None else cascade.get(k)
        c = op_cost(op, rows, tier, avg_value_tokens,
                    batch_size=batch_size, cascade_escalate=esc)
        total.per_op.append(c)
        total.llm_calls += c.llm_calls
        total.tok_in += c.tok_in
        total.tok_out += c.tok_out
        total.usd += c.usd
        # ops execute in sequence; each op's calls run `width`-wide
        total.latency_s += c.latency_s / width
        if op.is_llm:
            total.rows_processed += c.rows_in if esc is None \
                else c.rows_in * min(max(esc, 0.0), 1.0)
        rows = c.rows_out
    return total


# ---------------------------------------------------------------------------
# Hardware-grounded cost (beyond-paper axis)
# ---------------------------------------------------------------------------

def chip_seconds(tok_in: float, tok_out: float, active_params: float,
                 mfu: float = 0.4, peak_flops: float = 197e12) -> float:
    """Approximate chip-seconds to serve the tokens on a TPU v5e chip:
    prefill 2*N*T_in + decode 2*N*T_out FLOPs at `mfu` utilization."""
    flops = 2.0 * active_params * (tok_in + tok_out)
    return flops / (mfu * peak_flops)
