"""Improvement-score computation (paper §4.2–4.3, Eqs. 2–8).

The improvement score I_{m1->m} = E[m(x) = m*(x), m1(x) != m(x)] measures the
quality gained by upgrading an operator's backend from the baseline m1 to a
stronger model m, with the strongest tier m* as ground-truth proxy.

Four estimators, from most to least expensive, each tracking *exactly* which
model invocations it performs (a UsageMeter per estimator is the data behind
the paper's "4x lower optimization overhead than Smart" claim):

  exact       Eq. 2 verbatim: every tier runs on every sample record.
  pushdown    Eq. 3: factor Pr(m=m*, m1!=m) = Pr(m=m*|m1!=m)Pr(m1!=m) and run
              m* only on records where m1 != m ("evaluation pushdown").
  reuse       Eq. 4: total-probability expansion of I13 reuses I12 and its
              cached comparisons; m* runs only where (m1=m2, m2!=m3) for the
              new term. NOTE: the paper derives Eq. 4 as a pure law-of-
              total-probability identity, but the substitution of its first
              term with I12 additionally requires nested correctness
              (Hypothesis 2) — property-tested in tests/test_improvement.py
              (see the hypothesis-found counterexample there).
  approx      Eqs. 6-8 under the model-capability hypothesis: m*-evaluations
              for I12/I13 are eliminated entirely; I1* needs m* only on the
              (m1=m2=m3) subset.

All estimators share one lazily-memoized output store, so "computation
reuse" is structural: a record evaluated once by a tier is never re-run.
Output equality is semantic equality (binary outputs compare directly;
free-text via the hashing embedder — paper's Sentence-BERT role).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.core import backends as bk
from repro.core import plan as plan_ir
from repro.core import runtime as rt
from repro.core import semhash

TIERS4 = ("m1", "m2", "m3", "m*")


class OutputStore:
    """Lazy, memoized per-(tier, record) model outputs + equality cache.

    With a ``dispatcher`` (``runtime.Dispatcher``), each tier sweep's
    per-record calls fan out over the tier's worker pool — under the
    threaded driver the scoring calls of one ``ensure`` genuinely overlap
    (the simulated dispatcher's fanout is None, i.e. inline)."""

    def __init__(self, backends: Dict[str, bk.Backend],
                 op: plan_ir.Operator, values: Sequence,
                 meter: Optional[bk.UsageMeter] = None,
                 dispatcher: Optional["rt.Dispatcher"] = None,
                 batch_size: int = 1):
        self.backends = backends
        self.op = op
        self.values = list(values)
        self.meter = meter if meter is not None else bk.UsageMeter()
        self.dispatcher = dispatcher
        # batch prompting for the scoring sweeps: an operator's evaluation
        # on k records is priced at ceil(k/batch) calls — the same batch
        # size the executor will run at, so scores *and* overhead are
        # measured under execution conditions (batch accuracy penalty
        # included), making tier choice batch-aware
        self.batch_size = max(1, int(batch_size))
        self._out: Dict[str, Dict[int, object]] = {t: {} for t in backends}
        self._eq: Dict[tuple, bool] = {}

    @property
    def n(self) -> int:
        return len(self.values)

    def ensure(self, tier: str, idxs: Sequence[int]) -> None:
        missing = [i for i in idxs if i not in self._out[tier]]
        if not missing:
            return
        backend = self.backends[tier]
        fan = self.dispatcher.fanout(backend.tier.name) \
            if self.dispatcher is not None else None
        outs = rt.run_backend_calls(
            self.op, [self.values[i] for i in missing], backend,
            self.meter, batch_size=self.batch_size, fanout=fan)
        for i, o in zip(missing, outs):
            self._out[tier][i] = o

    def out(self, tier: str, i: int):
        self.ensure(tier, [i])
        return self._out[tier][i]

    def eq(self, a: str, b: str, i: int) -> bool:
        key = (a, b, i) if a <= b else (b, a, i)
        if key not in self._eq:
            va, vb = self.out(a, i), self.out(b, i)
            self._eq[key] = bool(semhash.semantic_equal(va, vb))
        return self._eq[key]

    def eq_frac(self, a: str, b: str, idxs: Sequence[int]) -> float:
        if not idxs:
            return 0.0
        self.ensure(a, idxs)
        self.ensure(b, idxs)
        return sum(self.eq(a, b, i) for i in idxs) / len(idxs)

    def calls(self, tier: str) -> int:
        return self.meter.calls(tier)


@dataclasses.dataclass
class ImprovementResult:
    scores: Dict[str, float]          # tier -> I_{m1->tier}
    meter: bk.UsageMeter              # invocation accounting
    method: str

    def score(self, tier: str) -> float:
        return self.scores[tier]


def _idx(store: OutputStore) -> List[int]:
    return list(range(store.n))


# ---------------------------------------------------------------------------
# Eq. 2 — exact
# ---------------------------------------------------------------------------

def improvement_exact(store: OutputStore) -> ImprovementResult:
    n = store.n
    all_i = _idx(store)
    for t in TIERS4:
        store.ensure(t, all_i)
    i12 = sum(store.eq("m2", "m*", i) and not store.eq("m1", "m2", i)
              for i in all_i) / n
    i13 = sum(store.eq("m3", "m*", i) and not store.eq("m1", "m3", i)
              for i in all_i) / n
    i1s = sum(not store.eq("m1", "m*", i) for i in all_i) / n
    return ImprovementResult({"m2": i12, "m3": i13, "m*": i1s}, store.meter,
                             "exact")


# ---------------------------------------------------------------------------
# Eq. 3 — evaluation pushdown
# ---------------------------------------------------------------------------

def improvement_pushdown(store: OutputStore) -> ImprovementResult:
    n = store.n
    all_i = _idx(store)
    store.ensure("m1", all_i)
    store.ensure("m2", all_i)
    d12 = [i for i in all_i if not store.eq("m1", "m2", i)]
    # m* runs only on the m1 != m2 subset
    i12 = sum(store.eq("m2", "m*", i) for i in d12) / n

    store.ensure("m3", all_i)
    d13 = [i for i in all_i if not store.eq("m1", "m3", i)]
    i13 = sum(store.eq("m3", "m*", i) for i in d13) / n

    # I_{m1->m*} = Pr(m1 != m*) has no pushdown form — full m* sweep
    i1s = sum(not store.eq("m1", "m*", i) for i in all_i) / n
    return ImprovementResult({"m2": i12, "m3": i13, "m*": i1s}, store.meter,
                             "pushdown")


# ---------------------------------------------------------------------------
# Eqs. 4-5 — computation reuse (exact under the binary response model)
# ---------------------------------------------------------------------------

def improvement_reuse(store: OutputStore) -> ImprovementResult:
    n = store.n
    all_i = _idx(store)
    store.ensure("m1", all_i)
    store.ensure("m2", all_i)
    d12 = [i for i in all_i if not store.eq("m1", "m2", i)]
    i12 = sum(store.eq("m2", "m*", i) for i in d12) / n

    # Eq. 4: I13 = I12 + Pr(m3=m*, m2!=m3, m1=m2); the new m* evaluations
    # are confined to records with (m1 = m2) & (m2 != m3); m1=m2 comparisons
    # are reused from the I12 pass.
    store.ensure("m3", all_i)
    t2 = [i for i in all_i
          if store.eq("m1", "m2", i) and not store.eq("m2", "m3", i)]
    i13 = i12 + sum(store.eq("m3", "m*", i) for i in t2) / n

    # Eq. 5: expand Pr(m1 != m*) over the (m1?m2, m2?m3) cells, reusing all
    # cached comparisons. m* evaluation is still needed per cell — the
    # savings relative to `pushdown` come from I13; eliminating the m* sweep
    # entirely requires the capability hypothesis (`approx`).
    i1s = sum(not store.eq("m1", "m*", i) for i in all_i) / n
    return ImprovementResult({"m2": i12, "m3": i13, "m*": i1s}, store.meter,
                             "reuse")


# ---------------------------------------------------------------------------
# Eqs. 6-8 — model-capability-hypothesis approximation
# ---------------------------------------------------------------------------

def improvement_approx(store: OutputStore,
                       max_cond_eval: Optional[int] = None
                       ) -> ImprovementResult:
    """Eqs. 6-8. Conditional terms (Pr(x|y)) are probability *estimates*;
    when ``max_cond_eval`` is set they are computed on a bounded prefix of
    the conditioning subset and multiplied by the exactly-counted base rate
    — this is what caps m3/m* invocations per operator independent of the
    sample size (the overhead profile behind Table 9)."""
    n = store.n
    all_i = _idx(store)
    store.ensure("m1", all_i)
    store.ensure("m2", all_i)

    def sub(idxs):
        if max_cond_eval is None or len(idxs) <= max_cond_eval:
            return idxs
        return idxs[:max_cond_eval]

    # Eq. 6: I12 ~= Pr(m1 != m2)           (observation 1: m1!=m2 => m2=m*)
    p_neq12 = sum(not store.eq("m1", "m2", i) for i in all_i) / n
    i12 = p_neq12

    # Eq. 7: I13 ~= I12 + Pr(m2 != m3 | m1 = m2) Pr(m1 = m2); m3 evaluated
    # only on (a bounded slice of) the m1 = m2 subset.
    a12 = [i for i in all_i if store.eq("m1", "m2", i)]
    a12_s = sub(a12)
    store.ensure("m3", a12_s)
    p_23neq_g_12eq = (sum(not store.eq("m2", "m3", i) for i in a12_s)
                      / len(a12_s)) if a12_s else 0.0
    i13 = i12 + p_23neq_g_12eq * (len(a12) / n)

    # Eq. 8: m* evaluated ONLY on records where m1 = m2 and m2 = m3.
    agree = [i for i in a12_s if store.eq("m2", "m3", i)]
    agree_s = sub(agree)
    if agree_s:
        p_cond = sum(not store.eq("m1", "m*", i)
                     for i in agree_s) / len(agree_s)
    else:
        p_cond = 0.0
    # last term: Pr(m2 = m3 | m1 != m2) Pr(m1 != m2); m3 on the m1!=m2 subset
    d12 = [i for i in all_i if not store.eq("m1", "m2", i)]
    d12_s = sub(d12)
    store.ensure("m3", d12_s)
    p_23eq_g_12neq = (sum(store.eq("m2", "m3", i) for i in d12_s)
                      / len(d12_s)) if d12_s else 0.0
    i1s = p_cond * (1.0 - i13) + (i13 - i12) + p_23eq_g_12neq * p_neq12
    i1s = min(max(i1s, 0.0), 1.0)
    return ImprovementResult({"m2": i12, "m3": i13, "m*": i1s}, store.meter,
                             "approx")


ESTIMATORS = {
    "exact": improvement_exact,
    "pushdown": improvement_pushdown,
    "reuse": improvement_reuse,
    "approx": improvement_approx,
}


# ---------------------------------------------------------------------------
# Tier-0 cascade as a candidate assignment (core.cascade)
# ---------------------------------------------------------------------------

def improvement_cascade(store: OutputStore, proxy: str,
                        decisions: Dict[int, object]) -> Dict[str, float]:
    """Score a tier-0 embedding cascade with the improvement-score metric.

    ``decisions`` maps sample index -> the cascade's on-device resolution
    (bool for SEM_FILTER pass/drop); indices absent from it escalate, i.e.
    the cascade answers them with the ``proxy`` tier's own output. Returns

      agree        fraction of resolved records whose decision matches the
                   proxy tier (the cascade's escalation target — its output
                   is what an un-cascaded plan would produce)
      resolved     fraction answered on device (1 - escalation rate)
      improvement  I_{m1->cascade(proxy)} under Eq. 2 with the cascade as
                   the candidate model: escalated records contribute
                   exactly the proxy tier's improvement term; resolved
                   records contribute when they match the proxy *and*
                   differ from m1.
    """
    n = store.n
    if n == 0:
        return {"agree": 1.0, "resolved": 0.0, "improvement": 0.0}
    all_i = _idx(store)
    store.ensure("m1", all_i)
    store.ensure(proxy, all_i)

    def same(decision, out) -> bool:
        # filter decisions are bools; model outputs may be "yes"/"true"
        # text — compare through the executor's one shared parser
        if isinstance(decision, bool):
            return decision == rt.bool_mask([out])[0]
        return bool(semhash.semantic_equal(decision, out))

    agree = 0
    gain = 0.0
    for i in all_i:
        if i in decisions:
            d = decisions[i]
            ok = same(d, store.out(proxy, i))
            agree += ok
            if ok and not same(d, store.out("m1", i)):
                gain += 1.0
        elif not store.eq("m1", proxy, i):
            gain += 1.0
    nres = len(decisions)
    return {"agree": (agree / nres) if nres else 1.0,
            "resolved": nres / n,
            "improvement": gain / n}


def improvement_scores(backends: Dict[str, bk.Backend],
                       op: plan_ir.Operator, values: Sequence,
                       method: str = "approx",
                       meter: Optional[bk.UsageMeter] = None,
                       max_cond_eval: Optional[int] = None,
                       dispatcher: Optional["rt.Dispatcher"] = None,
                       batch_size: int = 1) -> ImprovementResult:
    store = OutputStore(backends, op, values, meter=meter,
                        dispatcher=dispatcher, batch_size=batch_size)
    if method == "approx":
        return improvement_approx(store, max_cond_eval=max_cond_eval)
    return ESTIMATORS[method](store)
