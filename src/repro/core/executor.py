"""Physical-plan executor (paper §2.2 "query executor").

Runs a plan over a :class:`Table`: UDF operators execute as native compute;
LLM operators dispatch to the backend tier assigned by the physical plan
(default tier when unassigned — the paper uses the strongest model as the
default backbone). Execution wall-clock is *simulated*: every backend call
reports a latency drawn from its tier's latency model, and the executor
schedules calls over ``concurrency`` workers (paper: 16 coroutines),
reporting the resulting makespan. Monetary cost comes from tier token
prices. Both are accumulated in a UsageMeter so benchmarks can break costs
down per model tier (paper Fig. 10).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Dict, List, Optional

from repro.core import backends as bk
from repro.core import plan as plan_ir
from repro.core import udf as udf_mod
from repro.core.table import Table

ROWID = "_rowid"


def with_rowids(table: Table) -> Table:
    if ROWID in table.columns:
        return table
    t = table.with_column(ROWID, list(range(table.n_rows)), "numeric")
    return t


@dataclasses.dataclass
class ExecutionResult:
    table: Optional[Table]          # surviving rows (None after reduce)
    scalar: Any                     # reduce output (None otherwise)
    meter: bk.UsageMeter
    wall_s: float                   # simulated wall-clock (scheduled)
    cpu_s: float                    # real python time spent
    rows_processed: float = 0.0     # LLM-processed records (Fig. 13 metric)

    def value(self):
        """The query answer: reduce scalar, else the surviving table."""
        return self.scalar if self.scalar is not None else self.table


def _makespan(total_latency_s: float, n_calls: int, concurrency: int,
              per_call_s: Optional[float] = None) -> float:
    """Wall-clock of n homogeneous calls over W workers."""
    if n_calls <= 0:
        return 0.0
    per_call = per_call_s if per_call_s is not None \
        else total_latency_s / n_calls
    waves = math.ceil(n_calls / max(1, concurrency))
    return waves * per_call


def _vkey(v) -> str:
    return v if isinstance(v, str) else repr(v)


class OutputCache:
    """LLM-output memo keyed by (tier, op semantics, value).

    Semantic operators are deterministic per (model, prompt) here, so
    repeated sample executions — the judge runs the original plan once per
    optimizer iteration, rewritten plans share most operators — hit the
    cache instead of re-invoking the backend. This is the executor-level
    analogue of the paper's computation-reuse theme (cf. QuestCache [18]);
    only cache *misses* are billed."""

    def __init__(self):
        self.data: Dict[tuple, Any] = {}
        self.hits = 0
        self.misses = 0

    def key(self, op: plan_ir.Operator, tier: str, batch: int, v) -> tuple:
        return (op.kind, op.instruction, op.input_column, tier, batch,
                _vkey(v))


def run_llm_op(op: plan_ir.Operator, values, backend, tier_name: str,
               meter: bk.UsageMeter, *, batch_size: int = 1,
               cache: Optional[OutputCache] = None):
    """Execute one LLM operator, via the cache when provided. Returns
    (outputs, n_calls_made, latency_of_calls_made)."""
    before_calls = meter.calls(tier_name)
    before_lat = meter.by_tier.get(tier_name, bk.Usage()).latency_s
    if cache is None or op.kind == plan_ir.REDUCE:
        if cache is not None and op.kind == plan_ir.REDUCE:
            rkey = cache.key(op, tier_name, batch_size,
                             "\x1e".join(_vkey(v) for v in values))
            if rkey in cache.data:
                cache.hits += 1
                return [cache.data[rkey]], 0, 0.0
            outs = backend.run_values(op, values, meter=meter,
                                      batch_size=batch_size)
            cache.misses += 1
            cache.data[rkey] = outs[0]
        else:
            outs = backend.run_values(op, values, meter=meter,
                                      batch_size=batch_size)
        n_calls = meter.calls(tier_name) - before_calls
        lat = meter.by_tier[tier_name].latency_s - before_lat
        return outs, n_calls, lat

    keys = [cache.key(op, tier_name, batch_size, v) for v in values]
    missing = [i for i, k in enumerate(keys) if k not in cache.data]
    cache.hits += len(values) - len(missing)
    cache.misses += len(missing)
    if missing:
        outs_new = backend.run_values(op, [values[i] for i in missing],
                                      meter=meter, batch_size=batch_size)
        for i, o in zip(missing, outs_new):
            cache.data[keys[i]] = o
    n_calls = meter.calls(tier_name) - before_calls
    lat = (meter.by_tier[tier_name].latency_s - before_lat) if missing \
        else 0.0
    return [cache.data[k] for k in keys], n_calls, lat


def execute(plan: plan_ir.LogicalPlan, table: Table,
            backends: Dict[str, bk.Backend],
            *, default_tier: str = "m*", concurrency: int = 16,
            batch_size: int = 1, cache: Optional[OutputCache] = None,
            meter: Optional[bk.UsageMeter] = None) -> ExecutionResult:
    t0 = time.perf_counter()
    meter = meter if meter is not None else bk.UsageMeter()
    table = with_rowids(table)
    wall = 0.0
    scalar = None
    rows_processed = 0.0

    for k, op in enumerate(plan.ops):
        if table.n_rows == 0:
            # a filter upstream emptied the table: maps must still define
            # their output column (a downstream reduce reads it), filters/
            # ranks are no-ops, reduces aggregate the empty column
            if op.kind == plan_ir.MAP:
                table = table.with_column(op.output_column, [])
                continue
            if op.kind != plan_ir.REDUCE:
                continue
            values = table.columns.get(op.input_column, [])
        else:
            values = table.resolve(op.input_column)
        if op.udf is not None:
            compiled = udf_mod.resolve_udf(op)

            def safe(v, default=None):
                # generated UDFs are format-fragile by design (Fig. 12b);
                # a row that crashes one yields the kind's null answer
                try:
                    return compiled.fn(v)
                except Exception:
                    return default

            wall += table.n_rows * 2e-6
            if op.kind == plan_ir.FILTER:
                mask = [bool(safe(v, False)) for v in values]
                table = table.select(mask)
            elif op.kind == plan_ir.MAP:
                table = table.with_column(
                    op.output_column, [safe(v) for v in values])
            elif op.kind == plan_ir.REDUCE:
                scalar = safe(list(values))
            elif op.kind == plan_ir.RANK:
                order = safe(list(values), list(range(len(values))))
                ranks = [0] * len(order)
                for r, i in enumerate(order):
                    ranks[i] = r
                table = table.with_column(op.output_column or "rank", ranks,
                                          "numeric")
            continue

        tier_name = op.tier or default_tier
        backend = backends[tier_name]
        # account under the backend's own tier name (a dict key like "m*"
        # may map to a differently-named backend, e.g. a JAXBackend tier)
        outs, n_calls, lat = run_llm_op(op, values, backend,
                                        backend.tier.name, meter,
                                        batch_size=batch_size, cache=cache)
        wall += _makespan(lat, n_calls, concurrency)
        rows_processed += len(values)

        if op.kind == plan_ir.FILTER:
            mask = [bool(o) if isinstance(o, bool) else
                    str(o).strip().lower().startswith(("true", "yes"))
                    for o in outs]
            table = table.select(mask)
        elif op.kind == plan_ir.MAP:
            table = table.with_column(op.output_column, outs)
        elif op.kind == plan_ir.REDUCE:
            scalar = outs[0]
        elif op.kind == plan_ir.RANK:
            sims = [(o if isinstance(o, (int, float)) else i)
                    for i, o in enumerate(outs)]
            order = sorted(range(len(sims)), key=lambda i: sims[i],
                           reverse=True)
            ranks = [0] * len(order)
            for r, i in enumerate(order):
                ranks[i] = r
            table = table.with_column(op.output_column or "rank", ranks,
                                      "numeric")

    return ExecutionResult(
        table=None if scalar is not None else table,
        scalar=scalar, meter=meter, wall_s=wall,
        cpu_s=time.perf_counter() - t0, rows_processed=rows_processed)
