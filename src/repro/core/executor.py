"""Physical-plan executor (paper §2.2 "query executor") — morsel-driven.

Runs a plan over a :class:`Table`: UDF operators execute as native compute;
LLM operators dispatch to the backend tier assigned by the physical plan
(default tier when unassigned — the paper uses the strongest model as the
default backbone).

The table is split into row **morsels** so operators pipeline: a downstream
map starts on rows an upstream filter has already passed instead of waiting
for the whole column (``morsel_size=0`` restores the per-operator barrier).
Reduce and rank are pipeline barriers — they need every surviving row.

*How* morsels run is the execution context's **driver**
(``runtime.Dispatcher``):

* ``driver="simulated"`` — backend calls execute inline; every call reports
  its latency into the meter's call log and is placed on the earliest-free
  worker of its tier by the event scheduler. ``wall_s`` is the modeled
  makespan (deterministic; Table-9 accounting).
* ``driver="threads"`` — backend calls run on per-tier bounded worker
  pools and morsel chains advance concurrently, so independent operators'
  morsels genuinely overlap. ``wall_s`` is **measured** wall time.

With ``batch_size > 1`` and coalescing enabled (``ctx.coalesce``, the
default), streamable LLM operators run through a
``runtime.BatchCoalescer``: each morsel submits its surviving rows into a
per-operator accumulation queue and receives a *future* that resolves as
soon as the batches containing its rows flush — so downstream morsels
still start early, but batch slots fill across morsel boundaries
(``ceil(survivors/batch)`` calls, like whole-table batching, instead of
``sum(ceil(s_i/batch))`` per-morsel ceilings).

With ``ctx.shards > 1`` the morsel stream fans out round-robin across
shard workers (``distributed.morsel_shards.ShardedDispatcher``): each
morsel's chain runs on its shard's pools, coalesced batch *formation*
stays global, and shard outputs merge back in logical morsel order
(``Table.concat`` via ``_merge``); per-shard staging meters combine into
``ctx.meter`` with a deterministic call log (``disp.finalize``).

Monetary cost comes from tier token prices; both axes accumulate in a
UsageMeter so benchmarks can break costs down per model tier (paper
Fig. 10). Neither morsel pipelining, coalescing, the driver, nor the
shard count changes the answer — results, call counts, and per-tier
meter totals are identical across barrier/morsel/coalesced,
simulated/threaded, and shards in {1, 2, 4} execution.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, List, Optional, Tuple

from repro.core import backends as bk
from repro.core import plan as plan_ir
from repro.core import runtime as rt
from repro.core.table import Table

# re-exported for backwards compatibility (they live in runtime now)
from repro.core.runtime import OutputCache, run_llm_op   # noqa: F401

ROWID = "_rowid"


def with_rowids(table: Table) -> Table:
    if ROWID in table.columns:
        return table
    t = table.with_column(ROWID, list(range(table.n_rows)), "numeric")
    return t


@dataclasses.dataclass
class ExecutionResult:
    table: Optional[Table]          # surviving rows (None after reduce)
    scalar: Any                     # reduce output (None unless is_reduce)
    meter: bk.UsageMeter
    wall_s: float                   # simulated (event-model) or measured
    cpu_s: float                    # real python time spent
    rows_processed: float = 0.0     # LLM-processed records (Fig. 13 metric)
    # whether the plan ended in a reduce — carried explicitly because a
    # crashed/unanswerable reduce legitimately yields ``scalar=None`` and
    # sniffing ``scalar is not None`` would misclassify the query's kind
    is_reduce: bool = False
    # BatchCoalescer.stats for this run (None when coalescing was inactive)
    coalesce_stats: Optional[dict] = None
    # tier-0 cascade routing counters (None when no cascade was configured):
    # embed_calls / passed / dropped / escalated
    cascade_stats: Optional[dict] = None

    def value(self):
        """The query answer: reduce scalar, else the surviving table."""
        return self.scalar if self.is_reduce else self.table


def _split_morsels(table: Table, morsel_size: int,
                   batch_size: int) -> List[Tuple[Table, float]]:
    """Split into (morsel, ready_time) pairs. Full morsels are multiples of
    the batch size, so batch-prompting call counts match the barrier
    executor exactly: sum(ceil(s_i/b)) == ceil(n/b)."""
    if morsel_size <= 0 or table.n_rows <= morsel_size:
        return [(table, 0.0)]
    step = max(morsel_size, batch_size)
    step = ((step + batch_size - 1) // batch_size) * batch_size
    return [(table.take(range(i, min(i + step, table.n_rows))), 0.0)
            for i in range(0, table.n_rows, step)]


def _merge(parts: List[Tuple[Table, float]]) -> Tuple[Table, float]:
    tables = [t for t, _ in parts]
    ready = max((r for _, r in parts), default=0.0)
    return (tables[0] if len(tables) == 1 else Table.concat(tables)), ready


class _PendingMorsel:
    """A morsel whose LLM outputs are still inside the batch coalescer.

    The chain carries this placeholder instead of a table; the *next*
    stage that needs the rows forces it (waits on the coalescer future and
    folds the outputs in). Deferring the wait downstream keeps submission
    tasks non-blocking, which preserves the chain pool's FIFO liveness
    argument: a submitter never holds a worker while waiting on a batch
    another queued task must complete.

    ``fold`` (a tier-0 cascade partition's ``merge``) maps the coalescer
    future's outputs — the *escalated* rows only — back to a full per-row
    output list before ``apply_outputs``."""

    __slots__ = ("op", "tbl", "fut", "fold")

    def __init__(self, op: plan_ir.Operator, tbl: Table, fut, fold=None):
        self.op = op
        self.tbl = tbl
        self.fut = fut
        self.fold = fold


class _FailedMorsel:
    """Poison value carried down a morsel chain after a failure while
    coalescing is active. Raising inside the chain would leave downstream
    accumulation queues short of their morsel-boundary watermark — and
    every *other* morsel's future would then wait forever — so the error
    flows as a value instead: each later step still advances its group's
    watermark with an empty submission, and the exception re-raises at the
    next point the morsel is forced (barrier or final merge)."""

    __slots__ = ("exc",)

    def __init__(self, exc: BaseException):
        self.exc = exc


def _force(value, ready: float) -> Tuple[Table, float]:
    """Materialize a (possibly pending) morsel into its output table."""
    if isinstance(value, _FailedMorsel):
        raise value.exc
    if isinstance(value, _PendingMorsel):
        outs, finish = value.fut.result()
        if value.fold is not None:
            outs = value.fold(outs)
        tbl, _ = rt.apply_outputs(value.op, value.tbl, outs)
        return tbl, max(ready, finish)
    return value, ready


def _settle(parts) -> List[Tuple[Table, float]]:
    """Resolve EVERY morsel task, then surface the first failure (in
    morsel order). Waiting for all tasks — instead of raising at the
    first failed one — is what makes the executor's cleanup safe on a
    shared (server) dispatcher: ``finalize``/``release_query`` must not
    run while sibling morsels of the same query are still billing, or
    stragglers would resurrect released routing state and their calls
    would miss the per-query meter merge."""
    settled: List[Tuple[Table, float]] = []
    first_exc: Optional[BaseException] = None
    for p in parts:
        try:
            settled.append(_force(*p.result()))
        except BaseException as e:
            if first_exc is None:
                first_exc = e
    if first_exc is not None:
        raise first_exc
    return settled


def execute(plan: plan_ir.LogicalPlan, table: Table,
            backends, *, default_tier: Optional[str] = None,
            concurrency: Optional[int] = None,
            batch_size: Optional[int] = None,
            cache: Optional[OutputCache] = None,
            meter: Optional[bk.UsageMeter] = None,
            morsel_size: Optional[int] = None,
            driver: Optional[str] = None,
            coalesce: Optional[bool] = None,
            linger_s: Optional[float] = None,
            shards: Optional[int] = None,
            shard_cache: Optional[str] = None,
            procs: Optional[int] = None,
            cascade=None,
            call_policy: Optional[rt.CallPolicy] = None,
            scheduler: Optional[rt.EventScheduler] = None,
            dispatcher: Optional[rt.Dispatcher] = None,
            query_key=None
            ) -> ExecutionResult:
    """Execute ``plan`` over ``table``.

    ``backends`` is either a ``{tier: Backend}`` dict (legacy call shape;
    the keyword arguments then configure the run, with the
    ``ExecutionContext`` field defaults filling the gaps) or a
    :class:`runtime.ExecutionContext` (every keyword argument given here
    overrides the matching context field). A caller-supplied ``dispatcher``
    shares its worker pools across executions — the judge overlaps both
    sample runs on one pool this way, and ``launch.query_server`` admits
    every query onto one server-lifetime dispatcher — and ``wall_s`` then
    reports the dispatcher's cumulative makespan. ``scheduler`` is the
    legacy form of the same: it is wrapped in a
    :class:`runtime.SimulatedDispatcher`.

    ``cascade`` (a ``core.cascade.CascadeRouter``) enables the tier-0
    embedding cascade for this execution: eligible SEM_FILTER/RANK
    operators resolve their confident bands in one batched device pass per
    morsel and escalate only the uncertain band to the LLM tier (see
    ``ExecutionResult.cascade_stats``).

    ``query_key`` scopes this execution on a *shared* dispatcher: it
    prefixes every logical meter key (``(query, op, morsel, ...)``) so
    concurrently admitted queries' call logs stay disjoint and
    per-query-sortable, and it gives the execution its own round-robin
    shard cursor (concurrent queries spread across shards instead of all
    starting on shard 0). Solo executions leave it ``None`` — their key
    shapes are unchanged.
    """
    t0 = time.perf_counter()
    over = {k: v for k, v in (("default_tier", default_tier),
                              ("concurrency", concurrency),
                              ("batch_size", batch_size),
                              ("cache", cache), ("meter", meter),
                              ("morsel_size", morsel_size),
                              ("driver", driver),
                              ("coalesce", coalesce),
                              ("linger_s", linger_s),
                              ("shards", shards),
                              ("shard_cache", shard_cache),
                              ("procs", procs),
                              ("cascade", cascade),
                              ("call_policy", call_policy))
            if v is not None}
    ctx = rt.as_context(backends, **over)

    owns_dispatcher = dispatcher is None
    if dispatcher is None:
        dispatcher = rt.SimulatedDispatcher(scheduler) \
            if scheduler is not None else ctx.make_dispatcher()
    try:
        return _run(plan, table, ctx, dispatcher, t0, query_key=query_key)
    finally:
        if owns_dispatcher:
            dispatcher.close()


def _run(plan: plan_ir.LogicalPlan, table: Table, ctx: rt.ExecutionContext,
         disp: rt.Dispatcher, t0: float, query_key=None) -> ExecutionResult:
    meter = ctx.meter
    # logical meter-key prefix: () solo, (query_id,) on a shared server —
    # keys within one execution keep one shape, so per-query merge sorts
    kp = () if query_key is None else (query_key,)
    table = with_rowids(table)
    # Morsel boundaries do NOT depend on the shard count: a sharded
    # dispatcher only changes *where* each morsel runs (round-robin by
    # morsel index), so results and per-morsel call grouping are
    # shard-count invariant by construction.
    parts = [disp.done(t) for t, _ in
             _split_morsels(table, ctx.morsel_size, ctx.batch_size)]
    scalar = None
    is_reduce = False
    rows_lock = threading.Lock()
    rows_processed = [0.0]
    coal: Optional[rt.BatchCoalescer] = None
    if ctx.coalesce and ctx.batch_size > 1 and any(
            op.udf is None and op.kind in (plan_ir.FILTER, plan_ir.MAP)
            for op in plan.ops):
        coal = rt.BatchCoalescer(disp, meter, batch_size=ctx.batch_size,
                                 cache=ctx.cache, linger_s=ctx.linger_s)
    casc = ctx.cascade
    casc_stats = {"embed_calls": 0, "passed": 0, "dropped": 0,
                  "escalated": 0, "embed_failures": 0} \
        if casc is not None else None

    def cascade_partition(op, oi, idx, values, ready):
        """Run the tier-0 embedding pass over one morsel's values (one
        metered ``tier0-embed`` call on the morsel's shard; chunk ``-1``
        in the logical key sorts the device pass ahead of the operator's
        LLM chunks) and band-route every row. The partition is a pure
        function of (op, values), so routing — and therefore which rows
        the LLM tiers see — is driver-, shard-, and order-invariant.

        Returns None when the embed pass *fails*: graceful degradation —
        the caller escalates the whole morsel to the LLM tier, so a
        tier-0 outage costs the cascade's savings, not the query (results
        are byte-identical to a no-cascade run, since escalate-everything
        is exactly what no cascade does). The failure count is reported
        in ``cascade_stats["embed_failures"]``."""
        try:
            part = casc.partition(op, values, disp, meter, ready=ready,
                                  shard=disp.shard_of(idx, query_key),
                                  key=kp + (oi, idx, -1))
        except Exception:
            with rows_lock:
                casc_stats["embed_failures"] += 1
            return None
        with rows_lock:
            casc_stats["embed_calls"] += 1
            casc_stats["passed"] += part.n_pass
            casc_stats["dropped"] += part.n_drop
            casc_stats["escalated"] += len(part.escalate)
        return part

    def llm_calls(op, oi, idx, values, ready):
        """Dispatch one operator over one morsel's values on the morsel's
        shard; (op index, morsel index) is the call's logical meter key."""
        backend = ctx.backend(op.tier)
        # account under the backend's own tier name (a dict key like "m*"
        # may map to a differently-named backend, e.g. a JAXBackend tier)
        outs, finish = disp.run_llm(op, values, backend, backend.tier.name,
                                    meter, batch_size=ctx.batch_size,
                                    cache=ctx.cache, ready_s=ready,
                                    shard=disp.shard_of(idx, query_key),
                                    key=kp + (oi, idx))
        with rows_lock:
            rows_processed[0] += len(values)
        return outs, finish

    def step(op, oi, group, idx, value, ready):
        """Advance one morsel through one streamable (filter/map) operator;
        runs on a chain-pool thread under the threaded driver. ``value``
        may be a _PendingMorsel from an upstream coalesced operator, or a
        _FailedMorsel poison (then only keep the watermark moving)."""
        if isinstance(value, _FailedMorsel):
            if group is not None:
                group.submit(idx, [], ready)
            return value, ready
        try:
            tbl, ready = _force(value, ready)
            if group is not None:
                # coalesced LLM operator: hand the surviving rows to the
                # accumulation queue (empty morsels still advance the
                # watermark) and resume downstream when their batches flush
                values = tbl.resolve(op.input_column) if tbl.n_rows else []
                if casc is not None and values and casc.active_for(op):
                    # tier-0 cascade: resolve the confident bands on
                    # device, submit ONLY the uncertain band to the batch
                    # queue; the partition's merge folds the escalated
                    # outputs back when the morsel is forced. A failed
                    # embed pass (part is None) degrades: fall through
                    # and submit every row, exactly as if no cascade
                    # were configured for this morsel.
                    part = cascade_partition(op, oi, idx, values, ready)
                    if part is not None:
                        with rows_lock:
                            rows_processed[0] += len(part.escalate)
                        fut = group.submit(
                            idx, [values[i] for i in part.escalate],
                            max(ready, part.finish))
                        return (_PendingMorsel(op, tbl, fut,
                                               fold=part.merge),
                                ready)
                with rows_lock:
                    rows_processed[0] += len(values)
                return (_PendingMorsel(op, tbl,
                                       group.submit(idx, values, ready)),
                        ready)
            if tbl.n_rows == 0:
                # an upstream filter emptied this morsel: maps must still
                # define their output column (downstream reads it)
                if op.kind == plan_ir.MAP:
                    tbl = tbl.with_column(op.output_column, [])
                return tbl, ready
            values = tbl.resolve(op.input_column)
            if op.udf is not None:
                # host UDF morsels pipeline against LLM work; threaded
                # shards serialize them through one host lock (one
                # interpreter), process shards run them GIL-free
                (out_tbl, _), finish = disp.run_udf(
                    op, tbl, values, ready_s=ready,
                    shard=disp.shard_of(idx, query_key))
                return out_tbl, finish
            if casc is not None and casc.active_for(op):
                part = cascade_partition(op, oi, idx, values, ready)
                if part is not None:
                    if part.escalate:
                        esc, finish = llm_calls(
                            op, oi, idx,
                            [values[i] for i in part.escalate],
                            max(ready, part.finish))
                    else:
                        esc, finish = [], part.finish
                    out_tbl, _ = rt.apply_outputs(op, tbl,
                                                  part.merge(esc))
                    return out_tbl, finish
                # degraded: the LLM tier answers the whole morsel
            outs, finish = llm_calls(op, oi, idx, values, ready)
            out_tbl, _ = rt.apply_outputs(op, tbl, outs)
            return out_tbl, finish
        except BaseException as e:
            if coal is None:
                raise               # no accumulation queues to keep alive
            if group is not None:
                group.submit(idx, [], ready)
            return _FailedMorsel(e), ready

    try:
        for oi, op in enumerate(plan.ops):
            if op.kind in (plan_ir.REDUCE, plan_ir.RANK):
                # pipeline barrier: needs every surviving row
                tbl, ready = _merge(_settle(parts))
                if op.kind == plan_ir.RANK and tbl.n_rows == 0:
                    parts = [disp.done(tbl, ready)]
                    continue
                values = tbl.columns.get(op.input_column, []) \
                    if tbl.n_rows == 0 else tbl.resolve(op.input_column)
                if op.udf is not None:
                    (tbl, out), finish = disp.run_udf(
                        op, tbl, values, ready_s=ready)
                else:
                    part = None
                    if (casc is not None and tbl.n_rows > 0
                            and casc.active_for(op)):
                        # cascaded RANK: the pass/drop tails keep their
                        # embedding order; only the middle band is
                        # re-ranked by the LLM tier. A failed embed pass
                        # (part None) degrades to a full LLM re-rank.
                        part = cascade_partition(op, oi, 0, values, ready)
                    if part is not None:
                        if part.escalate:
                            esc, finish = llm_calls(
                                op, oi, 0,
                                [values[i] for i in part.escalate],
                                max(ready, part.finish))
                        else:
                            esc, finish = [], part.finish
                        tbl, out = rt.apply_outputs(op, tbl,
                                                    part.merge(esc))
                    else:
                        outs, finish = llm_calls(op, oi, 0, values, ready)
                        tbl, out = rt.apply_outputs(op, tbl, outs)
                if op.kind == plan_ir.REDUCE:
                    scalar = out
                    is_reduce = True
                # everything downstream restarts from the barrier's output
                parts = [disp.done(t, finish) for t, _ in
                         _split_morsels(tbl, ctx.morsel_size,
                                        ctx.batch_size)]
                continue

            # streamable operator (filter / map): advance each morsel on
            # its shard (round-robin morsel fan-out under a sharded
            # dispatcher; everything lands on shard 0 otherwise)
            group = None
            if coal is not None and op.udf is None:
                backend = ctx.backend(op.tier)
                group = coal.open(op, backend, backend.tier.name,
                                  expected=len(parts), op_key=kp + (oi,))
            parts = [
                disp.defer(p,
                           lambda value, ready, op=op, oi=oi, group=group,
                           i=i: step(op, oi, group, i, value, ready),
                           shard=disp.shard_of(i, query_key))
                for i, p in enumerate(parts)]

        out_table, _ = _merge(_settle(parts))
    finally:
        if coal is not None:
            # normal exit: a no-op (every group is watermarked and
            # drained). On error it fails pending futures so blocked chain
            # tasks unwind before the dispatcher's pool shutdown.
            coal.close()
        # sharded dispatch: merge per-shard staging meters into ctx.meter
        # (deterministic combined call log); no-op on single-host drivers.
        # finalize is per-execution, not terminal — a shared dispatcher
        # keeps serving other in-flight queries' staging untouched.
        disp.finalize(meter)
        # calibration sync point: the meter's call log is complete for
        # this execution and (when sharded) deterministically merged, so
        # the cost model may fold it in now — never mid-execution. The
        # per-meter cursor makes this idempotent if an outer layer (e.g.
        # the query server) observes the same meter again.
        if ctx.cost_model is not None:
            ctx.cost_model.observe(meter)
        if query_key is not None:
            disp.release_query(query_key)
    return ExecutionResult(
        table=None if is_reduce else out_table,
        scalar=scalar, meter=meter, wall_s=disp.wall_s,
        cpu_s=time.perf_counter() - t0, rows_processed=rows_processed[0],
        is_reduce=is_reduce,
        coalesce_stats=dict(coal.stats) if coal is not None else None,
        cascade_stats=dict(casc_stats) if casc_stats is not None else None)
