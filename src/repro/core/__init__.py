"""Nirvana core — the paper's contribution.

Semantic-operator plan IR, selectivity cost model, NL transformation rules,
random-walk agentic logical optimizer (Alg. 1), LLM-as-a-judge execution-
consistency verifier, improvement-score physical optimizer (Alg. 2,
Eqs. 2-8 with evaluation pushdown / computation reuse / capability-
hypothesis approximation), backend tier cascade, plan executor, and the
SemanticDataFrame user API.
"""
from repro.core.table import Table                                # noqa: F401
from repro.core.plan import (LogicalPlan, Operator,               # noqa: F401
                             MAP, FILTER, REDUCE, RANK)
from repro.core.cost import (DEFAULT_TIERS, TIER_ORDER, TierSpec,  # noqa: F401
                             plan_cost)
from repro.core.backends import (Backend, SimulatedBackend,       # noqa: F401
                                 UsageMeter, Usage, make_backends,
                                 UDFOracle)
from repro.core.improvement import (improvement_scores,          # noqa: F401
                                    OutputStore, ESTIMATORS)
from repro.core.logical_optimizer import (LogicalOptConfig,       # noqa: F401
                                          optimize as optimize_logical,
                                          optimize_beam)
from repro.core.physical_optimizer import (PhysicalOptConfig,     # noqa: F401
                                           optimize as optimize_physical,
                                           select_tier, smart_select)
from repro.core.cascade import (CascadeBands, CascadeRouter,      # noqa: F401
                                EmbeddingBackend)
from repro.core.runtime import (EventScheduler, ExecutionContext,  # noqa: F401
                                OutputCache, as_context)
from repro.core.executor import execute, ExecutionResult          # noqa: F401
from repro.core.dataframe import SemanticDataFrame, QueryReport   # noqa: F401
from repro.core import judge, rewriter, rules, udf, semhash       # noqa: F401
