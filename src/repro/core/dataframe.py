"""SemanticDataFrame — the user-facing programmable operator API (paper
Table 1 / Listing 1).

    df = SemanticDataFrame(table)
    df = (df.semantic_map("Extract the genre(s) of each movie.",
                          input_column="Plot", output_column="Genre")
            .semantic_filter("The rating is higher than 8.5.",
                             input_column="IMDB_rating")
            .semantic_reduce("Count the number of movies.",
                             input_column="Title"))
    result = df.execute(backends)        # optimizes, then runs

Operator calls build the logical plan lazily; ``execute`` runs the full
Nirvana pipeline: logical optimization (random-walk agentic rewriter) ->
physical optimization (improvement-score model selection) -> execution,
and returns the result plus the complete cost/latency breakdown per phase
(the Fig. 9 decomposition).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

from repro.core import backends as bk
from repro.core import executor as ex
from repro.core import logical_optimizer as lopt
from repro.core import physical_optimizer as popt
from repro.core import plan as plan_ir
from repro.core import runtime as rt
from repro.core.table import Table


@dataclasses.dataclass
class QueryReport:
    result: Any
    logical: Optional[lopt.OptResult]
    physical: Optional[popt.PhysicalOptResult]
    execution: ex.ExecutionResult
    plan: plan_ir.LogicalPlan

    @property
    def total_usd(self) -> float:
        usd = self.execution.meter.total.usd
        if self.logical:
            usd += self.logical.meter.total.usd
        if self.physical:
            usd += self.physical.meter.total.usd
        return usd

    @property
    def total_wall_s(self) -> float:
        w = self.execution.wall_s
        if self.logical:
            w += self.logical.opt_wall_s
        if self.physical:
            w += self.physical.opt_wall_s
        return w

    def phase_breakdown(self) -> Dict[str, Dict[str, float]]:
        out = {"execution": {"wall_s": self.execution.wall_s,
                             "usd": self.execution.meter.total.usd}}
        if self.logical:
            out["logical_opt"] = {"wall_s": self.logical.opt_wall_s,
                                  "usd": self.logical.meter.total.usd}
        if self.physical:
            out["physical_opt"] = {"wall_s": self.physical.opt_wall_s,
                                   "usd": self.physical.meter.total.usd}
        return out


class SemanticDataFrame:
    def __init__(self, table: Table, _ops: tuple = ()):
        self.table = table
        self._ops = _ops

    # ------------------------------------------------------------------
    # Table-1 operators
    # ------------------------------------------------------------------
    def semantic_map(self, user_instruction: str, input_column: str,
                     output_column: str) -> "SemanticDataFrame":
        op = plan_ir.Operator(plan_ir.MAP, user_instruction, input_column,
                              output_column)
        return SemanticDataFrame(self.table, self._ops + (op,))

    def semantic_filter(self, user_instruction: str,
                        input_column: str) -> "SemanticDataFrame":
        op = plan_ir.Operator(plan_ir.FILTER, user_instruction, input_column)
        return SemanticDataFrame(self.table, self._ops + (op,))

    def semantic_reduce(self, user_instruction: str,
                        input_column: str) -> "SemanticDataFrame":
        op = plan_ir.Operator(plan_ir.REDUCE, user_instruction, input_column)
        return SemanticDataFrame(self.table, self._ops + (op,))

    def semantic_rank(self, user_instruction: str, input_column: str,
                      output_column: str = "rank") -> "SemanticDataFrame":
        op = plan_ir.Operator(plan_ir.RANK, user_instruction, input_column,
                              output_column)
        return SemanticDataFrame(self.table, self._ops + (op,))

    # ------------------------------------------------------------------
    def plan(self) -> plan_ir.LogicalPlan:
        return plan_ir.LogicalPlan(self._ops, source=self.table.name)

    def execute(self, backends: "Dict[str, bk.Backend] | rt.ExecutionContext",
                *, logical: bool = True, physical: bool = True,
                rewriter=None,
                lcfg: Optional[lopt.LogicalOptConfig] = None,
                pcfg: Optional[popt.PhysicalOptConfig] = None,
                concurrency: int = 16,
                default_tier: str = "m*",
                driver: str = "simulated") -> QueryReport:
        plan = self.plan()
        plan.validate()

        # one ExecutionContext threads the whole pipeline: the logical
        # optimizer's candidate evaluation, the physical optimizer's sample
        # flow, and the final execution (optimizers fork their own meters)
        if isinstance(backends, rt.ExecutionContext):
            ctx = backends
        else:
            ctx = rt.ExecutionContext(backends=backends,
                                      default_tier=default_tier,
                                      concurrency=concurrency,
                                      driver=driver)

        lres = None
        if logical:
            # configs inherit tier/concurrency from the context by default
            lres = lopt.optimize(plan, self.table, ctx, rewriter=rewriter,
                                 cfg=lcfg or lopt.LogicalOptConfig())
            plan = lres.best

        pres = None
        if physical and plan.n_llm_ops:
            pres = popt.optimize(plan, self.table, ctx,
                                 cfg=pcfg or popt.PhysicalOptConfig())
            plan = pres.plan

        run = ex.execute(plan, self.table, ctx)
        return QueryReport(result=run.value(), logical=lres, physical=pres,
                           execution=run, plan=plan)
