"""Execution-consistency verification with an LLM-as-a-judge (paper §3.1).

Formal equivalence checking does not apply to NL-driven operators, so
Nirvana executes both the original and the rewritten plan on a data sample
and rates the similarity of their outputs. The paper prompts an LLM for a
0-10 rating; here the rating is computed from semantic output comparison
(the Sentence-BERT-style embedder), which keeps the verifier *independent*
of the rewriter — the paper's circular-trust requirement — while remaining
deterministic and measurable.

Rating model (normalized to [0, 1], the plan's `accuracy`):
  both reduce scalars   numeric closeness (relative error), else embedding
                        cosine of the rendered values
  both tables           Jaccard overlap of surviving row ids x mean semantic
                        similarity over columns produced by either plan
  table vs scalar       0.0

Judge failures are *emergent*, exactly the paper's two causes (§5.3.5): low
sample coverage (a sample may miss the rows where a corrupted predicate
diverges) and vague operator outputs (close-but-wrong map outputs clear the
embedding threshold). Table 7 measures both.

Every verification is also costed as one judge-LLM call (prompt = both
plans' rendered outputs), so optimizer-overhead accounting includes it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core import backends as bk
from repro.core import cost as cost_mod
from repro.core import cost_model
from repro.core import executor as ex
from repro.core import plan as plan_ir
from repro.core import runtime as rt
from repro.core import semhash
from repro.core.table import Table


@dataclasses.dataclass
class JudgeResult:
    rating: float                # in [0,1]; plan accuracy estimate
    usage: bk.Usage              # judge-call cost (one LLM rating call)
    detail: str = ""


def _scalar_similarity(a, b) -> float:
    na, nb = cost_mod.text_tokens(a), cost_mod.text_tokens(b)  # noqa: F841
    if isinstance(a, (int, float)) and isinstance(b, (int, float)):
        if a == b:
            return 1.0
        scale = max(abs(float(a)), abs(float(b)), 1e-9)
        return float(max(0.0, 1.0 - abs(float(a) - float(b)) / scale))
    if a is None or b is None:
        return 1.0 if a is b else 0.0
    return float(np.dot(semhash.embed_one(a), semhash.embed_one(b)))


def _table_similarity(ta: Table, tb: Table, produced_cols) -> float:
    ids_a = set(ta.columns.get(ex.ROWID, []))
    ids_b = set(tb.columns.get(ex.ROWID, []))
    union = ids_a | ids_b
    if not union:
        return 1.0  # both empty — consistent
    jacc = len(ids_a & ids_b) / len(union)
    shared = sorted(ids_a & ids_b)
    if not shared or not produced_cols:
        return jacc
    pos_a = {r: i for i, r in enumerate(ta.columns[ex.ROWID])}
    pos_b = {r: i for i, r in enumerate(tb.columns[ex.ROWID])}
    sims = []
    for col in produced_cols:
        if col not in ta.columns or col not in tb.columns:
            continue
        xs = [ta.columns[col][pos_a[r]] for r in shared]
        ys = [tb.columns[col][pos_b[r]] for r in shared]
        s = semhash.pairwise_similarity(
            [str(x) for x in xs], [str(y) for y in ys])
        sims.append(float(np.mean(s)) if len(s) else 1.0)
    col_sim = float(np.mean(sims)) if sims else 1.0
    return jacc * col_sim


@dataclasses.dataclass
class Judge:
    """Rates semantic consistency between a rewritten plan and the original
    by execution consistency on a sample (Alg. 1's ``evaluate``).

    Sample executions share an :class:`executor.OutputCache` across
    ratings: the original plan is billed once, and rewritten plans only pay
    for operators the rewrite actually changed. Both sample executions of a
    rating run against **one** dispatcher, so they share the same worker
    pool (the paper's 16 coroutines serve the verifier too) — simulated or
    real threads, per the context's ``driver`` — instead of being
    accounted back-to-back. The context's ``batch_size``/``coalesce``/
    ``linger_s`` flow through unchanged, so with batching enabled each
    sample run packs its morsels through a ``runtime.BatchCoalescer`` and
    the verifier pays coalesced (not per-morsel) call counts."""
    backends: "Dict[str, bk.Backend] | rt.ExecutionContext"
    judge_tier: str = "m*"          # the tier priced for the rating call
    exec_tier: str = "m*"           # backend used to execute sample plans
    concurrency: int = 16

    def __post_init__(self):
        if isinstance(self.backends, rt.ExecutionContext):
            # a caller-built context wins over the field defaults
            self.ctx = self.backends.fork(cache=ex.OutputCache())
            self.exec_tier = self.ctx.default_tier
            self.concurrency = self.ctx.concurrency
        else:
            self.ctx = rt.ExecutionContext(
                backends=self.backends, default_tier=self.exec_tier,
                concurrency=self.concurrency, cache=ex.OutputCache())
        self.cache = self.ctx.cache

    def rate(self, original: plan_ir.LogicalPlan,
             rewritten: plan_ir.LogicalPlan, sample: Table,
             meter: Optional[bk.UsageMeter] = None) -> JudgeResult:
        meter = meter if meter is not None else bk.UsageMeter()
        rctx = self.ctx.fork(meter=meter)
        disp = rctx.make_dispatcher()
        try:
            ra = ex.execute(original, sample, rctx, dispatcher=disp)
            rb = ex.execute(rewritten, sample, rctx, dispatcher=disp)
            exec_wall = disp.wall_s
        finally:
            disp.close()

        # compare by the *declared* result kind: an unanswerable reduce
        # yields scalar=None yet is still a scalar-valued query
        if ra.is_reduce != rb.is_reduce:
            rating, detail = 0.0, "result-kind mismatch"
        elif ra.is_reduce:
            rating = _scalar_similarity(ra.scalar, rb.scalar)
            detail = f"scalar {ra.scalar!r} vs {rb.scalar!r}"
        else:
            produced = {c for op in original.ops for c in op.writes} | \
                       {c for op in rewritten.ops for c in op.writes}
            rating = _table_similarity(ra.table, rb.table, sorted(produced))
            detail = (f"rows {ra.table.n_rows} vs {rb.table.n_rows}")

        # the rating itself is one judge-LLM call over both rendered
        # outputs, priced by the context's cost model (tiers + the judge
        # prompt-length rule live there so a calibrated serve re-prices it)
        model = self.ctx.cost_model or cost_model.DEFAULT_MODEL
        tier = model.tiers[self.judge_tier]
        tok_in = model.judge_tokens(sample.n_rows)
        usage = bk.Usage(calls=1, tok_in=tok_in, tok_out=4.0,
                         usd=tier.usd(tok_in, 4.0),
                         latency_s=tier.latency(4.0))
        meter.record(self.judge_tier, usage, op_kind="judge")
        # execution + judging both contribute to verification wall-clock;
        # the shared dispatcher's wall covers both sample runs (modeled
        # makespan under the simulated driver, measured under threads)
        usage_total = bk.Usage(calls=usage.calls, tok_in=usage.tok_in,
                               tok_out=usage.tok_out, usd=usage.usd,
                               latency_s=usage.latency_s + exec_wall)
        return JudgeResult(rating=float(max(0.0, min(1.0, rating))),
                           usage=usage_total, detail=detail)
