"""Cost-aware physical plan optimizer — paper §4, Algorithm 2.

Assigns the most cost-effective backend tier to every LLM operator of a
logical plan. Per operator: compute improvement scores I_{m1->m} over a
data sample (estimator selectable: exact / pushdown / reuse / approx — the
paper's headline configuration is `approx`, Eqs. 6-8), then upgrade from
the cheapest tier m1 only while the *marginal* improvement clears the
user's margin dI_min.

The sample flows through the plan operator-by-operator with the already-
selected backends (matching the paper's optimize-then-execute pipeline in
Fig. 4), so downstream operators are scored on realistic inputs.

Batch awareness: with ``ctx.batch_size > 1`` the scoring sweeps batch
records the way coalesced execution will — an operator's evaluation on a
tier costs ``ceil(sample / batch_size)`` calls (not one call per record),
and the improvement scores reflect the batch-prompting accuracy penalty —
so both the tier choice and the reported optimization overhead match the
batched execution the plan is headed for.

Sync vs async (Table 9): every backend call lands in the meter's call log
and runs through the context's dispatcher (``runtime.Dispatcher``). Under
the simulated driver, ``async`` places each operator's scoring calls
concurrently on per-tier event-scheduler pools with a barrier before the
next operator (its sample input depends on this operator's output) and
``sync`` collapses all tiers onto one worker, i.e. the sequential sum.
Under ``driver="threads"`` the scoring calls of one operator run
concurrently *for real* on the tier worker pools and ``opt_wall_s`` is
measured wall time.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core import backends as bk
from repro.core import cascade as casc_mod
from repro.core import cost_model as cm
from repro.core import executor as ex
from repro.core import improvement as imp
from repro.core import plan as plan_ir
from repro.core import runtime as rt
from repro.core.table import Table


@dataclasses.dataclass
class PhysicalOptConfig:
    delta_min: float = 0.20        # improvement margin (paper §5.1.4: 20%)
    sample_ratio: float = 0.05
    sample_min: int = 8
    sample_max: int = 64
    estimator: str = "approx"      # exact | pushdown | reuse | approx
    max_cond_eval: int = 16        # bound conditional-term evaluations
    # None = inherit from the ExecutionContext (16 / "async" for bare dicts)
    concurrency: Optional[int] = None   # async worker count
    mode: Optional[str] = None          # sync | async
    seed: int = 0
    # band slack around the capability sample's class boundaries when
    # calibrating a tier-0 cascade (ctx.cascade is set): larger margins
    # escalate more rows
    cascade_margin: float = 0.02
    # cost x makespan weight for tier selection; None = inherit from the
    # context's CostModel (the library default model's weight is 0, which
    # reproduces pure improvement-margin selection exactly)
    latency_weight: Optional[float] = None


@dataclasses.dataclass
class PhysicalOptResult:
    plan: plan_ir.LogicalPlan               # with tiers assigned
    assignments: Dict[int, str]             # op index -> tier
    scores: Dict[int, Dict[str, float]]     # op index -> improvement scores
    meter: bk.UsageMeter                    # optimization-phase usage
    opt_wall_s: float
    # op index -> adopted cascade calibration (bands + sample agreement /
    # resolved-fraction / improvement stats); empty without ctx.cascade
    cascades: Dict[int, dict] = dataclasses.field(default_factory=dict)


def select_tier(scores: Dict[str, float], delta_min: float,
                order=("m2", "m3", "m*"),
                penalty: Optional[Dict[str, float]] = None) -> str:
    """Algorithm 2's greedy upgrade: start at m1, upgrade tier-by-tier while
    the marginal improvement I_curr - I_last exceeds the margin.

    ``penalty`` (scheduler-aware mode) charges each candidate a
    cost x makespan handicap in improvement-score units: an upgrade must
    clear ``delta_min`` *plus* the candidate's penalty increase over the
    incumbent. ``None`` (the default, and always the case at
    ``latency_weight=0``) is byte-identical to the classic walk."""
    chosen, i_last = "m1", 0.0
    for m in order:
        i_curr = scores[m]
        need = delta_min
        if penalty is not None:
            need += penalty.get(m, 0.0) - penalty.get(chosen, 0.0)
        if i_curr - i_last >= need:
            chosen, i_last = m, i_curr
    return chosen


def optimize(plan: plan_ir.LogicalPlan, table: Table,
             backends: "Dict[str, bk.Backend] | rt.ExecutionContext",
             cfg: PhysicalOptConfig = PhysicalOptConfig(),
             dispatcher: Optional[rt.Dispatcher] = None
             ) -> PhysicalOptResult:
    ctx = rt.as_context(backends)
    n_sample = min(max(int(table.n_rows * cfg.sample_ratio), cfg.sample_min),
                   cfg.sample_max, table.n_rows)
    sample = ex.with_rowids(table.sample(n_sample, seed=cfg.seed))

    meter = bk.UsageMeter()        # optimization-phase accounting only
    owns_dispatcher = dispatcher is None
    if dispatcher is None:
        over = {k: v for k, v in (("concurrency", cfg.concurrency),
                                  ("mode", cfg.mode)) if v is not None}
        dispatcher = ctx.fork(**over).make_dispatcher() if over \
            else ctx.make_dispatcher()
    try:
        return _optimize(plan, sample, ctx, cfg, meter, dispatcher,
                         n_rows=table.n_rows)
    finally:
        if owns_dispatcher:
            dispatcher.close()


def _tier_penalty(model, op, n_rows, ctx, disp,
                  weight: float) -> Optional[Dict[str, float]]:
    """Scheduler-aware handicap per candidate tier, in improvement-score
    units: each tier's full-table USD and makespan (event-scheduler replay
    seeded with the dispatcher's current pool occupancy, so a busy tier
    looks slower than an idle one), normalized by the worst candidate and
    scaled by ``weight``. At weight 0 there is no penalty (None) and
    ``select_tier`` runs its classic walk."""
    if weight <= 0:
        return None
    occ = disp.occupancy() if disp is not None else {}
    usd: Dict[str, float] = {}
    mk: Dict[str, float] = {}
    for m in cm.TIER_ORDER:
        if m not in model.tiers:
            continue
        c = model.op_cost(op, float(n_rows), model.tiers[m],
                          batch_size=ctx.batch_size)
        usd[m] = c.usd
        mk[m] = model.op_makespan(
            op, float(n_rows), m, batch_size=ctx.batch_size,
            concurrency=ctx.concurrency, shards=ctx.shards,
            per_tier=ctx.per_tier_concurrency, occupancy=occ)
    umax = max(usd.values()) or 1.0
    mmax = max(mk.values()) or 1.0
    return {m: weight * 0.5 * (usd[m] / umax + mk[m] / mmax)
            for m in usd}


def _optimize(plan, sample, ctx, cfg, meter, disp,
              n_rows: Optional[int] = None) -> PhysicalOptResult:
    cursor = 0
    assignments: Dict[int, str] = {}
    all_scores: Dict[int, Dict[str, float]] = {}
    cascades: Dict[int, dict] = {}
    model = ctx.cost_model or cm.DEFAULT_MODEL
    weight = cfg.latency_weight if cfg.latency_weight is not None \
        else model.latency_weight
    if n_rows is None:
        n_rows = sample.n_rows

    cur = sample
    for k, op in enumerate(plan.ops):
        if cur.n_rows == 0:
            if op.is_llm:
                assignments[k] = "m1"
            continue
        values = cur.resolve(op.input_column)
        if op.is_llm:
            # batch-aware scoring: sweeps run (and are priced) at the
            # context's batch size — ceil(sample/batch) calls per tier
            # instead of per-record ceilings, and the scores see the batch
            # accuracy penalty the execution will actually pay. The store
            # is built here (not inside improvement_scores) so cascade
            # calibration below reuses the sampled tier outputs.
            store = imp.OutputStore(ctx.backends, op, values, meter=meter,
                                    dispatcher=disp,
                                    batch_size=ctx.batch_size)
            if cfg.estimator == "approx":
                res = imp.improvement_approx(
                    store, max_cond_eval=cfg.max_cond_eval)
            else:
                res = imp.ESTIMATORS[cfg.estimator](store)
            tier = select_tier(res.scores, cfg.delta_min,
                               penalty=_tier_penalty(model, op, n_rows,
                                                     ctx, disp, weight))
            assignments[k] = tier
            all_scores[k] = dict(res.scores)
            adopted = _calibrate_cascade(ctx, cfg, op, values, store, tier,
                                         meter)
            if adopted is not None:
                cascades[k] = adopted
            # scoring calls for one operator run as one concurrent stage
            # (simulated driver: drain + barrier; threads: already real)
            cursor = disp.checkpoint(meter, cursor)
        # flow the sample forward using the chosen tier (or the UDF)
        cur = _apply_op(op, cur, values, ctx,
                        assignments.get(k, "m1"), meter, disp)
        cursor = disp.checkpoint(meter, cursor)
        # ^ the next operator consumes this one's output

    tiered = plan.with_tiers(assignments)
    return PhysicalOptResult(plan=tiered, assignments=assignments,
                             scores=all_scores, meter=meter,
                             opt_wall_s=disp.wall_s, cascades=cascades)


def _calibrate_cascade(ctx, cfg, op, values, store, tier, meter):
    """Calibrate tier-0 cascade bands for one operator from the capability
    sample and adopt them onto ``ctx.cascade`` when the cascade clears the
    improvement-score gate.

    The embedding pass over the sample bills into the optimizer's meter
    under ``tier0-embed`` (cascade calibration is optimization overhead,
    like every other scoring sweep). SEM_FILTER bands are adopted only if
    the resolved sample rows' disagreement with the selected tier stays
    within ``delta_min`` — the same margin Algorithm 2 applies between
    tiers; RANK bands (middle-quartile escalation) only need a non-empty
    resolved tail. Returns the adopted calibration record, or None."""
    router = ctx.cascade
    if (router is None or op.udf is not None
            or op.kind not in router.KINDS):
        return None
    cscores = router.backend.run_values(op, values, meter=meter,
                                        batch_size=max(1, len(values)))
    all_i = list(range(store.n))
    store.ensure(tier, all_i)
    ref_outs = [store.out(tier, i) for i in all_i]
    bands = casc_mod.calibrate_bands(cscores, ref_outs, op.kind,
                                     margin=cfg.cascade_margin)
    if bands is None:
        return None
    if op.kind == plan_ir.FILTER:
        decisions = {i: True for i, s in enumerate(cscores)
                     if s >= bands.hi}
        decisions.update({i: False for i, s in enumerate(cscores)
                          if s <= bands.lo})
        stats = imp.improvement_cascade(store, tier, decisions)
        if not decisions or (1.0 - stats["agree"]) > cfg.delta_min:
            return None
    else:
        resolved = sum(1 for s in cscores
                       if s >= bands.hi or s <= bands.lo)
        if resolved == 0:
            return None
        stats = {"agree": None, "resolved": resolved / len(cscores),
                 "improvement": None}
    router.set_bands(op, bands)
    return {"bands": (bands.lo, bands.hi), **stats}


def _apply_op(op: plan_ir.Operator, table: Table, values,
              ctx: rt.ExecutionContext, tier: str,
              meter: bk.UsageMeter,
              dispatcher: Optional[rt.Dispatcher] = None) -> Table:
    """Advance the optimizer's sample through one operator (shared
    ``runtime`` apply path — same UDF safety and bool-mask parsing as the
    executor, and the *same accounting*: calls bill under the backend's own
    tier name and honor the context's batch size and output cache, so
    optimizer-phase usage is directly comparable to execution-phase usage)."""
    if op.udf is not None:
        table, _ = rt.run_udf_op(op, table, values)
        return table
    backend = ctx.backends[tier]
    fan = dispatcher.fanout(backend.tier.name) \
        if dispatcher is not None else None
    outs, _, _ = rt.run_llm_op(op, values, backend, backend.tier.name,
                               meter, batch_size=ctx.batch_size,
                               cache=ctx.cache, fanout=fan)
    table, _ = rt.apply_outputs(op, table, outs)
    return table


# ---------------------------------------------------------------------------
# Smart [13] comparison baselines (Table 9): single-operator model selection
# without pushdown/reuse/approx, in three flavours.
# ---------------------------------------------------------------------------

def smart_select(op: plan_ir.Operator, values,
                 backends: Dict[str, bk.Backend], delta_min: float,
                 variant: str = "exhaustive",
                 meter: Optional[bk.UsageMeter] = None):
    """Smart-style selection for one operator.

    exhaustive   every tier runs the full sample; exact Eq.-2 scores
    efficient    early-exits the tier loop once a tier clears the margin
    multi-model  splits records among tiers (mixed-integer-ish heuristic):
                 each tier runs a 1/|M| slice plus m* on everything
    """
    meter = meter if meter is not None else bk.UsageMeter()
    store = imp.OutputStore(backends, op, values, meter=meter)
    n = store.n
    idx = list(range(n))
    if variant == "exhaustive":
        res = imp.improvement_exact(store)
        return select_tier(res.scores, delta_min), res.scores, meter
    if variant == "efficient":
        store.ensure("m1", idx)
        store.ensure("m*", idx)
        scores = {}
        chosen, i_last = "m1", 0.0
        for m in ("m2", "m3", "m*"):
            store.ensure(m, idx)
            s = sum(store.eq(m, "m*", i) and not store.eq("m1", m, i)
                    for i in idx) / n if m != "m*" else \
                sum(not store.eq("m1", "m*", i) for i in idx) / n
            scores[m] = s
            if s - i_last >= delta_min:
                chosen, i_last = m, s
                break   # early exit: first sufficient tier wins
        for m in ("m2", "m3", "m*"):
            scores.setdefault(m, 0.0)
        return chosen, scores, meter
    # multi-model
    store.ensure("m*", idx)
    scores = {}
    k = max(1, n // 3)
    slices = {"m2": idx[:k], "m3": idx[k:2 * k], "m*": idx}
    for m, sl in slices.items():
        if m == "m*":
            scores[m] = sum(not store.eq("m1", "m*", i) for i in idx) / n
            continue
        store.ensure(m, sl)
        store.ensure("m1", sl)
        scores[m] = (sum(store.eq(m, "m*", i) and not store.eq("m1", m, i)
                         for i in sl) / max(1, len(sl)))
    return select_tier(scores, delta_min), scores, meter
