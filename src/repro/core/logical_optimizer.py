"""Agentic logical plan optimizer — paper §3, Algorithm 1.

Random-walk tree search over semantically-equivalent plans:

  1. sample a plan from the candidate set with Eq. 1's mixture probability
     Pr(p_i) = lam * 1/|P| + (1-lam) * softmax(cost_max - cost)_i
  2. rewrite it (LLM-sim / greedy-rule / local-model rewriter)
  3. verify by execution consistency on a data sample (LLM-as-a-judge) and
     estimate cost with the selectivity cost model
  4. accept iff accuracy >= epsilon and cost <= parent's cost

Returns the lowest-cost accepted plan plus the full search trace (the tree
of Fig. 3), and meters every LLM call the optimizer itself made — rewriter
calls, sample executions, judge ratings — so optimization overhead is a
first-class output (Tables 6 & 8; Fig. 9 breakdown).

Beam-search variant (App. D) included for the comparison benchmark.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Dict, List, Optional, Sequence

from repro.core import backends as bk
from repro.core import cost_model
from repro.core import judge as judge_mod
from repro.core import plan as plan_ir
from repro.core import rewriter as rw
from repro.core import runtime as rt
from repro.core.table import Table


@dataclasses.dataclass
class Candidate:
    plan: plan_ir.LogicalPlan
    cost: float
    acc: float
    parent: Optional[int]           # index into OptResult.candidates
    rule: str = ""
    accepted: bool = True
    judge_detail: str = ""
    rewrite_correct: Optional[bool] = None  # ground truth (Table 7 scoring)


@dataclasses.dataclass
class OptResult:
    best: plan_ir.LogicalPlan
    best_cost: float
    initial_cost: float
    candidates: List[Candidate]
    meter: bk.UsageMeter            # optimization-phase usage only
    opt_wall_s: float               # simulated optimizer wall-clock
    n_iterations: int

    @property
    def accepted_set(self) -> List[Candidate]:
        return [c for c in self.candidates if c.accepted]


@dataclasses.dataclass
class LogicalOptConfig:
    n_iterations: int = 3           # N_max (paper §5.1.4)
    epsilon: float = 0.8            # error tolerance
    lam: float = 0.2                # Eq. 1 exploration weight
    sample_ratio: float = 0.05
    sample_min: int = 8
    sample_max: int = 24            # verification sample cap — execution-
                                    # consistency needs far fewer rows than
                                    # the physical optimizer's scoring
    # None = inherit from the ExecutionContext (16 / "m*" for bare dicts)
    concurrency: Optional[int] = None
    default_tier: Optional[str] = None
    seed: int = 0


def sample_probabilities(costs: Sequence[float], lam: float) -> List[float]:
    """Eq. 1. Costs are normalized by cost_max so the softmax temperature is
    scale-free (USD costs span orders of magnitude across datasets)."""
    n = len(costs)
    cmax = max(costs)
    scale = max(cmax, 1e-12)
    ws = [math.exp((cmax - c) / scale) for c in costs]
    z = sum(ws)
    return [lam / n + (1.0 - lam) * w / z for w in ws]


def _cfg_context(backends, cfg: LogicalOptConfig) -> rt.ExecutionContext:
    """Context for candidate evaluation: explicit cfg fields win, otherwise
    inherit from a caller-supplied ExecutionContext."""
    over = {}
    if cfg.default_tier is not None:
        over["default_tier"] = cfg.default_tier
    if cfg.concurrency is not None:
        over["concurrency"] = cfg.concurrency
    return rt.as_context(backends, **over)


def optimize(plan: plan_ir.LogicalPlan, table: Table,
             backends: "Dict[str, bk.Backend] | rt.ExecutionContext",
             rewriter=None,
             cfg: LogicalOptConfig = LogicalOptConfig()) -> OptResult:
    rng = random.Random(cfg.seed)
    rewriter = rewriter or rw.LLMSimRewriter()
    ctx = _cfg_context(backends, cfg)
    judge = judge_mod.Judge(ctx)   # candidate evaluation shares the context
    n_sample = min(max(int(table.n_rows * cfg.sample_ratio), cfg.sample_min),
                   cfg.sample_max, table.n_rows)
    sample = table.sample(n_sample, seed=cfg.seed)

    meter = bk.UsageMeter()
    wall = 0.0

    model = ctx.cost_model or cost_model.DEFAULT_MODEL

    def plan_cost_of(p: plan_ir.LogicalPlan) -> float:
        # batch-aware: candidate costs price ceil(rows/batch) coalesced
        # calls, so rewrites are judged at the batch size they will run
        # at. The context's CostModel supplies the (possibly calibrated)
        # estimates and the objective — pure USD at latency_weight=0,
        # USD + makespan-equivalent otherwise.
        return model.objective(model.plan_cost(
            p, table.n_rows, default_tier=ctx.default_tier,
            concurrency=ctx.concurrency, batch_size=ctx.batch_size,
            shards=ctx.shards))

    c0 = plan_cost_of(plan)
    cands: List[Candidate] = [Candidate(plan, c0, 1.0, None, "init")]
    accepted = [0]

    for _ in range(cfg.n_iterations):
        probs = sample_probabilities([cands[i].cost for i in accepted],
                                     cfg.lam)
        pick = rng.choices(accepted, weights=probs, k=1)[0]
        parent = cands[pick]

        outcome = rewriter.rewrite(parent.plan, rng)
        meter.record("rewriter", outcome.usage)
        wall += outcome.usage.latency_s
        if outcome.plan is None:
            continue
        if outcome.plan.signature() == parent.plan.signature():
            continue
        if any(outcome.plan.signature() == c.plan.signature()
               for c in cands):
            continue

        jr = judge.rate(plan, outcome.plan, sample, meter=meter)
        wall += jr.usage.latency_s
        cost_new = plan_cost_of(outcome.plan)
        ok = jr.rating >= cfg.epsilon and cost_new <= parent.cost
        cand = Candidate(outcome.plan, cost_new, jr.rating, pick,
                         outcome.rewrite.rule, accepted=ok,
                         judge_detail=jr.detail,
                         rewrite_correct=outcome.rewrite.correct)
        cands.append(cand)
        if ok:
            accepted.append(len(cands) - 1)

    best_i = min(accepted, key=lambda i: cands[i].cost)
    return OptResult(best=cands[best_i].plan, best_cost=cands[best_i].cost,
                     initial_cost=c0, candidates=cands, meter=meter,
                     opt_wall_s=wall, n_iterations=cfg.n_iterations)


# ---------------------------------------------------------------------------
# App. D: beam-search comparison baseline
# ---------------------------------------------------------------------------

def optimize_beam(plan: plan_ir.LogicalPlan, table: Table,
                  backends: "Dict[str, bk.Backend] | rt.ExecutionContext",
                  rewriter=None,
                  cfg: LogicalOptConfig = LogicalOptConfig(),
                  beam_width: int = 2) -> OptResult:
    """Expands the `beam_width` lowest-cost plans every step (the App.-D
    baseline: ~2x the optimization cost at similar end-to-end quality)."""
    rng = random.Random(cfg.seed)
    rewriter = rewriter or rw.LLMSimRewriter()
    ctx = _cfg_context(backends, cfg)
    judge = judge_mod.Judge(ctx)
    n_sample = min(max(int(table.n_rows * cfg.sample_ratio), cfg.sample_min),
                   cfg.sample_max, table.n_rows)
    sample = table.sample(n_sample, seed=cfg.seed)

    meter = bk.UsageMeter()
    wall = 0.0

    model = ctx.cost_model or cost_model.DEFAULT_MODEL

    def plan_cost_of(p):
        return model.objective(model.plan_cost(
            p, table.n_rows, default_tier=ctx.default_tier,
            concurrency=ctx.concurrency, batch_size=ctx.batch_size,
            shards=ctx.shards))

    c0 = plan_cost_of(plan)
    cands: List[Candidate] = [Candidate(plan, c0, 1.0, None, "init")]
    accepted = [0]

    for _ in range(cfg.n_iterations):
        beam = sorted(accepted, key=lambda i: cands[i].cost)[:beam_width]
        for pick in beam:
            parent = cands[pick]
            outcome = rewriter.rewrite(parent.plan, rng)
            meter.record("rewriter", outcome.usage)
            wall += outcome.usage.latency_s
            if outcome.plan is None:
                continue
            if any(outcome.plan.signature() == c.plan.signature()
                   for c in cands):
                continue
            jr = judge.rate(plan, outcome.plan, sample, meter=meter)
            wall += jr.usage.latency_s
            cost_new = plan_cost_of(outcome.plan)
            ok = jr.rating >= cfg.epsilon and cost_new <= parent.cost
            cand = Candidate(outcome.plan, cost_new, jr.rating, pick,
                             outcome.rewrite.rule, accepted=ok,
                             judge_detail=jr.detail,
                             rewrite_correct=outcome.rewrite.correct)
            cands.append(cand)
            if ok:
                accepted.append(len(cands) - 1)

    best_i = min(accepted, key=lambda i: cands[i].cost)
    return OptResult(best=cands[best_i].plan, best_cost=cands[best_i].cost,
                     initial_cost=c0, candidates=cands, meter=meter,
                     opt_wall_s=wall, n_iterations=cfg.n_iterations)
