"""Transformation rules for the logical optimizer (paper §3.2).

Three rules, exactly the paper's set, each expressed both as natural
language (the ``nl`` attribute — what the paper feeds its LLM rewriter) and
as a verified plan transformation:

  filter pushdown      move a filter that does not rely on results of
                       preceding operators to an earlier stage
  operator fusion      merge operators on the same field into one (predicates
                       conjoined; fused-filter selectivity 0.5/k)
  non-LLM replacement  swap an operator's NL instruction for an equivalent
                       compute function (repro.core.udf)

plus the filter re-ordering the paper's case study applies (Fig. 11a:
"randomly reorders two filter operators, as the optimizer has no knowledge
of their selectivities").

Every applicable (rule, site) pair yields a :class:`Rewrite` whose
``apply()`` returns the new plan. ``corrupt()`` produces a *semantically
wrong* variant of a rewrite — the controlled error source used to measure
LLM-as-a-judge reliability (paper Table 7): rewriters in `llm_sim` mode
emit corrupted rewrites at a configurable rate, and the benchmark scores
the judge's accept/reject decisions against the known `correct` flag.
"""
from __future__ import annotations

import dataclasses
import random
import re
from typing import Callable, List, Optional

from repro.core import plan as plan_ir
from repro.core import udf as udf_mod


@dataclasses.dataclass
class Rewrite:
    rule: str
    description: str
    apply: Callable[[], plan_ir.LogicalPlan]
    correct: bool = True      # ground truth (hidden from the judge)


# ---------------------------------------------------------------------------
# Rule: filter pushdown
# ---------------------------------------------------------------------------

NL_FILTER_PUSHDOWN = (
    "Move a filter operator that does not rely on results of preceding "
    "operators to an earlier stage in the plan.")


def filter_pushdown_candidates(plan: plan_ir.LogicalPlan) -> List[Rewrite]:
    out = []
    for i, op in enumerate(plan.ops):
        if op.kind != plan_ir.FILTER:
            continue
        earliest = plan.movable_before(i)
        if earliest >= i:
            continue
        # only worthwhile if it jumps at least one LLM op (prunes rows early)
        crossed = plan.ops[earliest:i]
        if not any(o.is_llm for o in crossed):
            continue
        out.append(Rewrite(
            "filter_pushdown",
            f"push filter@{i} ({op.instruction!r}) to position {earliest}",
            lambda i=i, earliest=earliest: plan.move_op(i, earliest)))
    return out


# ---------------------------------------------------------------------------
# Rule: filter re-ordering (selectivity-blind random swap, Fig. 11a)
# ---------------------------------------------------------------------------

NL_FILTER_REORDER = (
    "Reorder two adjacent independent filter operators (their relative "
    "selectivities are unknown to the optimizer).")


def filter_reorder_candidates(plan: plan_ir.LogicalPlan) -> List[Rewrite]:
    out = []
    for i in range(len(plan.ops) - 1):
        a, b = plan.ops[i], plan.ops[i + 1]
        if (a.kind == plan_ir.FILTER and b.kind == plan_ir.FILTER
                and not plan.depends_on(i + 1, i)):
            out.append(Rewrite(
                "filter_reorder",
                f"swap filters @{i} and @{i + 1}",
                lambda i=i: plan.move_op(i + 1, i)))
    return out


# ---------------------------------------------------------------------------
# Rule: operator fusion
# ---------------------------------------------------------------------------

NL_OPERATOR_FUSION = (
    "Merge multiple operators applied to the same field into one operator, "
    "rewriting the predicate so semantics are preserved (e.g. two filters "
    "'higher than 8.5' and 'lower than 9' become one filter 'higher than "
    "8.5 and lower than 9').")


def _fuse_instructions(a: str, b: str) -> str:
    a = a.strip().rstrip(".")
    b = b.strip().rstrip(".")
    # drop a repeated subject for readability: "The rating is higher than
    # 8.5" + "The rating is lower than 9" -> "... higher than 8.5 and lower
    # than 9"
    m_a = re.match(r"(.*?\bis\b)\s+(.*)", a, re.I)
    m_b = re.match(r"(.*?\bis\b)\s+(.*)", b, re.I)
    if m_a and m_b and m_a.group(1).lower() == m_b.group(1).lower():
        return f"{m_a.group(1)} {m_a.group(2)} and {m_b.group(2)}."
    return f"{a} and {b}."


def operator_fusion_candidates(plan: plan_ir.LogicalPlan) -> List[Rewrite]:
    out = []
    for i in range(len(plan.ops)):
        a = plan.ops[i]
        if a.kind != plan_ir.FILTER or not a.is_llm:
            continue
        for j in range(i + 1, len(plan.ops)):
            b = plan.ops[j]
            if plan.depends_on(j, i) and b.kind != plan_ir.FILTER:
                break
            if (b.kind == plan_ir.FILTER and b.is_llm
                    and b.input_column == a.input_column
                    # b must be free to slide up to i
                    and plan.movable_before(j) <= i + 1):
                fused = a.with_(
                    instruction=_fuse_instructions(a.instruction,
                                                   b.instruction),
                    fused_from=a.fused_from + b.fused_from,
                    selectivity=None)
                out.append(Rewrite(
                    "operator_fusion",
                    f"fuse filters @{i} + @{j} on column "
                    f"{a.input_column!r}",
                    lambda i=i, j=j, fused=fused: plan.fuse_ops(i, j, fused)))
    return out


# ---------------------------------------------------------------------------
# Rule: non-LLM replacement
# ---------------------------------------------------------------------------

NL_NON_LLM_REPLACEMENT = (
    "Replace an operator's natural-language instruction with an equivalent "
    "compute function (UDF) when the instruction can be interpreted as a "
    "deterministic computation, e.g. 'Score is higher than 8.5 and lower "
    "than 9' -> lambda x: 8.5 < parse_number(x) < 9.")


def non_llm_candidates(plan: plan_ir.LogicalPlan) -> List[Rewrite]:
    out = []
    for i, op in enumerate(plan.ops):
        if not op.is_llm:
            continue
        compiled = udf_mod.compile_udf(op)
        if compiled is None:
            continue
        out.append(Rewrite(
            "non_llm_replacement",
            f"replace LLM op@{i} with UDF {compiled.source!r}",
            lambda i=i, src=compiled.source:
                plan.replace_op(i, plan.ops[i].with_(udf=src))))
    return out


# ---------------------------------------------------------------------------
# Rule registry
# ---------------------------------------------------------------------------

RULES = {
    "filter_pushdown": (NL_FILTER_PUSHDOWN, filter_pushdown_candidates),
    "filter_reorder": (NL_FILTER_REORDER, filter_reorder_candidates),
    "operator_fusion": (NL_OPERATOR_FUSION, operator_fusion_candidates),
    "non_llm_replacement": (NL_NON_LLM_REPLACEMENT, non_llm_candidates),
}

# the subset the paper calls "semantic-aware" (Table 8 ablation)
SEMANTIC_RULES = ("non_llm_replacement",)
BASIC_RULES = ("filter_pushdown", "filter_reorder", "operator_fusion")


def all_candidates(plan: plan_ir.LogicalPlan,
                   rules: Optional[tuple] = None) -> List[Rewrite]:
    names = rules if rules is not None else tuple(RULES)
    out = []
    for name in names:
        _, fn = RULES[name]
        out.extend(fn(plan))
    return out


# ---------------------------------------------------------------------------
# Controlled corruption (for judge-reliability measurement)
# ---------------------------------------------------------------------------

def corrupt(rewrite: Rewrite, plan: plan_ir.LogicalPlan,
            rng: random.Random) -> Rewrite:
    """Return a semantically WRONG variant of `rewrite` — models the LLM
    rewriter hallucinating. Corruption modes mirror the paper's observed
    failures (Fig. 12b): off-by-constant UDF boundaries, dropped conjuncts,
    filters pushed past the map that produces their input."""
    def bad_apply(rewrite=rewrite):
        new = rewrite.apply()
        ops = list(new.ops)
        # pick an op to damage, preferring ones the rewrite touched
        idxs = [k for k, (o_new) in enumerate(ops)]
        rng.shuffle(idxs)
        for k in idxs:
            op = ops[k]
            if op.udf and re.search(r"\d", op.udf):
                # perturb the first numeric constant in the UDF (keeping
                # int-ness so e.g. list indices stay valid python)
                def bump(m):
                    delta = rng.choice((-1, 1))
                    if "." in m.group(0):
                        return str(float(m.group(0)) + delta)
                    return str(abs(int(m.group(0)) + delta))
                ops[k] = op.with_(udf=re.sub(r"\d+(?:\.\d+)?", bump,
                                             op.udf, count=1))
                return plan_ir.LogicalPlan(tuple(ops), new.source)
            if op.kind == plan_ir.FILTER and " and " in op.instruction:
                # drop a conjunct
                kept = op.instruction.split(" and ")[0].rstrip(".") + "."
                ops[k] = op.with_(instruction=kept)
                return plan_ir.LogicalPlan(tuple(ops), new.source)
            if op.kind == plan_ir.FILTER and op.is_llm:
                # negate the predicate
                ops[k] = op.with_(
                    instruction="It is NOT the case that: " + op.instruction)
                return plan_ir.LogicalPlan(tuple(ops), new.source)
        # fallback: drop the last op entirely
        return plan_ir.LogicalPlan(tuple(ops[:-1]) or new.ops, new.source)

    return Rewrite(rewrite.rule, rewrite.description + " [corrupted]",
                   bad_apply, correct=False)
