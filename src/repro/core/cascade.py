"""Embedding tier-0 semantic cascade (the first device-resident tier).

The cheapest tier in ``cost.DEFAULT_TIERS`` still answers one LLM call per
``batch_size`` rows. This module adds a tier *below* m1 — ``tier0-embed`` —
that scores a whole morsel in **one batched pass through the Pallas
similarity kernels**: every row embedding is compared against a predicate
anchor embedding (the operator instruction), and the cosine score routes
the row through calibrated confidence bands:

    score >= bands.hi   high-confidence PASS   (filter: keep; no LLM call)
    score <= bands.lo   high-confidence DROP   (filter: remove; no LLM call)
    otherwise           ESCALATE               (the uncertain band goes to
                                                the operator's LLM tier
                                                through the normal
                                                coalescer / sharder path)

This is the same shape real semantic-analytics systems converge on (vector
prefilters below LLM invocation; SEMA-style semantic operators, CAESURA's
cheapest-capable-model routing) — here it is a first-class backend:

* :class:`EmbeddingBackend` implements the ``backends.Backend`` protocol.
  Its ``run_values`` returns raw cosine *scores* (it is a scoring tier, not
  an answering tier), bills one ``tier0-embed`` call per invocation with a
  deterministic modeled latency in the per-tier totals and the **measured**
  kernel wall in ``UsageMeter.call_log`` — so the event scheduler places
  the device pass on the simulated timeline and Table-9 accounting sees the
  cascade.
* :class:`CascadeRouter` holds the backend plus per-operator
  :class:`CascadeBands` and emits the per-morsel pass/drop/escalate
  partition the executor folds around ``run_llm_op``.

Determinism: the embedding of a value and the band thresholds are pure
functions of (operator, value) fixed before execution starts, so the
partition — and therefore which rows reach the LLM tiers, in which morsel,
in which order — is identical across drivers (simulated/threads), shard
counts, and admission order. The three executor invariance guarantees hold
with the cascade enabled (test-enforced in ``tests/test_cascade.py``).

Band thresholds come either from the physical optimizer (calibrated
against the capability sample — see ``physical_optimizer`` +
``improvement.improvement_cascade``) or from ``default_bands`` for
serve-style blanket enablement (``launch/serve.py --cascade``).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional, Protocol, Sequence

import numpy as np

from repro.core import backends as bk
from repro.core import cost as cost_mod
from repro.core import plan as plan_ir
from repro.core import semhash

# numeric offsets for resolved RANK rows: pass-band rows sort above every
# escalated row, escalated rows (rescored by the LLM, normalized to (0,1))
# sort above every drop-band row — cosine in [-1, 1] cannot cross an offset
_RANK_PASS_OFFSET = 10.0
_RANK_DROP_OFFSET = -10.0


class Encoder(Protocol):
    """Embedding provider for the cascade: anchor = the predicate,
    values = the rows. Rows must come back L2-normalized."""

    def encode_anchor(self, op: plan_ir.Operator) -> np.ndarray:
        ...

    def encode_values(self, op: plan_ir.Operator,
                      values: Sequence[Any]) -> np.ndarray:
        ...


class HashingEncoder:
    """Default dependency-free encoder: the ``semhash`` n-gram hasher
    (the repo's Sentence-BERT stand-in). Real deployments would swap in a
    learned sentence encoder behind the same protocol."""

    def encode_anchor(self, op: plan_ir.Operator) -> np.ndarray:
        return semhash.embed_one(op.instruction)

    def encode_values(self, op: plan_ir.Operator,
                      values: Sequence[Any]) -> np.ndarray:
        return semhash.embed(list(values))


def _kernel_scores(vals: np.ndarray, anchor: np.ndarray) -> np.ndarray:
    """One batched device pass: rowwise cosine of every value embedding
    against the (broadcast) anchor through the Pallas kernel; pure-numpy
    fallback when jax is unavailable (missing-dep gate, not a perf path)."""
    try:
        from repro.kernels import ops as kops
        tiled = np.broadcast_to(anchor, vals.shape)
        return np.asarray(kops.rowwise_cosine(vals, tiled), np.float32)
    except ImportError:
        return np.asarray(vals @ anchor, np.float32)


@dataclasses.dataclass(frozen=True)
class CascadeBands:
    """Calibrated confidence bands. ``lo <= hi``; rows with
    ``lo < score < hi`` escalate. ``lo == hi`` means nothing escalates
    (boundary scores pass); ``lo=-2, hi=2`` escalates everything (the
    cascade becomes a no-op plus one scoring pass per morsel)."""
    lo: float
    hi: float

    def __post_init__(self):
        if self.lo > self.hi:
            raise ValueError(f"cascade bands lo {self.lo} > hi {self.hi}")


# blanket-enable default (serve --cascade without per-op calibration):
# conservative band — only strongly-anchored rows resolve on-device
DEFAULT_BANDS = CascadeBands(lo=-0.35, hi=0.35)


class EmbeddingBackend:
    """``tier0-embed``: the device-resident scoring backend.

    ``run_values`` returns the rows' cosine scores against the operator's
    anchor (floats — the :class:`CascadeRouter` thresholds them; this
    backend is never assigned as an operator's answering tier). One
    invocation = one batched kernel pass = one metered call:

    * per-tier totals bill a **modeled** latency
      (``EMBED_TIER.latency_call_s + rows * EMBED_ROW_S``) so meter totals
      stay byte-identical across drivers and shard counts;
    * ``call_log`` carries the **measured** kernel wall, so the simulated
      event timeline and threaded pools schedule the real device cost.
    """

    def __init__(self, encoder: Optional[Encoder] = None,
                 tier: Optional[cost_mod.TierSpec] = None):
        self.encoder = encoder if encoder is not None else HashingEncoder()
        self.tier = tier if tier is not None else cost_mod.EMBED_TIER
        self._anchors: Dict[tuple, np.ndarray] = {}
        self._alock = threading.Lock()

    def _anchor(self, op: plan_ir.Operator) -> np.ndarray:
        key = (op.kind, op.instruction, op.input_column)
        with self._alock:
            a = self._anchors.get(key)
        if a is None:
            a = np.asarray(self.encoder.encode_anchor(op), np.float32)
            with self._alock:
                self._anchors[key] = a
        return a

    def scores(self, op: plan_ir.Operator,
               values: Sequence[Any]) -> np.ndarray:
        """Unmetered scoring (calibration-time use)."""
        values = list(values)
        if not values:
            return np.zeros((0,), np.float32)
        vals = np.asarray(self.encoder.encode_values(op, values),
                          np.float32)
        return _kernel_scores(vals, self._anchor(op))

    def run_values(self, op: plan_ir.Operator, values: Sequence[Any],
                   meter: Optional[bk.UsageMeter] = None,
                   batch_size: int = 1) -> List[Any]:
        values = list(values)
        t0 = time.perf_counter()
        sims = self.scores(op, values)
        measured = time.perf_counter() - t0
        if meter is not None and values:
            tok_in = sum(cost_mod.text_tokens(v) for v in values)
            modeled = (self.tier.latency_call_s
                       + len(values) * cost_mod.EMBED_ROW_S)
            usage = bk.Usage(calls=1, tok_in=tok_in, tok_out=0.0,
                             usd=self.tier.usd(tok_in, 0.0),
                             latency_s=modeled)
            meter.record(self.tier.name, usage,
                         per_call_latency_s=[measured], op_kind=op.kind)
        return [float(s) for s in sims]


class CascadePartition:
    """One morsel's routing decision: ``resolved[i]`` holds the on-device
    answer for pass/drop rows (filter: bool; rank: offset composite score)
    and ``None`` for rows in ``escalate`` (indices into ``values``, in row
    order). ``merge`` folds the escalated rows' LLM outputs back into a
    full per-row output list shaped for ``runtime.apply_outputs``."""

    __slots__ = ("op", "resolved", "escalate", "n_pass", "n_drop", "finish")

    def __init__(self, op: plan_ir.Operator, resolved: List[Any],
                 escalate: List[int], n_pass: int, n_drop: int,
                 finish: float):
        self.op = op
        self.resolved = resolved
        self.escalate = escalate
        self.n_pass = n_pass
        self.n_drop = n_drop
        self.finish = finish

    def merge(self, esc_outs: Sequence[Any]) -> List[Any]:
        if len(esc_outs) != len(self.escalate):
            raise ValueError(
                f"cascade merge: {len(self.escalate)} escalated rows but "
                f"{len(esc_outs)} LLM outputs")
        full = list(self.resolved)
        if self.op.kind == plan_ir.RANK:
            # escalated rows keep their LLM-judged *ordering*, normalized
            # into (0, 1) so the middle block slots between the pass band
            # (offset +10 + cosine) and the drop band (offset -10 + cosine)
            from repro.core import runtime as rt
            sims = rt.rank_scores(list(esc_outs))
            order = sorted(range(len(sims)), key=lambda j: sims[j],
                           reverse=True)          # stable: ties keep row order
            k = len(order)
            for pos, j in enumerate(order):
                full[self.escalate[j]] = 1.0 - (pos + 1) / (k + 1)
            return full
        for j, i in enumerate(self.escalate):
            full[i] = esc_outs[j]
        return full


class CascadeRouter:
    """Routing layer between the executor's morsel stream and the LLM
    dispatch path. Holds one :class:`EmbeddingBackend` plus band
    thresholds: per-operator calibrated bands (``set_bands``; installed by
    the physical optimizer) with an optional ``default_bands`` fallback
    (blanket enablement). An operator cascades iff it is a non-UDF
    SEM_FILTER/RANK predicate and bands are available for it."""

    KINDS = (plan_ir.FILTER, plan_ir.RANK)

    def __init__(self, backend: Optional[EmbeddingBackend] = None,
                 default_bands: Optional[CascadeBands] = None):
        self.backend = backend if backend is not None else EmbeddingBackend()
        self.default_bands = default_bands
        self._bands: Dict[tuple, CascadeBands] = {}
        self._lock = threading.Lock()

    @staticmethod
    def _sig(op: plan_ir.Operator) -> tuple:
        return (op.kind, op.instruction, op.input_column)

    def set_bands(self, op: plan_ir.Operator, bands: CascadeBands) -> None:
        with self._lock:
            self._bands[self._sig(op)] = bands

    def bands_for(self, op: plan_ir.Operator) -> Optional[CascadeBands]:
        with self._lock:
            b = self._bands.get(self._sig(op))
        return b if b is not None else self.default_bands

    def active_for(self, op: plan_ir.Operator) -> bool:
        return (op.udf is None and op.kind in self.KINDS
                and self.bands_for(op) is not None)

    def partition(self, op: plan_ir.Operator, values: Sequence[Any],
                  disp, meter: bk.UsageMeter, *, ready: float = 0.0,
                  shard: int = 0,
                  key: Optional[tuple] = None) -> CascadePartition:
        """Score one morsel's rows (one ``tier0-embed`` call through the
        dispatcher: billed on the morsel's shard, placed on the event
        timeline) and band-route them. Deterministic given (op, values).

        Failure contract: exceptions propagate to the caller — the
        executor's ``cascade_partition`` catches them and *degrades*
        (escalates the whole morsel to the LLM tier, byte-identical to a
        no-cascade run) instead of failing the query; an active
        ``CallPolicy`` additionally retries the embed call below the
        dispatcher before the failure ever surfaces here."""
        bands = self.bands_for(op)
        values = list(values)
        # the device pass rides the dispatcher like any backend call —
        # batch_size=len(values) keeps it one kernel launch per morsel
        sims, finish = disp.run_llm(
            op, values, self.backend, self.backend.tier.name, meter,
            batch_size=max(1, len(values)), cache=None, ready_s=ready,
            shard=shard, key=key)
        resolved: List[Any] = [None] * len(values)
        escalate: List[int] = []
        n_pass = n_drop = 0
        is_rank = op.kind == plan_ir.RANK
        for i, s in enumerate(sims):
            if s >= bands.hi:
                resolved[i] = (_RANK_PASS_OFFSET + s) if is_rank else True
                n_pass += 1
            elif s <= bands.lo:
                resolved[i] = (_RANK_DROP_OFFSET + s) if is_rank else False
                n_drop += 1
            else:
                escalate.append(i)
        return CascadePartition(op, resolved, escalate, n_pass, n_drop,
                                finish)


def calibrate_bands(scores: Sequence[float], ref_outs: Sequence[Any],
                    kind: str, margin: float = 0.02
                    ) -> Optional[CascadeBands]:
    """Derive bands from a capability sample's scores + reference outputs
    (the operator's selected tier — the cascade's escalation target, so
    agreement with it is the right yardstick).

    FILTER: conservative separation — pass only above every sample
    negative, drop only below every sample positive (+/- margin), so the
    cascade disagrees with the reference on zero sample rows; overlapping
    classes widen the escalation band instead of guessing. RANK: the
    middle two quartiles of the score distribution escalate for LLM
    re-ordering; the tails keep their embedding order."""
    scores = [float(s) for s in scores]
    if not scores:
        return None
    if kind == plan_ir.RANK:
        lo = float(np.percentile(scores, 25.0))
        hi = float(np.percentile(scores, 75.0))
        return CascadeBands(lo=min(lo, hi), hi=max(lo, hi))
    from repro.core import runtime as rt
    mask = rt.bool_mask(list(ref_outs))
    pos = [s for s, m in zip(scores, mask) if m]
    neg = [s for s, m in zip(scores, mask) if not m]
    if pos and neg:
        hi = max(neg) + margin
        lo = min(pos) - margin
        if lo > hi:                  # separable sample: nothing uncertain
            mid = 0.5 * (lo + hi)
            lo = hi = mid
    elif neg:
        # no sample positive: never auto-pass; drop at/below the sample
        # negatives' ceiling, escalate anything stronger
        hi = 2.0
        lo = max(neg) + margin
        lo = min(lo, hi)
    elif pos:
        lo = -2.0
        hi = min(pos) - margin
    else:
        return None
    return CascadeBands(lo=lo, hi=hi)
