"""CostModel: the single calibrated estimation surface for both optimizers.

Everything that prices an operator — tier specs, per-op output-token
priors, the tokens-per-char rule, batch/cascade call-count math — lives on
one :class:`CostModel` object instead of a bag of module constants and
free functions (the old ``core.cost``, which now delegates here). One
model instance is threaded through ``ExecutionContext.cost_model`` to the
logical optimizer (candidate objective), the physical optimizer
(Algorithm-2 tier selection, including the tier-0 cascade pricing), the
judge (rating-call price), the query server, ``launch/serve.py`` and the
benchmarks — so a calibration learned anywhere is visible everywhere.

Two capabilities beyond the static price card:

* **Online calibration** — :meth:`observe` ingests a finalized
  ``UsageMeter``'s call log (each entry now carries its operator kind and
  per-call output tokens) and maintains, per (op kind, tier), the q-error
  ``max(pred/meas, meas/pred)`` of the model's latency prediction plus
  EWMA estimates of measured per-call latency and output tokens. The
  estimates feed back into :meth:`op_cost`/:meth:`plan_cost`, so the
  second query is priced with what the first one measured. ``observe``
  runs only at deterministic sync points — executor finalize and
  per-query server finalize, never mid-execution — and folds the window
  in *logical call-key order* (the same sort ``UsageMeter.merge`` uses),
  so calibration state is identical across drivers, shard counts, and
  admission orders. Per-meter cursors make repeated observation of the
  same meter idempotent.

* **Scheduler-aware cost** — :meth:`plan_cost` can replay the candidate
  plan's calls onto an :class:`runtime.EventScheduler` seeded with the
  current dispatcher pool occupancy (``PlanCost.makespan_s``), and
  :meth:`op_makespan` does the same for one operator, so the physical
  optimizer can select tiers on a weighted USD x makespan objective.
  ``latency_weight=0`` (the default) reproduces the pure-USD behaviour
  exactly: no makespan is computed and no penalty is applied.

The module-level :data:`DEFAULT_MODEL` backs the deprecated free
functions in ``core.cost``; it is **never calibrated implicitly** — only
a model explicitly placed on an ``ExecutionContext`` observes meters, so
library defaults stay byte-stable across runs and tests.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import weakref
from typing import Dict, List, Optional, Tuple

from repro.core import plan as plan_ir

TOKENS_PER_CHAR = 0.25   # ~4 chars/token


# ---------------------------------------------------------------------------
# Backend tiers (m1 < m2 < m3 < m*) — §4's four-model setting
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TierSpec:
    name: str
    capability: float            # P(correct) scale for the simulator
    usd_per_m_in: float
    usd_per_m_out: float
    latency_call_s: float        # per-call overhead (network + queue)
    latency_tok_s: float         # per output token
    arch: Optional[str] = None   # JAX model zoo id backing this tier

    def usd(self, tok_in: float, tok_out: float) -> float:
        return (tok_in * self.usd_per_m_in
                + tok_out * self.usd_per_m_out) / 1e6

    def latency(self, tok_out: float) -> float:
        return self.latency_call_s + tok_out * self.latency_tok_s


# price card mirrors OpenAI's GPT-4.1 family (paper §5.1.4); capabilities are
# the simulator's knobs calibrated so Table-2-style alignment stats reproduce
# (misaligned fraction ~0.15 on a hard map; see benchmarks/table2).
DEFAULT_TIERS: Dict[str, TierSpec] = {
    "m1": TierSpec("m1", 0.88, 0.10, 0.40, 0.35, 0.004, arch="qwen2-0.5b"),
    "m2": TierSpec("m2", 0.92, 0.15, 0.60, 0.45, 0.006,
                   arch="granite-moe-1b-a400m"),
    "m3": TierSpec("m3", 0.96, 0.40, 1.60, 0.60, 0.010, arch="minicpm3-4b"),
    "m*": TierSpec("m*", 0.99, 2.00, 8.00, 0.90, 0.022,
                   arch="codeqwen1.5-7b"),
}
TIER_ORDER = ("m1", "m2", "m3", "m*")

# tier-0 embedding pass (core.cascade): one batched Pallas kernel launch
# scores a whole morsel, so the per-row price is ~1000x below m1's and the
# "per-call" latency is a kernel launch, not a network round trip. Not part
# of TIER_ORDER — it cannot answer an operator alone; it only *routes*
# (cascade bands decide pass/drop, the uncertain band escalates to an LLM
# tier), so improvement-score tier selection never assigns it directly.
EMBED_TIER_NAME = "tier0-embed"
EMBED_ROW_S = 2e-6              # modeled per-row device time
EMBED_TIER = TierSpec(EMBED_TIER_NAME, 0.0, 0.0001, 0.0, 0.002, 0.0)

# output length model per operator kind (tokens per record)
OUT_TOKENS = {plan_ir.FILTER: 2.0, plan_ir.MAP: 24.0, plan_ir.REDUCE: 16.0,
              plan_ir.RANK: 6.0}

# fallback per-call output tokens for kinds outside OUT_TOKENS (e.g. the
# judge's rating call bills under op kind "judge")
_OUT_TOKENS_DEFAULT = 8.0

# plans with more calls than this are priced analytically (waves formula)
# instead of being replayed call-by-call through the event scheduler
_MAX_REPLAY_CALLS = 4096


# ---------------------------------------------------------------------------
# Cost records
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class OpCost:
    llm_calls: float = 0.0
    tok_in: float = 0.0
    tok_out: float = 0.0
    usd: float = 0.0
    latency_s: float = 0.0       # sequential latency of this op's calls
    rows_in: float = 0.0
    rows_out: float = 0.0


@dataclasses.dataclass
class PlanCost:
    per_op: list
    llm_calls: float = 0.0
    tok_in: float = 0.0
    tok_out: float = 0.0
    usd: float = 0.0
    latency_s: float = 0.0       # wall-clock under `concurrency`
    rows_processed: float = 0.0  # paper Fig. 10/13 metric
    # event-scheduler replay of the plan's calls (0.0 unless the model
    # computed it — latency_weight > 0, an occupancy seed, or makespan=True)
    makespan_s: float = 0.0

    @property
    def cost(self) -> float:
        """The scalar the logical optimizer minimizes (Alg. 1 line 9)."""
        return self.usd

    def describe(self) -> str:
        return (f"calls={self.llm_calls:.0f} tok_in={self.tok_in:.0f} "
                f"usd={self.usd:.4f} latency={self.latency_s:.1f}s "
                f"rows={self.rows_processed:.0f}")


def _qerror(pred: float, meas: float) -> float:
    """The classic cardinality-estimation metric, applied to latency/tokens:
    symmetric multiplicative error, >= 1.0, 1.0 = perfect."""
    p = max(float(pred), 1e-12)
    m = max(float(meas), 1e-12)
    return max(p / m, m / p)


@dataclasses.dataclass
class _CalEntry:
    """EWMA calibration state for one (op kind, tier) pair."""
    n: int = 0
    latency_s: float = 0.0       # EWMA measured per-call latency
    tok_out: float = 0.0         # EWMA measured per-call output tokens
    qerr_ewma: float = 0.0       # EWMA of prospective latency q-error
    qerr_last: float = 0.0
    qerr_max: float = 0.0


@dataclasses.dataclass
class _AdmissionCal:
    """Whole-plan makespan calibration for the admission controller:
    an EWMA correction ratio (measured / raw-replay makespan) applied to
    future :meth:`CostModel.admission_estimate` calls, plus the q-error
    trajectory of the *corrected* predictions against measurements."""
    n: int = 0
    ratio: float = 1.0           # EWMA of measured / raw-replay makespan
    qerr_ewma: float = 0.0       # q-error of corrected pred vs measured
    qerr_last: float = 0.0
    qerr_max: float = 0.0


# ---------------------------------------------------------------------------
# The model
# ---------------------------------------------------------------------------

class CostModel:
    """One calibrated estimation surface (see module docstring).

    ``latency_weight`` steers the cost x makespan trade: 0 = pure USD
    (byte-identical to the pre-CostModel behaviour; test-enforced),
    > 0 mixes a normalized makespan term into the physical optimizer's
    upgrade margin and a USD-equivalent makespan term into the logical
    optimizer's objective (``usd_per_second`` is the exchange rate).
    ``ewma_alpha`` is the calibration smoothing factor; the first
    observation snaps the estimate to the measurement so one run is
    enough to converge on a stationary backend."""

    def __init__(self, tiers: Optional[Dict[str, TierSpec]] = None,
                 out_tokens: Optional[Dict[str, float]] = None,
                 tokens_per_char: float = TOKENS_PER_CHAR,
                 embed_tier: TierSpec = EMBED_TIER,
                 embed_row_s: float = EMBED_ROW_S,
                 latency_weight: float = 0.0,
                 usd_per_second: float = 0.001,
                 ewma_alpha: float = 0.5):
        self.tiers = dict(tiers or DEFAULT_TIERS)
        self.out_tokens = dict(out_tokens or OUT_TOKENS)
        self.tokens_per_char = float(tokens_per_char)
        self.embed_tier = embed_tier
        self.embed_row_s = float(embed_row_s)
        self.latency_weight = float(latency_weight)
        self.usd_per_second = float(usd_per_second)
        self.ewma_alpha = float(ewma_alpha)
        self._cal: Dict[Tuple[str, str], _CalEntry] = {}
        # whole-plan makespan calibration for admission control — kept
        # OUT of the per-(op, tier) state and out of calibration_state():
        # admission feedback only exists on serving paths, and the
        # invariance suites byte-compare calibration_state between served
        # and solo runs
        self._adm = _AdmissionCal()
        # meter -> consumed call_log length; weak keys so a long-lived
        # model does not pin every per-query meter it ever observed
        self._cursors = weakref.WeakKeyDictionary()
        self._lock = threading.Lock()

    # -- token model -----------------------------------------------------
    def text_tokens(self, text) -> float:
        """The single source of truth for the ~4-chars-per-token rule."""
        return max(1.0, len(str(text)) * self.tokens_per_char)

    def judge_tokens(self, n_rows: int) -> float:
        """Prompt-length model of one judge rating call (both plans'
        rendered sample outputs)."""
        return 200.0 + 40.0 * float(n_rows)

    def tier_list(self, tiers: Optional[Dict[str, TierSpec]] = None
                  ) -> List[TierSpec]:
        t = tiers or self.tiers
        return [t[k] for k in TIER_ORDER if k in t]

    # -- calibrated priors ----------------------------------------------
    def _prior_tok_out(self, kind: str) -> float:
        return self.out_tokens.get(kind, _OUT_TOKENS_DEFAULT)

    def _prior_call_latency(self, kind: str, tier_name: str) -> float:
        spec = self.tiers.get(tier_name)
        if spec is None:
            if tier_name == self.embed_tier.name:
                return self.embed_tier.latency_call_s
            return 0.0
        return spec.latency(self._prior_tok_out(kind))

    def predicted_call_latency(self, kind: str, tier_name: str) -> float:
        """Per-call latency the model currently predicts for (kind, tier):
        the calibrated EWMA when observed, the price-card prior otherwise."""
        with self._lock:
            e = self._cal.get((kind, tier_name))
            if e is not None and e.n > 0:
                return e.latency_s
        return self._prior_call_latency(kind, tier_name)

    def predicted_tok_out(self, kind: str, tier_name: str) -> float:
        with self._lock:
            e = self._cal.get((kind, tier_name))
            if e is not None and e.n > 0:
                return e.tok_out
        return self._prior_tok_out(kind)

    # -- operator / plan estimation --------------------------------------
    def op_cost(self, op: plan_ir.Operator, rows_in: float, tier: TierSpec,
                avg_value_tokens: float = 60.0,
                concurrency: int = 1, batch_size: int = 1,
                cascade_escalate: Optional[float] = None) -> OpCost:
        """Cost of one operator over ``rows_in`` records.

        LLM ops: ``ceil(rows / batch_size)`` calls — the executor's batch
        coalescer packs surviving rows across morsel boundaries, so the
        model prices whole-table batching, not per-morsel ragged ceilings.
        Batched records share the instruction prompt and the call's output
        budget. (Reduce: hierarchical tree over batches of ~32 values per
        call.) UDF ops: zero LLM cost, negligible latency.

        ``cascade_escalate`` prices a tier-0 embedding cascade on this
        operator (``core.cascade``): one batched kernel pass scores every
        row (EMBED_TIER prices + a launch latency), and only the escalated
        fraction reaches the LLM tier — ``ceil(rows * frac / batch)``
        calls instead of ``ceil(rows / batch)``.

        Output-token and latency estimates use the calibrated per-(kind,
        tier) EWMAs when :meth:`observe` has seen measurements; otherwise
        the static priors — so an uncalibrated model reproduces the old
        free-function numbers exactly."""
        rows_out = rows_in * op.selectivity if op.kind == plan_ir.FILTER \
            else (1.0 if op.kind == plan_ir.REDUCE else rows_in)
        c = OpCost(rows_in=rows_in, rows_out=rows_out)
        if not op.is_llm:
            c.latency_s = rows_in * 2e-6
            return c
        ins_tok = self.text_tokens(op.instruction)
        out_per_call = self.predicted_tok_out(op.kind, tier.name)
        if op.kind == plan_ir.REDUCE:
            batch = 32.0
            calls = 0.0
            level = rows_in
            while level > 1.0:
                level = math.ceil(level / batch)
                calls += level
            calls = max(calls, 1.0)
            c.llm_calls = calls
            c.tok_in = calls * (ins_tok + batch * avg_value_tokens * 0.5)
            c.tok_out = calls * out_per_call
        else:
            b = max(1, int(batch_size))
            llm_rows = rows_in
            if cascade_escalate is not None:
                llm_rows = rows_in * min(max(cascade_escalate, 0.0), 1.0)
            calls = math.ceil(llm_rows / b) if llm_rows > 0 else 0.0
            c.llm_calls = float(calls)
            c.tok_in = calls * ins_tok + llm_rows * avg_value_tokens
            c.tok_out = calls * out_per_call
        c.usd = tier.usd(c.tok_in, c.tok_out)
        c.latency_s = c.llm_calls * self._call_latency(op.kind, tier, c)
        if cascade_escalate is not None and op.kind != plan_ir.REDUCE:
            # the device pass itself: every row is embedded and scored in
            # one batched kernel launch, billed under the tier-0 price card
            c.usd += self.embed_tier.usd(rows_in * avg_value_tokens, 0.0)
            c.latency_s += (self.embed_tier.latency_call_s
                            + rows_in * self.embed_row_s)
        return c

    def _call_latency(self, kind: str, tier: TierSpec, c: OpCost) -> float:
        with self._lock:
            e = self._cal.get((kind, tier.name))
            if e is not None and e.n > 0:
                return e.latency_s
        per_call_out = c.tok_out / max(c.llm_calls, 1.0)
        return tier.latency(per_call_out)

    def plan_cost(self, plan: plan_ir.LogicalPlan, n_rows: int,
                  tiers: Optional[Dict[str, TierSpec]] = None,
                  default_tier: str = "m*",
                  avg_value_tokens: float = 60.0,
                  concurrency: int = 16, batch_size: int = 1,
                  shards: int = 1,
                  cascade: Optional[Dict[int, float]] = None,
                  occupancy: Optional[Dict[str, List[float]]] = None,
                  makespan: Optional[bool] = None) -> PlanCost:
        """Estimate a full plan: record counts flow through selectivities.

        ``concurrency`` is one shard worker's replica width; ``shards``
        multiplies it (morsel-parallel sharded execution runs a
        pool-per-(shard, tier), so un-quota'd effective width is
        ``concurrency * shards`` — matching ``ShardedDispatcher``).

        ``cascade`` maps op index -> expected escalation fraction for
        operators running behind a tier-0 embedding cascade (see
        ``op_cost``); ``rows_processed`` then counts only the escalated
        (LLM-seen) rows — the Fig. 13 metric the cascade is built to
        shrink.

        ``makespan`` controls the event-scheduler replay that fills
        ``PlanCost.makespan_s``: ``None`` computes it iff the model's
        ``latency_weight > 0`` or an ``occupancy`` seed was given (so the
        default-weight path never pays for it), ``True``/``False`` force
        it. ``occupancy`` is ``Dispatcher.occupancy()`` — per-tier lists
        of busy-until offsets the replay pre-loads, so the estimate sees
        the pools as the scheduler currently does."""
        tiers = tiers or self.tiers
        rows = float(n_rows)
        total = PlanCost(per_op=[])
        width = max(1, int(concurrency)) * max(1, int(shards))
        for k, op in enumerate(plan.ops):
            tier = tiers[op.tier or default_tier]
            esc = None if cascade is None else cascade.get(k)
            c = self.op_cost(op, rows, tier, avg_value_tokens,
                             batch_size=batch_size, cascade_escalate=esc)
            total.per_op.append(c)
            total.llm_calls += c.llm_calls
            total.tok_in += c.tok_in
            total.tok_out += c.tok_out
            total.usd += c.usd
            # ops execute in sequence; each op's calls run `width`-wide
            total.latency_s += c.latency_s / width
            if op.is_llm:
                total.rows_processed += c.rows_in if esc is None \
                    else c.rows_in * min(max(esc, 0.0), 1.0)
            rows = c.rows_out
        want_makespan = (self.latency_weight > 0 or occupancy is not None) \
            if makespan is None else bool(makespan)
        if want_makespan:
            total.makespan_s = self._replay(
                plan, total.per_op, tiers, default_tier,
                concurrency=concurrency, shards=shards, occupancy=occupancy)
        return total

    def objective(self, pc: PlanCost) -> float:
        """The scalar a cost-aware optimizer minimizes: pure USD at
        ``latency_weight=0`` (exactly the old ``PlanCost.cost``), else
        USD plus a USD-equivalent makespan term."""
        if self.latency_weight <= 0:
            return pc.usd
        return pc.usd + (self.latency_weight * self.usd_per_second
                         * pc.makespan_s)

    # -- event-scheduler replay ------------------------------------------
    def _replay(self, plan, per_op, tiers, default_tier, *,
                concurrency: int, shards: int,
                occupancy: Optional[Dict[str, List[float]]],
                per_tier: Optional[Dict[str, int]] = None,
                mode: str = "async") -> float:
        # lazy import: runtime builds on backends -> cost -> this module,
        # so the dependency must not exist at import time
        from repro.core import runtime as rt
        sched = rt.EventScheduler(
            concurrency=max(1, int(concurrency)) * max(1, int(shards)),
            per_tier=per_tier, mode=mode)
        sched.seed_occupancy(occupancy)
        ready = 0.0
        for op, c in zip(plan.ops, per_op):
            if not op.is_llm:
                if c.latency_s > 0:
                    ready = sched.submit(rt.HOST_TIER, c.latency_s, ready)
                continue
            tname = op.tier or default_tier
            calls = int(round(c.llm_calls))
            if calls <= 0:
                continue
            per_call = c.latency_s / calls
            if calls > _MAX_REPLAY_CALLS:
                # analytic waves fallback: occupy one long slab instead of
                # replaying every call (keeps huge-table estimates cheap)
                waves = -(-calls // sched.workers(tname))
                ready = sched.submit(tname, waves * per_call, ready)
                continue
            finish = ready
            for _ in range(calls):
                finish = max(finish, sched.submit(tname, per_call, ready))
            ready = finish   # the next operator consumes this one's output
        return sched.makespan

    def op_makespan(self, op: plan_ir.Operator, rows_in: float,
                    tier_name: str, *, batch_size: int = 1,
                    concurrency: int = 16, shards: int = 1,
                    per_tier: Optional[Dict[str, int]] = None,
                    occupancy: Optional[Dict[str, List[float]]] = None,
                    avg_value_tokens: float = 60.0) -> float:
        """Makespan estimate of running ``op`` alone on ``tier_name``
        under the given pool occupancy — the physical optimizer's
        per-candidate latency axis."""
        from repro.core import runtime as rt
        spec = self.tiers[tier_name]
        c = self.op_cost(op, rows_in, spec, avg_value_tokens,
                         batch_size=batch_size)
        sched = rt.EventScheduler(
            concurrency=max(1, int(concurrency)) * max(1, int(shards)),
            per_tier=per_tier)
        sched.seed_occupancy(occupancy)
        calls = int(round(c.llm_calls))
        if calls <= 0:
            return sched.makespan
        per_call = c.latency_s / calls
        if calls > _MAX_REPLAY_CALLS:
            waves = -(-calls // sched.workers(tier_name))
            sched.submit(tier_name, waves * per_call, 0.0)
        else:
            for _ in range(calls):
                sched.submit(tier_name, per_call, 0.0)
        return sched.makespan

    # -- admission control (QueryServer digital twin) --------------------
    def admission_estimate(self, plan: plan_ir.LogicalPlan, n_rows: int, *,
                           occupancy: Optional[Dict[str, List[float]]] = None,
                           default_tier: str = "m*",
                           concurrency: int = 16, batch_size: int = 1,
                           shards: int = 1,
                           avg_value_tokens: float = 60.0) -> float:
        """Predicted makespan (seconds) of running ``plan`` over
        ``n_rows`` rows under the *current* serving load — the admission
        controller's gate. The candidate's calls are replayed onto an
        ``EventScheduler`` seeded with ``occupancy`` (the live
        ``Dispatcher.occupancy()`` snapshot: the simulated driver as a
        free digital twin of the fleet), then scaled by the EWMA
        correction ratio :meth:`observe_makespan` has learned from
        predicted-vs-actual feedback. Per-call latencies inside the
        replay already use the per-(op, tier) calibrated EWMAs, so both
        calibration loops compound."""
        pc = self.plan_cost(plan, n_rows, default_tier=default_tier,
                            avg_value_tokens=avg_value_tokens,
                            concurrency=concurrency, batch_size=batch_size,
                            shards=shards, occupancy=occupancy or {},
                            makespan=True)
        with self._lock:
            ratio = self._adm.ratio if self._adm.n > 0 else 1.0
        return pc.makespan_s * ratio

    def observe_makespan(self, predicted_s: float, measured_s: float
                         ) -> None:
        """Fold one completed query's predicted-vs-actual makespan into
        the admission calibration: the q-error of the prediction we
        *made* (post-correction) and an EWMA update of the correction
        ratio. With corrected = raw * r and k = measured / corrected, the
        ideal ratio is measured / raw = k * r — so the update needs only
        the corrected prediction, not the raw replay value."""
        pred = max(float(predicted_s), 1e-12)
        meas = max(float(measured_s), 1e-12)
        q = _qerror(pred, meas)
        a = self.ewma_alpha
        with self._lock:
            e = self._adm
            e.qerr_last = q
            e.qerr_max = max(e.qerr_max, q)
            e.qerr_ewma = q if e.n == 0 else a * q + (1.0 - a) * e.qerr_ewma
            ideal = (meas / pred) * e.ratio
            e.ratio = ideal if e.n == 0 else a * ideal + (1.0 - a) * e.ratio
            e.n += 1

    def admission_report(self) -> dict:
        """Admission-estimate accuracy snapshot (``--explain-cost``):
        how many makespan predictions have been checked against
        measurements, the learned correction ratio, and the q-error
        trajectory of the corrected predictions."""
        with self._lock:
            e = self._adm
            return {"observations": e.n, "ratio": e.ratio,
                    "qerr_ewma": e.qerr_ewma, "qerr_last": e.qerr_last,
                    "qerr_max": e.qerr_max, "ewma_alpha": self.ewma_alpha}

    # -- online calibration ----------------------------------------------
    def observe(self, meter) -> int:
        """Ingest a finalized meter's call log since this model's last
        cursor for it; returns how many calls were folded in.

        Callers invoke this only at sync points (executor finalize,
        per-query server finalize) where the log is complete for the unit
        of work — never mid-execution. The window is sorted by logical
        call key (``UsageMeter.merge`` semantics) before folding, so the
        EWMA/q-error state is independent of thread arrival order, the
        driver, and the shard count. Idempotent per meter: a second
        observe of the same meter ingests only entries recorded since.

        Fault-tolerance contract: only calls that *produced an answer*
        calibrate. A retried call's successful attempt carries its op
        kind and folds normally under the tier that served it — including
        a breaker/fallback substitution, which bills (and therefore
        calibrates) under the fallback tier's own name, keeping q-error
        state truthful about who actually answered. Failed attempts are
        billed untyped (``op_kind=None`` — e.g. ``testing.FlakyBackend``
        fault entries), so the ``info is None`` skip below excludes them:
        a storm of injected faults never corrupts the latency EWMAs."""
        with meter._lock:
            log = list(meter.call_log)
            keys = list(meter.call_keys)
            ops = list(getattr(meter, "call_ops", ()))
        start = self._cursors.get(meter, 0)
        if start >= len(log):
            return 0
        window = []
        for pos in range(start, len(log)):
            tier_name, lat = log[pos]
            info = ops[pos] if pos < len(ops) else None
            if info is None:
                continue            # untyped call (e.g. rewriter usage)
            kind, tok_out = info
            k = keys[pos] if pos < len(keys) else None
            sort_key = (0, k) if k is not None else (1, (pos,))
            window.append((sort_key, tier_name, kind,
                           float(lat), float(tok_out)))
        try:
            window.sort(key=lambda e: e[0])
        except TypeError:
            # un-comparable key mixture: keep meter position order (still
            # deterministic for single-threaded meters)
            window.sort(key=lambda e: e[0][0])
        a = self.ewma_alpha
        with self._lock:
            for _, tier_name, kind, lat, tok_out in window:
                e = self._cal.setdefault((kind, tier_name), _CalEntry())
                pred = e.latency_s if e.n > 0 \
                    else self._prior_call_latency(kind, tier_name)
                q = _qerror(pred, lat)
                e.qerr_last = q
                e.qerr_max = max(e.qerr_max, q)
                e.qerr_ewma = q if e.n == 0 \
                    else a * q + (1.0 - a) * e.qerr_ewma
                if e.n == 0:
                    # snap-to-first: one observation replaces the prior,
                    # so a single run converges on a stationary backend
                    e.latency_s, e.tok_out = lat, tok_out
                else:
                    e.latency_s = a * lat + (1.0 - a) * e.latency_s
                    e.tok_out = a * tok_out + (1.0 - a) * e.tok_out
                e.n += 1
        self._cursors[meter] = len(log)
        return len(window)

    def qerror_report(self) -> List[dict]:
        """Per-(op kind, tier) calibration rows, sorted by (kind, tier):
        current vs prior prediction, measured EWMAs, and the q-errors of
        both against the measurements. ``qerror`` is what the calibrated
        model is off by *now*; ``prior_qerror`` is what the uncalibrated
        price card would be off by — the gap is what :meth:`observe`
        bought."""
        with self._lock:
            items = sorted(self._cal.items())
            rows = []
            for (kind, tier_name), e in items:
                prior_lat = self._prior_call_latency(kind, tier_name)
                prior_out = self._prior_tok_out(kind)
                pred_lat = e.latency_s if e.n > 0 else prior_lat
                pred_out = e.tok_out if e.n > 0 else prior_out
                rows.append({
                    "op": kind, "tier": tier_name, "calls": e.n,
                    "meas_latency_s": e.latency_s,
                    "pred_latency_s": pred_lat,
                    "prior_latency_s": prior_lat,
                    "meas_tok_out": e.tok_out,
                    "pred_tok_out": pred_out,
                    "prior_tok_out": prior_out,
                    "qerror": _qerror(pred_lat, e.latency_s),
                    "prior_qerror": _qerror(prior_lat, e.latency_s),
                    "tok_qerror": _qerror(pred_out, e.tok_out),
                    "qerr_ewma": e.qerr_ewma,
                    "qerr_last": e.qerr_last,
                    "qerr_max": e.qerr_max,
                    "ewma_alpha": self.ewma_alpha,
                })
        return rows

    def calibration_state(self) -> Dict[Tuple[str, str], tuple]:
        """Canonical snapshot of the EWMA state — byte-comparable across
        runs (the determinism/invariance tests diff exactly this)."""
        with self._lock:
            return {k: (e.n, round(e.latency_s, 12), round(e.tok_out, 12),
                        round(e.qerr_ewma, 12))
                    for k, e in sorted(self._cal.items())}

    def reset_calibration(self) -> None:
        with self._lock:
            self._cal.clear()
            self._adm = _AdmissionCal()
            self._cursors = weakref.WeakKeyDictionary()


# ---------------------------------------------------------------------------
# Hardware-grounded cost (beyond-paper axis)
# ---------------------------------------------------------------------------

def chip_seconds(tok_in: float, tok_out: float, active_params: float,
                 mfu: float = 0.4, peak_flops: float = 197e12) -> float:
    """Approximate chip-seconds to serve the tokens on a TPU v5e chip:
    prefill 2*N*T_in + decode 2*N*T_out FLOPs at `mfu` utilization."""
    flops = 2.0 * active_params * (tok_in + tok_out)
    return flops / (mfu * peak_flops)


# the default instance behind core.cost's deprecated free functions —
# never observed/calibrated implicitly (see module docstring)
DEFAULT_MODEL = CostModel()
