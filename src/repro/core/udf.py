"""NL -> UDF semantic parser for the *non-LLM replacement* rule (paper §3.2).

The paper's logical optimizer asks an LLM to interpret an operator's natural
language instruction as an equivalent compute function, e.g.

    "Score is higher than 8.5 and lower than 9"  ->  lambda x: 8.5 < x < 9
    "whether the movie has ever won 2 Oscars"    ->
        lambda x: 'Oscar' in x and int(x.split('Oscar')[0].strip()) == 2

This module is the deterministic analogue: a pattern-grammar compiler from
instruction text to python source + callable. It intentionally covers the
same instruction families as the paper's workloads (App. F) — numeric
comparisons, substring/entity predicates, award counts, money extraction,
count/sum/avg/min/max/mode reductions — and *intentionally keeps the paper's
failure mode*: compiled UDFs assume a value format, and rows that deviate
make the UDF wrong (Fig. 12b). That behaviour is exercised by
benchmarks/table5_quality.py.

Compiled sources use only the names in ``_SAFE_GLOBALS`` and are evaluated
with empty builtins, so a UDF can never touch the filesystem or network.
"""
from __future__ import annotations

import dataclasses
import math
import re
import statistics
from typing import Callable, List, Optional

from repro.core import plan as plan_ir

_NUM = r"[-+]?\d+(?:[\.,]\d+)?"


def parse_number(x) -> Optional[float]:
    """First number in a value; handles '8.5', '92%', 'N250m', '430 Million'."""
    if isinstance(x, (int, float)):
        return float(x)
    s = str(x)
    m = re.search(_NUM, s.replace(",", ""))
    if not m:
        return None
    v = float(m.group(0))
    tail = s[m.end():m.end() + 12].lower()
    if re.match(r"\s*(m\b|m[^a-z]|million)", tail):
        v *= 1e6
    elif re.match(r"\s*(b\b|billion)", tail):
        v *= 1e9
    elif re.match(r"\s*(k\b|thousand)", tail):
        v *= 1e3
    return v


def parse_money(x) -> Optional[float]:
    return parse_number(x)


_SAFE_GLOBALS = {
    "__builtins__": {},
    "len": len, "sum": sum, "min": min, "max": max, "abs": abs,
    "float": float, "int": int, "str": str, "round": round,
    "sorted": sorted, "any": any, "all": all,
    "re": re, "math": math, "statistics": statistics,
    "parse_number": parse_number, "parse_money": parse_money,
}


@dataclasses.dataclass
class CompiledUDF:
    source: str              # python lambda source (shown in case studies)
    fn: Callable             # filter/map: per-value; reduce: List -> scalar
    note: str = ""

    def __call__(self, *a):
        return self.fn(*a)


def _make(source: str, note: str = "") -> CompiledUDF:
    fn = eval(source, dict(_SAFE_GLOBALS))  # noqa: S307 — sandboxed globals
    return CompiledUDF(source=source, fn=fn, note=note)


# ---------------------------------------------------------------------------
# Filter predicates
# ---------------------------------------------------------------------------

_RANGE_RE = re.compile(
    r"(?:higher|greater|more|larger)\s+than\s+(" + _NUM + r").*?"
    r"(?:lower|less|smaller)\s+than\s+(" + _NUM + r")", re.I | re.S)
_GT_RE = re.compile(
    r"(?:higher|greater|more|larger)\s+than\s+(" + _NUM + r")", re.I)
_LT_RE = re.compile(
    r"(?:lower|less|smaller|fewer)\s+than\s+(" + _NUM + r")", re.I)
_WON_RE = re.compile(
    r"won\s+(?:more\s+than\s+)?(\d+)\s+Oscars?", re.I)
_EQ_NUM_RE = re.compile(r"(?:is\s+exactly|equals?)\s+(" + _NUM + r")", re.I)
_OR_VALUES_RE = re.compile(r"has\s+(\d+)\s+or\s+(\d+)\s+(\w+)", re.I)
# quoted literal or a capitalized multiword entity after a linking verb
_ENTITY_RE = re.compile(
    r"(?:directed\s+by|located\s+in|belongs?\s+to|is\s+about|stars?|"
    r"support[s]?|published\s+by|is\s+a|there\s+a|is\s+an?|in)\s+"
    r"((?:[A-Z][\w\.\-']*(?:[ ,]\s*)?)+|\"[^\"]+\"|'[^']+')", 0)
_QUOTED_RE = re.compile(r"[\"']([^\"']+)[\"']")


def compile_filter(instruction: str) -> Optional[CompiledUDF]:
    ins = instruction.strip().rstrip(".?")
    m = _RANGE_RE.search(ins)
    if m:
        lo, hi = m.group(1), m.group(2)
        return _make(
            f"lambda x: (parse_number(x) is not None) and "
            f"{lo} < parse_number(x) < {hi}", "numeric range")
    m = _WON_RE.search(ins)
    if m:
        n = int(m.group(1))
        op = ">" if re.search(r"more\s+than", ins, re.I) else "=="
        # the paper's own split-based parse (Fig. 11) — format-fragile on
        # purpose: rows like "Nominated for 2 Oscars" defeat it.
        return _make(
            f"lambda x: ('Oscar' in str(x)) and "
            f"(parse_number(str(x).split('Oscar')[0])) is not None and "
            f"int(parse_number(str(x).split('Oscar')[0])) {op} {n}",
            "award count")
    m = _OR_VALUES_RE.search(ins)
    if m:
        a, b = int(m.group(1)), int(m.group(2))
        return _make(
            f"lambda x: (parse_number(x) is not None) and "
            f"int(parse_number(x)) in ({a}, {b})", "value-set")
    m = _GT_RE.search(ins)
    if m:
        return _make(
            f"lambda x: (parse_number(x) is not None) and "
            f"parse_number(x) > {m.group(1)}", "numeric >")
    m = _LT_RE.search(ins)
    if m:
        return _make(
            f"lambda x: (parse_number(x) is not None) and "
            f"parse_number(x) < {m.group(1)}", "numeric <")
    m = _EQ_NUM_RE.search(ins)
    if m:
        return _make(
            f"lambda x: (parse_number(x) is not None) and "
            f"parse_number(x) == {m.group(1)}", "numeric ==")
    m = _QUOTED_RE.search(ins) or _ENTITY_RE.search(ins)
    if m:
        needle = m.group(1).strip().strip("\"'").strip(" ,")
        # skip degenerate 1-word lowercase captures and modality references
        if (len(needle) >= 3 and needle.lower() not in
                ("the", "it", "is", "an", "a")
                and not _mentions_modality(ins)):
            needle_esc = needle.replace("\\", "\\\\").replace("'", "\\'")
            return _make(
                f"lambda x: '{needle_esc}'.lower() in str(x).lower()",
                "substring/entity")
    return None


def _mentions_modality(ins: str) -> bool:
    """Instructions grounded in images/audio can never be a compute UDF."""
    return bool(re.search(
        r"picture|image|poster|photo|observed|audio|sound|style", ins, re.I))


# ---------------------------------------------------------------------------
# Map transformations
# ---------------------------------------------------------------------------

_EXTRACT_NUM_RE = re.compile(r"extract\s+the\s+[\w\s]*?(price|rating|score|"
                             r"number|count|year)", re.I)
_CONVERT_RE = re.compile(
    r"convert\s+the\s+price\s+in\s+(\w+)\s+into\s+(?:the\s+price\s+in\s+)?(\w+)",
    re.I)

_FX = {("idr", "usd"): 6.5e-5, ("usd", "idr"): 15384.0,
       ("ngn", "usd"): 6.7e-4}


def compile_map(instruction: str) -> Optional[CompiledUDF]:
    ins = instruction.strip().rstrip(".?")
    m = _CONVERT_RE.search(ins)
    if m:
        rate = _FX.get((m.group(1).lower(), m.group(2).lower()))
        if rate:
            return _make(
                f"lambda x: (parse_money(x) * {rate}) "
                f"if parse_money(x) is not None else None", "fx convert")
    if _EXTRACT_NUM_RE.search(ins) and not _mentions_modality(ins):
        return _make(
            "lambda x: parse_money(x)", "numeric extraction")
    return None


# ---------------------------------------------------------------------------
# Reduce aggregations (List -> scalar)
# ---------------------------------------------------------------------------

def compile_reduce(instruction: str) -> Optional[CompiledUDF]:
    ins = instruction.lower()
    if re.search(r"count\s+the\s+number|how\s+many", ins):
        return _make("lambda xs: len(xs)", "count")
    nums = ("lambda xs: [parse_number(x) for x in xs if "
            "parse_number(x) is not None]")
    if re.search(r"average|mean", ins):
        return _make(
            f"lambda xs: (lambda v: sum(v) / len(v) if v else None)"
            f"(({nums})(xs))", "average")
    if re.search(r"total|sum\b", ins):
        return _make(
            f"lambda xs: (lambda v: sum(v) if v else None)(({nums})(xs))",
            "sum")
    if re.search(r"max|highest|largest", ins):
        return _make(
            f"lambda xs: (lambda v: max(v) if v else None)(({nums})(xs))",
            "max")
    if re.search(r"min|lowest|smallest|cheapest", ins):
        return _make(
            f"lambda xs: (lambda v: min(v) if v else None)(({nums})(xs))",
            "min")
    if re.search(r"appears\s+the\s+most|most\s+frequent|most\s+common", ins):
        return _make(
            "lambda xs: (statistics.mode([str(x) for x in xs]) "
            "if xs else None)", "mode")
    return None


def compile_udf(op: plan_ir.Operator) -> Optional[CompiledUDF]:
    """Compile an operator's instruction to a UDF, or None if no pattern of
    the grammar applies (the operator then stays LLM-executed)."""
    if op.kind == plan_ir.FILTER:
        return compile_filter(op.instruction)
    if op.kind == plan_ir.MAP:
        return compile_map(op.instruction)
    if op.kind == plan_ir.REDUCE:
        return compile_reduce(op.instruction)
    if op.kind == plan_ir.RANK:
        ins = op.instruction.lower()
        if re.search(r"(rank|order|sort).*(rating|price|score|number)", ins):
            desc = bool(re.search(r"descend|highest|best", ins))
            return _make(
                f"lambda xs: sorted(range(len(xs)), key=lambda i: "
                f"(parse_number(xs[i]) is None, parse_number(xs[i]) or 0), "
                f"reverse={desc})", "numeric rank")
    return None


def resolve_udf(op: plan_ir.Operator) -> Optional[CompiledUDF]:
    """Re-hydrate the callable for an operator whose ``udf`` source was set
    by the rewriter (sources round-trip through plan JSON)."""
    if op.udf is None:
        return None
    return CompiledUDF(source=op.udf, fn=eval(op.udf, dict(_SAFE_GLOBALS)))  # noqa: S307
