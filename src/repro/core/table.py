"""Multi-modal table abstraction (paper §2.1).

A :class:`Table` is a dict of named columns with per-column *modality* tags
(numeric | text | image | audio | date). Unstructured fields are stored as
text handles (file paths / URIs) exactly as the paper describes — Nirvana
"represents unstructured fields as text that store file paths pointing to
remote locations". Synthetic datasets (``repro.data``) attach the content
behind a handle via the ``blobs`` side store so semantic operators can
resolve it without a network.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Any, Dict, List, Optional, Sequence

MODALITIES = ("numeric", "text", "image", "audio", "date")


@dataclasses.dataclass
class Table:
    columns: Dict[str, List[Any]]
    modalities: Dict[str, str] = dataclasses.field(default_factory=dict)
    # handle -> content for unstructured fields (posters, estate photos, ...)
    blobs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    name: str = ""

    def __post_init__(self):
        lens = {len(v) for v in self.columns.values()}
        if len(lens) > 1:
            raise ValueError(f"ragged table: column lengths {lens}")
        for c in self.columns:
            self.modalities.setdefault(c, "text")

    # ------------------------------------------------------------------
    @property
    def n_rows(self) -> int:
        return len(next(iter(self.columns.values()))) if self.columns else 0

    @property
    def column_names(self) -> tuple:
        return tuple(self.columns)

    def column(self, name: str) -> List[Any]:
        return self.columns[name]

    def resolve(self, name: str) -> List[Any]:
        """Column values with blob handles dereferenced (multi-modal read)."""
        vals = self.columns[name]
        if self.modalities.get(name) in ("image", "audio"):
            return [self.blobs.get(v, v) for v in vals]
        return vals

    def row(self, i: int) -> dict:
        return {c: v[i] for c, v in self.columns.items()}

    # ------------------------------------------------------------------
    def select(self, mask: Sequence[bool]) -> "Table":
        idx = [i for i, m in enumerate(mask) if m]
        return self.take(idx)

    def take(self, idx: Sequence[int]) -> "Table":
        cols = {c: [v[i] for i in idx] for c, v in self.columns.items()}
        return Table(cols, dict(self.modalities), self.blobs, self.name)

    def with_column(self, name: str, values: List[Any],
                    modality: str = "text") -> "Table":
        if len(values) != self.n_rows:
            raise ValueError(
                f"column {name}: {len(values)} values vs {self.n_rows} rows")
        cols = dict(self.columns)
        cols[name] = list(values)
        mods = dict(self.modalities)
        mods[name] = modality
        return Table(cols, mods, self.blobs, self.name)

    def head(self, n: int) -> "Table":
        return self.take(range(min(n, self.n_rows)))

    @staticmethod
    def concat(parts: Sequence["Table"]) -> "Table":
        """Row-wise concatenation of like-schema tables (morsel merge).

        All parts must share column names; modalities/blobs/name are taken
        from the first part."""
        if not parts:
            raise ValueError("concat of zero tables")
        first = parts[0]
        cols = {c: [v for p in parts for v in p.columns[c]]
                for c in first.columns}
        return Table(cols, dict(first.modalities), first.blobs, first.name)

    def sample(self, n: int, seed: int = 0) -> "Table":
        """Deterministic row sample (optimizers validate on samples)."""
        if n >= self.n_rows:
            return self
        rng = random.Random(seed)
        idx = sorted(rng.sample(range(self.n_rows), n))
        return self.take(idx)

    def __repr__(self):
        return (f"Table({self.name!r}, rows={self.n_rows}, "
                f"cols={list(self.columns)})")
