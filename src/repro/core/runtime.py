"""Execution runtime: the single scheduling/dispatch surface shared by the
executor, the judge, and both optimizers.

Four pieces:

* :class:`EventScheduler` — a discrete-event makespan model. Every LLM call
  becomes a *job* ``(tier, duration, ready_time)``; each tier owns a pool of
  workers (paper: 16 coroutines) and a job starts on the earliest-free
  worker of its tier, no earlier than its ready time. The resulting
  makespan replaces the old per-operator "waves" formulas (the deleted
  ``executor._makespan`` / ``physical_optimizer._wall``): unlike waves, the
  event model fills ragged-wave idle slots, overlaps operators that run on
  different tiers, and honours per-tier concurrency caps. ``mode="sync"``
  collapses every tier onto one worker, reproducing the paper's Table-9
  sequential accounting.

* :class:`Dispatcher` — how operator work actually *runs*. Two drivers:

  - :class:`SimulatedDispatcher` (``driver="simulated"``): backend calls
    execute inline, one after another; their metered per-call latencies are
    replayed through an :class:`EventScheduler`, so ``wall_s`` is a
    deterministic *model* of overlapped execution (Table-9 accounting, and
    the mode every hand-checkable schedule test uses).
  - :class:`ThreadPoolDispatcher` (``driver="threads"``): backend calls run
    on per-tier **bounded worker pools** (pool caps are serving quotas —
    ``per_tier_concurrency`` wins over the default ``concurrency``), morsel
    chains advance on a separate chain pool, and morsels of independent
    operators genuinely overlap. ``wall_s`` is **measured** wall time.

  Results, call counts, and per-tier meter totals are identical across
  drivers: the :class:`OutputCache` is single-flight (a value computed by
  one in-flight morsel is awaited, not re-billed, by concurrent morsels)
  and ``UsageMeter`` is lock-protected. With ``batch_size > 1`` the
  :class:`BatchCoalescer` forms batches in *logical row order* (morsel
  index, then row position) regardless of thread arrival order, and
  cross-morsel duplicate values dedupe *before* batch formation — so the
  grouping of misses into batched calls is deterministic and identical
  across drivers (this closes PR 2's documented corner where duplicate
  values could land in different batched calls per driver).

* :class:`BatchCoalescer` — cross-morsel batch packing. With
  ``batch_size > 1`` a selective upstream filter emits ragged morsels
  whose remainder rows each burn a full batch slot downstream
  (``sum(ceil(s_i/b)) > ceil(S/b)``). The coalescer sits between morsel
  fan-out and the backend: per operator it buffers ready rows from
  *different* morsels into an accumulation queue, flushes a batch the
  moment ``batch_size`` slots fill, and flushes partial batches on a
  morsel-boundary **watermark** (every contributing morsel has reported)
  or after a configurable ``linger_s`` — mirroring the slot-fill logic of
  ``engine.ContinuousBatcher``, one level up the stack. A morsel's
  pipeline resumes as soon as the batches containing *its* rows flush (a
  per-morsel future), so downstream operators keep pipelined start times.
  Under the simulated driver the linger is *event-time* (deterministic);
  under threads a timer thread flushes lingering partials in real time.

* :class:`ExecutionContext` — bundles everything an execution needs
  (backends, default tier, batch size, concurrency, morsel size, driver,
  :class:`OutputCache`, ``UsageMeter``) into one object threaded through
  ``executor.execute``, ``judge.Judge``, the logical optimizer's candidate
  evaluation, and the physical optimizer's sample flow. ``as_context``
  upgrades a bare ``{tier: Backend}`` dict, so every public entry point
  accepts either. ``make_dispatcher()`` builds the context's driver.

* shared operator application — ``run_llm_op`` (cache-aware backend
  dispatch, optionally fanned out over a tier pool), ``bool_mask`` (the one
  place LLM filter outputs are parsed), ``apply_outputs`` and
  ``run_udf_op`` (the one place operator outputs mutate a table).

Per-call latencies flow from the backends through ``UsageMeter.call_log``;
the simulated driver consumes new log entries via
:meth:`EventScheduler.drain`, so any backend that meters itself is
automatically schedulable — and the same log can be *replayed* through an
EventScheduler after a threaded run to report measured vs simulated wall
side by side (``launch/serve.py --semantic`` does exactly that).
"""
from __future__ import annotations

import dataclasses
import heapq
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import backends as bk
from repro.core import plan as plan_ir
from repro.core import udf as udf_mod
from repro.core.table import Table

# rows per morsel in the pipelined executor; must stay a multiple of the
# batch size so batch-prompting call counts match the barrier executor
DEFAULT_MORSEL_ROWS = 32

# cost of native (UDF) compute per row — matches the seed executor's model
UDF_SECONDS_PER_ROW = 2e-6

# pseudo-tier for host-side (UDF) compute: one Python process, one worker —
# morsels pipeline against LLM calls but serialize against each other
HOST_TIER = "\x00host"


# ---------------------------------------------------------------------------
# Discrete-event scheduler
# ---------------------------------------------------------------------------

class EventScheduler:
    """Per-tier worker pools + greedy earliest-free-worker placement.

    ``submit`` returns the job's finish time; ``makespan`` is the latest
    finish observed so far. ``barrier()`` forbids later jobs from starting
    before everything already submitted has finished (the physical
    optimizer uses it between dependent sample-flow stages).
    """

    def __init__(self, concurrency: int = 16,
                 per_tier: Optional[Dict[str, int]] = None,
                 mode: str = "async"):
        if mode not in ("sync", "async"):
            raise ValueError(f"unknown scheduler mode {mode!r}")
        self.mode = mode
        self.concurrency = max(1, int(concurrency))
        self.per_tier = dict(per_tier or {})
        self._pools: Dict[str, List[float]] = {}
        self._makespan = 0.0
        self._floor = 0.0
        self.n_jobs = 0

    def workers(self, tier: str) -> int:
        if self.mode == "sync" or tier == HOST_TIER:
            return 1
        return max(1, int(self.per_tier.get(tier, self.concurrency)))

    def _pool(self, tier: str) -> List[float]:
        # sync mode: one global single-worker pool => pure sequential sum
        # (host compute stays its own resource even then)
        key = tier if (self.mode != "sync" or tier == HOST_TIER) \
            else "\x00sync"
        pool = self._pools.get(key)
        if pool is None:
            pool = [0.0] * self.workers(tier)
            self._pools[key] = pool
        return pool

    def submit(self, tier: str, duration_s: float,
               ready_s: float = 0.0) -> float:
        """Schedule one job; returns its finish time."""
        pool = self._pool(tier)
        free = heapq.heappop(pool)
        start = max(free, ready_s, self._floor)
        finish = start + max(0.0, duration_s)
        heapq.heappush(pool, finish)
        self.n_jobs += 1
        if finish > self._makespan:
            self._makespan = finish
        return finish

    def barrier(self) -> float:
        """All later jobs start no earlier than the current makespan."""
        self._floor = self._makespan
        return self._floor

    def drain(self, meter: bk.UsageMeter, cursor: int,
              ready_s: float = 0.0) -> Tuple[int, float]:
        """Submit every call the meter logged since ``cursor``; returns
        (new cursor, latest finish among the drained jobs)."""
        log = meter.call_log
        finish = ready_s
        for tier, lat in log[cursor:]:
            finish = max(finish, self.submit(tier, lat, ready_s))
        return len(log), finish

    @property
    def makespan(self) -> float:
        return self._makespan


# ---------------------------------------------------------------------------
# LLM-output cache
# ---------------------------------------------------------------------------

def _vkey(v) -> str:
    return v if isinstance(v, str) else repr(v)


class OutputCache:
    """LLM-output memo keyed by (tier, op semantics, value) — thread-safe.

    Semantic operators are deterministic per (model, prompt) here, so
    repeated sample executions — the judge runs the original plan once per
    optimizer iteration, rewritten plans share most operators — hit the
    cache instead of re-invoking the backend. This is the executor-level
    analogue of the paper's computation-reuse theme (cf. QuestCache [18]);
    only cache *misses* are billed. Keys are per-value, so morsel-pipelined
    and barrier execution populate and hit the cache identically.

    Under the threaded driver, concurrent morsels may race on a key. The
    cache is **single-flight**: ``claim`` hands the key to exactly one
    caller (the others get an event to wait on), so a value in flight is
    billed once — the same totals a sequential run produces. Duplicate keys
    *within* one claim are deliberately re-owned, matching the sequential
    path's double-billing of within-request duplicates."""

    def __init__(self):
        self.data: Dict[tuple, Any] = {}
        self.hits = 0
        self.misses = 0
        self._lock = threading.Lock()
        # key -> (owner token, event set when the owner publishes/releases)
        self._pending: Dict[tuple, Tuple[object, threading.Event]] = {}

    def key(self, op: plan_ir.Operator, tier: str, batch: int, v) -> tuple:
        return (op.kind, op.instruction, op.input_column, tier, batch,
                _vkey(v))

    def claim(self, keys: Sequence[tuple],
              token: object) -> List[Tuple[str, Any]]:
        """Partition ``keys`` in order into ``("hit", value)``,
        ``("own", None)`` (this caller must compute and publish), or
        ``("wait", event)`` (another caller is computing it)."""
        out: List[Tuple[str, Any]] = []
        with self._lock:
            for k in keys:
                if k in self.data:
                    self.hits += 1
                    out.append(("hit", self.data[k]))
                    continue
                pend = self._pending.get(k)
                if pend is not None and pend[0] is not token:
                    self.hits += 1      # a sequential run would hit here
                    out.append(("wait", pend[1]))
                    continue
                if pend is None:
                    self._pending[k] = (token, threading.Event())
                self.misses += 1
                out.append(("own", None))
        return out

    def peek(self, k: tuple) -> Tuple[bool, Any]:
        """Non-claiming lookup; counts a hit when present (a sequential run
        would hit here). Used by the :class:`BatchCoalescer` at batch
        formation so cached rows never occupy a batch slot."""
        with self._lock:
            if k in self.data:
                self.hits += 1
                return True, self.data[k]
        return False, None

    def note_hits(self, n: int = 1) -> None:
        """Count hits resolved outside ``claim`` (coalescer followers:
        duplicate rows answered by an in-flight batch slot)."""
        with self._lock:
            self.hits += n

    def publish(self, k: tuple, value) -> None:
        with self._lock:
            self.data[k] = value
            pend = self._pending.pop(k, None)
        if pend is not None:
            pend[1].set()

    def release(self, keys: Sequence[tuple], token: object) -> None:
        """Abandon in-flight reservations (owner failed); waiters recompute."""
        events = []
        with self._lock:
            for k in keys:
                pend = self._pending.get(k)
                if pend is not None and pend[0] is token:
                    events.append(self._pending.pop(k)[1])
        for e in events:
            e.set()

    def wait_value(self, k: tuple,
                   event: threading.Event) -> Tuple[bool, Any]:
        event.wait()
        with self._lock:
            if k in self.data:
                return True, self.data[k]
        return False, None


def run_backend_calls(op: plan_ir.Operator, values: Sequence[Any], backend,
                      meter: bk.UsageMeter, batch_size: int = 1,
                      fanout: Optional[Callable] = None) -> List[Any]:
    """Invoke the backend over ``values``. Without a ``fanout`` the whole
    request is one inline ``run_values`` (the backend batches internally).
    With a ``fanout`` — a callable mapping a list of thunks to their results,
    supplied by :class:`ThreadPoolDispatcher` — each batch-sized chunk
    becomes its own ``run_values`` call on the tier's worker pool, so the
    per-call latencies genuinely overlap. Chunk boundaries equal the
    backend's internal batching, so call counts and meter totals match the
    inline path exactly."""
    values = list(values)
    if fanout is None:
        return backend.run_values(op, values, meter=meter,
                                  batch_size=batch_size)
    if op.kind == plan_ir.REDUCE:
        chunks = [values]
    else:
        step = max(1, int(batch_size))
        chunks = [values[i:i + step] for i in range(0, len(values), step)]
    thunks = [
        (lambda c=c: backend.run_values(op, c, meter=meter,
                                        batch_size=batch_size))
        for c in chunks]
    return [o for part in fanout(thunks) for o in part]


def run_llm_op(op: plan_ir.Operator, values, backend, tier_name: str,
               meter: bk.UsageMeter, *, batch_size: int = 1,
               cache: Optional[OutputCache] = None,
               fanout: Optional[Callable] = None):
    """Execute one LLM operator, via the cache when provided. Returns
    (outputs, n_calls_made, latency_of_calls_made).

    ``fanout`` (see :func:`run_backend_calls`) runs the backend calls on a
    tier worker pool; the returned call/latency deltas are then approximate
    (other threads may bill the same tier concurrently) — callers on the
    threaded path ignore them and read the meter instead."""
    values = list(values)
    before_calls = meter.calls(tier_name)
    before_lat = meter.latency(tier_name)

    def deltas(ran_calls: bool):
        if fanout is not None:
            return 0, 0.0
        if not ran_calls:
            return 0, 0.0
        return (meter.calls(tier_name) - before_calls,
                meter.latency(tier_name) - before_lat)

    if cache is None:
        outs = run_backend_calls(op, values, backend, meter, batch_size,
                                 fanout)
        n, lat = deltas(True)
        return outs, n, lat

    token = object()
    if op.kind == plan_ir.REDUCE:
        rkey = cache.key(op, tier_name, batch_size,
                         "\x1e".join(_vkey(v) for v in values))
        state, got = cache.claim([rkey], token)[0]
        if state == "hit":
            return [got], 0, 0.0
        if state == "wait":
            ok, val = cache.wait_value(rkey, got)
            if ok:
                return [val], 0, 0.0
            state, got = cache.claim([rkey], token)[0]  # owner failed
            if state == "hit":
                return [got], 0, 0.0
        try:
            outs = run_backend_calls(op, values, backend, meter, batch_size,
                                     fanout)
        except BaseException:
            cache.release([rkey], token)
            raise
        cache.publish(rkey, outs[0])
        n, lat = deltas(True)
        return [outs[0]], n, lat

    keys = [cache.key(op, tier_name, batch_size, v) for v in values]
    states = cache.claim(keys, token)
    own = [i for i, (s, _) in enumerate(states) if s == "own"]
    outs: List[Any] = [None] * len(values)
    try:
        if own:
            got = run_backend_calls(op, [values[i] for i in own], backend,
                                    meter, batch_size, fanout)
            for i, o in zip(own, got):
                outs[i] = o
                cache.publish(keys[i], o)
    except BaseException:
        cache.release([keys[i] for i in own], token)
        raise
    for i, (s, v) in enumerate(states):
        if s == "hit":
            outs[i] = v
        elif s == "wait":
            ok, val = cache.wait_value(keys[i], v)
            if not ok:   # the owning caller failed: compute solo
                val = run_backend_calls(op, [values[i]], backend, meter,
                                        batch_size, fanout)[0]
                cache.publish(keys[i], val)
            outs[i] = val
    n, lat = deltas(bool(own))
    return outs, n, lat


# ---------------------------------------------------------------------------
# Shared operator application (executor + physical-optimizer sample flow)
# ---------------------------------------------------------------------------

def bool_mask(outs) -> List[bool]:
    """Parse LLM filter outputs into a row mask (the one shared parser)."""
    return [o if isinstance(o, bool) else
            str(o).strip().lower().startswith(("true", "yes"))
            for o in outs]


def rank_scores(outs) -> List[float]:
    """Parse RANK outputs into similarity scores. Real LLMs return digits
    as *strings*, so numeric text parses as a score. ``bool`` is an ``int``
    subclass — True/False are filter-shaped answers, not scores — and any
    unparseable output falls back to the row's input position."""
    sims: List[float] = []
    for i, o in enumerate(outs):
        if isinstance(o, (int, float)) and not isinstance(o, bool):
            sims.append(float(o))
            continue
        try:
            sims.append(float(str(o).strip()))
        except (TypeError, ValueError):
            sims.append(float(i))
    return sims


def _rank_column(sims) -> List[int]:
    order = sorted(range(len(sims)), key=lambda i: sims[i], reverse=True)
    ranks = [0] * len(order)
    for r, i in enumerate(order):
        ranks[i] = r
    return ranks


def apply_outputs(op: plan_ir.Operator, table: Table,
                  outs) -> Tuple[Table, Any]:
    """Fold one LLM operator's outputs into the table.

    Returns ``(table, scalar)``; scalar is meaningful only for reduce."""
    if op.kind == plan_ir.FILTER:
        return table.select(bool_mask(outs)), None
    if op.kind == plan_ir.MAP:
        return table.with_column(op.output_column, outs), None
    if op.kind == plan_ir.REDUCE:
        return table, outs[0]
    return table.with_column(op.output_column or "rank",
                             _rank_column(rank_scores(outs)), "numeric"), None


def run_udf_op(op: plan_ir.Operator, table: Table,
               values) -> Tuple[Table, Any]:
    """Run one compiled-UDF operator natively (no LLM calls).

    Generated UDFs are format-fragile by design (paper Fig. 12b); a row
    that crashes one yields the kind's null answer."""
    compiled = udf_mod.resolve_udf(op)

    def safe(v, default=None):
        try:
            return compiled.fn(v)
        except Exception:
            return default

    if op.kind == plan_ir.FILTER:
        return table.select([bool(safe(v, False)) for v in values]), None
    if op.kind == plan_ir.MAP:
        return table.with_column(op.output_column,
                                 [safe(v) for v in values]), None
    if op.kind == plan_ir.REDUCE:
        return table, safe(list(values))
    order = safe(list(values), list(range(len(values))))
    ranks = [0] * len(order)
    for r, i in enumerate(order):
        ranks[i] = r
    return table.with_column(op.output_column or "rank", ranks,
                             "numeric"), None


# ---------------------------------------------------------------------------
# Dispatchers: simulated (event-model) vs threads (measured)
# ---------------------------------------------------------------------------

class _DoneTask:
    """An already-completed morsel task."""
    __slots__ = ("_value", "finish")

    def __init__(self, value, finish: float = 0.0):
        self._value = value
        self.finish = finish

    def result(self):
        return self._value, self.finish


class _FutureTask:
    """A morsel task running on the chain pool."""
    __slots__ = ("_fut",)

    def __init__(self, fut: Future):
        self._fut = fut

    def result(self):
        return self._fut.result()


class Dispatcher:
    """How operator work runs: the executor hands every morsel step and
    every backend call to a dispatcher, which either simulates overlap
    (:class:`SimulatedDispatcher`) or provides it for real
    (:class:`ThreadPoolDispatcher`). Both expose the same task interface:

      done(value, finish)         wrap an immediate morsel
      defer(task, fn)             fn(value, ready_s) -> (value, finish_s)
                                  after ``task`` completes
      run_llm(...) / run_host(..) one operator's backend / host work
      checkpoint(meter, cursor)   optimizer stage boundary (drain+barrier
                                  under simulation, no-op under threads)
      wall_s                      modeled makespan / measured elapsed
    """

    kind = "abstract"

    def done(self, value, finish: float = 0.0) -> _DoneTask:
        return _DoneTask(value, finish)

    def fanout(self, tier_name: str) -> Optional[Callable]:
        """Per-tier call fanout for :func:`run_backend_calls`; None means
        run inline (sequential)."""
        return None

    def close(self) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class SimulatedDispatcher(Dispatcher):
    """Inline execution + EventScheduler replay (deterministic wall model)."""

    kind = "simulated"

    def __init__(self, scheduler: EventScheduler):
        self.sched = scheduler

    def defer(self, task, fn):
        value, ready = task.result()
        return _DoneTask(*fn(value, ready))

    def run_llm(self, op, values, backend, tier_name, meter, *,
                batch_size: int = 1, cache: Optional[OutputCache] = None,
                ready_s: float = 0.0):
        cursor = len(meter.call_log)
        outs, _, _ = run_llm_op(op, values, backend, tier_name, meter,
                                batch_size=batch_size, cache=cache)
        _, finish = self.sched.drain(meter, cursor, ready_s=ready_s)
        return outs, finish

    def run_host(self, fn, n_rows: int, ready_s: float = 0.0):
        finish = self.sched.submit(HOST_TIER,
                                   n_rows * UDF_SECONDS_PER_ROW,
                                   ready_s=ready_s)
        return fn(), finish

    def checkpoint(self, meter: bk.UsageMeter, cursor: int) -> int:
        cursor, _ = self.sched.drain(meter, cursor)
        self.sched.barrier()
        return cursor

    @property
    def wall_s(self) -> float:
        return self.sched.makespan


class ThreadPoolDispatcher(Dispatcher):
    """Real concurrency: per-tier bounded worker pools for backend calls
    (pool caps = serving quotas) plus a chain pool that advances morsel
    pipelines. ``wall_s`` is measured (construction -> last completion).

    Liveness: the executor submits morsel tasks in operator order, so every
    chain task's dependency sits *earlier* in the chain pool's FIFO queue —
    a blocked worker always waits on a task some other worker has already
    dequeued, and tier pools (which never block on chain tasks) guarantee
    progress. ``mode="sync"`` collapses every tier onto one shared
    single-worker pool, the threaded analogue of sequential accounting."""

    kind = "threads"

    def __init__(self, concurrency: int = 16,
                 per_tier: Optional[Dict[str, int]] = None,
                 mode: str = "async", chain_workers: int = 32):
        if mode not in ("sync", "async"):
            raise ValueError(f"unknown dispatcher mode {mode!r}")
        self.mode = mode
        self.concurrency = max(1, int(concurrency))
        self.per_tier = dict(per_tier or {})
        self._pools: Dict[str, ThreadPoolExecutor] = {}
        self._lock = threading.Lock()
        self._chain = ThreadPoolExecutor(max_workers=max(1, chain_workers),
                                         thread_name_prefix="morsel")
        self._host_lock = threading.Lock()
        self._t0 = time.perf_counter()
        self._last = self._t0

    def workers(self, tier: str) -> int:
        if self.mode == "sync":
            return 1
        return max(1, int(self.per_tier.get(tier, self.concurrency)))

    def _pool(self, tier: str) -> ThreadPoolExecutor:
        key = tier if self.mode != "sync" else "\x00sync"
        with self._lock:
            pool = self._pools.get(key)
            if pool is None:
                pool = ThreadPoolExecutor(max_workers=self.workers(tier))
                self._pools[key] = pool
            return pool

    def _touch(self) -> None:
        now = time.perf_counter()
        with self._lock:
            if now > self._last:
                self._last = now

    def fanout(self, tier_name: str) -> Callable:
        pool = self._pool(tier_name)

        def fan(thunks):
            futs = [pool.submit(t) for t in thunks]
            res = [f.result() for f in futs]
            self._touch()
            return res

        return fan

    def defer(self, task, fn):
        def chain():
            value, ready = task.result()
            return fn(value, ready)

        return _FutureTask(self._chain.submit(chain))

    def run_llm(self, op, values, backend, tier_name, meter, *,
                batch_size: int = 1, cache: Optional[OutputCache] = None,
                ready_s: float = 0.0):
        outs, _, _ = run_llm_op(op, values, backend, tier_name, meter,
                                batch_size=batch_size, cache=cache,
                                fanout=self.fanout(tier_name))
        return outs, 0.0

    def run_host(self, fn, n_rows: int, ready_s: float = 0.0):
        # one Python process: host UDF work serializes against itself but
        # overlaps in-flight backend I/O
        with self._host_lock:
            out = fn()
        self._touch()
        return out, 0.0

    def checkpoint(self, meter: bk.UsageMeter, cursor: int) -> int:
        return len(meter.call_log)

    @property
    def wall_s(self) -> float:
        with self._lock:
            return max(0.0, self._last - self._t0)

    def close(self) -> None:
        self._chain.shutdown(wait=True)
        with self._lock:
            pools = list(self._pools.values())
            self._pools.clear()
        for p in pools:
            p.shutdown(wait=True)


DRIVERS = ("simulated", "threads")


# ---------------------------------------------------------------------------
# Cross-morsel batch coalescing
# ---------------------------------------------------------------------------

class _MorselState:
    """Per-(operator, morsel) resolution buffer: row outputs fill in as the
    batches containing them flush; ``fut`` completes with
    ``(outs, finish_s)`` once every row is resolved."""

    __slots__ = ("outs", "remaining", "finish", "fut", "_lock")

    def __init__(self, n: int, ready: float):
        self.outs: List[Any] = [None] * n
        self.remaining = n
        self.finish = ready
        self.fut: Future = Future()
        self._lock = threading.Lock()

    def resolve(self, pos: int, out, finish: float) -> None:
        with self._lock:
            self.outs[pos] = out
            if finish > self.finish:
                self.finish = finish
            self.remaining -= 1
            done = self.remaining == 0
        if done and not self.fut.done():
            self.fut.set_result((self.outs, self.finish))

    def fail(self, exc: BaseException) -> None:
        if not self.fut.done():
            try:
                self.fut.set_exception(exc)
            except Exception:
                pass                      # lost a race with set_result


class _Slot:
    """One occupied batch slot: a leader value plus every (morsel, row)
    resolved by it — cross-morsel duplicates attach as followers instead
    of taking their own slot (dedupe *before* batch formation)."""

    __slots__ = ("value", "key", "ready", "targets")

    def __init__(self, value, key, ready: float, target):
        self.value = value
        self.key = key
        self.ready = ready
        self.targets = [target]           # [(morsel_state, row_pos)]


class _Batch:
    __slots__ = ("slots", "ready")

    def __init__(self, slots: List[_Slot], ready: float):
        self.slots = slots
        self.ready = ready


class _OpGroup:
    """One operator's accumulation queue inside a :class:`BatchCoalescer`.

    Submissions may arrive in any thread order; a reorder buffer admits
    them into batch formation strictly by morsel index, so the batches are
    the logical-row-order chunks whole-table batching would form —
    deterministic, and identical across drivers."""

    def __init__(self, coal: "BatchCoalescer", op, backend, tier_name: str,
                 expected: int):
        self.coal = coal
        self.op = op
        self.backend = backend
        self.tier = tier_name
        self.expected = max(1, int(expected))
        self.lock = threading.Lock()
        self.stash: Dict[int, tuple] = {}      # morsel idx -> (vals, rdy, st)
        self.next_idx = 0
        self.queue: List[_Slot] = []           # formation queue (partial)
        self.queue_ready = 0.0                 # max event-ready of queue
        self.queue_born = 0.0                  # event-ready of its 1st row
        self.queue_since = 0.0                 # wall time queue went nonempty
        self.inflight: Dict[tuple, _Slot] = {}  # cache key -> unresolved slot
        self.states: List[_MorselState] = []
        self.closed = False

    # -- submission ------------------------------------------------------
    def submit(self, idx: int, values: Sequence[Any],
               ready: float = 0.0) -> Future:
        """Register one morsel's surviving rows (possibly empty — empties
        still advance the watermark); returns the morsel's future."""
        values = list(values)
        state = _MorselState(len(values), ready)
        batches: List[_Batch] = []
        with self.lock:
            if self.closed:
                state.fail(RuntimeError("coalescer closed"))
                return state.fut
            if idx < self.next_idx or idx in self.stash:
                # duplicate submission (recovery path after a submit that
                # itself failed): don't wedge the reorder buffer
                state.fail(RuntimeError(f"morsel {idx} already submitted"))
                return state.fut
            self.states.append(state)
            self.stash[idx] = (values, ready, state)
            self._advance(batches)
        self._execute(batches)
        return state.fut

    def _advance(self, batches: List[_Batch]) -> None:
        """Admit contiguous stashed morsels (reorder buffer) into batch
        formation; cut full batches, the watermark partial, and — under
        the simulated driver — event-time linger partials. Lock held."""
        linger = self.coal.linger_s
        while self.next_idx in self.stash:
            values, ready, state = self.stash.pop(self.next_idx)
            self.next_idx += 1
            if (linger is not None and self.queue
                    and self.coal.disp.kind == "simulated"
                    and ready > self.queue_born + linger):
                # the next rows arrive (event time) after the partial's
                # linger deadline — anchored to the *oldest* queued row,
                # so the deadline cannot slide forward with each arrival
                # (mirrors the threads timer, which measures from
                # queue_since): launch the partial at the deadline
                self._cut(batches, partial=True,
                          launch=self.queue_born + linger)
            for pos, v in enumerate(values):
                self._enqueue_row(state, pos, v, ready, batches)
            if not values:
                state.fut.set_result(([], ready))
        if self.next_idx >= self.expected and self.queue:
            self._cut(batches, partial=len(self.queue) < self.coal.batch)

    def _enqueue_row(self, state: _MorselState, pos: int, v, ready: float,
                     batches: List[_Batch]) -> None:
        cache = self.coal.cache
        key = None
        if cache is not None:
            key = cache.key(self.op, self.tier, self.coal.batch, v)
            lead = self.inflight.get(key)
            if lead is not None:           # duplicate of a queued/in-flight
                lead.targets.append((state, pos))   # row: follow, no slot
                cache.note_hits(1)
                self.coal.stats["dedup_follows"] += 1
                return
            hit, val = cache.peek(key)
            if hit:
                state.resolve(pos, val, ready)
                return
        slot = _Slot(v, key, ready, (state, pos))
        if key is not None:
            self.inflight[key] = slot
        if not self.queue:
            self.queue_since = time.perf_counter()
            self.queue_born = ready
        self.queue.append(slot)
        if ready > self.queue_ready:
            self.queue_ready = ready
        self.coal.stats["rows"] += 1
        if len(self.queue) >= self.coal.batch:
            self._cut(batches, partial=False)

    def _cut(self, batches: List[_Batch], partial: bool,
             launch: Optional[float] = None) -> None:
        slots, self.queue = self.queue, []
        ready = launch if launch is not None else \
            max((s.ready for s in slots), default=0.0)
        self.queue_ready = 0.0
        batches.append(_Batch(slots, ready))
        self.coal.stats["flushes"] += 1
        if partial:
            self.coal.stats["partial_flushes"] += 1

    # -- flush execution -------------------------------------------------
    def _execute(self, batches: List[_Batch]) -> None:
        """Run flushed batches outside the group lock. Under threads,
        several batches cut by one submission run concurrently on
        ephemeral threads — each still routes its backend call through the
        tier's bounded pool, so serving quotas hold and cache waits never
        occupy a tier worker (same liveness structure as morsel chains)."""
        if not batches:
            return
        if len(batches) == 1 or self.coal.disp.kind != "threads":
            for b in batches:
                self._run_batch(b)
            return
        threads = [threading.Thread(target=self._run_batch, args=(b,),
                                    daemon=True) for b in batches]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    def _run_batch(self, b: _Batch) -> None:
        try:
            outs, finish = self.coal.disp.run_llm(
                self.op, [s.value for s in b.slots], self.backend,
                self.tier, self.coal.meter, batch_size=self.coal.batch,
                cache=self.coal.cache, ready_s=b.ready)
        except BaseException as e:        # backend failure: fail the rows,
            self._fail_batch(b, e)        # don't hang downstream morsels
            return
        with self.lock:
            for s in b.slots:
                if s.key is not None:
                    self.inflight.pop(s.key, None)
            targets = [(s.targets[:], out) for s, out in zip(b.slots, outs)]
        for tgts, out in targets:
            for state, pos in tgts:
                state.resolve(pos, out, finish)

    def _fail_batch(self, b: _Batch, exc: BaseException) -> None:
        with self.lock:
            for s in b.slots:
                if s.key is not None:
                    self.inflight.pop(s.key, None)
            targets = [t for s in b.slots for t in s.targets]
        for state, _ in targets:
            state.fail(exc)

    def flush_expired(self, now: float) -> None:
        """Timer hook (threads driver): flush a partial batch whose oldest
        row has waited longer than ``linger_s``."""
        batches: List[_Batch] = []
        with self.lock:
            if (self.queue and self.coal.linger_s is not None
                    and now - self.queue_since >= self.coal.linger_s):
                self._cut(batches, partial=len(self.queue) < self.coal.batch)
        self._execute(batches)

    def close(self, exc: Optional[BaseException] = None) -> None:
        with self.lock:
            self.closed = True
            states = self.states
        err = exc or RuntimeError("coalescer closed with pending rows")
        for st in states:
            if not st.fut.done():
                st.fail(err)


class BatchCoalescer:
    """Cross-morsel batch packing for one execution (see module docstring).

    One instance serves one executor run; ``open`` registers an operator
    with its expected contributor count (= number of morsels entering it),
    and each morsel ``submit``s its rows once. ``stats`` records flushes,
    partial flushes, rows slotted, and follower dedupes — benchmarks and
    tests read it from ``ExecutionResult.coalesce_stats``."""

    def __init__(self, dispatcher: Dispatcher, meter: bk.UsageMeter, *,
                 batch_size: int, cache: Optional[OutputCache] = None,
                 linger_s: Optional[float] = None):
        self.disp = dispatcher
        self.meter = meter
        self.batch = max(1, int(batch_size))
        self.cache = cache
        self.linger_s = linger_s
        self.stats = {"flushes": 0, "partial_flushes": 0, "rows": 0,
                      "dedup_follows": 0}
        self._groups: List[_OpGroup] = []
        self._lock = threading.Lock()
        self._timer: Optional[threading.Thread] = None
        self._stop = threading.Event()

    def open(self, op, backend, tier_name: str, expected: int) -> _OpGroup:
        g = _OpGroup(self, op, backend, tier_name, expected)
        with self._lock:
            self._groups.append(g)
        if self.linger_s is not None and self.disp.kind == "threads":
            self._ensure_timer()
        return g

    def _ensure_timer(self) -> None:
        with self._lock:
            if self._timer is None:
                self._timer = threading.Thread(target=self._linger_loop,
                                               name="coalesce-linger",
                                               daemon=True)
                self._timer.start()

    def _linger_loop(self) -> None:
        tick = max(0.002, (self.linger_s or 0.01) / 4.0)
        while not self._stop.wait(tick):
            with self._lock:
                groups = list(self._groups)
            now = time.perf_counter()
            for g in groups:
                g.flush_expired(now)

    def close(self, exc: Optional[BaseException] = None) -> None:
        """Stop the linger timer and fail any unresolved morsel futures so
        blocked chain tasks unwind (error paths must not deadlock the
        dispatcher's chain-pool shutdown)."""
        self._stop.set()
        if self._timer is not None:
            self._timer.join(timeout=5.0)
        with self._lock:
            groups = list(self._groups)
        for g in groups:
            g.close(exc)


# ---------------------------------------------------------------------------
# Execution context
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ExecutionContext:
    """Everything an execution needs, in one object.

    ``concurrency`` is the default per-tier worker count;
    ``per_tier_concurrency`` overrides it for individual tiers (a weak tier
    served on many replicas can take more simultaneous calls than the
    flagship). ``morsel_size=0`` disables pipelining (whole-table barrier
    between operators — the seed executor's behaviour). ``driver`` selects
    how backend calls run: ``"simulated"`` (inline + event-scheduler wall
    model) or ``"threads"`` (per-tier worker pools, measured wall).

    ``coalesce`` (default on; only active with ``batch_size > 1``) routes
    streamable LLM operators through a :class:`BatchCoalescer`, packing
    rows from different morsels into full batches instead of paying
    per-morsel ragged-remainder calls; ``linger_s`` bounds how long a
    partial batch may wait for more rows before flushing (None = only the
    morsel-boundary watermark flushes partials)."""
    backends: Dict[str, bk.Backend]
    default_tier: str = "m*"
    concurrency: int = 16
    per_tier_concurrency: Optional[Dict[str, int]] = None
    batch_size: int = 1
    morsel_size: int = DEFAULT_MORSEL_ROWS
    mode: str = "async"
    driver: str = "simulated"
    coalesce: bool = True
    linger_s: Optional[float] = None
    cache: Optional[OutputCache] = None
    meter: bk.UsageMeter = dataclasses.field(default_factory=bk.UsageMeter)

    def backend(self, tier_name: Optional[str]):
        return self.backends[tier_name or self.default_tier]

    def make_scheduler(self) -> EventScheduler:
        return EventScheduler(self.concurrency,
                              per_tier=self.per_tier_concurrency,
                              mode=self.mode)

    def make_dispatcher(self) -> Dispatcher:
        if self.driver == "threads":
            return ThreadPoolDispatcher(self.concurrency,
                                        per_tier=self.per_tier_concurrency,
                                        mode=self.mode)
        if self.driver != "simulated":
            raise ValueError(f"unknown driver {self.driver!r} "
                             f"(expected one of {DRIVERS})")
        return SimulatedDispatcher(self.make_scheduler())

    def fork(self, **overrides) -> "ExecutionContext":
        """A sibling context; e.g. ``fork(meter=UsageMeter())`` gives an
        optimizer its own accounting while sharing backends and cache."""
        return dataclasses.replace(self, **overrides)


def as_context(backends_or_ctx, **defaults) -> ExecutionContext:
    """Upgrade a ``{tier: Backend}`` dict to an ExecutionContext; pass an
    existing context through (with ``defaults`` applied as overrides)."""
    if isinstance(backends_or_ctx, ExecutionContext):
        return backends_or_ctx.fork(**defaults) if defaults \
            else backends_or_ctx
    return ExecutionContext(backends=backends_or_ctx, **defaults)
