"""Event-driven execution runtime: the single scheduling surface shared by
the executor, the judge, and both optimizers.

Three pieces:

* :class:`EventScheduler` — a discrete-event makespan model. Every LLM call
  becomes a *job* ``(tier, duration, ready_time)``; each tier owns a pool of
  workers (paper: 16 coroutines) and a job starts on the earliest-free
  worker of its tier, no earlier than its ready time. The resulting
  makespan replaces the old per-operator "waves" formulas (the deleted
  ``executor._makespan`` / ``physical_optimizer._wall``): unlike waves, the
  event model fills ragged-wave idle slots, overlaps operators that run on
  different tiers, and honours per-tier concurrency caps. ``mode="sync"``
  collapses every tier onto one worker, reproducing the paper's Table-9
  sequential accounting.

* :class:`ExecutionContext` — bundles everything an execution needs
  (backends, default tier, batch size, concurrency, morsel size,
  :class:`OutputCache`, ``UsageMeter``) into one object threaded through
  ``executor.execute``, ``judge.Judge``, the logical optimizer's candidate
  evaluation, and the physical optimizer's sample flow. ``as_context``
  upgrades a bare ``{tier: Backend}`` dict, so every public entry point
  accepts either.

* shared operator application — ``run_llm_op`` (cache-aware backend
  dispatch), ``bool_mask`` (the one place LLM filter outputs are parsed),
  ``apply_outputs`` and ``run_udf_op`` (the one place operator outputs
  mutate a table). Previously the executor and the physical optimizer each
  carried a private copy of this logic.

Per-call latencies flow from the backends through ``UsageMeter.call_log``;
schedulers consume new log entries via :meth:`EventScheduler.drain`, so any
backend that meters itself is automatically schedulable.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Dict, List, Optional, Tuple

from repro.core import backends as bk
from repro.core import plan as plan_ir
from repro.core import udf as udf_mod
from repro.core.table import Table

# rows per morsel in the pipelined executor; must stay a multiple of the
# batch size so batch-prompting call counts match the barrier executor
DEFAULT_MORSEL_ROWS = 32

# cost of native (UDF) compute per row — matches the seed executor's model
UDF_SECONDS_PER_ROW = 2e-6

# pseudo-tier for host-side (UDF) compute: one Python process, one worker —
# morsels pipeline against LLM calls but serialize against each other
HOST_TIER = "\x00host"


# ---------------------------------------------------------------------------
# Discrete-event scheduler
# ---------------------------------------------------------------------------

class EventScheduler:
    """Per-tier worker pools + greedy earliest-free-worker placement.

    ``submit`` returns the job's finish time; ``makespan`` is the latest
    finish observed so far. ``barrier()`` forbids later jobs from starting
    before everything already submitted has finished (the physical
    optimizer uses it between dependent sample-flow stages).
    """

    def __init__(self, concurrency: int = 16,
                 per_tier: Optional[Dict[str, int]] = None,
                 mode: str = "async"):
        if mode not in ("sync", "async"):
            raise ValueError(f"unknown scheduler mode {mode!r}")
        self.mode = mode
        self.concurrency = max(1, int(concurrency))
        self.per_tier = dict(per_tier or {})
        self._pools: Dict[str, List[float]] = {}
        self._makespan = 0.0
        self._floor = 0.0
        self.n_jobs = 0

    def workers(self, tier: str) -> int:
        if self.mode == "sync" or tier == HOST_TIER:
            return 1
        return max(1, int(self.per_tier.get(tier, self.concurrency)))

    def _pool(self, tier: str) -> List[float]:
        # sync mode: one global single-worker pool => pure sequential sum
        # (host compute stays its own resource even then)
        key = tier if (self.mode != "sync" or tier == HOST_TIER) \
            else "\x00sync"
        pool = self._pools.get(key)
        if pool is None:
            pool = [0.0] * self.workers(tier)
            self._pools[key] = pool
        return pool

    def submit(self, tier: str, duration_s: float,
               ready_s: float = 0.0) -> float:
        """Schedule one job; returns its finish time."""
        pool = self._pool(tier)
        free = heapq.heappop(pool)
        start = max(free, ready_s, self._floor)
        finish = start + max(0.0, duration_s)
        heapq.heappush(pool, finish)
        self.n_jobs += 1
        if finish > self._makespan:
            self._makespan = finish
        return finish

    def barrier(self) -> float:
        """All later jobs start no earlier than the current makespan."""
        self._floor = self._makespan
        return self._floor

    def drain(self, meter: bk.UsageMeter, cursor: int,
              ready_s: float = 0.0) -> Tuple[int, float]:
        """Submit every call the meter logged since ``cursor``; returns
        (new cursor, latest finish among the drained jobs)."""
        log = meter.call_log
        finish = ready_s
        for tier, lat in log[cursor:]:
            finish = max(finish, self.submit(tier, lat, ready_s))
        return len(log), finish

    @property
    def makespan(self) -> float:
        return self._makespan


# ---------------------------------------------------------------------------
# LLM-output cache
# ---------------------------------------------------------------------------

def _vkey(v) -> str:
    return v if isinstance(v, str) else repr(v)


class OutputCache:
    """LLM-output memo keyed by (tier, op semantics, value).

    Semantic operators are deterministic per (model, prompt) here, so
    repeated sample executions — the judge runs the original plan once per
    optimizer iteration, rewritten plans share most operators — hit the
    cache instead of re-invoking the backend. This is the executor-level
    analogue of the paper's computation-reuse theme (cf. QuestCache [18]);
    only cache *misses* are billed. Keys are per-value, so morsel-pipelined
    and barrier execution populate and hit the cache identically."""

    def __init__(self):
        self.data: Dict[tuple, Any] = {}
        self.hits = 0
        self.misses = 0

    def key(self, op: plan_ir.Operator, tier: str, batch: int, v) -> tuple:
        return (op.kind, op.instruction, op.input_column, tier, batch,
                _vkey(v))


def run_llm_op(op: plan_ir.Operator, values, backend, tier_name: str,
               meter: bk.UsageMeter, *, batch_size: int = 1,
               cache: Optional[OutputCache] = None):
    """Execute one LLM operator, via the cache when provided. Returns
    (outputs, n_calls_made, latency_of_calls_made)."""
    before_calls = meter.calls(tier_name)
    before_lat = meter.by_tier.get(tier_name, bk.Usage()).latency_s
    if cache is None or op.kind == plan_ir.REDUCE:
        if cache is not None and op.kind == plan_ir.REDUCE:
            rkey = cache.key(op, tier_name, batch_size,
                             "\x1e".join(_vkey(v) for v in values))
            if rkey in cache.data:
                cache.hits += 1
                return [cache.data[rkey]], 0, 0.0
            outs = backend.run_values(op, values, meter=meter,
                                      batch_size=batch_size)
            cache.misses += 1
            cache.data[rkey] = outs[0]
        else:
            outs = backend.run_values(op, values, meter=meter,
                                      batch_size=batch_size)
        n_calls = meter.calls(tier_name) - before_calls
        lat = meter.by_tier[tier_name].latency_s - before_lat
        return outs, n_calls, lat

    keys = [cache.key(op, tier_name, batch_size, v) for v in values]
    missing = [i for i, k in enumerate(keys) if k not in cache.data]
    cache.hits += len(values) - len(missing)
    cache.misses += len(missing)
    if missing:
        outs_new = backend.run_values(op, [values[i] for i in missing],
                                      meter=meter, batch_size=batch_size)
        for i, o in zip(missing, outs_new):
            cache.data[keys[i]] = o
    n_calls = meter.calls(tier_name) - before_calls
    lat = (meter.by_tier[tier_name].latency_s - before_lat) if missing \
        else 0.0
    return [cache.data[k] for k in keys], n_calls, lat


# ---------------------------------------------------------------------------
# Shared operator application (executor + physical-optimizer sample flow)
# ---------------------------------------------------------------------------

def bool_mask(outs) -> List[bool]:
    """Parse LLM filter outputs into a row mask (the one shared parser)."""
    return [o if isinstance(o, bool) else
            str(o).strip().lower().startswith(("true", "yes"))
            for o in outs]


def _rank_column(sims) -> List[int]:
    order = sorted(range(len(sims)), key=lambda i: sims[i], reverse=True)
    ranks = [0] * len(order)
    for r, i in enumerate(order):
        ranks[i] = r
    return ranks


def apply_outputs(op: plan_ir.Operator, table: Table,
                  outs) -> Tuple[Table, Any]:
    """Fold one LLM operator's outputs into the table.

    Returns ``(table, scalar)``; scalar is non-None only for reduce."""
    if op.kind == plan_ir.FILTER:
        return table.select(bool_mask(outs)), None
    if op.kind == plan_ir.MAP:
        return table.with_column(op.output_column, outs), None
    if op.kind == plan_ir.REDUCE:
        return table, outs[0]
    sims = [(o if isinstance(o, (int, float)) else i)
            for i, o in enumerate(outs)]
    return table.with_column(op.output_column or "rank",
                             _rank_column(sims), "numeric"), None


def run_udf_op(op: plan_ir.Operator, table: Table,
               values) -> Tuple[Table, Any]:
    """Run one compiled-UDF operator natively (no LLM calls).

    Generated UDFs are format-fragile by design (paper Fig. 12b); a row
    that crashes one yields the kind's null answer."""
    compiled = udf_mod.resolve_udf(op)

    def safe(v, default=None):
        try:
            return compiled.fn(v)
        except Exception:
            return default

    if op.kind == plan_ir.FILTER:
        return table.select([bool(safe(v, False)) for v in values]), None
    if op.kind == plan_ir.MAP:
        return table.with_column(op.output_column,
                                 [safe(v) for v in values]), None
    if op.kind == plan_ir.REDUCE:
        return table, safe(list(values))
    order = safe(list(values), list(range(len(values))))
    ranks = [0] * len(order)
    for r, i in enumerate(order):
        ranks[i] = r
    return table.with_column(op.output_column or "rank", ranks,
                             "numeric"), None


# ---------------------------------------------------------------------------
# Execution context
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ExecutionContext:
    """Everything an execution needs, in one object.

    ``concurrency`` is the default per-tier worker count;
    ``per_tier_concurrency`` overrides it for individual tiers (a weak tier
    served on many replicas can take more simultaneous calls than the
    flagship). ``morsel_size=0`` disables pipelining (whole-table barrier
    between operators — the seed executor's behaviour)."""
    backends: Dict[str, bk.Backend]
    default_tier: str = "m*"
    concurrency: int = 16
    per_tier_concurrency: Optional[Dict[str, int]] = None
    batch_size: int = 1
    morsel_size: int = DEFAULT_MORSEL_ROWS
    mode: str = "async"
    cache: Optional[OutputCache] = None
    meter: bk.UsageMeter = dataclasses.field(default_factory=bk.UsageMeter)

    def backend(self, tier_name: Optional[str]):
        return self.backends[tier_name or self.default_tier]

    def make_scheduler(self) -> EventScheduler:
        return EventScheduler(self.concurrency,
                              per_tier=self.per_tier_concurrency,
                              mode=self.mode)

    def fork(self, **overrides) -> "ExecutionContext":
        """A sibling context; e.g. ``fork(meter=UsageMeter())`` gives an
        optimizer its own accounting while sharing backends and cache."""
        return dataclasses.replace(self, **overrides)


def as_context(backends_or_ctx, **defaults) -> ExecutionContext:
    """Upgrade a ``{tier: Backend}`` dict to an ExecutionContext; pass an
    existing context through (with ``defaults`` applied as overrides)."""
    if isinstance(backends_or_ctx, ExecutionContext):
        return backends_or_ctx.fork(**defaults) if defaults \
            else backends_or_ctx
    return ExecutionContext(backends=backends_or_ctx, **defaults)
