"""Execution runtime: the single scheduling/dispatch surface shared by the
executor, the judge, and both optimizers.

Four pieces:

* :class:`EventScheduler` — a discrete-event makespan model. Every LLM call
  becomes a *job* ``(tier, duration, ready_time)``; each tier owns a pool of
  workers (paper: 16 coroutines) and a job starts on the earliest-free
  worker of its tier, no earlier than its ready time. The resulting
  makespan replaces the old per-operator "waves" formulas (the deleted
  ``executor._makespan`` / ``physical_optimizer._wall``): unlike waves, the
  event model fills ragged-wave idle slots, overlaps operators that run on
  different tiers, and honours per-tier concurrency caps. ``mode="sync"``
  collapses every tier onto one worker, reproducing the paper's Table-9
  sequential accounting.

* :class:`Dispatcher` — how operator work actually *runs*. Two drivers:

  - :class:`SimulatedDispatcher` (``driver="simulated"``): backend calls
    execute inline, one after another; their metered per-call latencies are
    replayed through an :class:`EventScheduler`, so ``wall_s`` is a
    deterministic *model* of overlapped execution (Table-9 accounting, and
    the mode every hand-checkable schedule test uses).
  - :class:`ThreadPoolDispatcher` (``driver="threads"``): backend calls run
    on per-tier **bounded worker pools** (pool caps are serving quotas —
    ``per_tier_concurrency`` wins over the default ``concurrency``), morsel
    chains advance on a separate chain pool, and morsels of independent
    operators genuinely overlap. ``wall_s`` is **measured** wall time.

  Results, call counts, and per-tier meter totals are identical across
  drivers: the :class:`OutputCache` is single-flight (a value computed by
  one in-flight morsel is awaited, not re-billed, by concurrent morsels)
  and ``UsageMeter`` is lock-protected. With ``batch_size > 1`` the
  :class:`BatchCoalescer` forms batches in *logical row order* (morsel
  index, then row position) regardless of thread arrival order, and
  cross-morsel duplicate values dedupe *before* batch formation — so the
  grouping of misses into batched calls is deterministic and identical
  across drivers (this closes PR 2's documented corner where duplicate
  values could land in different batched calls per driver).

  A third layer sits above both drivers:
  ``distributed.morsel_shards.ShardedDispatcher`` (``ctx.shards > 1``)
  partitions the morsel stream round-robin across N shard workers, each
  backed by its own inner dispatcher — pool-per-(shard, tier) under
  threads (explicit ``per_tier_concurrency`` caps are *serving quotas*
  split across shards, remainder to shard 0; the default ``concurrency``
  is each shard's own replica width), one shared event scheduler with
  per-(shard, tier) pools under simulation. Morsel chains advance on
  per-shard chain pools; per-shard staging meters merge deterministically
  (``UsageMeter.merge``, sorted by logical call key) into the context
  meter when the executor finalizes. Batch formation stays *global*
  (one reorder buffer in morsel order, shared cache dedupe) so results,
  call counts, and per-tier totals are shard-count invariant; only batch
  *execution* round-robins across the (shard, tier) pools.

  Above everything sits ``launch.query_server.QueryServer``: ONE
  long-lived dispatcher (``ExecutionContext.dispatcher()``) serves
  continuously admitted queries, each executed with a ``query_key`` that
  scopes its logical meter keys and shard cursor — so per-query meters
  finalize independently while ``per_tier_concurrency`` caps act as
  serving quotas across tenants. ``ExecutionContext.close()`` is the
  matching shutdown path (release pools; the cache's creator closes the
  cache — ``OutputCache.close`` releases its in-flight reservations).

* :class:`BatchCoalescer` — cross-morsel batch packing. With
  ``batch_size > 1`` a selective upstream filter emits ragged morsels
  whose remainder rows each burn a full batch slot downstream
  (``sum(ceil(s_i/b)) > ceil(S/b)``). The coalescer sits between morsel
  fan-out and the backend: per operator it buffers ready rows from
  *different* morsels into an accumulation queue, flushes a batch the
  moment ``batch_size`` slots fill, and flushes partial batches on a
  morsel-boundary **watermark** (every contributing morsel has reported)
  or after a configurable ``linger_s`` — mirroring the slot-fill logic of
  ``engine.ContinuousBatcher``, one level up the stack. A morsel's
  pipeline resumes as soon as the batches containing *its* rows flush (a
  per-morsel future), so downstream operators keep pipelined start times.
  Under the simulated driver the linger is *event-time* (deterministic);
  under threads a timer thread flushes lingering partials in real time.

* :class:`ExecutionContext` — bundles everything an execution needs
  (backends, default tier, batch size, concurrency, morsel size, driver,
  :class:`OutputCache`, ``UsageMeter``) into one object threaded through
  ``executor.execute``, ``judge.Judge``, the logical optimizer's candidate
  evaluation, and the physical optimizer's sample flow. ``as_context``
  upgrades a bare ``{tier: Backend}`` dict, so every public entry point
  accepts either. ``make_dispatcher()`` builds the context's driver.

* shared operator application — ``run_llm_op`` (cache-aware backend
  dispatch, optionally fanned out over a tier pool), ``bool_mask`` (the one
  place LLM filter outputs are parsed), ``apply_outputs`` and
  ``run_udf_op`` (the one place operator outputs mutate a table).

Per-call latencies flow from the backends through ``UsageMeter.call_log``;
the simulated driver consumes new log entries via
:meth:`EventScheduler.drain`, so any backend that meters itself is
automatically schedulable — and the same log can be *replayed* through an
EventScheduler after a threaded run to report measured vs simulated wall
side by side (``launch/serve.py --semantic`` does exactly that).
"""
from __future__ import annotations

import contextlib
import dataclasses
import heapq
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import backends as bk
from repro.core import plan as plan_ir
from repro.core import udf as udf_mod
from repro.core.table import Table

# rows per morsel in the pipelined executor; must stay a multiple of the
# batch size so batch-prompting call counts match the barrier executor
DEFAULT_MORSEL_ROWS = 32

# cost of native (UDF) compute per row — matches the seed executor's model
UDF_SECONDS_PER_ROW = 2e-6

# pseudo-tier for host-side (UDF) compute: one Python process, one worker —
# morsels pipeline against LLM calls but serialize against each other
HOST_TIER = "\x00host"


# ---------------------------------------------------------------------------
# Discrete-event scheduler
# ---------------------------------------------------------------------------

class EventScheduler:
    """Per-tier worker pools + greedy earliest-free-worker placement.

    ``submit`` returns the job's finish time; ``makespan`` is the latest
    finish observed so far. ``barrier()`` forbids later jobs from starting
    before everything already submitted has finished (the physical
    optimizer uses it between dependent sample-flow stages).

    ``submit``/``barrier`` are lock-protected: a long-lived server admits
    queries from concurrent threads, and under the simulated driver they
    all replay onto one shared scheduler — placement must not corrupt the
    pool heaps (the *interleaving* of concurrently admitted queries is
    still arrival-dependent; only solo replays are fully deterministic).
    """

    def __init__(self, concurrency: int = 16,
                 per_tier: Optional[Dict[str, int]] = None,
                 mode: str = "async"):
        if mode not in ("sync", "async"):
            raise ValueError(f"unknown scheduler mode {mode!r}")
        self.mode = mode
        self.concurrency = max(1, int(concurrency))
        self.per_tier = dict(per_tier or {})
        self._pools: Dict[str, List[float]] = {}
        self._makespan = 0.0
        self._floor = 0.0
        self.n_jobs = 0
        self._elock = threading.Lock()

    def workers(self, tier: str) -> int:
        if self.mode == "sync" or tier == HOST_TIER:
            return 1
        return max(1, int(self.per_tier.get(tier, self.concurrency)))

    def _pool(self, tier: str) -> List[float]:
        # sync mode: one global single-worker pool => pure sequential sum
        # (host compute stays its own resource even then)
        key = tier if (self.mode != "sync" or tier == HOST_TIER) \
            else "\x00sync"
        pool = self._pools.get(key)
        if pool is None:
            pool = [0.0] * self.workers(tier)
            self._pools[key] = pool
        return pool

    def submit(self, tier: str, duration_s: float,
               ready_s: float = 0.0) -> float:
        """Schedule one job; returns its finish time."""
        with self._elock:
            pool = self._pool(tier)
            free = heapq.heappop(pool)
            start = max(free, ready_s, self._floor)
            finish = start + max(0.0, duration_s)
            heapq.heappush(pool, finish)
            self.n_jobs += 1
            if finish > self._makespan:
                self._makespan = finish
            return finish

    def barrier(self) -> float:
        """All later jobs start no earlier than the current makespan."""
        with self._elock:
            self._floor = self._makespan
            return self._floor

    def seed_occupancy(self, occupancy: Optional[Dict[str, List[float]]]
                       ) -> None:
        """Pre-load per-tier busy-until offsets (``Dispatcher.occupancy()``
        shape: tier -> remaining-busy seconds per occupied worker slot) as
        zero-ready jobs, so later submissions see the pools exactly as the
        live dispatcher does — the digital-twin seed every ``CostModel``
        makespan replay and ``QueryServer`` admission estimate uses."""
        for tname, busy in (occupancy or {}).items():
            for b in busy:
                if b > 0:
                    self.submit(tname, float(b), 0.0)

    def drain(self, meter: bk.UsageMeter, cursor: int,
              ready_s: float = 0.0) -> Tuple[int, float]:
        """Submit every call the meter logged since ``cursor``; returns
        (new cursor, latest finish among the drained jobs)."""
        log = meter.call_log
        finish = ready_s
        for tier, lat in log[cursor:]:
            finish = max(finish, self.submit(tier, lat, ready_s))
        return len(log), finish

    @property
    def makespan(self) -> float:
        return self._makespan


# ---------------------------------------------------------------------------
# LLM-output cache
# ---------------------------------------------------------------------------

def _vkey(v) -> str:
    return v if isinstance(v, str) else repr(v)


class OutputCache:
    """LLM-output memo keyed by (tier, op semantics, value) — thread-safe.

    Semantic operators are deterministic per (model, prompt) here, so
    repeated sample executions — the judge runs the original plan once per
    optimizer iteration, rewritten plans share most operators — hit the
    cache instead of re-invoking the backend. This is the executor-level
    analogue of the paper's computation-reuse theme (cf. QuestCache [18]);
    only cache *misses* are billed. Keys are per-value, so morsel-pipelined
    and barrier execution populate and hit the cache identically.

    Under the threaded driver, concurrent morsels may race on a key. The
    cache is **single-flight**: ``claim`` hands the key to exactly one
    caller (the others get an event to wait on), so a value in flight is
    billed once — the same totals a sequential run produces. Duplicate keys
    *within* one claim are deliberately re-owned, matching the sequential
    path's double-billing of within-request duplicates."""

    def __init__(self):
        self.data: Dict[tuple, Any] = {}
        self.hits = 0
        self.misses = 0
        self.closed = False
        self._lock = threading.Lock()
        # key -> (owner token, event set when the owner publishes/releases)
        self._pending: Dict[tuple, Tuple[object, threading.Event]] = {}

    def key(self, op: plan_ir.Operator, tier: str, batch: int, v) -> tuple:
        return (op.kind, op.instruction, op.input_column, tier, batch,
                _vkey(v))

    def claim(self, keys: Sequence[tuple],
              token: object) -> List[Tuple[str, Any]]:
        """Partition ``keys`` in order into ``("hit", value)``,
        ``("own", None)`` (this caller must compute and publish), or
        ``("wait", event)`` (another caller is computing it)."""
        out: List[Tuple[str, Any]] = []
        with self._lock:
            for k in keys:
                if k in self.data:
                    self.hits += 1
                    out.append(("hit", self.data[k]))
                    continue
                pend = self._pending.get(k)
                if pend is not None and pend[0] is not token:
                    self.hits += 1      # a sequential run would hit here
                    out.append(("wait", pend[1]))
                    continue
                if pend is None and not self.closed:
                    # closed caches stop single-flighting: no reservation
                    # is created after close(), so a draining server can
                    # never re-grow waiters it just released (stragglers
                    # compute solo instead of parking on a dead owner)
                    self._pending[k] = (token, threading.Event())
                self.misses += 1
                out.append(("own", None))
        return out

    def peek(self, k: tuple) -> Tuple[bool, Any]:
        """Non-claiming lookup; counts a hit when present (a sequential run
        would hit here). Used by the :class:`BatchCoalescer` at batch
        formation so cached rows never occupy a batch slot."""
        with self._lock:
            if k in self.data:
                self.hits += 1
                return True, self.data[k]
        return False, None

    def note_hits(self, n: int = 1) -> None:
        """Count hits resolved outside ``claim`` (coalescer followers:
        duplicate rows answered by an in-flight batch slot)."""
        with self._lock:
            self.hits += n

    def publish(self, k: tuple, value) -> None:
        with self._lock:
            self.data[k] = value
            pend = self._pending.pop(k, None)
        if pend is not None:
            pend[1].set()

    def release(self, keys: Sequence[tuple], token: object) -> None:
        """Abandon in-flight reservations (owner failed); waiters recompute."""
        events = []
        with self._lock:
            for k in keys:
                pend = self._pending.get(k)
                if pend is not None and pend[0] is token:
                    events.append(self._pending.pop(k)[1])
        for e in events:
            e.set()

    def wait_value(self, k: tuple,
                   event: threading.Event) -> Tuple[bool, Any]:
        event.wait()
        with self._lock:
            if k in self.data:
                return True, self.data[k]
        return False, None

    def close(self) -> None:
        """Terminal, idempotent shutdown — the cache's *creator* calls
        this once nothing should be waiting anymore: every in-flight
        reservation is released (threads blocked in ``wait_value`` on an
        owner that will never publish unblock and recompute solo) and no
        NEW reservation is ever created, so a draining server cannot
        re-grow waiters it just released. Published data stays readable,
        but single-flight dedupe is off from here on — do not close a
        cache other live contexts still execute against."""
        with self._lock:
            pending = list(self._pending.values())
            self._pending.clear()
            self.closed = True
        for _, event in pending:
            event.set()


# ---------------------------------------------------------------------------
# Fault tolerance: call policy, retries, circuit breaker, tier fallback
# ---------------------------------------------------------------------------

class TransientCallError(RuntimeError):
    """A backend call failed in a way a retry may fix (the kind of error
    a real LLM endpoint returns for overload / 5xx / connection resets).
    Chaos harnesses (``testing.FlakyBackend``) raise it; policies retry
    any ``Exception``, but this type names the contract."""


class CallTimeoutError(TransientCallError):
    """A backend call exceeded the policy's per-call deadline."""


class ShardDeadError(RuntimeError):
    """Raised when work is routed to a shard marked dead and no live
    shard remains to absorb it."""


# negative-int markers appended to logical meter keys so retried / fallback
# attempts sort deterministically next to their primary attempt in a merged
# log without ever colliding with real chunk ordinals (>= 0). The cascade
# already reserves -1 for its embed pass.
RETRY_KEY_MARK = -2
FALLBACK_KEY_MARK = -3

_CALL_LOCAL = threading.local()


def current_call_timeout() -> Optional[float]:
    """The per-call deadline (seconds) installed by the active
    :class:`CallPolicy` for the backend call running on this thread, or
    None when no policy is enforcing one. Backends that can bound their
    own work (and fault harnesses deciding whether a call "times out")
    read it here — the policy layer cannot preempt a running call, so the
    deadline is cooperative."""
    return getattr(_CALL_LOCAL, "timeout_s", None)


@contextlib.contextmanager
def _call_deadline(timeout_s: Optional[float]):
    prev = getattr(_CALL_LOCAL, "timeout_s", None)
    _CALL_LOCAL.timeout_s = timeout_s
    try:
        yield
    finally:
        _CALL_LOCAL.timeout_s = prev


@dataclasses.dataclass(frozen=True)
class CallPolicy:
    """Per-call fault-tolerance policy (all defaults = fail-fast, i.e.
    today's behaviour; an all-default policy is *inactive* and the
    runtime takes the exact pre-policy code paths, byte for byte).

    ``retries``             extra attempts per backend call after the
                            first failure.
    ``call_timeout_s``      cooperative per-call deadline, surfaced to
                            backends via :func:`current_call_timeout`.
    ``backoff_s``           base backoff between attempts. Sleeps only
                            happen under the threads driver; the delay is
                            deterministic — ``backoff_s * attempt *
                            unit_hash(seed, key, attempt)`` — so a fixed
                            fault plan reproduces the same schedule.
    ``retry_budget``        global cap on retry attempts across the whole
                            dispatcher (None = unlimited). Exhausted
                            budget = no more retries, straight to
                            fallback/raise.
    ``breaker_threshold``   consecutive *exhausted* calls on one
                            (tier, shard) before its circuit opens and
                            calls skip straight to the fallback
                            (0 = breaker disabled). A tripped breaker
                            stays open for the dispatcher's lifetime.
    ``fallback_tier``       sibling tier that serves a call once its
                            primary exhausts retries or its breaker is
                            open (None = re-raise). Fallback calls bill
                            under the fallback tier's own name with a
                            ``FALLBACK_KEY_MARK`` key suffix, so the
                            substitution is visible in the log and the
                            CostModel calibrates the tier that actually
                            served.
    ``shard_failure_threshold``  consecutive failed calls on one shard
                            before ``ShardedDispatcher`` declares the
                            shard dead and requeues its pending work
                            (None = detection off; ``kill_shard`` only).
    ``seed``                seed for the deterministic backoff jitter.
    """

    retries: int = 0
    call_timeout_s: Optional[float] = None
    backoff_s: float = 0.0
    retry_budget: Optional[int] = None
    breaker_threshold: int = 0
    fallback_tier: Optional[str] = None
    shard_failure_threshold: Optional[int] = None
    seed: int = 0

    @property
    def active(self) -> bool:
        """Whether the per-call layer must engage. All-default policies
        (and ones that only set ``shard_failure_threshold``) keep the
        pre-policy call path."""
        return (self.retries > 0 or self.call_timeout_s is not None
                or self.breaker_threshold > 0
                or self.fallback_tier is not None
                or self.retry_budget is not None)


class FaultPolicyRuntime:
    """Shared mutable state enforcing one :class:`CallPolicy` across a
    dispatcher: retry-budget counter, per-(tier, shard) breaker state,
    and fault statistics. One instance is shared by every inner shard
    dispatcher so the budget and breakers are global to the execution.

    ``invoke`` wraps exactly one logical backend call (one chunk). It
    sits *below* the :class:`OutputCache` — retries re-run only the
    failed chunk, and a call ultimately served by the fallback tier still
    publishes under the primary tier's cache key (the cache stores the
    logical call's answer, whatever tier produced it)."""

    def __init__(self, policy: CallPolicy,
                 backends: Optional[Dict[str, Any]] = None,
                 real_time: bool = False):
        self.policy = policy
        self.backends = dict(backends or {})
        self.real_time = bool(real_time)
        self._lock = threading.Lock()
        self._consec: Dict[Tuple[str, int], int] = {}
        self._open: set = set()
        self._retries_spent = 0
        self.stats = {"attempts": 0, "retries": 0, "failures": 0,
                      "exhausted": 0, "breaker_trips": 0,
                      "fallback_calls": 0, "budget_denied": 0}

    # -- breaker ---------------------------------------------------------
    def breaker_open(self, tier_name: str, shard: int) -> bool:
        if self.policy.breaker_threshold <= 0:
            return False
        with self._lock:
            return (tier_name, shard) in self._open

    def _note_result(self, tier_name: str, shard: int, ok: bool) -> None:
        th = self.policy.breaker_threshold
        if th <= 0:
            return
        k = (tier_name, shard)
        with self._lock:
            if ok:
                self._consec[k] = 0
                return
            n = self._consec.get(k, 0) + 1
            self._consec[k] = n
            if n >= th and k not in self._open:
                self._open.add(k)
                self.stats["breaker_trips"] += 1

    def reset_breakers(self) -> None:
        """Close every open breaker (operator intervention; nothing in
        the hot path re-closes one)."""
        with self._lock:
            self._open.clear()
            self._consec.clear()

    # -- retry budget / backoff -----------------------------------------
    def _take_retry_token(self) -> bool:
        budget = self.policy.retry_budget
        with self._lock:
            if budget is not None and self._retries_spent >= budget:
                self.stats["budget_denied"] += 1
                return False
            self._retries_spent += 1
            return True

    def _backoff(self, key: Optional[tuple], attempt: int) -> None:
        base = self.policy.backoff_s
        if base <= 0.0 or not self.real_time:
            return   # simulated driver: backoff is modeled as zero-cost
        jitter = bk._unit_hash("backoff", self.policy.seed,
                               repr(key), attempt)
        time.sleep(base * attempt * (0.5 + 0.5 * jitter))

    # -- fallback --------------------------------------------------------
    def fallback_backend(self, tier_name: str):
        fb = self.policy.fallback_tier
        if fb is None or fb == tier_name:
            return None, None
        backend = self.backends.get(fb)
        if backend is None:
            return None, None
        return fb, backend

    def _run_fallback(self, fb_backend, op, values, meter, batch_size,
                      key: Optional[tuple]):
        with self._lock:
            self.stats["fallback_calls"] += 1
        fkey = None if key is None else tuple(key) + (FALLBACK_KEY_MARK,)
        with _call_deadline(self.policy.call_timeout_s):
            if fkey is None:
                return fb_backend.run_values(op, values, meter=meter,
                                             batch_size=batch_size)
            with meter.keyed(fkey):
                return fb_backend.run_values(op, values, meter=meter,
                                             batch_size=batch_size)

    # -- the call wrapper ------------------------------------------------
    def invoke(self, backend, tier_name: str, op, values, meter,
               batch_size: int, key: Optional[tuple],
               shard: int = 0) -> List[Any]:
        pol = self.policy
        fb_name, fb_backend = self.fallback_backend(tier_name)
        if fb_backend is not None and self.breaker_open(tier_name, shard):
            return self._run_fallback(fb_backend, op, values, meter,
                                      batch_size, key)
        last: Optional[BaseException] = None
        for attempt in range(max(0, pol.retries) + 1):
            if attempt > 0 and not self._take_retry_token():
                break
            self._backoff(key, attempt)
            akey = key if (attempt == 0 or key is None) \
                else tuple(key) + (RETRY_KEY_MARK, attempt)
            with self._lock:
                self.stats["attempts"] += 1
                if attempt > 0:
                    self.stats["retries"] += 1
            try:
                with _call_deadline(pol.call_timeout_s):
                    if akey is None:
                        outs = backend.run_values(op, values, meter=meter,
                                                  batch_size=batch_size)
                    else:
                        with meter.keyed(akey):
                            outs = backend.run_values(
                                op, values, meter=meter,
                                batch_size=batch_size)
                self._note_result(tier_name, shard, ok=True)
                return outs
            except Exception as e:
                last = e
                with self._lock:
                    self.stats["failures"] += 1
        with self._lock:
            self.stats["exhausted"] += 1
        self._note_result(tier_name, shard, ok=False)
        if fb_backend is not None:
            return self._run_fallback(fb_backend, op, values, meter,
                                      batch_size, key)
        assert last is not None
        raise last

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            out = dict(self.stats)
            out["open_breakers"] = sorted(self._open)
            out["retry_budget_spent"] = self._retries_spent
        return out


def run_backend_calls(op: plan_ir.Operator, values: Sequence[Any], backend,
                      meter: bk.UsageMeter, batch_size: int = 1,
                      fanout: Optional[Callable] = None,
                      key: Optional[tuple] = None,
                      policy: Optional[FaultPolicyRuntime] = None,
                      tier_name: str = "", shard: int = 0,
                      positions: Optional[Sequence[int]] = None,
                      on_chunk: Optional[Callable] = None) -> List[Any]:
    """Invoke the backend over ``values``. Without a ``fanout`` the whole
    request is one inline ``run_values`` (the backend batches internally).
    With a ``fanout`` — a callable mapping a list of thunks to their results,
    supplied by :class:`ThreadPoolDispatcher` — each batch-sized chunk
    becomes its own ``run_values`` call on the tier's worker pool, so the
    per-call latencies genuinely overlap. Chunk boundaries equal the
    backend's internal batching, so call counts and meter totals match the
    inline path exactly.

    ``key`` is the call site's logical identity (e.g. ``(op, morsel)``);
    it is re-entered as the meter's ambient key *inside* each thunk so the
    billed entries carry it even when they run on a tier-pool thread —
    ``UsageMeter.merge`` sorts by these keys for deterministic shard-merge
    logs.

    ``policy`` (an *active* :class:`FaultPolicyRuntime`) wraps every chunk
    call in retry/deadline/breaker/fallback enforcement. With a policy the
    inline (no-fanout) path chunks exactly like the fanout path and bills
    each chunk under ``key + (j,)`` — normalizing the per-attempt key shape
    across drivers so a seeded fault plan draws identically under both
    (without a policy the inline path is byte-identical to the pre-policy
    runtime, including key shapes).

    ``positions`` (parallel to ``values``) are each value's index in the
    call site's *full* row set — the cache layer passes the ``own``
    indices so a value set that is a union of whole original chunks
    (e.g. a shard-death requeue whose completed chunks now cache-hit)
    bills each chunk under its ORIGINAL index, keeping the merged log
    byte-identical to a healthy run. When the chunk-aligned grouping
    does not reproduce the compact chunking (cache holes inside a
    chunk), compact indices are kept — exactly the pre-existing
    behaviour. ``on_chunk(chunk_positions, chunk_outputs)`` fires the
    moment each chunk's call returns (on the pool thread under a
    ``fanout``); the cache layer uses it for incremental publishing so
    sibling-chunk failure never discards completed work."""
    values = list(values)
    policed = policy is not None and policy.policy.active
    if fanout is None and not policed:
        if key is None:
            return backend.run_values(op, values, meter=meter,
                                      batch_size=batch_size)
        with meter.keyed(key):
            return backend.run_values(op, values, meter=meter,
                                      batch_size=batch_size)
    if op.kind == plan_ir.REDUCE:
        groups = [(0, list(range(len(values))))]
    else:
        step = max(1, int(batch_size))
        compact = [list(range(i, min(i + step, len(values))))
                   for i in range(0, len(values), step)]
        groups = list(enumerate(compact))
        if positions is not None:
            by_chunk: Dict[int, List[int]] = {}
            for idx, p in enumerate(positions):
                by_chunk.setdefault(p // step, []).append(idx)
            aligned = sorted(by_chunk.items())
            if [g for _, g in aligned] == compact:
                groups = aligned

    def call(idxs, j):
        c = [values[i] for i in idxs]
        ck = None if key is None else tuple(key) + (j,)
        if policed:
            out = policy.invoke(backend, tier_name, op, c, meter,
                                batch_size, ck, shard=shard)
        elif ck is None:
            out = backend.run_values(op, c, meter=meter,
                                     batch_size=batch_size)
        else:
            with meter.keyed(ck):
                out = backend.run_values(op, c, meter=meter,
                                         batch_size=batch_size)
        if on_chunk is not None and positions is not None:
            on_chunk([positions[i] for i in idxs], out)
        return out

    if fanout is None:
        return [o for j, idxs in groups for o in call(idxs, j)]
    thunks = [(lambda idxs=idxs, j=j: call(idxs, j))
              for j, idxs in groups]
    return [o for part in fanout(thunks) for o in part]


def run_llm_op(op: plan_ir.Operator, values, backend, tier_name: str,
               meter: bk.UsageMeter, *, batch_size: int = 1,
               cache: Optional[OutputCache] = None,
               fanout: Optional[Callable] = None,
               key: Optional[tuple] = None,
               policy: Optional[FaultPolicyRuntime] = None,
               shard: int = 0):
    """Execute one LLM operator, via the cache when provided. Returns
    (outputs, n_calls_made, latency_of_calls_made).

    ``fanout`` (see :func:`run_backend_calls`) runs the backend calls on a
    tier worker pool; the returned call/latency deltas are then approximate
    (other threads may bill the same tier concurrently) — callers on the
    threaded path ignore them and read the meter instead.

    ``policy``/``shard`` thread fault-tolerance enforcement down to every
    chunk call (see :class:`FaultPolicyRuntime`). Retries happen *below*
    the cache layer: a call that ultimately succeeds (retried or served by
    the fallback tier) publishes under its primary-tier cache key."""
    values = list(values)
    before_calls = meter.calls(tier_name)
    before_lat = meter.latency(tier_name)

    def deltas(ran_calls: bool):
        if fanout is not None:
            return 0, 0.0
        if not ran_calls:
            return 0, 0.0
        return (meter.calls(tier_name) - before_calls,
                meter.latency(tier_name) - before_lat)

    if cache is None:
        outs = run_backend_calls(op, values, backend, meter, batch_size,
                                 fanout, key=key, policy=policy,
                                 tier_name=tier_name, shard=shard)
        n, lat = deltas(True)
        return outs, n, lat

    token = object()
    if op.kind == plan_ir.REDUCE:
        rkey = cache.key(op, tier_name, batch_size,
                         "\x1e".join(_vkey(v) for v in values))
        state, got = cache.claim([rkey], token)[0]
        if state == "hit":
            return [got], 0, 0.0
        if state == "wait":
            ok, val = cache.wait_value(rkey, got)
            if ok:
                return [val], 0, 0.0
            state, got = cache.claim([rkey], token)[0]  # owner failed
            if state == "hit":
                return [got], 0, 0.0
        try:
            outs = run_backend_calls(op, values, backend, meter, batch_size,
                                     fanout, key=key, policy=policy,
                                     tier_name=tier_name, shard=shard)
        except BaseException:
            cache.release([rkey], token)
            raise
        cache.publish(rkey, outs[0])
        n, lat = deltas(True)
        return [outs[0]], n, lat

    keys = [cache.key(op, tier_name, batch_size, v) for v in values]
    states = cache.claim(keys, token)
    own = [i for i, (s, _) in enumerate(states) if s == "own"]
    outs: List[Any] = [None] * len(values)
    try:
        if own:
            def publish_chunk(poss, got_c):
                # incremental publish: a chunk's outputs become cache
                # hits the moment its call returns, so a requeued morsel
                # whose sibling chunks died (shard loss) re-resolves the
                # completed chunks as hits instead of re-billing them —
                # the exactly-once guarantee for partial fanout failure
                for p, o in zip(poss, got_c):
                    cache.publish(keys[p], o)

            got = run_backend_calls(op, [values[i] for i in own], backend,
                                    meter, batch_size, fanout, key=key,
                                    policy=policy, tier_name=tier_name,
                                    shard=shard, positions=own,
                                    on_chunk=publish_chunk)
            for i, o in zip(own, got):
                outs[i] = o
                cache.publish(keys[i], o)
    except BaseException:
        cache.release([keys[i] for i in own], token)
        raise
    for i, (s, v) in enumerate(states):
        if s == "hit":
            outs[i] = v
        elif s == "wait":
            ok, val = cache.wait_value(keys[i], v)
            if not ok:   # the owning caller failed: compute solo
                val = run_backend_calls(op, [values[i]], backend, meter,
                                        batch_size, fanout, key=key,
                                        policy=policy, tier_name=tier_name,
                                        shard=shard)[0]
                cache.publish(keys[i], val)
            outs[i] = val
    n, lat = deltas(bool(own))
    return outs, n, lat


# ---------------------------------------------------------------------------
# Shared operator application (executor + physical-optimizer sample flow)
# ---------------------------------------------------------------------------

def bool_mask(outs) -> List[bool]:
    """Parse LLM filter outputs into a row mask (the one shared parser)."""
    return [o if isinstance(o, bool) else
            str(o).strip().lower().startswith(("true", "yes"))
            for o in outs]


def rank_scores(outs) -> List[float]:
    """Parse RANK outputs into similarity scores. Real LLMs return digits
    as *strings*, so numeric text parses as a score. ``bool`` is an ``int``
    subclass — True/False are filter-shaped answers, not scores — and any
    unparseable output falls back to the row's input position."""
    sims: List[float] = []
    for i, o in enumerate(outs):
        if isinstance(o, (int, float)) and not isinstance(o, bool):
            sims.append(float(o))
            continue
        try:
            sims.append(float(str(o).strip()))
        except (TypeError, ValueError):
            sims.append(float(i))
    return sims


def _rank_column(sims) -> List[int]:
    order = sorted(range(len(sims)), key=lambda i: sims[i], reverse=True)
    ranks = [0] * len(order)
    for r, i in enumerate(order):
        ranks[i] = r
    return ranks


def apply_outputs(op: plan_ir.Operator, table: Table,
                  outs) -> Tuple[Table, Any]:
    """Fold one LLM operator's outputs into the table.

    Returns ``(table, scalar)``; scalar is meaningful only for reduce."""
    if op.kind == plan_ir.FILTER:
        return table.select(bool_mask(outs)), None
    if op.kind == plan_ir.MAP:
        return table.with_column(op.output_column, outs), None
    if op.kind == plan_ir.REDUCE:
        return table, outs[0]
    return table.with_column(op.output_column or "rank",
                             _rank_column(rank_scores(outs)), "numeric"), None


def run_udf_op(op: plan_ir.Operator, table: Table,
               values) -> Tuple[Table, Any]:
    """Run one compiled-UDF operator natively (no LLM calls).

    Generated UDFs are format-fragile by design (paper Fig. 12b); a row
    that crashes one yields the kind's null answer."""
    compiled = udf_mod.resolve_udf(op)

    def safe(v, default=None):
        try:
            return compiled.fn(v)
        except Exception:
            return default

    if op.kind == plan_ir.FILTER:
        return table.select([bool(safe(v, False)) for v in values]), None
    if op.kind == plan_ir.MAP:
        return table.with_column(op.output_column,
                                 [safe(v) for v in values]), None
    if op.kind == plan_ir.REDUCE:
        return table, safe(list(values))
    order = safe(list(values), list(range(len(values))))
    ranks = [0] * len(order)
    for r, i in enumerate(order):
        ranks[i] = r
    return table.with_column(op.output_column or "rank", ranks,
                             "numeric"), None


# ---------------------------------------------------------------------------
# Dispatchers: simulated (event-model) vs threads (measured)
# ---------------------------------------------------------------------------

class _DoneTask:
    """An already-completed morsel task."""
    __slots__ = ("_value", "finish")

    def __init__(self, value, finish: float = 0.0):
        self._value = value
        self.finish = finish

    def result(self):
        return self._value, self.finish


class _FutureTask:
    """A morsel task running on the chain pool."""
    __slots__ = ("_fut",)

    def __init__(self, fut: Future):
        self._fut = fut

    def result(self):
        return self._fut.result()


class Dispatcher:
    """How operator work runs: the executor hands every morsel step and
    every backend call to a dispatcher, which either simulates overlap
    (:class:`SimulatedDispatcher`) or provides it for real
    (:class:`ThreadPoolDispatcher`). Both expose the same task interface:

      done(value, finish)         wrap an immediate morsel
      defer(task, fn)             fn(value, ready_s) -> (value, finish_s)
                                  after ``task`` completes
      run_llm(...) / run_host(..) one operator's backend / host work
      checkpoint(meter, cursor)   optimizer stage boundary (drain+barrier
                                  under simulation, no-op under threads)
      wall_s                      modeled makespan / measured elapsed

    The shard hooks (``n_shards`` / ``shard_of`` / the ``shard=`` keyword
    on defer/run_llm/run_host, ``meter_for`` and ``finalize``) are no-ops
    on the single-host dispatchers; ``distributed.morsel_shards.
    ShardedDispatcher`` overrides them to route morsels to per-shard
    worker pools and stage per-shard meters.
    """

    kind = "abstract"
    n_shards = 1
    # the dispatcher-wide FaultPolicyRuntime (None = fail-fast); set by
    # the concrete drivers' constructors when an active CallPolicy is
    # configured on the ExecutionContext
    policy: Optional[FaultPolicyRuntime] = None

    def fault_stats(self) -> Optional[Dict[str, Any]]:
        """Snapshot of the fault-policy counters (attempts, retries,
        breaker trips, fallback calls, open breakers); None when no
        policy is active."""
        pol = self.policy
        return None if pol is None else pol.snapshot()

    def shard_of(self, morsel_idx: int, query=None) -> int:
        """Which shard owns morsel ``morsel_idx`` (round-robin when
        sharded; always 0 on single-host dispatchers). ``query`` is the
        admitting query's id on a shared server — a sharded dispatcher
        offsets each query's round-robin cursor so concurrently admitted
        queries spread across shards instead of all starting on shard 0."""
        return 0

    def release_query(self, query) -> None:
        """Drop per-query routing state (the round-robin cursor offset);
        the executor calls this once per keyed execution. No-op on
        single-host dispatchers."""

    def meter_for(self, meter: bk.UsageMeter, shard: int) -> bk.UsageMeter:
        """The meter a call on ``shard`` should bill into (a per-shard
        staging meter when sharded, ``meter`` itself otherwise)."""
        return meter

    def finalize(self, meter: bk.UsageMeter) -> None:
        """Merge any per-shard staging for ``meter`` back into it
        (deterministic combined call log). No-op on single-host
        dispatchers; the executor calls this once per execution."""

    def done(self, value, finish: float = 0.0) -> _DoneTask:
        return _DoneTask(value, finish)

    def run_udf(self, op, table, values, ready_s: float = 0.0,
                shard: int = 0):
        """One compiled-UDF operator step. Default: host work under
        :meth:`run_host` (simulated cost model / host-lock serialization).
        The ``procs`` driver overrides this to execute the step in a
        worker process, GIL-free. Returns ``((table, scalar), finish_s)``."""
        return self.run_host(lambda: run_udf_op(op, table, values),
                             table.n_rows, ready_s=ready_s, shard=shard)

    def occupancy(self) -> Dict[str, List[float]]:
        """Per-tier busy-until offsets (seconds of remaining work per
        occupied worker slot) for seeding a ``CostModel`` makespan replay.
        Empty on dispatchers with no cheap occupancy signal — an empty
        seed just means the replay assumes idle pools."""
        return {}

    def fanout(self, tier_name: str) -> Optional[Callable]:
        """Per-tier call fanout for :func:`run_backend_calls`; None means
        run inline (sequential)."""
        return None

    def close(self) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class SimulatedDispatcher(Dispatcher):
    """Inline execution + EventScheduler replay (deterministic wall model)."""

    kind = "simulated"

    def __init__(self, scheduler: EventScheduler,
                 policy: Optional[FaultPolicyRuntime] = None):
        self.sched = scheduler
        self.policy = policy

    def defer(self, task, fn, shard: int = 0):
        value, ready = task.result()
        return _DoneTask(*fn(value, ready))

    def run_llm(self, op, values, backend, tier_name, meter, *,
                batch_size: int = 1, cache: Optional[OutputCache] = None,
                ready_s: float = 0.0, shard: int = 0,
                key: Optional[tuple] = None):
        cursor = len(meter.call_log)
        outs, _, _ = run_llm_op(op, values, backend, tier_name, meter,
                                batch_size=batch_size, cache=cache, key=key,
                                policy=self.policy, shard=shard)
        _, finish = self.sched.drain(meter, cursor, ready_s=ready_s)
        return outs, finish

    def run_host(self, fn, n_rows: int, ready_s: float = 0.0,
                 shard: int = 0):
        finish = self.sched.submit(HOST_TIER,
                                   n_rows * UDF_SECONDS_PER_ROW,
                                   ready_s=ready_s)
        return fn(), finish

    def checkpoint(self, meter: bk.UsageMeter, cursor: int) -> int:
        cursor, _ = self.sched.drain(meter, cursor)
        self.sched.barrier()
        return cursor

    def occupancy(self) -> Dict[str, List[float]]:
        sched = self.sched
        with sched._elock:
            now = sched._floor
            out: Dict[str, List[float]] = {}
            for key, pool in sched._pools.items():
                if key in (HOST_TIER, "\x00sync"):
                    continue
                busy = [t - now for t in pool if t > now]
                if busy:
                    out[key] = sorted(busy)
            return out

    @property
    def wall_s(self) -> float:
        return self.sched.makespan


class ThreadPoolDispatcher(Dispatcher):
    """Real concurrency: per-tier bounded worker pools for backend calls
    (pool caps = serving quotas) plus a chain pool that advances morsel
    pipelines. ``wall_s`` is measured (construction -> last completion).

    Liveness: the executor submits morsel tasks in operator order, so every
    chain task's dependency sits *earlier* in the chain pool's FIFO queue —
    a blocked worker always waits on a task some other worker has already
    dequeued, and tier pools (which never block on chain tasks) guarantee
    progress. ``mode="sync"`` collapses every tier onto one shared
    single-worker pool, the threaded analogue of sequential accounting."""

    kind = "threads"

    def __init__(self, concurrency: int = 16,
                 per_tier: Optional[Dict[str, int]] = None,
                 mode: str = "async", chain_workers: int = 32,
                 host_lock: Optional[threading.Lock] = None,
                 policy: Optional[FaultPolicyRuntime] = None):
        if mode not in ("sync", "async"):
            raise ValueError(f"unknown dispatcher mode {mode!r}")
        self.mode = mode
        self.policy = policy
        self.concurrency = max(1, int(concurrency))
        self.per_tier = dict(per_tier or {})
        self._pools: Dict[str, ThreadPoolExecutor] = {}
        self._lock = threading.Lock()
        self._chain = ThreadPoolExecutor(max_workers=max(1, chain_workers),
                                         thread_name_prefix="morsel")
        # shard workers in one process share a host lock (UDF compute is
        # one Python interpreter no matter how many shards dispatch it)
        self._host_lock = host_lock if host_lock is not None \
            else threading.Lock()
        # in-flight backend-call tracking for occupancy(): tier ->
        # {flight id: start perf_counter}, plus a per-tier EWMA of call
        # duration to turn "started t ago" into "busy for ~d more"
        self._inflight: Dict[str, Dict[int, float]] = {}
        self._ewma: Dict[str, float] = {}
        self._seq = 0
        self._t0 = time.perf_counter()
        self._last = self._t0

    def workers(self, tier: str) -> int:
        if self.mode == "sync":
            return 1
        return max(1, int(self.per_tier.get(tier, self.concurrency)))

    def _pool(self, tier: str) -> ThreadPoolExecutor:
        key = tier if self.mode != "sync" else "\x00sync"
        with self._lock:
            pool = self._pools.get(key)
            if pool is None:
                pool = ThreadPoolExecutor(max_workers=self.workers(tier))
                self._pools[key] = pool
            return pool

    def _touch(self) -> None:
        now = time.perf_counter()
        with self._lock:
            if now > self._last:
                self._last = now

    def _tracked(self, tier_name: str, thunk):
        """Wrap a tier-pool thunk so occupancy() can see it in flight."""
        def run():
            with self._lock:
                self._seq += 1
                tid = self._seq
                self._inflight.setdefault(tier_name, {})[tid] = \
                    time.perf_counter()
            try:
                return thunk()
            finally:
                now = time.perf_counter()
                with self._lock:
                    t0 = self._inflight.get(tier_name, {}).pop(tid, now)
                    prev = self._ewma.get(tier_name)
                    dt = now - t0
                    self._ewma[tier_name] = dt if prev is None \
                        else 0.8 * prev + 0.2 * dt
        return run

    def occupancy(self) -> Dict[str, List[float]]:
        """Estimated remaining-busy offsets per tier from calls currently
        in flight: EWMA(call duration) minus elapsed, floored at ~0 —
        the measured-driver analogue of the event scheduler's busy-until
        pool state, good enough to seed a makespan replay."""
        now = time.perf_counter()
        with self._lock:
            out: Dict[str, List[float]] = {}
            for tier, flights in self._inflight.items():
                if not flights:
                    continue
                est = self._ewma.get(tier, 0.0)
                out[tier] = sorted(max(est - (now - t0), 1e-6)
                                   for t0 in flights.values())
            return out

    def fanout(self, tier_name: str) -> Callable:
        pool = self._pool(tier_name)

        def fan(thunks):
            futs = [pool.submit(self._tracked(tier_name, t))
                    for t in thunks]
            # settle EVERY thunk before surfacing the first failure: a
            # caller's cleanup (per-query meter finalize on a shared
            # dispatcher) must not run while sibling chunks of the same
            # call are still billing
            res, first = [], None
            for f in futs:
                try:
                    res.append(f.result())
                except BaseException as e:
                    if first is None:
                        first = e
            self._touch()
            if first is not None:
                raise first
            return res

        return fan

    def defer(self, task, fn, shard: int = 0):
        def chain():
            value, ready = task.result()
            return fn(value, ready)

        return _FutureTask(self._chain.submit(chain))

    def run_llm(self, op, values, backend, tier_name, meter, *,
                batch_size: int = 1, cache: Optional[OutputCache] = None,
                ready_s: float = 0.0, shard: int = 0,
                key: Optional[tuple] = None):
        outs, _, _ = run_llm_op(op, values, backend, tier_name, meter,
                                batch_size=batch_size, cache=cache,
                                fanout=self.fanout(tier_name), key=key,
                                policy=self.policy, shard=shard)
        return outs, 0.0

    def run_host(self, fn, n_rows: int, ready_s: float = 0.0,
                 shard: int = 0):
        # one Python process: host UDF work serializes against itself but
        # overlaps in-flight backend I/O
        with self._host_lock:
            out = fn()
        self._touch()
        return out, 0.0

    def checkpoint(self, meter: bk.UsageMeter, cursor: int) -> int:
        return len(meter.call_log)

    @property
    def wall_s(self) -> float:
        with self._lock:
            return max(0.0, self._last - self._t0)

    def abandon(self) -> None:
        """Non-blocking teardown for a killed shard worker: already
        *running* calls complete (and bill exactly once into their
        staging meter); *queued* tasks are cancelled so the owning
        ``ShardedDispatcher`` can requeue them onto surviving shards.
        Idempotent; a later ``close()`` is a no-op on the same pools."""
        self._chain.shutdown(wait=False, cancel_futures=True)
        with self._lock:
            pools = list(self._pools.values())
            self._pools.clear()
        for p in pools:
            p.shutdown(wait=False, cancel_futures=True)

    def close(self) -> None:
        self._chain.shutdown(wait=True)
        with self._lock:
            pools = list(self._pools.values())
            self._pools.clear()
        for p in pools:
            p.shutdown(wait=True)


DRIVERS = ("simulated", "threads")


# ---------------------------------------------------------------------------
# Cross-morsel batch coalescing
# ---------------------------------------------------------------------------

class _MorselState:
    """Per-(operator, morsel) resolution buffer: row outputs fill in as the
    batches containing them flush; ``fut`` completes with
    ``(outs, finish_s)`` once every row is resolved.

    A failed batch poisons its rows via :meth:`poison_row` — the morsel's
    future then completes *exceptionally*, but only once every one of its
    rows has settled (resolved by a sibling batch or poisoned too). Failing
    the future the moment any batch died would let the poisoned morsel's
    chain unwind — and the whole execution settle — while sibling batches
    holding this morsel's other rows are still billing calls, making the
    final meter racy; waiting for all rows keeps teardown deterministic."""

    __slots__ = ("outs", "remaining", "finish", "fut", "exc", "_lock")

    def __init__(self, n: int, ready: float):
        self.outs: List[Any] = [None] * n
        self.remaining = n
        self.finish = ready
        self.fut: Future = Future()
        self.exc: Optional[BaseException] = None
        self._lock = threading.Lock()

    def _settle(self) -> None:
        if self.fut.done():
            return
        try:
            if self.exc is not None:
                self.fut.set_exception(self.exc)
            else:
                self.fut.set_result((self.outs, self.finish))
        except Exception:
            pass                          # lost a race with fail()

    def resolve(self, pos: int, out, finish: float) -> None:
        with self._lock:
            self.outs[pos] = out
            if finish > self.finish:
                self.finish = finish
            self.remaining -= 1
            done = self.remaining == 0
        if done:
            self._settle()

    def poison_row(self, pos: int, exc: BaseException) -> None:
        with self._lock:
            if self.exc is None:
                self.exc = exc            # first failure wins
            self.remaining -= 1
            done = self.remaining == 0
        if done:
            self._settle()

    def fail(self, exc: BaseException) -> None:
        """Terminal close path (coalescer shutdown): complete the future
        exceptionally NOW, regardless of unsettled rows."""
        if not self.fut.done():
            try:
                self.fut.set_exception(exc)
            except Exception:
                pass                      # lost a race with set_result


class _Slot:
    """One occupied batch slot: a leader value plus every (morsel, row)
    resolved by it — cross-morsel duplicates attach as followers instead
    of taking their own slot (dedupe *before* batch formation)."""

    __slots__ = ("value", "key", "ready", "targets")

    def __init__(self, value, key, ready: float, target):
        self.value = value
        self.key = key
        self.ready = ready
        self.targets = [target]           # [(morsel_state, row_pos)]


class _Batch:
    __slots__ = ("slots", "ready", "seq", "shard")

    def __init__(self, slots: List[_Slot], ready: float, seq: int = 0,
                 shard: int = 0):
        self.slots = slots
        self.ready = ready
        self.seq = seq           # formation ordinal within the op group
        self.shard = shard       # which (shard, tier) pool executes it


class _OpGroup:
    """One operator's accumulation queue inside a :class:`BatchCoalescer`.

    Submissions may arrive in any thread order; a reorder buffer admits
    them into batch formation strictly by morsel index, so the batches are
    the logical-row-order chunks whole-table batching would form —
    deterministic, and identical across drivers *and shard counts* (under
    a sharded dispatcher, formation stays global; only the execution of a
    flushed batch round-robins across the (shard, tier) pools by its
    formation ordinal)."""

    def __init__(self, coal: "BatchCoalescer", op, backend, tier_name: str,
                 expected: int, op_key: Optional[tuple] = None):
        self.coal = coal
        self.op = op
        self.backend = backend
        self.tier = tier_name
        self.op_key = op_key
        self.batch_seq = 0
        self.expected = max(1, int(expected))
        self.lock = threading.Lock()
        self.stash: Dict[int, tuple] = {}      # morsel idx -> (vals, rdy, st)
        self.next_idx = 0
        self.queue: List[_Slot] = []           # formation queue (partial)
        self.queue_ready = 0.0                 # max event-ready of queue
        self.queue_born = 0.0                  # event-ready of its 1st row
        self.queue_since = 0.0                 # wall time queue went nonempty
        self.inflight: Dict[tuple, _Slot] = {}  # cache key -> unresolved slot
        self.states: List[_MorselState] = []
        self.closed = False

    # -- submission ------------------------------------------------------
    def submit(self, idx: int, values: Sequence[Any],
               ready: float = 0.0) -> Future:
        """Register one morsel's surviving rows (possibly empty — empties
        still advance the watermark); returns the morsel's future."""
        values = list(values)
        state = _MorselState(len(values), ready)
        batches: List[_Batch] = []
        with self.lock:
            if self.closed:
                state.fail(RuntimeError("coalescer closed"))
                return state.fut
            if idx < self.next_idx or idx in self.stash:
                # duplicate submission (recovery path after a submit that
                # itself failed): don't wedge the reorder buffer
                state.fail(RuntimeError(f"morsel {idx} already submitted"))
                return state.fut
            self.states.append(state)
            self.stash[idx] = (values, ready, state)
            self._advance(batches)
        self._execute(batches)
        return state.fut

    def _advance(self, batches: List[_Batch]) -> None:
        """Admit contiguous stashed morsels (reorder buffer) into batch
        formation; cut full batches, the watermark partial, and — under
        the simulated driver — event-time linger partials. Lock held."""
        linger = self.coal.linger_s
        while self.next_idx in self.stash:
            values, ready, state = self.stash.pop(self.next_idx)
            self.next_idx += 1
            if (linger is not None and self.queue
                    and self.coal.disp.kind == "simulated"
                    and ready > self.queue_born + linger):
                # the next rows arrive (event time) after the partial's
                # linger deadline — anchored to the *oldest* queued row,
                # so the deadline cannot slide forward with each arrival
                # (mirrors the threads timer, which measures from
                # queue_since): launch the partial at the deadline
                self._cut(batches, partial=True,
                          launch=self.queue_born + linger)
            for pos, v in enumerate(values):
                self._enqueue_row(state, pos, v, ready, batches)
            if not values:
                state.fut.set_result(([], ready))
        if self.next_idx >= self.expected and self.queue:
            self._cut(batches, partial=len(self.queue) < self.coal.batch)

    def _enqueue_row(self, state: _MorselState, pos: int, v, ready: float,
                     batches: List[_Batch]) -> None:
        cache = self.coal.cache
        key = None
        if cache is not None:
            key = cache.key(self.op, self.tier, self.coal.batch, v)
            lead = self.inflight.get(key)
            if lead is not None:           # duplicate of a queued/in-flight
                lead.targets.append((state, pos))   # row: follow, no slot
                cache.note_hits(1)
                self.coal.stats["dedup_follows"] += 1
                return
            hit, val = cache.peek(key)
            if hit:
                state.resolve(pos, val, ready)
                return
        slot = _Slot(v, key, ready, (state, pos))
        if key is not None:
            self.inflight[key] = slot
        if not self.queue:
            self.queue_since = time.perf_counter()
            self.queue_born = ready
        self.queue.append(slot)
        if ready > self.queue_ready:
            self.queue_ready = ready
        self.coal.stats["rows"] += 1
        if len(self.queue) >= self.coal.batch:
            self._cut(batches, partial=False)

    def _cut(self, batches: List[_Batch], partial: bool,
             launch: Optional[float] = None) -> None:
        slots, self.queue = self.queue, []
        ready = launch if launch is not None else \
            max((s.ready for s in slots), default=0.0)
        self.queue_ready = 0.0
        seq = self.batch_seq
        self.batch_seq += 1
        batches.append(_Batch(slots, ready, seq,
                              seq % max(1, self.coal.disp.n_shards)))
        self.coal.stats["flushes"] += 1
        if partial:
            self.coal.stats["partial_flushes"] += 1

    # -- flush execution -------------------------------------------------
    def _execute(self, batches: List[_Batch]) -> None:
        """Run flushed batches outside the group lock. Under threads,
        several batches cut by one submission run concurrently on
        ephemeral threads — each still routes its backend call through the
        tier's bounded pool, so serving quotas hold and cache waits never
        occupy a tier worker (same liveness structure as morsel chains)."""
        if not batches:
            return
        if len(batches) == 1 or self.coal.disp.kind == "simulated":
            for b in batches:
                self._run_batch(b)
            return
        threads = [threading.Thread(target=self._run_batch, args=(b,),
                                    daemon=True) for b in batches]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    def _run_batch(self, b: _Batch) -> None:
        key = None if self.op_key is None \
            else tuple(self.op_key) + (b.seq,)
        try:
            outs, finish = self.coal.disp.run_llm(
                self.op, [s.value for s in b.slots], self.backend,
                self.tier, self.coal.meter, batch_size=self.coal.batch,
                cache=self.coal.cache, ready_s=b.ready, shard=b.shard,
                key=key)
        except BaseException as e:        # backend failure: poison the rows,
            self._fail_batch(b, e)        # don't hang downstream morsels
            return
        if len(outs) != len(b.slots):
            self._fail_batch(b, RuntimeError(
                f"backend returned {len(outs)} outputs for "
                f"{len(b.slots)} batched rows"))
            return
        with self.lock:
            for s in b.slots:
                if s.key is not None:
                    self.inflight.pop(s.key, None)
            targets = [(s.targets[:], out) for s, out in zip(b.slots, outs)]
        for tgts, out in targets:
            for state, pos in tgts:
                state.resolve(pos, out, finish)

    def _fail_batch(self, b: _Batch, exc: BaseException) -> None:
        """Poison every row this batch held. Row-level (not morsel-level):
        a morsel whose rows straddle several batches keeps its in-flight
        sibling batches running to completion — their calls bill
        deterministically — and its future completes exceptionally only
        once all its rows have settled."""
        with self.lock:
            for s in b.slots:
                if s.key is not None:
                    self.inflight.pop(s.key, None)
            targets = [t for s in b.slots for t in s.targets]
        for state, pos in targets:
            state.poison_row(pos, exc)

    def cut_expired(self, now: float) -> List[_Batch]:
        """Cut (but do not execute) a partial batch whose oldest row has
        waited longer than ``linger_s``. Lock-held and non-blocking, so
        the shared linger ticker can harvest expired batches from every
        group without ever waiting on a backend call."""
        batches: List[_Batch] = []
        with self.lock:
            if (self.queue and not self.closed
                    and self.coal.linger_s is not None
                    and now - self.queue_since >= self.coal.linger_s):
                self._cut(batches, partial=len(self.queue) < self.coal.batch)
        return batches

    def flush_expired(self, now: float) -> None:
        """Timer hook (threads driver): flush a partial batch whose oldest
        row has waited longer than ``linger_s``."""
        self._execute(self.cut_expired(now))

    def close(self, exc: Optional[BaseException] = None) -> None:
        with self.lock:
            self.closed = True
            states = self.states
        err = exc or RuntimeError("coalescer closed with pending rows")
        for st in states:
            if not st.fut.done():
                st.fail(err)


class _LingerTicker:
    """One process-wide ``coalesce-linger`` daemon serving *every*
    registered :class:`BatchCoalescer`.

    Per-coalescer timer threads multiply under sharded execution
    (shards x concurrent executions would each spawn one); instead every
    coalescer with a wall-time linger registers here, the single daemon
    ticks at a quarter of the smallest registered linger, and it parks
    (then exits) when the registry drains so idle processes carry no
    timer thread at all."""

    def __init__(self):
        self._lock = threading.Lock()
        self._coals: Dict[int, "BatchCoalescer"] = {}
        self._wake = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def register(self, coal: "BatchCoalescer") -> None:
        with self._lock:
            self._coals[id(coal)] = coal
            self._wake.set()
            if self._thread is None or not self._thread.is_alive():
                self._thread = threading.Thread(target=self._loop,
                                                name="coalesce-linger",
                                                daemon=True)
                self._thread.start()

    def unregister(self, coal: "BatchCoalescer") -> None:
        with self._lock:
            self._coals.pop(id(coal), None)

    def stop(self, timeout: float = 2.0) -> None:
        """Deterministic shutdown for long-lived processes: drop every
        registration and join the daemon (it parks, sees the empty
        registry, and exits). Idempotent; a later ``register`` simply
        starts a fresh daemon."""
        with self._lock:
            self._coals.clear()
            thread = self._thread
        if thread is not None and thread.is_alive():
            thread.join(timeout)

    def n_threads(self) -> int:
        """Live ticker threads (for tests: must never exceed 1)."""
        return sum(1 for t in threading.enumerate()
                   if t.name == "coalesce-linger" and t.is_alive())

    def _loop(self) -> None:
        while True:
            with self._lock:
                coals = list(self._coals.values())
                if not coals:
                    self._wake.clear()
            if not coals:
                if not self._wake.wait(timeout=0.25):
                    with self._lock:
                        if not self._coals:
                            self._thread = None
                            return
                continue
            tick = min(max(0.002, (c.linger_s or 0.01) / 4.0)
                       for c in coals)
            time.sleep(tick)
            now = time.perf_counter()
            for c in coals:
                c.tick(now)


_LINGER_TICKER = _LingerTicker()


class BatchCoalescer:
    """Cross-morsel batch packing for one execution (see module docstring).

    One instance serves one executor run; ``open`` registers an operator
    with its expected contributor count (= number of morsels entering it),
    and each morsel ``submit``s its rows once. ``stats`` records flushes,
    partial flushes, rows slotted, and follower dedupes — benchmarks and
    tests read it from ``ExecutionResult.coalesce_stats``. Wall-time
    linger flushes (threads driver) are driven by the shared
    :data:`_LINGER_TICKER` daemon, not a per-coalescer thread."""

    def __init__(self, dispatcher: Dispatcher, meter: bk.UsageMeter, *,
                 batch_size: int, cache: Optional[OutputCache] = None,
                 linger_s: Optional[float] = None):
        self.disp = dispatcher
        self.meter = meter
        self.batch = max(1, int(batch_size))
        self.cache = cache
        self.linger_s = linger_s
        self.stats = {"flushes": 0, "partial_flushes": 0, "rows": 0,
                      "dedup_follows": 0}
        self._groups: List[_OpGroup] = []
        self._lock = threading.Lock()
        self._ticking = False

    def open(self, op, backend, tier_name: str, expected: int,
             op_key: Optional[tuple] = None) -> _OpGroup:
        """Register one operator's accumulation group. ``op_key`` is the
        group's logical meter-key prefix — ``(op_index,)`` solo, or
        ``(query_id, op_index)`` under a query server, so concurrently
        admitted queries' batch calls never collide in a merged log."""
        with self._lock:
            if op_key is None:
                op_key = (len(self._groups),)
            g = _OpGroup(self, op, backend, tier_name, expected,
                         op_key=op_key)
            self._groups.append(g)
            need_tick = (self.linger_s is not None
                         and self.disp.kind != "simulated"
                         and not self._ticking)
            if need_tick:
                self._ticking = True
        if need_tick:
            _LINGER_TICKER.register(self)
        return g

    def tick(self, now: float) -> None:
        """Shared-ticker hook: flush partials whose linger expired.

        The cut happens here (cheap, lock-held, non-blocking) but the
        flushed batches execute on an ephemeral thread — the ticker
        daemon is shared by every coalescer in the process, so it must
        never block on one coalescer's backend call (a 2 s call would
        otherwise stall every other coalescer's linger deadline)."""
        with self._lock:
            groups = list(self._groups)
        work = [(g, b) for g in groups for b in [g.cut_expired(now)] if b]
        if not work:
            return

        def execute():
            for g, batches in work:
                g._execute(batches)

        threading.Thread(target=execute, name="coalesce-linger-flush",
                         daemon=True).start()

    def close(self, exc: Optional[BaseException] = None) -> None:
        """Deregister from the shared linger ticker and fail any
        unresolved morsel futures so blocked chain tasks unwind (error
        paths must not deadlock the dispatcher's chain-pool shutdown)."""
        with self._lock:
            was_ticking, self._ticking = self._ticking, False
        if was_ticking:
            _LINGER_TICKER.unregister(self)
        with self._lock:
            groups = list(self._groups)
        for g in groups:
            g.close(exc)


# ---------------------------------------------------------------------------
# Execution context
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ExecutionContext:
    """Everything an execution needs, in one object.

    ``concurrency`` is the default per-tier worker count;
    ``per_tier_concurrency`` overrides it for individual tiers (a weak tier
    served on many replicas can take more simultaneous calls than the
    flagship). ``morsel_size=0`` disables pipelining (whole-table barrier
    between operators — the seed executor's behaviour). ``driver`` selects
    how backend calls run: ``"simulated"`` (inline + event-scheduler wall
    model) or ``"threads"`` (per-tier worker pools, measured wall).

    ``coalesce`` (default on; only active with ``batch_size > 1``) routes
    streamable LLM operators through a :class:`BatchCoalescer`, packing
    rows from different morsels into full batches instead of paying
    per-morsel ragged-remainder calls; ``linger_s`` bounds how long a
    partial batch may wait for more rows before flushing (None = only the
    morsel-boundary watermark flushes partials).

    ``shards > 1`` runs the morsel stream through a
    ``distributed.morsel_shards.ShardedDispatcher``: morsels round-robin
    across shard workers, each with its own pool-per-(shard, tier)
    dispatcher under the selected ``driver``. Explicit
    ``per_tier_concurrency`` caps are treated as global serving quotas
    split across shards (remainder to shard 0); the default
    ``concurrency`` is each shard's own replica width. ``shard_cache``
    selects ``"shared"`` (default: one process-wide ``OutputCache``, so
    cross-shard duplicates bill once through the single-flight protocol
    and results/calls/meters are shard-count invariant) or ``"local"``
    (each shard memoizes independently — cheaper coordination, duplicate
    billing across shards).

    ``procs >= 1`` selects the third execution substrate: a
    ``ShardedDispatcher`` whose per-shard inner workers are spawned
    subprocesses (``distributed.process_workers``) — backend calls and
    host UDFs run GIL-free in the workers while the coordinator keeps
    the shared cache, fault policy, and meter merge. Mutually exclusive
    with ``shards > 1`` (both pick a shard topology).

    ``cascade`` (a ``core.cascade.CascadeRouter`` or None) enables the
    tier-0 embedding cascade: SEM_FILTER/RANK operators with bands score
    every morsel in one batched device pass and only the uncertain band
    escalates to the LLM tier. Typed ``Any`` to keep this module free of
    the kernels import chain."""
    backends: Dict[str, bk.Backend]
    default_tier: str = "m*"
    concurrency: int = 16
    per_tier_concurrency: Optional[Dict[str, int]] = None
    batch_size: int = 1
    morsel_size: int = DEFAULT_MORSEL_ROWS
    mode: str = "async"
    driver: str = "simulated"
    coalesce: bool = True
    linger_s: Optional[float] = None
    shards: int = 1
    shard_cache: str = "shared"
    # > 0: that many process shard workers (GIL-free morsel execution);
    # the `driver` field then only governs any coordinator-side work
    procs: int = 0
    cascade: Optional[Any] = None
    cache: Optional[OutputCache] = None
    # the calibrated estimation surface (core.cost_model.CostModel) this
    # execution's optimizers price with and the executor's finalize sync
    # point feeds (CostModel.observe). None = uncalibrated library default
    # (cost_model.DEFAULT_MODEL) for pricing, and no observation — the
    # default model must stay byte-stable, so it is never fed implicitly.
    # Typed Any only to keep dataclass field ordering simple; forks share
    # the instance, so a judge's sample runs calibrate the same model.
    cost_model: Optional[Any] = None
    # fault-tolerance policy (CallPolicy) enforced by this context's
    # dispatchers: per-call deadline, bounded retries, retry budget,
    # per-(tier, shard) circuit breaker with sibling-tier fallback, and
    # the sharded dispatcher's consecutive-failure shard liveness
    # threshold. None (or an all-default CallPolicy) = fail-fast, with
    # call paths byte-identical to the pre-policy runtime.
    call_policy: Optional[CallPolicy] = None
    meter: bk.UsageMeter = dataclasses.field(default_factory=bk.UsageMeter)
    # long-lived dispatcher owned by this context (see dispatcher()/close();
    # init=False fields are NOT carried across fork(), so every fork starts
    # unopened and close() releases only what this context created)
    _disp: Optional[Dispatcher] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)
    _closed: bool = dataclasses.field(
        default=False, init=False, repr=False, compare=False)
    _dlock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, init=False, repr=False,
        compare=False)

    def backend(self, tier_name: Optional[str]):
        return self.backends[tier_name or self.default_tier]

    def make_scheduler(self) -> EventScheduler:
        return EventScheduler(self.concurrency,
                              per_tier=self.per_tier_concurrency,
                              mode=self.mode)

    def make_dispatcher(self) -> Dispatcher:
        if self.driver not in DRIVERS:
            raise ValueError(f"unknown driver {self.driver!r} "
                             f"(expected one of {DRIVERS})")
        policy_rt = None
        if self.call_policy is not None and self.call_policy.active:
            policy_rt = FaultPolicyRuntime(
                self.call_policy, backends=self.backends,
                real_time=(self.driver == "threads" or self.procs >= 1))
        if self.procs >= 1:
            if self.shards > 1:
                raise ValueError(
                    "procs and shards are mutually exclusive (both pick "
                    f"a shard topology; got procs={self.procs}, "
                    f"shards={self.shards})")
            from repro.distributed.morsel_shards import ShardedDispatcher
            return ShardedDispatcher(
                shards=self.procs, driver="procs",
                concurrency=self.concurrency,
                per_tier=self.per_tier_concurrency, mode=self.mode,
                shared_cache=self.shard_cache != "local",
                policy=policy_rt,
                failure_threshold=(self.call_policy.shard_failure_threshold
                                   if self.call_policy else None),
                backends=self.backends)
        if self.shards > 1:
            # local import: morsel_shards builds on this module
            from repro.distributed.morsel_shards import ShardedDispatcher
            return ShardedDispatcher(
                shards=self.shards, driver=self.driver,
                concurrency=self.concurrency,
                per_tier=self.per_tier_concurrency, mode=self.mode,
                shared_cache=self.shard_cache != "local",
                policy=policy_rt,
                failure_threshold=(self.call_policy.shard_failure_threshold
                                   if self.call_policy else None))
        if self.driver == "threads":
            return ThreadPoolDispatcher(self.concurrency,
                                        per_tier=self.per_tier_concurrency,
                                        mode=self.mode, policy=policy_rt)
        return SimulatedDispatcher(self.make_scheduler(), policy=policy_rt)

    def dispatcher(self) -> Dispatcher:
        """The context's **long-lived** dispatcher: created on first use,
        reused across executions (pass it to ``executor.execute(...,
        dispatcher=...)``), released by :meth:`close`. This is the serving
        entry point — ``make_dispatcher()`` still builds a fresh throwaway
        dispatcher per call for one-shot executions."""
        with self._dlock:
            if self._closed:
                raise RuntimeError("ExecutionContext is closed")
            if self._disp is None:
                self._disp = self.make_dispatcher()
            return self._disp

    def close(self) -> None:
        """Idempotent shutdown for long-lived use: release the context's
        dispatcher (tier/chain/shard pools). The cache is deliberately
        NOT closed here — ``cache`` may be shared with sibling contexts
        (forks, a judge's sample runs) that are still executing; whoever
        *created* the cache closes it (``OutputCache.close``), which is
        what ``launch.query_server.QueryServer`` does for the serving
        cache it builds. Safe to call twice; a context-manager
        ``with ExecutionContext(...) as ctx:`` calls it on exit."""
        with self._dlock:
            if self._closed:
                return
            self._closed = True
            disp, self._disp = self._disp, None
        if disp is not None:
            disp.close()

    def __enter__(self) -> "ExecutionContext":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def fork(self, **overrides) -> "ExecutionContext":
        """A sibling context; e.g. ``fork(meter=UsageMeter())`` gives an
        optimizer its own accounting while sharing backends and cache.
        Forks never share the parent's long-lived dispatcher (``_disp``
        is ``init=False``) — each fork opens and closes its own."""
        return dataclasses.replace(self, **overrides)


def as_context(backends_or_ctx, **defaults) -> ExecutionContext:
    """Upgrade a ``{tier: Backend}`` dict to an ExecutionContext; pass an
    existing context through (with ``defaults`` applied as overrides)."""
    if isinstance(backends_or_ctx, ExecutionContext):
        return backends_or_ctx.fork(**defaults) if defaults \
            else backends_or_ctx
    return ExecutionContext(backends=backends_or_ctx, **defaults)
