"""Serving launcher — three modes over one zoo engine. Full knob
reference with semantics and quickstarts: ``docs/SERVING.md``.

**Token serving** (default; no ``--semantic``): continuous-batching
generation over a zoo model — reports throughput, slot occupancy, and
per-request latency percentiles. Full-size configs are proven via
launch/dryrun.py (decode cells lower the same decode_step this engine
drives)::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \\
        --reduced --requests 16 --slots 4 --max-new 24

**One semantic query** (``--semantic <dataset>``): the named dataset's
first workload query runs through the execution runtime
(``core.runtime.ExecutionContext`` + morsel-pipelined executor) with the
default tier backed by THIS engine (oracle-echo mode); the report shows
measured vs event-replay simulated wall side by side::

    PYTHONPATH=src python -m repro.launch.serve --semantic movie --slots 4

**Streaming semantic serve** (``--semantic <dataset> --serve N``): a
long-lived ``launch.query_server.QueryServer`` admits N workload queries
onto ONE shared dispatcher — queries interleave on the same worker
pools (continuous batching at the *analytics* level) — and the report
shows per-query latency percentiles plus concurrent makespan vs the
back-to-back sequential estimate::

    PYTHONPATH=src python -m repro.launch.serve --semantic movie \\
        --serve 4 --stagger 0.2 --slots 4

Execution knobs (one line each; all apply to ``--semantic`` modes):

* ``--driver {threads,simulated}`` — how backend calls run: real per-tier
  worker pools with *measured* wall (default), or inline execution with
  a deterministic event-model wall (Table-9 accounting).
* ``--batch N`` — batch prompting: N records share one LLM call.
* ``--coalesce / --no-coalesce`` — pack batch slots across morsel
  boundaries via ``runtime.BatchCoalescer`` (default on; only active
  with ``--batch`` > 1).
* ``--linger S`` — max seconds a partial coalesced batch waits for more
  rows before flushing (default: flush only on morsel watermarks) — the
  analytics-level counterpart of the ContinuousBatcher slot-fill policy.
* ``--shards N`` — morsel-parallel shard workers, pool-per-(shard, tier)
  dispatch; results/calls/meters identical to ``--shards 1``.
* ``--cascade`` — tier-0 embedding cascade (``core.cascade``): filter and
  rank predicates score every morsel in one batched device pass; only the
  band between ``--cascade-lo`` and ``--cascade-hi`` escalates to the LLM
  tier (device passes bill under ``tier0-embed``).
* ``--serve N`` — admit N workload queries onto one shared QueryServer
  (0 = off); ``--stagger S`` Poisson-ish mean inter-admission gap in
  seconds (seeded, explicit offsets; 0 = admit all at once).
* ``--tenants N`` / ``--lane {batch,interactive,mixed}`` /
  ``--admission SPEC`` / ``--slo S`` — multi-tenant QoS on the serve
  path: round-robin the served queries across N tenants, pick their
  priority lane (``mixed`` alternates), wire an
  ``query_server.AdmissionController`` (SPEC ``rows=R,depth=D,conc=C``;
  bare ``on`` for defaults), and attach an SLO deadline so the
  makespan gate denies queries predicted to bust it.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced as reduce_cfg
from repro.engine import ContinuousBatcher, GenerationEngine
from repro.models import registry

DEMO_PROMPTS = [
    "Answer true or false. Instruction: The rating is higher than 8.5. "
    "Input: 9.1 Answer:",
    "Extract the genre: A crime story about a heist gone wrong.",
    "Summarize: NEWLY BUILT DUPLEX WITH SWIMMING POOL, PRICE: N250m",
    "Does the game support VR? Platforms: Windows, MacOS, VR supported.",
]


def _semantic_context(args):
    """Build the engine-backed ExecutionContext both semantic modes use:
    the default tier (m1) is served by THIS engine in oracle-echo mode,
    the other tiers stay simulated."""
    from repro.core import backends as bk
    from repro.core import runtime as rt
    from repro.core.cost_model import DEFAULT_TIERS, CostModel
    from repro.data import load_dataset
    from repro.engine.jax_backend import JAXBackend

    table, oracle = load_dataset(args.semantic, max_rows=args.requests * 4)
    tier = DEFAULT_TIERS["m1"]
    cfg = reduce_cfg(get_config(tier.arch))
    bundle = registry.build(cfg)
    params = bundle.init(jax.random.PRNGKey(args.seed))
    engine = GenerationEngine(bundle, params, max_len=args.max_len,
                              n_slots=args.slots)
    backends = bk.make_backends(oracle)
    backends["m1"] = JAXBackend(tier, engine, oracle=oracle,
                                max_new_tokens=args.max_new)
    router = None
    if args.cascade:
        from repro.core import cascade as casc_mod
        router = casc_mod.CascadeRouter(
            default_bands=casc_mod.CascadeBands(lo=args.cascade_lo,
                                                hi=args.cascade_hi))
    # one calibrated cost model per process: the executor/server observe
    # sync points feed it measured per-call latencies, and --explain-cost
    # prints its q-error table after the run
    model = CostModel(latency_weight=args.latency_weight)
    # fault-tolerance knobs: an all-default policy stays None so the
    # dispatchers keep the byte-identical fail-fast call paths
    policy = None
    if (args.retries > 0 or args.call_timeout is not None
            or args.breaker_threshold > 0 or args.fallback_tier):
        policy = rt.CallPolicy(retries=args.retries,
                               call_timeout_s=args.call_timeout,
                               breaker_threshold=args.breaker_threshold,
                               fallback_tier=args.fallback_tier or None,
                               seed=args.seed)
    ctx = rt.ExecutionContext(backends=backends, default_tier="m1",
                              concurrency=args.slots,
                              morsel_size=args.slots * 4,
                              driver=args.driver,
                              batch_size=args.batch,
                              coalesce=args.coalesce,
                              linger_s=args.linger,
                              shards=args.shards,
                              procs=args.procs,
                              cascade=router,
                              cost_model=model,
                              call_policy=policy)
    return table, cfg, engine, ctx


def _explain_cost(args, ctx):
    """--explain-cost: print the calibrated model's per-(op, tier)
    q-error table after the run (predictions vs the measured call log
    ingested at the observe sync points)."""
    if not args.explain_cost or ctx.cost_model is None:
        return
    from repro.analysis import qerror
    print("[serve] cost-model calibration (q-error = max(pred/meas, "
          "meas/pred)):")
    print(qerror.render_text(ctx.cost_model))


def serve_semantic(args):
    """Semantic-analytics serving: a workload query executed through the
    event-driven runtime, default tier backed by the real engine."""
    from repro.core import executor as ex
    from repro.core import runtime as rt
    from repro.data import WORKLOADS

    table, cfg, engine, ctx = _semantic_context(args)
    if args.serve > 0:
        out = serve_queries(args, table, cfg, engine, ctx)
        _explain_cost(args, ctx)
        return out
    q = WORKLOADS[args.semantic][0]
    print(f"[serve] semantic query {q.qid} over {table.name} "
          f"({table.n_rows} rows), m1 = {cfg.name} on {args.slots} slots, "
          f"driver={args.driver} shards={args.shards} procs={args.procs} "
          f"batch={args.batch} "
          f"coalesce={args.coalesce} linger={args.linger} "
          f"cascade={args.cascade}")
    t0 = time.time()
    res = ex.execute(q.plan_for(table), table, ctx)
    dt = time.time() - t0
    print(f"[serve] answer: {repr(res.value())[:120]}")
    # measured vs simulated, side by side: replay the metered per-call
    # latencies through the event scheduler regardless of the driver
    replay = rt.EventScheduler(concurrency=args.slots)
    replay.drain(ctx.meter, 0)
    measured = res.wall_s if args.driver == "threads" else dt
    print(f"[serve] wall measured={measured:.2f}s "
          f"(driver={args.driver}, {len(ctx.meter.call_log)} calls)  "
          f"simulated={replay.makespan:.2f}s (event replay)  "
          f"host={dt:.2f}s")
    for tname, u in ctx.meter.by_tier.items():
        print(f"  [{tname}] calls={u.calls} tok_in={u.tok_in:.0f} "
              f"usd=${u.usd:.4f} latency_sum={u.latency_s:.2f}s")
    if res.cascade_stats is not None:
        print(f"[serve] cascade stats={res.cascade_stats}")
    print(f"[serve] engine stats={engine.stats} "
          f"occupancy={engine.occupancy:.2f}")
    _explain_cost(args, ctx)
    return res


def stagger_offsets(n: int, mean_s: float, seed: int = 0):
    """Deterministic Poisson-ish admission offsets: cumulative seeded
    exponential inter-arrival gaps with mean ``mean_s`` (all zeros when
    ``mean_s`` is 0 — admit everything at once). Explicit offsets, not a
    live random process, so a serve run is reproducible."""
    import random
    rng = random.Random(seed)
    offsets, t = [], 0.0
    for _ in range(max(0, n)):
        offsets.append(t)
        if mean_s > 0:
            t += rng.expovariate(1.0 / mean_s)
    return offsets


def parse_admission(spec: str):
    """``--admission`` spec -> :class:`AdmissionController` (or None).
    ``""`` = off; ``on`` = all-default controller; otherwise a
    comma-separated ``rows=R,depth=D,conc=C`` picks the per-tenant
    in-flight-row cap, per-tenant queue depth, and execution width."""
    from repro.launch.query_server import AdmissionController
    spec = (spec or "").strip()
    if not spec:
        return None
    kw = {}
    if spec not in ("on", "1", "true"):
        keys = {"rows": "max_tenant_rows", "depth": "max_queue_depth",
                "conc": "max_concurrent"}
        for part in spec.split(","):
            k, _, v = part.partition("=")
            if k.strip() not in keys:
                raise ValueError(f"bad --admission entry {part!r}; "
                                 f"expected rows=/depth=/conc= or 'on'")
            kw[keys[k.strip()]] = int(v)
    return AdmissionController(**kw)


def serve_queries(args, table, cfg, engine, ctx):
    """Streaming semantic serve: admit ``--serve N`` workload queries
    (staggered by ``--stagger``) onto one shared QueryServer and report
    per-query latency percentiles + makespan vs sequential estimate.
    With ``--admission`` the queries route through the multi-tenant
    admission controller (``--tenants/--lane/--slo`` shape the load)."""
    from repro.data import WORKLOADS
    from repro.launch.query_server import QueryServer

    queries = [WORKLOADS[args.semantic][i % len(WORKLOADS[args.semantic])]
               for i in range(args.serve)]
    offsets = stagger_offsets(len(queries), args.stagger, seed=args.seed)
    controller = parse_admission(args.admission)
    print(f"[serve] streaming {len(queries)} queries over {table.name} "
          f"({table.n_rows} rows), m1 = {cfg.name} on {args.slots} slots, "
          f"driver={args.driver} shards={args.shards} procs={args.procs} "
          f"batch={args.batch} stagger={args.stagger}s "
          f"tenants={args.tenants} lane={args.lane} "
          f"admission={'on' if controller else 'off'} slo={args.slo}")
    handles = []
    with QueryServer(ctx, admission=controller) as server:
        t0 = time.perf_counter()
        for i, (q, off) in enumerate(zip(queries, offsets)):
            lead = off - (time.perf_counter() - t0)
            if lead > 0:
                time.sleep(lead)
            lane = args.lane if args.lane in ("batch", "interactive") \
                else ("interactive" if i % 2 == 0 else "batch")
            handles.append(server.submit(
                q.plan_for(table), table, name=q.qid,
                tenant=f"t{i % max(1, args.tenants)}", lane=lane,
                deadline_s=args.slo))
        server.drain()
        makespan = time.perf_counter() - t0
        stats = server.stats()
    served = [h for h in handles if not h.rejected()]
    lats = sorted(h.latency_s for h in served) or [0.0]
    # per-query exec walls are measured UNDER co-tenant contention, so
    # their sum is only an upper bound on back-to-back execution — a
    # measured sequential baseline lives in benchmarks/bench_serve.py
    seq_bound = sum(h.exec_wall_s for h in served)
    for h in handles:
        if h.rejected():
            res = f"REJECTED ({h._fut.exception().reason})"
        elif h.failed():
            res = "FAILED"
        else:
            res = repr(h.result().value())[:60]
        print(f"  [{h.name}] tenant={h.tenant} lane={h.lane} "
              f"latency={h.latency_s:.2f}s "
              f"exec={h.exec_wall_s:.2f}s calls={h.meter.total.calls} "
              f"-> {res}")
    p = np.percentile
    print(f"[serve] makespan={makespan:.2f}s  sum-of-exec-walls="
          f"{seq_bound:.2f}s  overlap<={seq_bound / max(makespan, 1e-9):.2f}x"
          f" (upper bound; measured baseline: benchmarks/bench_serve.py)")
    print(f"[serve] latency p50={p(lats, 50):.2f}s p95={p(lats, 95):.2f}s "
          f"max={lats[-1]:.2f}s")
    print(f"[serve] server stats={stats}")
    print(f"[serve] engine stats={engine.stats} "
          f"occupancy={engine.occupancy:.2f}")
    return handles


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    # BooleanOptionalAction so --no-reduced actually reaches the full-size
    # config (store_true with default=True made it unreachable)
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=160)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--semantic", default="",
                    help="dataset name: serve a semantic workload through "
                         "the execution runtime instead of raw prompts")
    ap.add_argument("--driver", choices=("simulated", "threads"),
                    default="threads",
                    help="--semantic execution driver: real thread pools "
                         "(measured wall) or the event-model simulation")
    ap.add_argument("--shards", type=int, default=1,
                    help="--semantic: morsel-parallel shard workers "
                         "(pool-per-(shard, tier) dispatch; morsels "
                         "round-robin across shards, results identical "
                         "to --shards 1)")
    ap.add_argument("--procs", type=int, default=0,
                    help="--semantic: spawned process shard workers — "
                         "backend calls and host UDFs run GIL-free in "
                         "worker subprocesses, results identical to the "
                         "in-process drivers; mutually exclusive with "
                         "--shards > 1 (unpicklable backends, e.g. the "
                         "engine-backed m1, keep running in-process)")
    ap.add_argument("--batch", type=int, default=1,
                    help="--semantic batch prompting size (records per "
                         "LLM call)")
    ap.add_argument("--coalesce", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="--semantic: pack batch slots across morsel "
                         "boundaries (runtime.BatchCoalescer)")
    ap.add_argument("--linger", type=float, default=None,
                    help="--semantic: max seconds a partial coalesced "
                         "batch waits for more rows before flushing "
                         "(default: flush only on morsel watermarks)")
    ap.add_argument("--cascade", action="store_true",
                    help="--semantic: tier-0 embedding cascade — filter/"
                         "rank predicates resolve high-confidence rows in "
                         "one batched device pass; only the uncertain "
                         "band escalates to the LLM tier")
    ap.add_argument("--cascade-lo", type=float, default=-0.35,
                    help="--cascade: drop rows scoring at or below this "
                         "cosine (blanket band; the physical optimizer "
                         "calibrates per-operator bands instead)")
    ap.add_argument("--cascade-hi", type=float, default=0.35,
                    help="--cascade: pass rows scoring at or above this "
                         "cosine; lo < score < hi escalates")
    ap.add_argument("--serve", type=int, default=0,
                    help="--semantic: admit N workload queries onto one "
                         "long-lived QueryServer (shared dispatcher, "
                         "per-query meters + latency percentiles); "
                         "0 = execute the first query once and exit")
    ap.add_argument("--stagger", type=float, default=0.0,
                    help="--serve: Poisson-ish mean inter-admission gap "
                         "in seconds (seeded explicit offsets; 0 = admit "
                         "all queries at once)")
    ap.add_argument("--tenants", type=int, default=1,
                    help="--serve: round-robin the served queries across "
                         "N tenant ids (t0..tN-1) for the admission "
                         "controller's per-tenant caps")
    ap.add_argument("--lane", choices=("batch", "interactive", "mixed"),
                    default="batch",
                    help="--serve: priority lane for served queries; "
                         "'mixed' alternates interactive/batch so lane "
                         "preemption is visible in one run")
    ap.add_argument("--admission", default="",
                    help="--serve: enable the multi-tenant admission "
                         "controller — 'on' for defaults, or "
                         "'rows=R,depth=D,conc=C' (per-tenant in-flight "
                         "row cap, per-tenant queue depth, execution "
                         "width); empty = legacy FIFO admission")
    ap.add_argument("--slo", type=float, default=None,
                    help="--serve: per-query deadline in seconds; with "
                         "--admission, queries whose predicted makespan "
                         "under current load busts it are denied at "
                         "admission (AdmissionError) instead of running")
    ap.add_argument("--latency-weight", type=float, default=0.0,
                    help="--semantic: cost x makespan weight on the "
                         "context's CostModel — 0 (default) optimizes "
                         "pure USD exactly as before; > 0 mixes an "
                         "event-scheduler makespan estimate into both "
                         "optimizers' objectives")
    ap.add_argument("--explain-cost", action="store_true",
                    help="--semantic: after the run, print the cost "
                         "model's per-(op, tier) q-error table "
                         "(predicted vs measured latency/tokens from "
                         "online calibration)")
    ap.add_argument("--retries", type=int, default=0,
                    help="--semantic: extra attempts per backend call "
                         "after a transient failure (0 = fail fast, "
                         "today's behaviour)")
    ap.add_argument("--call-timeout", type=float, default=None,
                    help="--semantic: cooperative per-call deadline in "
                         "seconds, surfaced to backends via "
                         "runtime.current_call_timeout()")
    ap.add_argument("--breaker-threshold", type=int, default=0,
                    help="--semantic: consecutive exhausted calls on one "
                         "(tier, shard) before its circuit opens and "
                         "calls skip straight to --fallback-tier "
                         "(0 = breaker off)")
    ap.add_argument("--fallback-tier", default=None,
                    help="--semantic: sibling tier that serves a call "
                         "once its primary exhausts retries or its "
                         "breaker is open (billed under the fallback "
                         "tier's own name; unset = re-raise)")
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)

    if args.semantic:
        return serve_semantic(args)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    bundle = registry.build(cfg)
    params = bundle.init(jax.random.PRNGKey(args.seed))
    print(f"[serve] arch={cfg.name} params={cfg.param_count()/1e6:.2f}M "
          f"slots={args.slots} max_len={args.max_len}")

    engine = GenerationEngine(bundle, params, max_len=args.max_len,
                              n_slots=args.slots)
    batcher = ContinuousBatcher(engine)
    t0 = time.time()
    for i in range(args.requests):
        batcher.submit(DEMO_PROMPTS[i % len(DEMO_PROMPTS)] + f" [{i}]",
                       max_new_tokens=args.max_new)
    finished = batcher.run()
    dt = time.time() - t0

    lats = [r.done_s - r.submitted_s for r in finished.values()]
    new_toks = sum(len(r.output_ids) for r in finished.values())
    print(f"[serve] {len(finished)} requests in {dt:.2f}s  "
          f"({new_toks / dt:,.1f} new tok/s)")
    print(f"[serve] occupancy={engine.occupancy:.2f}  "
          f"p50={np.percentile(lats, 50):.2f}s "
          f"p99={np.percentile(lats, 99):.2f}s")
    print(f"[serve] stats={engine.stats}")
    return finished


if __name__ == "__main__":
    main()
