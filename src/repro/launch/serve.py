"""Serving launcher — continuous-batching generation over a zoo model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
        --requests 16 --slots 4 --max-new 24

Reports throughput, slot occupancy, and per-request latency percentiles.
Full-size configs are proven via launch/dryrun.py (decode cells lower the
same decode_step this engine drives).

``--semantic <dataset>`` serves a semantic-analytics workload instead: the
named dataset's first query runs through the execution runtime
(``core.runtime.ExecutionContext`` + morsel-pipelined executor) with the
default tier backed by THIS engine (oracle-echo mode). With the default
``--driver threads`` the morsels genuinely overlap on the engine's slots
and the reported wall is *measured*; the metered per-call latencies are
additionally replayed through an ``EventScheduler`` so the report shows
measured vs simulated wall side by side (``--driver simulated`` runs the
deterministic event-model path instead):

    PYTHONPATH=src python -m repro.launch.serve --semantic movie --slots 4

With ``--batch N`` (batch prompting) the runtime's ``BatchCoalescer``
packs batch slots across morsel boundaries; ``--linger S`` bounds how
long a partial batch may wait for more rows (the analytics-level
counterpart of the ContinuousBatcher's slot-fill policy), and
``--no-coalesce`` restores per-morsel batching.

``--shards N`` runs the morsel stream through the sharded dispatcher
(``distributed.morsel_shards``): morsels round-robin across N shard
workers, each with its own pool-per-(shard, tier); results, call counts,
and meter totals are identical to ``--shards 1``.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced as reduce_cfg
from repro.engine import ContinuousBatcher, GenerationEngine
from repro.models import registry

DEMO_PROMPTS = [
    "Answer true or false. Instruction: The rating is higher than 8.5. "
    "Input: 9.1 Answer:",
    "Extract the genre: A crime story about a heist gone wrong.",
    "Summarize: NEWLY BUILT DUPLEX WITH SWIMMING POOL, PRICE: N250m",
    "Does the game support VR? Platforms: Windows, MacOS, VR supported.",
]


def serve_semantic(args):
    """Semantic-analytics serving: a workload query executed through the
    event-driven runtime, default tier backed by the real engine."""
    from repro.core import backends as bk
    from repro.core import executor as ex
    from repro.core import runtime as rt
    from repro.core.cost import DEFAULT_TIERS
    from repro.data import WORKLOADS, load_dataset
    from repro.engine.jax_backend import JAXBackend

    table, oracle = load_dataset(args.semantic, max_rows=args.requests * 4)
    tier = DEFAULT_TIERS["m1"]
    cfg = reduce_cfg(get_config(tier.arch))
    bundle = registry.build(cfg)
    params = bundle.init(jax.random.PRNGKey(args.seed))
    engine = GenerationEngine(bundle, params, max_len=args.max_len,
                              n_slots=args.slots)
    backends = bk.make_backends(oracle)
    backends["m1"] = JAXBackend(tier, engine, oracle=oracle,
                                max_new_tokens=args.max_new)
    ctx = rt.ExecutionContext(backends=backends, default_tier="m1",
                              concurrency=args.slots,
                              morsel_size=args.slots * 4,
                              driver=args.driver,
                              batch_size=args.batch,
                              coalesce=args.coalesce,
                              linger_s=args.linger,
                              shards=args.shards)
    q = WORKLOADS[args.semantic][0]
    print(f"[serve] semantic query {q.qid} over {table.name} "
          f"({table.n_rows} rows), m1 = {cfg.name} on {args.slots} slots, "
          f"driver={args.driver} shards={args.shards} batch={args.batch} "
          f"coalesce={args.coalesce} linger={args.linger}")
    t0 = time.time()
    res = ex.execute(q.plan_for(table), table, ctx)
    dt = time.time() - t0
    print(f"[serve] answer: {repr(res.value())[:120]}")
    # measured vs simulated, side by side: replay the metered per-call
    # latencies through the event scheduler regardless of the driver
    replay = rt.EventScheduler(concurrency=args.slots)
    replay.drain(ctx.meter, 0)
    measured = res.wall_s if args.driver == "threads" else dt
    print(f"[serve] wall measured={measured:.2f}s "
          f"(driver={args.driver}, {len(ctx.meter.call_log)} calls)  "
          f"simulated={replay.makespan:.2f}s (event replay)  "
          f"host={dt:.2f}s")
    for tname, u in ctx.meter.by_tier.items():
        print(f"  [{tname}] calls={u.calls} tok_in={u.tok_in:.0f} "
              f"usd=${u.usd:.4f} latency_sum={u.latency_s:.2f}s")
    print(f"[serve] engine stats={engine.stats} "
          f"occupancy={engine.occupancy:.2f}")
    return res


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    # BooleanOptionalAction so --no-reduced actually reaches the full-size
    # config (store_true with default=True made it unreachable)
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=160)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--semantic", default="",
                    help="dataset name: serve a semantic workload through "
                         "the execution runtime instead of raw prompts")
    ap.add_argument("--driver", choices=("simulated", "threads"),
                    default="threads",
                    help="--semantic execution driver: real thread pools "
                         "(measured wall) or the event-model simulation")
    ap.add_argument("--shards", type=int, default=1,
                    help="--semantic: morsel-parallel shard workers "
                         "(pool-per-(shard, tier) dispatch; morsels "
                         "round-robin across shards, results identical "
                         "to --shards 1)")
    ap.add_argument("--batch", type=int, default=1,
                    help="--semantic batch prompting size (records per "
                         "LLM call)")
    ap.add_argument("--coalesce", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="--semantic: pack batch slots across morsel "
                         "boundaries (runtime.BatchCoalescer)")
    ap.add_argument("--linger", type=float, default=None,
                    help="--semantic: max seconds a partial coalesced "
                         "batch waits for more rows before flushing "
                         "(default: flush only on morsel watermarks)")
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)

    if args.semantic:
        return serve_semantic(args)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    bundle = registry.build(cfg)
    params = bundle.init(jax.random.PRNGKey(args.seed))
    print(f"[serve] arch={cfg.name} params={cfg.param_count()/1e6:.2f}M "
          f"slots={args.slots} max_len={args.max_len}")

    engine = GenerationEngine(bundle, params, max_len=args.max_len,
                              n_slots=args.slots)
    batcher = ContinuousBatcher(engine)
    t0 = time.time()
    for i in range(args.requests):
        batcher.submit(DEMO_PROMPTS[i % len(DEMO_PROMPTS)] + f" [{i}]",
                       max_new_tokens=args.max_new)
    finished = batcher.run()
    dt = time.time() - t0

    lats = [r.done_s - r.submitted_s for r in finished.values()]
    new_toks = sum(len(r.output_ids) for r in finished.values())
    print(f"[serve] {len(finished)} requests in {dt:.2f}s  "
          f"({new_toks / dt:,.1f} new tok/s)")
    print(f"[serve] occupancy={engine.occupancy:.2f}  "
          f"p50={np.percentile(lats, 50):.2f}s "
          f"p99={np.percentile(lats, 99):.2f}s")
    print(f"[serve] stats={engine.stats}")
    return finished


if __name__ == "__main__":
    main()
