"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --reduced --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Full-size configs target the production mesh (see launch/dryrun.py for the
lower/compile proof); --reduced trains the same-family tiny config on CPU.
The loop runs under the fault-tolerance supervisor: checkpoint every
--ckpt-every steps, restart-deterministic, straggler flagging on.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced as reduce_cfg
from repro.distributed.fault_tolerance import (SupervisorConfig,
                                               TrainSupervisor)
from repro.models import registry
from repro.training import optimizer as opt_mod
from repro.training import train_loop


def synthetic_batch_fn(cfg, batch: int, seq: int, seed: int = 0):
    """Deterministic per-step batches through the sharding-aware pipeline
    (restart-safe: content is a pure function of (seed, step))."""
    from repro.data.pipeline import TokenPipeline
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, global_batch=batch,
                         seq_len=seq, seed=seed)

    def fn(step: int):
        out = {"tokens": jnp.asarray(pipe.batch_at(step)["tokens"])}
        k = jax.random.PRNGKey(step)
        if cfg.n_prefix_embeds:
            out["prefix_embeds"] = jax.random.normal(
                k, (batch, cfg.n_prefix_embeds, cfg.d_model), jnp.bfloat16)
        if cfg.is_encoder_decoder:
            out = {"tokens": out["tokens"],
                   "enc_embeds": jax.random.normal(
                       k, (batch, seq, cfg.d_model), jnp.bfloat16)}
        return out
    return fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--async-ckpt", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    bundle = registry.build(cfg)
    print(f"[train] arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"steps={args.steps} batch={args.batch}x{args.seq}")

    state = train_loop.init_train_state(bundle, jax.random.PRNGKey(args.seed))
    opt_cfg = opt_mod.AdamWConfig(lr=args.lr, warmup_steps=10,
                                  total_steps=args.steps)
    step_fn = jax.jit(train_loop.make_train_step(
        bundle, opt_cfg, remat=True, microbatches=args.microbatches,
        compress_grads=args.compress_grads))

    sup = TrainSupervisor(
        step_fn, synthetic_batch_fn(cfg, args.batch, args.seq),
        SupervisorConfig(ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every,
                         async_save=args.async_ckpt))
    t0 = time.time()
    state, log = sup.run(state, args.steps)
    dt = time.time() - t0
    losses = [e["loss"] for e in log if "loss" in e]
    print(f"[train] done in {dt:.1f}s  loss {losses[0]:.3f} -> "
          f"{losses[-1]:.3f}  stragglers={sup.straggler.flagged}")
    tok_s = args.steps * args.batch * args.seq / dt
    print(f"[train] throughput {tok_s:,.0f} tok/s")
    return losses


if __name__ == "__main__":
    main()
