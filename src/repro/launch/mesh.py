"""Production mesh builders. Functions, not module-level constants, so that
importing this module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over whatever devices exist (CPU tests)."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
