import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape) cell on the
production meshes and record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out artifacts/dryrun

The first two lines of this module force 512 host platform devices BEFORE
any jax import so ``jax.make_mesh((2,16,16), ...)`` can build the production
mesh on this CPU-only container. Do not import this module from code that
needs real device counts (tests/benchmarks import nothing from here).
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.analysis import roofline as rl
from repro.configs import (ARCH_IDS, SHAPES, SUBQUADRATIC, get_config, cells)
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import common as cm
from repro.models import registry
from repro.training import optimizer as opt_mod
from repro.training import train_loop


def input_specs(arch: str, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    bundle = registry.build(get_config(arch))
    return bundle.batch_specs(SHAPES[shape_name])


def shardings_like(tree, rules, mesh):
    """Shardings for a pytree: Params via logical axes, plain leaves
    replicated."""
    def leaf(x):
        if cm.is_param(x):
            return jax.tree.map(
                lambda _: shd.NamedSharding(
                    mesh, shd.spec_for(x.value.shape, x.axes, rules, mesh)),
                x, is_leaf=lambda y: not cm.is_param(y))
        return shd.replicated(mesh)
    return jax.tree.map(leaf, tree, is_leaf=cm.is_param)


def _cast_bf16(shapes_tree):
    def leaf(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(x.shape, jnp.bfloat16)
        return x
    return jax.tree.map(leaf, shapes_tree)


def build_cell(arch: str, shape_name: str, mesh, *, moe_impl="gather",
               microbatches=1, serve_dtype=jnp.bfloat16, kv_int8=False):
    """Returns (jit_fn, example args, rules). ALL tracing (including
    eval_shape) must happen inside the activation-sharding context —
    traced jaxprs are cached by function identity, so a constraint-free
    trace made outside the context would be silently reused by lower()."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mode = {"train": "train", "prefill": "prefill",
            "decode": "serve"}[shape.kind]
    rules = shd.make_rules(cfg, mesh, mode)
    with shd.activation_sharding(mesh, rules):
        fn, args = _build_cell_traced(cfg, shape, mesh, rules,
                                      moe_impl=moe_impl,
                                      microbatches=microbatches,
                                      serve_dtype=serve_dtype,
                                      kv_int8=kv_int8)
    return fn, args, rules


def _build_cell_traced(cfg, shape, mesh, rules, *, moe_impl, microbatches,
                       serve_dtype, kv_int8=False):
    bundle = registry.build(cfg)
    batch_specs = bundle.batch_specs(shape)
    key = jax.random.PRNGKey(0)

    if shape.kind == "train":
        moe_ctx = None
        if cfg.moe is not None and moe_impl == "shardmap":
            moe_ctx = {"impl": "shardmap", "mesh": mesh,
                       "dp_axes": shd.dp_axes(mesh)}
        opt_cfg = opt_mod.AdamWConfig()
        step = train_loop.make_train_step(
            bundle, opt_cfg, dtype=jnp.bfloat16, remat=True, moe_ctx=moe_ctx,
            microbatches=microbatches)
        state_shapes = jax.eval_shape(
            lambda: train_loop.init_train_state(bundle, key))
        state_sh = shardings_like(state_shapes, rules, mesh)
        batch_sh = shd.batch_sharding(batch_specs, rules, mesh)
        metrics_shapes = jax.eval_shape(step, state_shapes, batch_specs)[1]
        metrics_sh = jax.tree.map(lambda _: shd.replicated(mesh),
                                  metrics_shapes)
        fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, metrics_sh),
                     donate_argnums=(0,))
        return fn, (state_shapes, batch_specs)

    params_shapes = _cast_bf16(jax.eval_shape(bundle.init, key))
    params_sh = shardings_like(params_shapes, rules, mesh)

    if shape.kind == "prefill":
        def fn(params, batch):
            return bundle.prefill(params, batch, max_len=None,
                                  dtype=serve_dtype)
        batch_sh = shd.batch_sharding(batch_specs, rules, mesh)
        out_shapes = jax.eval_shape(fn, params_shapes, batch_specs)
        out_sh = shardings_like(out_shapes, rules, mesh)
        jfn = jax.jit(fn, in_shardings=(params_sh, batch_sh),
                      out_shardings=out_sh)
        return jfn, (params_shapes, batch_specs)

    # decode: one new token against a KV cache of shape.seq_len
    kv_kw = {"kv_dtype": jnp.int8} if kv_int8 else {}
    cache_shapes = jax.eval_shape(
        lambda: bundle.init_cache(shape.global_batch, shape.seq_len,
                                  dtype=serve_dtype, **kv_kw))
    cache_sh = shardings_like(cache_shapes, rules, mesh)
    tok_specs = bundle.batch_specs(shape)
    tok_sh = shd.batch_sharding(tok_specs, rules, mesh)

    def fn(params, cache, token):
        return bundle.decode_step(params, cache, token, dtype=serve_dtype)

    out_shapes = jax.eval_shape(fn, params_shapes, cache_shapes,
                                tok_specs["token"])
    out_sh = shardings_like(out_shapes, rules, mesh)
    jfn = jax.jit(fn, in_shardings=(params_sh, cache_sh, tok_sh["token"]),
                  out_shardings=out_sh, donate_argnums=(1,))
    return jfn, (params_shapes, cache_shapes, tok_specs["token"])


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             moe_impl="gather", microbatches=1, save_hlo=None,
             kv_int8=False):
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "multi" if multi_pod else "single", "chips": chips,
           "moe_impl": moe_impl, "microbatches": microbatches,
           "kv_int8": kv_int8, "ok": False}
    if shape_name == "long_500k" and arch not in SUBQUADRATIC:
        rec.update(ok=True, skipped=True,
                   skip_reason="full-attention arch; long_500k requires "
                               "sub-quadratic context (see DESIGN.md)")
        return rec
    t0 = time.time()
    try:
        fn, args, rules = build_cell(arch, shape_name, mesh,
                                     moe_impl=moe_impl,
                                     microbatches=microbatches,
                                     kv_int8=kv_int8)
        with shd.activation_sharding(mesh, rules):
            lowered = fn.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        rec["lower_s"] = round(t1 - t0, 2)
        rec["compile_s"] = round(t2 - t1, 2)

        try:
            ma = compiled.memory_analysis()
            rec["memory_analysis"] = {
                k: int(getattr(ma, k)) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes") if hasattr(ma, k)}
            arg_b = rec["memory_analysis"].get("argument_size_in_bytes", 0)
            tmp_b = rec["memory_analysis"].get("temp_size_in_bytes", 0)
            out_b = rec["memory_analysis"].get("output_size_in_bytes", 0)
            ali_b = rec["memory_analysis"].get("alias_size_in_bytes", 0)
            rec["bytes_per_device"] = int(arg_b + tmp_b + out_b - ali_b)
        except Exception as e:  # pragma: no cover
            rec["memory_analysis_error"] = str(e)

        cost = {}
        try:
            cost = dict(compiled.cost_analysis())
            rec["cost_analysis"] = {k: float(v) for k, v in cost.items()
                                    if isinstance(v, (int, float))}
        except Exception as e:  # pragma: no cover
            rec["cost_analysis_error"] = str(e)

        hlo = compiled.as_text()
        if save_hlo:
            with open(save_hlo, "w") as f:
                f.write(hlo)
        hstats = rl.parse_hlo(hlo)
        coll = hstats.collectives
        rec["collectives"] = {
            "bytes_per_chip": coll.bytes_per_chip,
            "counts": coll.counts,
            "bytes_by_kind": coll.bytes_by_kind,
        }
        rec["dot_flops_per_device"] = hstats.dot_flops
        mf = rl.model_flops_estimate(cfg, shape)
        roof = rl.compute_roofline(cost, coll, chips, mf,
                                   flops_override=hstats.dot_flops)
        rec["roofline"] = {
            "compute_s": roof.compute_s, "memory_s": roof.memory_s,
            "collective_s": roof.collective_s, "dominant": roof.dominant,
            "model_flops": mf,
            "flops_per_device": roof.flops_per_device,
            "useful_flops_ratio": roof.useful_flops_ratio,
            "roofline_fraction": roof.roofline_fraction,
            "step_time_s": roof.step_time_s,
        }
        rec["ok"] = True
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc(limit=8)
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--moe-impl", default="gather",
                    choices=["gather", "shardmap"])
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8-quantized decode KV cache")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tagp = f"-{args.tag}" if args.tag else ""
                name = f"{arch}__{shape}__{'multi' if mp else 'single'}{tagp}"
                hlo_path = (os.path.join(args.out, name + ".hlo")
                            if args.save_hlo else None)
                rec = run_cell(arch, shape, multi_pod=mp,
                               moe_impl=args.moe_impl,
                               microbatches=args.microbatches,
                               save_hlo=hlo_path, kv_int8=args.kv_int8)
                with open(os.path.join(args.out, name + ".json"), "w") as f:
                    json.dump(rec, f, indent=1)
                status = ("SKIP" if rec.get("skipped")
                          else "OK" if rec["ok"] else "FAIL")
                n_fail += status == "FAIL"
                dom = rec.get("roofline", {}).get("dominant", "-")
                print(f"[{status:4s}] {name:60s} t={rec.get('total_s', 0):8.1f}s"
                      f" dom={dom}", flush=True)
                if status == "FAIL":
                    print(rec.get("error"), flush=True)
    print(f"done; failures={n_fail}", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
