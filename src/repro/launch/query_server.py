"""Streaming semantic serve: continuous query admission onto one shared
sharded dispatcher.

``executor.execute`` runs one query and tears its worker pools down; a
:class:`QueryServer` is the long-lived form — the analytics-level analog
of continuous batching at the token level (``engine.ContinuousBatcher``
fills decode slots across requests; the server fills **dispatcher
capacity** across queries). One server owns

* ONE :class:`runtime.ExecutionContext` — shared backends, one shared
  single-flight ``OutputCache`` (cross-query duplicate values bill once),
  and the server-lifetime ``UsageMeter`` that accumulates every admitted
  query's spend;
* ONE long-lived dispatcher (``ctx.dispatcher()``) — under
  ``driver="threads"`` the per-tier worker pools (or, with
  ``ctx.shards > 1``, the pool-per-(shard, tier) grid of the
  ``ShardedDispatcher``) persist across queries, so
  ``per_tier_concurrency`` caps act as true serving quotas **across
  tenants**: two in-flight queries' calls against one tier queue on the
  same bounded pool.

``submit(plan, table)`` admits a query from any caller thread and
returns a :class:`QueryHandle` immediately; the query's morsel stream is
fed into the shared dispatcher, interleaving with every other in-flight
query. Each handle carries its own per-query ``UsageMeter`` (finalized
independently via the dispatcher's per-execution staging merge) and its
own **measured** latency/exec wall; the server context's meter absorbs
each query's totals as it finishes, so ``server.ctx.meter`` is the
server-lifetime bill.

Isolation contract (test-enforced in ``tests/test_serve.py``):

* admission-order invariance — a query's results and per-query meter
  totals are byte-identical to running it solo on a fresh context
  (concurrent tenants only change *when* calls run, never what they
  answer or bill; shared-cache hits across queries require overlapping
  cache keys, which distinct instructions never produce);
* failure isolation — a backend failure inside one query poisons only
  that query's handle; other in-flight queries and later submissions
  are unaffected.

Per-query state that used to be per-process: the coalescer (one
``BatchCoalescer`` per execution, so one query's linger watermark cannot
stall another's), the sharded round-robin cursor (``shard_of(query=)``
offsets each query), and meter staging (keyed by the query's own meter
object, merged per-execution by ``disp.finalize``). The logical meter
keys are prefixed with the query id (``execute(query_key=...)``), so
every query's call log is internally sorted and disjoint from its
neighbours'.

Multi-tenant QoS (``QueryServer(admission=AdmissionController(...))``):
``submit(plan, table, tenant=, lane=, deadline_s=)`` routes through an
admission controller that (a) bounds per-tenant in-flight rows and
queue depth with backpressure (reject-or-queue; FIFO within each lane),
(b) runs two priority lanes — ``interactive`` preempts ``batch`` at
*dequeue* time, never mid-morsel, so admission-order invariance holds
within a lane — and (c) gates admission on a *predicted* makespan under
current load: the candidate's ``plan_cost`` calls replay onto an
``EventScheduler`` seeded with the live ``Dispatcher.occupancy()``
snapshot (the simulated driver as a free digital twin of the serving
fleet), and a query whose predicted completion busts its ``deadline_s``
is denied up front instead of burning capacity it cannot use. Completed
queries feed their predicted-vs-actual makespan back to
``CostModel.observe_makespan``, so the gate's estimates calibrate
online (``--explain-cost`` reports the accuracy). Admission control
changes only *when* a query starts — never what it answers or bills —
so the solo-identity contract above extends verbatim to admitted
queries.
"""
from __future__ import annotations

import collections
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FutureTimeout
from typing import Any, Deque, Dict, List, Optional, Tuple

from repro.core import backends as bk
from repro.core import executor as ex
from repro.core import plan as plan_ir
from repro.core import runtime as rt
from repro.core.table import Table

LANES = ("interactive", "batch")


class AdmissionError(RuntimeError):
    """A query was refused admission: ``reason`` is ``"backpressure"``
    (per-tenant queue depth exhausted) or ``"deadline"`` (predicted
    completion under current load busts the query's ``deadline_s``)."""

    def __init__(self, reason: str, message: str):
        super().__init__(message)
        self.reason = reason


class QueryHandle:
    """One admitted query: its future result, per-query meter, and
    measured timings. ``latency_s`` counts from admission (queueing
    included); ``exec_wall_s`` counts from the moment execution started
    on the shared dispatcher."""

    def __init__(self, qid: int, name: str, tenant: str = "default",
                 lane: str = "batch", deadline_s: Optional[float] = None):
        self.qid = qid
        self.name = name
        self.tenant = tenant
        self.lane = lane
        self.deadline_s = deadline_s
        self.state = "queued"   # queued -> running -> completed | failed
        #                         \-> rejected (admission denial)
        self.predicted_makespan_s: Optional[float] = None
        self.predicted_completion_s: Optional[float] = None
        self.meter = bk.UsageMeter()
        self.submitted_s = time.perf_counter()
        self.started_s: Optional[float] = None
        self.finished_s: Optional[float] = None
        self._fut: Future = Future()
        # retained while queued so the admission pump can start the query
        # later; dropped at dequeue so a long queue does not pin tables
        self._work: Optional[Tuple[plan_ir.LogicalPlan, Table]] = None

    def result(self, timeout: Optional[float] = None) -> ex.ExecutionResult:
        """Block for the query's :class:`executor.ExecutionResult`;
        re-raises the query's own failure (and only its own)."""
        return self._fut.result(timeout)

    def done(self) -> bool:
        return self._fut.done()

    def failed(self) -> bool:
        return self._fut.done() and self._fut.exception() is not None

    def rejected(self) -> bool:
        """True when admission control denied this query (its
        :meth:`result` raises :class:`AdmissionError`)."""
        return self.state == "rejected"

    @property
    def latency_s(self) -> float:
        """Admission-to-completion measured wall (includes queue wait)."""
        if self.finished_s is None:
            return 0.0
        return self.finished_s - self.submitted_s

    @property
    def exec_wall_s(self) -> float:
        """Execution-start-to-completion measured wall for THIS query
        (the shared dispatcher's ``wall_s`` is server-cumulative)."""
        if self.finished_s is None or self.started_s is None:
            return 0.0
        return self.finished_s - self.started_s


class AdmissionController:
    """Makespan-gated multi-tenant admission for a :class:`QueryServer`.

    Three mechanisms, all decided at *admission or dequeue time* (a
    running query is never preempted mid-morsel, so per-call batching,
    caching, and metering are untouched):

    * **bounded tenants** — ``max_tenant_rows`` caps the summed table
      rows a tenant may have executing at once (a query larger than the
      cap still runs when its tenant is otherwise idle, so big queries
      cannot starve); ``max_queue_depth`` caps how many queries a tenant
      may have *waiting* per submission — one more is rejected with
      ``AdmissionError("backpressure")`` instead of queueing unboundedly;
    * **priority lanes** — two FIFO queues, ``interactive`` and
      ``batch``; whenever an execution slot frees, the interactive queue
      is offered it first. Order *within* a lane is strict submission
      order (head-of-line blocking on a tenant cap lets the other lane
      overtake — that is the preemption — but never a later query in the
      same lane);
    * **makespan gate** — a query carrying ``deadline_s`` is admitted
      only if its *predicted* completion (queue wait plus
      ``CostModel.admission_estimate`` replayed onto an
      ``EventScheduler`` seeded with the live dispatcher occupancy)
      meets the deadline; otherwise ``AdmissionError("deadline")``.
      Predictions are corrected by the online ratio learned from
      completed queries (``CostModel.observe_makespan``).

    All mutable state is guarded by the owning server's lock; the
    controller is bound to exactly one server."""

    def __init__(self, *, max_tenant_rows: Optional[int] = None,
                 max_queue_depth: Optional[int] = None,
                 max_concurrent: Optional[int] = None,
                 default_lane: str = "batch"):
        if default_lane not in LANES:
            raise ValueError(f"unknown lane {default_lane!r}; "
                             f"expected one of {LANES}")
        self.max_tenant_rows = max_tenant_rows
        self.max_queue_depth = max_queue_depth
        self.max_concurrent = max_concurrent
        self.default_lane = default_lane
        self._server: Optional["QueryServer"] = None
        self._queues: Dict[str, Deque[QueryHandle]] = {
            lane: collections.deque() for lane in LANES}
        self._tenant_rows: Dict[str, int] = collections.defaultdict(int)
        self._tenant_queued: Dict[str, int] = collections.defaultdict(int)
        self._running = 0
        self._rejected_backpressure = 0
        self._rejected_deadline = 0
        self._served_by_lane: Dict[str, int] = {lane: 0 for lane in LANES}

    def _bind(self, server: "QueryServer") -> None:
        if self._server is not None:
            raise RuntimeError("AdmissionController is already bound "
                               "to a QueryServer")
        self._server = server
        if self.max_concurrent is None:
            self.max_concurrent = server.max_inflight

    # -- gate ------------------------------------------------------------
    def _tenant_ok(self, handle: QueryHandle, n_rows: int) -> bool:
        if self.max_tenant_rows is None:
            return True
        busy = self._tenant_rows[handle.tenant]
        return busy == 0 or busy + n_rows <= self.max_tenant_rows

    def _queued_ahead(self, lane: str) -> List[QueryHandle]:
        """Queued handles that dequeue before a new arrival to ``lane``:
        its whole lane queue, plus — for a batch arrival — every queued
        interactive query (interactive wins each free slot)."""
        ahead = list(self._queues[lane])
        if lane == "batch":
            ahead = list(self._queues["interactive"]) + ahead
        return ahead

    def _predict(self, server: "QueryServer", plan: plan_ir.LogicalPlan,
                 table: Table, lane: str) -> Tuple[Optional[float],
                                                   Optional[float]]:
        """(predicted exec makespan, predicted completion) for a
        candidate, or ``(None, None)`` when no cost model is wired."""
        model = server.ctx.cost_model
        if model is None:
            return None, None
        ctx = server.ctx
        shards = max(1, ctx.shards, ctx.procs)
        exec_s = model.admission_estimate(
            plan, table.n_rows,
            occupancy=server._disp.occupancy(),
            default_tier=ctx.default_tier,
            concurrency=ctx.concurrency,
            batch_size=ctx.batch_size,
            shards=shards)
        # queue wait: everyone who dequeues first, spread over the
        # execution slots (a deliberate fluid approximation — it is
        # deterministic given the queue snapshot, which is what the
        # denial-determinism contract needs)
        width = max(1, int(self.max_concurrent or 1))
        wait_s = sum(h.predicted_makespan_s or 0.0
                     for h in self._queued_ahead(lane)) / width
        return exec_s, wait_s + exec_s

    # -- admission (called by the server, under its lock) ----------------
    def _admit_locked(self, server: "QueryServer", handle: QueryHandle,
                      plan: plan_ir.LogicalPlan,
                      table: Table) -> Tuple[List[QueryHandle],
                                             Optional[AdmissionError]]:
        """Decide one submission: returns (queries to start now, denial).
        The handle is either queued/started (denial None) or left
        untracked with a denial to set on its future."""
        pred_exec, pred_done = self._predict(server, plan, table,
                                             handle.lane)
        handle.predicted_makespan_s = pred_exec
        handle.predicted_completion_s = pred_done
        if (handle.deadline_s is not None and pred_done is not None
                and pred_done > handle.deadline_s):
            self._rejected_deadline += 1
            return [], AdmissionError(
                "deadline",
                f"query {handle.name!r}: predicted completion "
                f"{pred_done:.3f}s busts deadline {handle.deadline_s:.3f}s "
                f"under current load")
        handle._work = (plan, table)
        self._queues[handle.lane].append(handle)
        self._tenant_queued[handle.tenant] += 1
        started = self._pump_locked(server)
        if handle.state == "queued" and self.max_queue_depth is not None \
                and self._tenant_queued[handle.tenant] > self.max_queue_depth:
            # could not start and the tenant's waiting allowance is spent:
            # shed THIS arrival (never an earlier one — FIFO is sacred)
            self._queues[handle.lane].remove(handle)
            self._tenant_queued[handle.tenant] -= 1
            handle._work = None
            self._rejected_backpressure += 1
            return started, AdmissionError(
                "backpressure",
                f"tenant {handle.tenant!r} already has "
                f"{self._tenant_queued[handle.tenant]} queries queued "
                f"(max_queue_depth={self.max_queue_depth})")
        return started, None

    def _pump_locked(self, server: "QueryServer") -> List[QueryHandle]:
        """Fill free execution slots: the interactive queue is offered
        each slot first, then batch. Within a lane the scan is FIFO, but
        an entry blocked by its *tenant's* cap is skipped — a capped
        tenant must not convoy other tenants behind it (when no cap
        binds, within-lane order is therefore strict submission order)."""
        started: List[QueryHandle] = []
        width = max(1, int(self.max_concurrent or 1))
        while self._running < width:
            picked: Optional[QueryHandle] = None
            for lane in LANES:
                q = self._queues[lane]
                for h in q:
                    if self._tenant_ok(h, h._work[1].n_rows):
                        picked = h
                        q.remove(h)
                        break
                if picked is not None:
                    break
            if picked is None:
                break
            self._tenant_queued[picked.tenant] -= 1
            self._tenant_rows[picked.tenant] += picked._work[1].n_rows
            self._running += 1
            self._served_by_lane[picked.lane] += 1
            picked.state = "dispatched"
            started.append(picked)
        return started

    def _release_locked(self, server: "QueryServer",
                        handle: QueryHandle,
                        n_rows: int) -> List[QueryHandle]:
        """Return a finished query's capacity and refill the slots."""
        self._running -= 1
        self._tenant_rows[handle.tenant] -= n_rows
        if self._tenant_rows[handle.tenant] <= 0:
            self._tenant_rows.pop(handle.tenant, None)
        if self._tenant_queued.get(handle.tenant) == 0:
            self._tenant_queued.pop(handle.tenant, None)
        return self._pump_locked(server)

    def stats(self) -> dict:
        """QoS counters (callers hold no lock: point-in-time snapshot)."""
        return {
            "running": self._running,
            "queued": {lane: len(q) for lane, q in self._queues.items()},
            "tenant_rows": dict(self._tenant_rows),
            "served_by_lane": dict(self._served_by_lane),
            "rejected_backpressure": self._rejected_backpressure,
            "rejected_deadline": self._rejected_deadline,
            "max_tenant_rows": self.max_tenant_rows,
            "max_queue_depth": self.max_queue_depth,
            "max_concurrent": self.max_concurrent,
        }


class QueryServer:
    """Long-lived semantic query server over one shared dispatcher.

    ::

        ctx = rt.ExecutionContext(backends=..., driver="threads",
                                  shards=2, concurrency=8)
        with QueryServer(ctx) as server:
            h1 = server.submit(plan1, table1)
            h2 = server.submit(plan2, table2)     # interleaves with h1
            res1, res2 = h1.result(), h2.result()

    ``max_inflight`` bounds how many admitted queries execute at once
    (later submissions queue in admission order); backend-call
    parallelism *within* each query is still governed by the context's
    ``concurrency`` / ``per_tier_concurrency`` / ``shards`` knobs.
    Passing ``admission=AdmissionController(...)`` upgrades the flat
    FIFO into multi-tenant QoS: per-tenant caps, priority lanes, and the
    makespan-gated deadline check (see :class:`AdmissionController`);
    without it, behaviour is byte-for-byte the pre-QoS server.
    ``close()`` drains in-flight queries, then releases the dispatcher's
    pools and the cache's in-flight reservations (idempotent; also the
    context-manager exit)."""

    def __init__(self, ctx_or_backends, *, max_inflight: int = 8,
                 admission: Optional[AdmissionController] = None,
                 **ctx_overrides):
        ctx = rt.as_context(ctx_or_backends, **ctx_overrides)
        self._owns_cache = ctx.cache is None
        if self._owns_cache:
            # the serving default: one shared single-flight cache, so
            # repeated values across queries bill once, server-lifetime
            ctx = ctx.fork(cache=rt.OutputCache())
        self.ctx = ctx
        self.max_inflight = max(1, max_inflight)
        self._admission = admission
        if admission is not None:
            admission._bind(self)   # before any resource allocation:
            #                         a double-bind raises cleanly
        self._disp = ctx.dispatcher()
        workers = self.max_inflight
        if admission is not None and admission.max_concurrent:
            workers = max(workers, int(admission.max_concurrent))
        self._pool = ThreadPoolExecutor(max_workers=workers,
                                        thread_name_prefix="query-admit")
        self._lock = threading.Lock()
        self._seq = 0
        # only in-flight handles are retained (a long-lived server must
        # not pin every finished query's result table + call log forever);
        # completed/failed queries survive as counters, and the caller
        # keeps the handle it got from submit()
        self._inflight: Dict[int, QueryHandle] = {}
        self._completed = 0
        self._failed = 0
        self._closed = False

    # -- admission -------------------------------------------------------
    def submit(self, plan: plan_ir.LogicalPlan, table: Table,
               name: Optional[str] = None, *,
               tenant: str = "default", lane: Optional[str] = None,
               deadline_s: Optional[float] = None) -> QueryHandle:
        """Admit one query (thread-safe, non-blocking): returns a
        :class:`QueryHandle` whose execution interleaves with every
        other in-flight query on the shared dispatcher.

        ``tenant`` / ``lane`` / ``deadline_s`` are QoS hints consumed by
        the server's :class:`AdmissionController`; without one they are
        recorded on the handle but do not gate anything. A denied query
        still returns its handle — ``handle.rejected()`` is true and
        ``handle.result()`` raises :class:`AdmissionError` — so callers
        keep one code path for admitted and shed work."""
        ctl = self._admission
        if lane is None:
            lane = ctl.default_lane if ctl is not None else "batch"
        if lane not in LANES:
            raise ValueError(f"unknown lane {lane!r}; "
                             f"expected one of {LANES}")
        to_start: List[QueryHandle] = []
        denial: Optional[AdmissionError] = None
        with self._lock:
            if self._closed:
                raise RuntimeError("QueryServer is closed")
            qid = self._seq
            self._seq += 1
            handle = QueryHandle(qid, name or f"q{qid}", tenant=tenant,
                                 lane=lane, deadline_s=deadline_s)
            if ctl is None:
                handle.state = "dispatched"
                handle._work = (plan, table)
                self._inflight[qid] = handle
                to_start = [handle]
            else:
                to_start, denial = ctl._admit_locked(self, handle,
                                                     plan, table)
                if denial is None:
                    self._inflight[qid] = handle
                else:
                    handle.state = "rejected"
                    handle.finished_s = time.perf_counter()
        for h in to_start:
            self._launch(h)
        if denial is not None:
            handle._fut.set_exception(denial)
        return handle

    def _launch(self, handle: QueryHandle) -> None:
        """Hand a dequeued query to the execution pool (outside the
        admission lock — pool submission can block on interpreter state)."""
        plan, table = handle._work  # type: ignore[misc]
        handle._work = None
        self._pool.submit(self._run_query, handle, plan, table)

    def _run_query(self, handle: QueryHandle, plan: plan_ir.LogicalPlan,
                   table: Table) -> None:
        handle.state = "running"
        handle.started_s = time.perf_counter()
        qctx = self.ctx.fork(meter=handle.meter)
        try:
            res = ex.execute(plan, table, qctx, dispatcher=self._disp,
                             query_key=handle.qid)
        except BaseException as e:
            handle.finished_s = time.perf_counter()
            handle.state = "failed"
            # failed queries still billed whatever ran before the error —
            # and still observed: per-query finalize is a calibration sync
            # point (idempotent via the model's per-meter cursor, so the
            # executor's own observe of the same meter is not re-counted)
            if self.ctx.cost_model is not None:
                self.ctx.cost_model.observe(handle.meter)
            self.ctx.meter.absorb(handle.meter)
            handle._fut.set_exception(e)
            self._retire(handle, table.n_rows, failed=True)
        else:
            handle.finished_s = time.perf_counter()
            handle.state = "completed"
            if self.ctx.cost_model is not None:
                self.ctx.cost_model.observe(handle.meter)
                # close the admission loop: predicted-vs-actual makespan
                # feeds the gate's online ratio + q-error telemetry
                # (completed queries only — a failed query's wall is not
                # a makespan measurement)
                if (self._admission is not None
                        and handle.predicted_makespan_s is not None
                        and handle.exec_wall_s > 0.0):
                    self.ctx.cost_model.observe_makespan(
                        handle.predicted_makespan_s, handle.exec_wall_s)
            self.ctx.meter.absorb(handle.meter)
            handle._fut.set_result(res)
            self._retire(handle, table.n_rows, failed=False)

    def _retire(self, handle: QueryHandle, n_rows: int,
                failed: bool) -> None:
        to_start: List[QueryHandle] = []
        with self._lock:
            self._inflight.pop(handle.qid, None)
            if failed:
                self._failed += 1
            else:
                self._completed += 1
            if self._admission is not None:
                to_start = self._admission._release_locked(self, handle,
                                                           n_rows)
        for h in to_start:
            self._launch(h)

    # -- lifecycle -------------------------------------------------------
    def drain(self, timeout: Optional[float] = None) -> None:
        """Wait for every admitted query (including ones admitted while
        draining) to finish. ONE deadline is shared by the whole drain:
        every per-handle wait gets the *remaining* budget
        (``deadline - now``), and every loop iteration — including the
        retirement-pending spin — re-checks it, so the drain returns or
        raises within ``timeout`` of the call no matter how many handles
        it waits through. Failures do not raise here — read them
        per-handle."""
        deadline = None if timeout is None \
            else time.perf_counter() + timeout
        while True:
            with self._lock:
                # checking emptiness under the admission lock makes this
                # drain's linearization point race-free: a submit either
                # registered its handle before the check (and is waited
                # on) or is ordered after the drain
                pending = list(self._inflight.values())
                if not pending:
                    return
            left = None if deadline is None \
                else deadline - time.perf_counter()
            if left is not None and left <= 0.0:
                raise TimeoutError(
                    f"{len(pending)} queries still in flight")
            waitable = [h for h in pending if not h.done()]
            if not waitable:
                time.sleep(0.001)   # resolved, retirement imminent
                continue
            try:
                waitable[0]._fut.exception(left)
            except (_FutureTimeout, TimeoutError):
                raise TimeoutError(
                    f"{len(pending)} queries still in flight") from None

    def close(self) -> None:
        """Drain, then release the shared dispatcher's pools — and, when
        the server created its own cache, that cache's reservations (a
        caller-supplied cache is left alone: other contexts may still be
        executing against it). Idempotent."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self.drain()
        self._pool.shutdown(wait=True)
        self.ctx.close()
        if self._owns_cache and self.ctx.cache is not None:
            self.ctx.cache.close()

    def __enter__(self) -> "QueryServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- reporting -------------------------------------------------------
    @property
    def wall_s(self) -> float:
        """Server-lifetime wall (the shared dispatcher's clock)."""
        return self._disp.wall_s

    def stats(self) -> dict:
        total: Any = self.ctx.meter.total
        with self._lock:
            out = {
                "admitted": self._seq,
                "completed": self._completed,
                "failed": self._failed,
                "inflight": len(self._inflight),
                "calls": total.calls,
                "usd": total.usd,
                "wall_s": self.wall_s,
            }
        # fault-policy counters (retries, breaker trips, fallback calls);
        # absent when the server runs fail-fast so existing consumers of
        # the stats shape see no new key by default
        faults = self._disp.fault_stats()
        if faults is not None:
            out["faults"] = faults
        # QoS counters appear only when an AdmissionController is wired,
        # same additive-key convention as "faults"
        if self._admission is not None:
            with self._lock:
                out["qos"] = self._admission.stats()
        return out
