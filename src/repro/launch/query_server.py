"""Streaming semantic serve: continuous query admission onto one shared
sharded dispatcher.

``executor.execute`` runs one query and tears its worker pools down; a
:class:`QueryServer` is the long-lived form — the analytics-level analog
of continuous batching at the token level (``engine.ContinuousBatcher``
fills decode slots across requests; the server fills **dispatcher
capacity** across queries). One server owns

* ONE :class:`runtime.ExecutionContext` — shared backends, one shared
  single-flight ``OutputCache`` (cross-query duplicate values bill once),
  and the server-lifetime ``UsageMeter`` that accumulates every admitted
  query's spend;
* ONE long-lived dispatcher (``ctx.dispatcher()``) — under
  ``driver="threads"`` the per-tier worker pools (or, with
  ``ctx.shards > 1``, the pool-per-(shard, tier) grid of the
  ``ShardedDispatcher``) persist across queries, so
  ``per_tier_concurrency`` caps act as true serving quotas **across
  tenants**: two in-flight queries' calls against one tier queue on the
  same bounded pool.

``submit(plan, table)`` admits a query from any caller thread and
returns a :class:`QueryHandle` immediately; the query's morsel stream is
fed into the shared dispatcher, interleaving with every other in-flight
query. Each handle carries its own per-query ``UsageMeter`` (finalized
independently via the dispatcher's per-execution staging merge) and its
own **measured** latency/exec wall; the server context's meter absorbs
each query's totals as it finishes, so ``server.ctx.meter`` is the
server-lifetime bill.

Isolation contract (test-enforced in ``tests/test_serve.py``):

* admission-order invariance — a query's results and per-query meter
  totals are byte-identical to running it solo on a fresh context
  (concurrent tenants only change *when* calls run, never what they
  answer or bill; shared-cache hits across queries require overlapping
  cache keys, which distinct instructions never produce);
* failure isolation — a backend failure inside one query poisons only
  that query's handle; other in-flight queries and later submissions
  are unaffected.

Per-query state that used to be per-process: the coalescer (one
``BatchCoalescer`` per execution, so one query's linger watermark cannot
stall another's), the sharded round-robin cursor (``shard_of(query=)``
offsets each query), and meter staging (keyed by the query's own meter
object, merged per-execution by ``disp.finalize``). The logical meter
keys are prefixed with the query id (``execute(query_key=...)``), so
every query's call log is internally sorted and disjoint from its
neighbours'.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FutureTimeout
from typing import Any, Dict, Optional

from repro.core import backends as bk
from repro.core import executor as ex
from repro.core import plan as plan_ir
from repro.core import runtime as rt
from repro.core.table import Table


class QueryHandle:
    """One admitted query: its future result, per-query meter, and
    measured timings. ``latency_s`` counts from admission (queueing
    included); ``exec_wall_s`` counts from the moment execution started
    on the shared dispatcher."""

    def __init__(self, qid: int, name: str):
        self.qid = qid
        self.name = name
        self.meter = bk.UsageMeter()
        self.submitted_s = time.perf_counter()
        self.started_s: Optional[float] = None
        self.finished_s: Optional[float] = None
        self._fut: Future = Future()

    def result(self, timeout: Optional[float] = None) -> ex.ExecutionResult:
        """Block for the query's :class:`executor.ExecutionResult`;
        re-raises the query's own failure (and only its own)."""
        return self._fut.result(timeout)

    def done(self) -> bool:
        return self._fut.done()

    def failed(self) -> bool:
        return self._fut.done() and self._fut.exception() is not None

    @property
    def latency_s(self) -> float:
        """Admission-to-completion measured wall (includes queue wait)."""
        if self.finished_s is None:
            return 0.0
        return self.finished_s - self.submitted_s

    @property
    def exec_wall_s(self) -> float:
        """Execution-start-to-completion measured wall for THIS query
        (the shared dispatcher's ``wall_s`` is server-cumulative)."""
        if self.finished_s is None or self.started_s is None:
            return 0.0
        return self.finished_s - self.started_s


class QueryServer:
    """Long-lived semantic query server over one shared dispatcher.

    ::

        ctx = rt.ExecutionContext(backends=..., driver="threads",
                                  shards=2, concurrency=8)
        with QueryServer(ctx) as server:
            h1 = server.submit(plan1, table1)
            h2 = server.submit(plan2, table2)     # interleaves with h1
            res1, res2 = h1.result(), h2.result()

    ``max_inflight`` bounds how many admitted queries execute at once
    (later submissions queue in admission order); backend-call
    parallelism *within* each query is still governed by the context's
    ``concurrency`` / ``per_tier_concurrency`` / ``shards`` knobs.
    ``close()`` drains in-flight queries, then releases the dispatcher's
    pools and the cache's in-flight reservations (idempotent; also the
    context-manager exit)."""

    def __init__(self, ctx_or_backends, *, max_inflight: int = 8,
                 **ctx_overrides):
        ctx = rt.as_context(ctx_or_backends, **ctx_overrides)
        self._owns_cache = ctx.cache is None
        if self._owns_cache:
            # the serving default: one shared single-flight cache, so
            # repeated values across queries bill once, server-lifetime
            ctx = ctx.fork(cache=rt.OutputCache())
        self.ctx = ctx
        self._disp = ctx.dispatcher()
        self._pool = ThreadPoolExecutor(max_workers=max(1, max_inflight),
                                        thread_name_prefix="query-admit")
        self._lock = threading.Lock()
        self._seq = 0
        # only in-flight handles are retained (a long-lived server must
        # not pin every finished query's result table + call log forever);
        # completed/failed queries survive as counters, and the caller
        # keeps the handle it got from submit()
        self._inflight: Dict[int, QueryHandle] = {}
        self._completed = 0
        self._failed = 0
        self._closed = False

    # -- admission -------------------------------------------------------
    def submit(self, plan: plan_ir.LogicalPlan, table: Table,
               name: Optional[str] = None) -> QueryHandle:
        """Admit one query (thread-safe, non-blocking): returns a
        :class:`QueryHandle` whose execution interleaves with every
        other in-flight query on the shared dispatcher."""
        with self._lock:
            if self._closed:
                raise RuntimeError("QueryServer is closed")
            qid = self._seq
            self._seq += 1
            handle = QueryHandle(qid, name or f"q{qid}")
            self._inflight[qid] = handle
        self._pool.submit(self._run_query, handle, plan, table)
        return handle

    def _run_query(self, handle: QueryHandle, plan: plan_ir.LogicalPlan,
                   table: Table) -> None:
        handle.started_s = time.perf_counter()
        qctx = self.ctx.fork(meter=handle.meter)
        try:
            res = ex.execute(plan, table, qctx, dispatcher=self._disp,
                             query_key=handle.qid)
        except BaseException as e:
            handle.finished_s = time.perf_counter()
            # failed queries still billed whatever ran before the error —
            # and still observed: per-query finalize is a calibration sync
            # point (idempotent via the model's per-meter cursor, so the
            # executor's own observe of the same meter is not re-counted)
            if self.ctx.cost_model is not None:
                self.ctx.cost_model.observe(handle.meter)
            self.ctx.meter.absorb(handle.meter)
            handle._fut.set_exception(e)
            self._retire(handle, failed=True)
        else:
            handle.finished_s = time.perf_counter()
            if self.ctx.cost_model is not None:
                self.ctx.cost_model.observe(handle.meter)
            self.ctx.meter.absorb(handle.meter)
            handle._fut.set_result(res)
            self._retire(handle, failed=False)

    def _retire(self, handle: QueryHandle, failed: bool) -> None:
        with self._lock:
            self._inflight.pop(handle.qid, None)
            if failed:
                self._failed += 1
            else:
                self._completed += 1

    # -- lifecycle -------------------------------------------------------
    def drain(self, timeout: Optional[float] = None) -> None:
        """Wait for every admitted query (including ones admitted while
        draining) to finish. ONE deadline is shared by the whole drain:
        every per-handle wait gets the *remaining* budget
        (``deadline - now``), and every loop iteration — including the
        retirement-pending spin — re-checks it, so the drain returns or
        raises within ``timeout`` of the call no matter how many handles
        it waits through. Failures do not raise here — read them
        per-handle."""
        deadline = None if timeout is None \
            else time.perf_counter() + timeout
        while True:
            with self._lock:
                # checking emptiness under the admission lock makes this
                # drain's linearization point race-free: a submit either
                # registered its handle before the check (and is waited
                # on) or is ordered after the drain
                pending = list(self._inflight.values())
                if not pending:
                    return
            left = None if deadline is None \
                else deadline - time.perf_counter()
            if left is not None and left <= 0.0:
                raise TimeoutError(
                    f"{len(pending)} queries still in flight")
            waitable = [h for h in pending if not h.done()]
            if not waitable:
                time.sleep(0.001)   # resolved, retirement imminent
                continue
            try:
                waitable[0]._fut.exception(left)
            except (_FutureTimeout, TimeoutError):
                raise TimeoutError(
                    f"{len(pending)} queries still in flight") from None

    def close(self) -> None:
        """Drain, then release the shared dispatcher's pools — and, when
        the server created its own cache, that cache's reservations (a
        caller-supplied cache is left alone: other contexts may still be
        executing against it). Idempotent."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self.drain()
        self._pool.shutdown(wait=True)
        self.ctx.close()
        if self._owns_cache and self.ctx.cache is not None:
            self.ctx.cache.close()

    def __enter__(self) -> "QueryServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- reporting -------------------------------------------------------
    @property
    def wall_s(self) -> float:
        """Server-lifetime wall (the shared dispatcher's clock)."""
        return self._disp.wall_s

    def stats(self) -> dict:
        total: Any = self.ctx.meter.total
        with self._lock:
            out = {
                "admitted": self._seq,
                "completed": self._completed,
                "failed": self._failed,
                "inflight": len(self._inflight),
                "calls": total.calls,
                "usd": total.usd,
                "wall_s": self.wall_s,
            }
        # fault-policy counters (retries, breaker trips, fallback calls);
        # absent when the server runs fail-fast so existing consumers of
        # the stats shape see no new key by default
        faults = self._disp.fault_stats()
        if faults is not None:
            out["faults"] = faults
        return out
