"""Version-compat shims for jax API drift.

Two renames moved under this roof so kernel/model code stays version-clean:

* Pallas-TPU compiler params: newer jax exposes
  ``pltpu.CompilerParams``; 0.4.x calls it ``pltpu.TPUCompilerParams``.
* ``shard_map``: newer jax promotes it to ``jax.shard_map`` (keyword
  ``check_vma``); 0.4.x ships it as
  ``jax.experimental.shard_map.shard_map`` (keyword ``check_rep``).
"""
from __future__ import annotations

import jax
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

if hasattr(jax, "shard_map"):
    _shard_map, _CHECK_KW = jax.shard_map, "check_vma"
else:
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
    """`jax.shard_map` signature, runnable on 0.4.x jax."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **{_CHECK_KW: check_vma})
