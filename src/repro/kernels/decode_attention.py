"""Decode attention (one token vs long KV cache) — Pallas TPU kernel.

Flash-decoding adapted to the TPU's sequential grid: decode is memory-bound
(the whole KV cache streams HBM->VMEM once; arithmetic intensity ~1 FLOP/B),
so the kernel's job is to keep that stream dense and never materialize
logits in HBM. The KV sequence is split into blocks ("split-K"); partial
(max, sum, acc) merge across the sequential last grid dimension in VMEM
scratch — the TPU analogue of the GPU version's cross-SM reduction tree.

Grid: (batch, q_heads, S/bk). The q row for a head is tiny (1 x D); it is
re-read per block from VMEM, which is free compared to the KV stream.
Variable cache lengths are masked from a scalar-prefetch cache_len vector.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

NEG_INF = -1e30
DEFAULT_BK = 512


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bk: int, scale: float):
    bi = pl.program_id(0)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    cache_len = len_ref[bi]
    k_start = ki * bk

    @pl.when(k_start < cache_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (1, d)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (1, bk)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        s = jnp.where(k_pos < cache_len, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                             # (1, bk)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)                # (bk, d)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[0, 0, ...] = (acc_scr[...] /
                            jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *,
                     bk: int = DEFAULT_BK, interpret: bool = False):
    """q: (B, Hq, 1, D); caches: (B, Hkv, S, D); cache_len: (B,) int32.
    Returns (B, Hq, 1, D) in q.dtype."""
    b, hq, one, d = q.shape
    assert one == 1
    _, hkv, s, _ = k_cache.shape
    g = hq // hkv
    scale = float(d ** -0.5)
    bk = min(bk, s)
    assert s % bk == 0, (s, bk)
    cache_len = jnp.asarray(cache_len, jnp.int32).reshape(b)

    grid = (b, hq, s // bk)
    kern = functools.partial(_kernel, bk=bk, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda bi, h, ki, *_: (bi, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, h, ki, *_, g=g: (bi, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, h, ki, *_, g=g: (bi, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d),
                               lambda bi, h, ki, *_: (bi, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, 1, d), q.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(cache_len, q, k_cache, v_cache)
