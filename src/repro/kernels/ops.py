"""jit'd public wrappers around the Pallas kernels.

Handles layout adaptation (models use (B, S, H, D); kernels want
(B, H, S, D)), padding to block multiples, and backend dispatch: on TPU the
kernels compile natively; on CPU (this container) they run in interpret
mode so tests validate the exact kernel bodies against the ref.py oracles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import decode_attention as dec_mod
from repro.kernels import flash_attention as fa_mod
from repro.kernels import similarity as sim_mod
from repro.kernels import ssd_scan as ssd_mod

NEG_INF = -1e30


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_axis(x, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


# ---------------------------------------------------------------------------
# Flash attention (train/prefill)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 0, bk: int = 0):
    """Model layout: q (B, Sq, Hq, D); k/v (B, Sk, Hkv, D).
    Returns (B, Sq, Hq, D)."""
    b, sq, hq, d = q.shape
    sk = k.shape[1]
    bq = bq or min(fa_mod.DEFAULT_BQ, max(8, sq))
    bk = bk or min(fa_mod.DEFAULT_BK, max(8, sk))
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    qt, sq0 = _pad_axis(qt, 2, bq)
    kt, sk0 = _pad_axis(kt, 2, bk)
    vt, _ = _pad_axis(vt, 2, bk)
    out = fa_mod.flash_attention(
        qt, kt, vt, causal=causal, window=window,
        q_offset=(sk0 - sq0) if causal else 0, sk_valid=sk0, bq=bq, bk=bk,
        interpret=_interpret())
    out = out[:, :, :sq0]
    return jnp.moveaxis(out, 1, 2)


# ---------------------------------------------------------------------------
# Decode attention (serve_step)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("bk",))
def decode_attention(q, k_cache, v_cache, cache_len, *, bk: int = 0):
    """Model layout: q (B, 1, Hq, D); caches (B, S, Hkv, D);
    cache_len scalar or (B,). Returns (B, 1, Hq, D)."""
    b, one, hq, d = q.shape
    s = k_cache.shape[1]
    bk = bk or min(dec_mod.DEFAULT_BK, max(8, s))
    qt = jnp.moveaxis(q, 2, 1)                      # (B, Hq, 1, D)
    kt = jnp.moveaxis(k_cache, 2, 1)
    vt = jnp.moveaxis(v_cache, 2, 1)
    kt, s0 = _pad_axis(kt, 2, bk)
    vt, _ = _pad_axis(vt, 2, bk)
    cl = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    out = dec_mod.decode_attention(qt, kt, vt, cl, bk=bk,
                                   interpret=_interpret())
    return jnp.moveaxis(out, 1, 2)


# ---------------------------------------------------------------------------
# SSD scan (Mamba2 / Hymba)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(dx, dA, B, C, initial_state=None, *, chunk: int = 0):
    """dx (B,S,H,P); dA (B,S,H); B/C (B,S,G,N). Returns (y, final_state)."""
    b, s, h, p = dx.shape
    chunk = chunk or min(ssd_mod.DEFAULT_CHUNK, s)
    while s % chunk:
        chunk //= 2
    return ssd_mod.ssd_scan(dx, dA, B, C, initial_state, chunk=chunk,
                            interpret=_interpret())


# ---------------------------------------------------------------------------
# Similarity (improvement score / judge)
# ---------------------------------------------------------------------------

def cosine_matrix(a, b):
    """(M, D) x (N, D) -> (M, N) fp32 cosine (rows pre-normalized)."""
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    a, m0 = _pad_axis(a, 0, sim_mod.BM)
    b, n0 = _pad_axis(b, 0, sim_mod.BN)
    out = sim_mod.cosine_matrix(a, b, interpret=_interpret())
    return np.asarray(out[:m0, :n0])


def rowwise_cosine(a, b):
    """Aligned pairs (M, D), (M, D) -> (M,) fp32 cosine."""
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    a, m0 = _pad_axis(a, 0, sim_mod.BM)
    b, _ = _pad_axis(b, 0, sim_mod.BM)
    out = sim_mod.rowwise_cosine(a, b, interpret=_interpret())
    return np.asarray(out[:m0])
