"""Pallas TPU kernels for the framework's compute hot spots.

  flash_attention   prefill/train attention (online softmax, VMEM tiling)
  decode_attention  one-token decode vs long KV (split-K flash decoding)
  ssd_scan          Mamba2/Hymba chunked SSD dual form
  similarity        batched cosine — the paper's improvement-score compare

``ops`` holds the jit'd public wrappers (layout, padding, CPU-interpret
dispatch); ``ref`` the pure-jnp oracles each kernel is tested against.
"""
