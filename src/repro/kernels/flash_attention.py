"""Flash attention (prefill/train) — Pallas TPU kernel.

Online-softmax tiling adapted to the TPU memory hierarchy: Q/K/V blocks are
staged HBM->VMEM by BlockSpec; the running (max, denominator, accumulator)
live in VMEM scratch across the *sequential* innermost KV grid dimension, so
the S x S score matrix never exists in HBM and every matmul hits the MXU
with 128-aligned operands. GQA is handled in the K/V index_map (query head
h reads KV head h // group) — no K/V replication in memory.

Grid: (batch, q_heads, Sq/bq, Sk/bk), dimension_semantics
("parallel", "parallel", "parallel", "arbitrary"). Causal blocks that are
fully masked are skipped with pl.when (upper-triangle block skip).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

NEG_INF = -1e30
DEFAULT_BQ = 128
DEFAULT_BK = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bq: int, bk: int, sk_valid: int, causal: bool, window: int,
            q_offset: int, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq + q_offset          # absolute position of q block
    k_start = ki * bk

    def compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < sk_valid          # excludes block-padding keys
        if causal:
            mask &= q_pos >= k_pos
        if window > 0:
            mask &= q_pos - k_pos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                             # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                    # (bq, 1)
        l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)                # (bk, d)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal:
        # skip blocks strictly above the diagonal (no valid positions)
        pl.when(q_start + bq - 1 >= k_start)(compute)
    else:
        compute()

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[0, 0, ...] = (acc_scr[...] /
                            jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, sk_valid: int = 0,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK, scale=None,
                    interpret: bool = False):
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Sk, D). Sq % bq == Sk % bk == 0
    (ops.py pads; sk_valid = unpadded key count, 0 = all valid).
    Returns (B, Hq, Sq, D) in q.dtype."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    scale = float(scale if scale is not None else d ** -0.5)
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)

    grid = (b, hq, sq // bq, sk // bk)
    kern = functools.partial(
        _kernel, bq=bq, bk=bk, sk_valid=int(sk_valid) or sk, causal=causal,
        window=int(window), q_offset=int(q_offset), scale=scale)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, h, qi, ki: (bi, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, h, qi, ki, g=g: (bi, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, h, qi, ki, g=g: (bi, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bi, h, qi, ki: (bi, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
