"""Mamba2 SSD chunked scan — Pallas TPU kernel.

The SSD dual form splits the sequence into chunks: inside a chunk the
output is a (masked, decay-weighted) L x L matmul — MXU work; across chunks
a small (N x P) state carries the recurrence. On TPU the natural mapping is
a *sequential* chunk grid dimension with the state living in VMEM scratch
between grid steps (the GPU version's inter-block shared-memory handoff has
no TPU analogue; the sequential-grid carry is the idiomatic replacement —
see DESIGN.md §Hardware-adaptation).

Grid: (batch, heads, S/L) with dimension_semantics ("parallel", "parallel",
"arbitrary"). B/C group projections are mapped per-head in the index_map
(head h reads group h // (H/G)) — the GQA-analogue of the SSD duality.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

DEFAULT_CHUNK = 256


def _kernel(dx_ref, dA_ref, b_ref, c_ref, init_ref, y_ref, fin_ref,
            state_scr, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = init_ref[0, 0].astype(jnp.float32)

    dx = dx_ref[0, :, 0].astype(jnp.float32)      # (L, P)
    dA = dA_ref[0, :, 0].astype(jnp.float32)      # (L,)
    B = b_ref[0, :, 0].astype(jnp.float32)        # (L, N)
    C = c_ref[0, :, 0].astype(jnp.float32)        # (L, N)
    state = state_scr[...]                        # (N, P)

    cs = jnp.cumsum(dA)                           # (L,) inclusive log-decay
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())))  # (L, L)
    delta = cs[:, None] - cs[None, :]
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    # mask before exp (upper-triangle deltas overflow; see models/ssm.py)
    m = scores * jnp.exp(jnp.where(li >= si, delta, -1e30))
    y_diag = jax.lax.dot_general(m, dx, (((1,), (0,)), ((), ())))  # (L, P)

    # incoming-state contribution, decayed from chunk start to each step
    y_off = jax.lax.dot_general(C * jnp.exp(cs)[:, None], state,
                                (((1,), (0,)), ((), ())))          # (L, P)
    y_ref[0, :, 0] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: decay to chunk end
    dec_end = jnp.exp(cs[-1] - cs)                # (L,)
    state_new = jax.lax.dot_general(B * dec_end[:, None], dx,
                                    (((0,), (0,)), ((), ())))      # (N, P)
    state_scr[...] = state * jnp.exp(cs[-1]) + state_new

    @pl.when(ci == nc - 1)
    def _finalize():
        fin_ref[0, 0] = state_scr[...]


def ssd_scan(dx, dA, B, C, initial_state=None, *,
             chunk: int = DEFAULT_CHUNK, interpret: bool = False):
    """Chunked SSD scan.

    dx: (B, S, H, P); dA: (B, S, H); B/C: (B, S, G, N). S % chunk == 0
    (ops.py pads). Returns (y (B,S,H,P) in dx.dtype, final_state
    (B,H,N,P) fp32).
    """
    b, s, h, p = dx.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    if initial_state is None:
        initial_state = jnp.zeros((b, h, n, p), jnp.float32)
    dA3 = dA[..., None]                            # (B,S,H,1) — 2D-tileable

    grid = (b, h, s // chunk)
    kern = functools.partial(_kernel, chunk=chunk)
    y, fin = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p),
                         lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1, 1),
                         lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda bi, hi, ci, rep=rep: (bi, ci, hi // rep, 0)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda bi, hi, ci, rep=rep: (bi, ci, hi // rep, 0)),
            pl.BlockSpec((1, 1, n, p), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, chunk, 1, p),
                         lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, n, p), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, s, h, p), dx.dtype),
            jax.ShapeDtypeStruct((b, h, n, p), jnp.float32),
        ),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(dx, dA3, B, C, initial_state)
    return y, fin
