"""Pure-jnp oracles for every Pallas kernel. The kernels are validated
against these in tests/test_kernels.py across shape/dtype sweeps."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=0, scale=None):
    """Naive full-matrix attention oracle.

    q: (B, Sq, Hq, D); k/v: (B, Sk, Hkv, D). GQA via head grouping.
    window > 0: sliding-window causal. Returns (B, Sq, Hq, D) in q.dtype.
    """
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(b, sq, hkv, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kf)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        # align last query to last key (supports sq < sk prefill continuation)
        offset = sk - sq
        mask &= (q_pos + offset) >= k_pos
        if window:
            mask &= (q_pos + offset) - k_pos < window
    elif window:
        mask &= jnp.abs(q_pos - k_pos) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return o.reshape(b, sq, hq, d).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, cache_len):
    """Single-position decode oracle. q: (B, 1, Hq, D); caches
    (B, S, Hkv, D); cache_len scalar or (B,). Returns (B, 1, Hq, D)."""
    b, _, hq, d = q.shape
    _, s, hkv, _ = k_cache.shape
    g = hq // hkv
    qf = (q.astype(jnp.float32) * d ** -0.5).reshape(b, hkv, g, d)
    sc = jnp.einsum("bhgd,bkhd->bhgk", qf, k_cache.astype(jnp.float32))
    valid = jnp.arange(s)[None, :] < jnp.reshape(cache_len, (-1, 1))
    sc = jnp.where(valid[:, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, 1, hq, d).astype(q.dtype)


def ssd_ref(dx, dA, B, C, initial_state=None):
    """Naive sequential SSD recurrence oracle (fp32 state path).

    dx: (B, S, H, P)  inputs pre-scaled by dt
    dA: (B, S, H)     log-decay per step
    B, C: (B, S, G, N) grouped projections
    Returns (y (B,S,H,P) in dx.dtype, final_state (B,H,N,P) fp32).
    """
    b, s, h, p = dx.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B.astype(jnp.float32), rep, axis=2)   # (B,S,H,N)
    Ch = jnp.repeat(C.astype(jnp.float32), rep, axis=2)
    dxf = dx.astype(jnp.float32)
    dAf = dA.astype(jnp.float32)
    state = (initial_state if initial_state is not None
             else jnp.zeros((b, h, n, p), jnp.float32))

    def step(state, t):
        decay = jnp.exp(dAf[:, t])                         # (B,H)
        upd = jnp.einsum("bhn,bhp->bhnp", Bh[:, t], dxf[:, t])
        state = state * decay[:, :, None, None] + upd
        y_t = jnp.einsum("bhn,bhnp->bhp", Ch[:, t], state)
        return state, y_t

    state, ys = jax.lax.scan(step, state, jnp.arange(s))
    y = jnp.moveaxis(ys, 0, 1)                             # (B,S,H,P)
    return y.astype(dx.dtype), state


def cosine_matrix_ref(a, b):
    """a: (M, D), b: (N, D) rows L2-normalized -> (M, N) fp32."""
    return (a.astype(jnp.float32) @ b.astype(jnp.float32).T)


def rowwise_cosine_ref(a, b):
    """Aligned rows: (M, D), (M, D) -> (M,) fp32."""
    return jnp.sum(a.astype(jnp.float32) * b.astype(jnp.float32), axis=-1)
