"""Semantic-similarity kernels — the paper-specific compute hot spot.

Every improvement-score evaluation (Eq. 2) and every LLM-as-a-judge rating
compares batches of operator outputs by embedding cosine (§4.2 uses
Sentence-BERT). The embeddings are L2-normalized, so the comparison is a
plain GEMM — but it runs per optimizer iteration over every sampled record
pair, so it gets the kernel treatment:

  cosine_matrix   (M, D) x (N, D) -> (M, N): tiled MXU GEMM, full-D panels
                  in VMEM (embedding D is small: 256).
  rowwise_cosine  aligned pairs (M, D), (M, D) -> (M,): one fused
                  multiply-reduce sweep (used by semantic_equal_batch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

BM = 128
BN = 128


def _pad_rows(x, block: int):
    """Pad axis 0 up to a multiple of ``block`` (zero rows are inert for
    both kernels: a zero embedding row dots to 0). Returns (padded, m0)."""
    m0 = x.shape[0]
    pad = (-m0) % block
    if pad == 0:
        return x, m0
    return jnp.pad(x, ((0, pad), (0, 0))), m0


def _matrix_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    o_ref[...] = jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())))


def cosine_matrix(a, b, *, bm: int = BM, bn: int = BN,
                  interpret: bool = False):
    """a: (M, D), b: (N, D), rows L2-normalized. Returns (M, N) fp32.

    Arbitrary M/N: inputs are padded up to block multiples and the result
    is sliced back, so callers (morsels, embedding cascades) never need
    divisibility — M=1 and M=BM+1 both work."""
    if a.shape[0] == 0 or b.shape[0] == 0:
        return jnp.zeros((a.shape[0], b.shape[0]), jnp.float32)
    a, m0 = _pad_rows(a, min(bm, a.shape[0]))
    b, n0 = _pad_rows(b, min(bn, b.shape[0]))
    m, d = a.shape
    n, _ = b.shape
    bm = min(bm, m)
    bn = min(bn, n)
    out = pl.pallas_call(
        _matrix_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(a, b)
    return out if (m0 == m and n0 == n) else out[:m0, :n0]


def _rowwise_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.sum(a * b, axis=-1, keepdims=True)


def rowwise_cosine(a, b, *, bm: int = BM, interpret: bool = False):
    """Aligned-pair cosine: (M, D), (M, D) -> (M,) fp32. Arbitrary M:
    rows pad up to a block multiple and the result slices back."""
    if a.shape[0] == 0:
        return jnp.zeros((0,), jnp.float32)
    a, m0 = _pad_rows(a, min(bm, a.shape[0]))
    b, _ = _pad_rows(b, min(bm, b.shape[0]))
    m, d = a.shape
    bm = min(bm, m)
    out = pl.pallas_call(
        _rowwise_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(a, b)
    return out[:m0, 0]
