"""The paper's query workloads (Appendix F) — 12 queries per dataset,
grouped Small (1 operator, q1-q4), Medium (2-3 operators, q5-q8), Large
(4+ operators, q9-q12).

Queries are transcribed from Listings 2-4. The Game listing truncates after
q10 in the paper PDF; q11/q12 follow the stated pattern for Large queries
(4+ operators ending in a single-value reduce).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List

from repro.core.dataframe import SemanticDataFrame
from repro.core.table import Table


@dataclasses.dataclass
class Query:
    qid: str
    size: str            # S | M | L
    question: str
    build: Callable[[SemanticDataFrame], SemanticDataFrame]

    def plan_for(self, table: Table):
        return self.build(SemanticDataFrame(table)).plan()


def _q(qid, size, question, build):
    return Query(qid, size, question, build)


MOVIE: List[Query] = [
    _q("q1", "S", "Extract the genres of all movies",
       lambda df: df.semantic_map(
           "According to the movie plot, extract the genre(s) of each "
           "movie.", "Plot", "Genre")),
    _q("q2", "S", "Find all movies directed by Christopher Nolan",
       lambda df: df.semantic_filter(
           "The movie is directed by Christopher Nolan.", "Director")),
    _q("q3", "S", "Find all movies whose poster is in the dark style",
       lambda df: df.semantic_filter(
           "Whether the movie poster image is in the dark style.",
           "Poster")),
    _q("q4", "S", "Find all movies that won more than 3 Oscars",
       lambda df: df.semantic_filter(
           "Whether the movie has ever won more than 3 Oscars?", "Awards")),
    _q("q5", "M", "Total box office of movies rated above 9",
       lambda df: df.semantic_filter(
           "The rating is higher than 9.", "IMDB_rating")
       .semantic_reduce("Compute the total box office gross.", "BoxOffice")),
    _q("q6", "M", "Count movies directed by Quentin Tarantino",
       lambda df: df.semantic_filter(
           "The movie is directed by Quentin Tarantino.", "Director")
       .semantic_reduce("Count the number of movies.", "Title")),
    _q("q7", "M", "Genre of the highest-rated Spielberg movie",
       lambda df: df.semantic_map(
           "According to the movie plot, extract the genre(s) of each "
           "movie.", "Plot", "Genre")
       .semantic_filter("The movie is directed by Steven Spielberg.",
                        "Director")
       .semantic_reduce("Find the highest rate in the rest movie.",
                        "IMDB_rating")),
    _q("q8", "M", "Count movies that won 2 Oscars with rating above 9",
       lambda df: df.semantic_filter(
           "The rating is higher than 9.", "IMDB_rating")
       .semantic_filter("Whether the movie has won 2 Oscars.", "Awards")
       .semantic_reduce("Count the number of movies.", "Title")),
    _q("q9", "L", "Max rating of crime movies rated in (8.5, 9)",
       lambda df: df.semantic_map(
           "According to the movie plot, extract the genre(s) of each "
           "movie.", "Plot", "Genre")
       .semantic_filter("The rating is higher than 8.5.", "IMDB_rating")
       .semantic_filter("The rating is lower than 9.", "IMDB_rating")
       .semantic_filter("The movie belongs to crime movies.", "Genre")
       .semantic_reduce("Find the maximum rating in the rest movies.",
                        "IMDB_rating")),
    _q("q10", "L", "Count crime movies rated in (8.5, 9)",
       lambda df: df.semantic_map(
           "According to the movie plot, extract the genre(s) of each "
           "movie.", "Plot", "Genre")
       .semantic_filter("The rating is higher than 8.5.", "IMDB_rating")
       .semantic_filter("The rating is lower than 9.", "IMDB_rating")
       .semantic_filter("The movie belongs to crime movies.", "Genre")
       .semantic_reduce("Count the number of crime movies.", "Title")),
    _q("q11", "L", "Average runtime of crime movies rated above 9",
       lambda df: df.semantic_map(
           "According to the movie plot, extract the genre(s) of each "
           "movie.", "Plot", "Genre")
       .semantic_filter("The rating is higher than 9.", "IMDB_rating")
       .semantic_filter("The movie belongs to crime movies.", "Genre")
       .semantic_reduce("Compute the average movie runtime.", "Runtime")),
    _q("q12", "L", "Main characters of crime movies rated above 9",
       lambda df: df.semantic_map(
           "According to the movie plot, extract the genre(s) of each "
           "movie.", "Plot", "Genre")
       .semantic_filter("The rating is higher than 9.", "IMDB_rating")
       .semantic_filter("The movie belongs to crime movies.", "Genre")
       .semantic_map("Extract the main character from the movie plot.",
                     "Plot", "Character")),
]


ESTATE: List[Query] = [
    _q("q1", "S", "Find houses with a yard",
       lambda df: df.semantic_filter(
           "Observed from the house picture, whether the house has a yard "
           "or not.", "image")),
    _q("q2", "S", "Extract house prices from details",
       lambda df: df.semantic_map(
           "Extract the house price from the detail about the estate.",
           "Details", "Price")),
    _q("q3", "S", "Houses located in Ajah, Lagos",
       lambda df: df.semantic_filter(
           "Whether the house is located in Ajah, Lagos.", "Location")),
    _q("q4", "S", "Extract amenities of the estates",
       lambda df: df.semantic_map(
           "Extract Amenities of the estate from the estate details.",
           "Details", "Amenities")),
    _q("q5", "M", "Amenities of estates with 4-5 bedrooms",
       lambda df: df.semantic_filter(
           "Whether the estate has more than 3 bedrooms", "Title")
       .semantic_map("Extract Amenities of the estate from the estate "
                     "details.", "Details", "Amenities")
       .semantic_filter("Whether the estate has less than 6 bedrooms.",
                        "Title")),
    _q("q6", "M", "Average price of estates with a yard",
       lambda df: df.semantic_map(
           "Extract the house price from the detail about the estate.",
           "Details", "Price")
       .semantic_filter("Observed from the house picture, whether the "
                        "house has a yard or not.", "image")
       .semantic_reduce("Compute the average price for the estates.",
                        "Price")),
    _q("q7", "M", "Features of 2-3 bedroom estates",
       lambda df: df.semantic_map(
           "Extract features from the detail about the estate.", "Details",
           "Features")
       .semantic_filter("Whether the estate has 2 or 3 bedrooms", "Title")),
    _q("q8", "M", "Amenities of 2-3 bedroom estates",
       lambda df: df.semantic_map(
           "Extract amenities from the detail about the estate.", "Details",
           "Amenities")
       .semantic_filter("Whether the estate has 2 or 3 bedrooms", "Title")),
    _q("q9", "L", "Average price of 4-5 bedroom estates",
       lambda df: df.semantic_map(
           "Extract the house price from the detail about the estate.",
           "Details", "Price")
       .semantic_filter("Whether the estate has more than 3 bedrooms",
                        "Title")
       .semantic_filter("Whether the estate has less than 6 bedrooms.",
                        "Title")
       .semantic_reduce("Compute the average price for the estates.",
                        "Price")),
    _q("q10", "L", "Lowest price of 4-5 bedroom detached duplexes",
       lambda df: df.semantic_map(
           "Extract the house price from the detail about the estate.",
           "Details", "Price")
       .semantic_filter("Whether the estate has more than 3 bedrooms.",
                        "Title")
       .semantic_filter("Whether the estate has less than 6 bedrooms.",
                        "Title")
       .semantic_filter("Whether the estate is a detached duplex.", "Title")
       .semantic_reduce("Compute the lowest price for the estates.",
                        "Price")),
    _q("q11", "L", "Lowest price of estates with a swimming pool",
       lambda df: df.semantic_map(
           "Extract the house price from the detail about the estate.",
           "Details", "Price")
       .semantic_map("Extract the amenities from the estate details.",
                     "Details", "Amenities")
       .semantic_filter("Is there a swimming pool in the estate.",
                        "Amenities")
       .semantic_reduce("Compute the lowest price for the estates.",
                        "Price")),
    _q("q12", "L", "Average price: gym + pool + Lekki",
       lambda df: df.semantic_map(
           "Extract the house price from the detail about the estate.",
           "Details", "Price")
       .semantic_map("Extract the amenities from the estate details.",
                     "Details", "Amenities")
       .semantic_filter("Is there a swimming pool in the estate.",
                        "Amenities")
       .semantic_filter("Is there a gym in the estate.", "Amenities")
       .semantic_filter("Is the estate located in Lekki, Lagos.",
                        "Location")
       .semantic_reduce("Compute the average price for the estates.",
                        "Price")),
]


GAME: List[Query] = [
    _q("q1", "S", "Games suitable only for adults (PEGI)",
       lambda df: df.semantic_filter(
           "According to the given PEGI rating (in picture), check if the "
           "game is only suitable for adults (18 years or older).",
           "rating")),
    _q("q2", "S", "Binary review labels",
       lambda df: df.semantic_map(
           "Give the video game a binary review (positive or negative) "
           "based on the existing review.", "overall_reviews", "comments")),
    _q("q3", "S", "Games that support VR",
       lambda df: df.semantic_filter(
           "Does the video game support VR?", "platforms")),
    _q("q4", "S", "Games with MetaCritic above 90",
       lambda df: df.semantic_filter(
           "The rating is higher than 90.", "metacriticts")),
    _q("q5", "M", "Top publisher of sports games",
       lambda df: df.semantic_map(
           "Extract the genre from the brief summary of the game.",
           "description", "genre")
       .semantic_filter("The video game is about sports.", "genre")
       .semantic_reduce("Find the publisher that appears the most.",
                        "publisher")),
    _q("q6", "M", "Lowest discounted price among MacOS games",
       lambda df: df.semantic_filter(
           "Is MacOS in the list of supported platforms?", "platforms")
       .semantic_reduce("Find the lowest price.", "discounted_price")),
    _q("q7", "M", "Shooting games supporting Chinese",
       lambda df: df.semantic_map(
           "Extract the genre from the brief summary of the game.",
           "description", "genre")
       .semantic_filter("The video game is about shooting.", "genre")
       .semantic_filter("Is Chinese one of the supported languages?",
                        "language")),
    _q("q8", "M", "Count single-developer games rated above 90",
       lambda df: df.semantic_filter(
           "The rating is higher than 90.", "metacriticts")
       .semantic_filter("Does the video game has only one developer?",
                        "developer")
       .semantic_reduce("Count the number of games.", "title")),
    _q("q9", "L", "Average USD price of VR shooting games",
       lambda df: df.semantic_map(
           "Extract the genre from the brief summary of the game.",
           "description", "genre")
       .semantic_filter("Does the game support VR.", "platforms")
       .semantic_filter("The game is a shooting game", "genre")
       .semantic_map("Convert the price in IDR into the price in USD.",
                     "discounted_price", "price_usd")
       .semantic_reduce("Compute the average price in USD of games.",
                        "price_usd")),
    _q("q10", "L", "Average price: Windows+MacOS, positive reviews",
       lambda df: df.semantic_map(
           "Convert the price in IDR into the price in USD.",
           "discounted_price", "price_usd")
       .semantic_filter("Does the game supports both Windows and MacOS?",
                        "platforms")
       .semantic_filter("Does the game receive a positive review?",
                        "overall_reviews")
       .semantic_reduce("Compute the average price in USD of games.",
                        "price_usd")),
    _q("q11", "L", "Count adult strategy games rated above 80",
       lambda df: df.semantic_map(
           "Extract the genre from the brief summary of the game.",
           "description", "genre")
       .semantic_filter("The rating is higher than 80.", "metacriticts")
       .semantic_filter("The game is a strategy game", "genre")
       .semantic_filter("According to the given PEGI rating (in picture), "
                        "check if the game is only suitable for adults (18 "
                        "years or older).", "rating")
       .semantic_reduce("Count the number of games.", "title")),
    _q("q12", "L", "Average MetaCritic of positive-review VR games",
       lambda df: df.semantic_map(
           "Give the video game a binary review (positive or negative) "
           "based on the existing review.", "overall_reviews", "comments")
       .semantic_filter("Does the video game support VR?", "platforms")
       .semantic_filter("The review is positive.", "comments")
       .semantic_reduce("Compute the average rating of the games.",
                        "metacriticts")),
]


WORKLOADS = {"movie": MOVIE, "estate": ESTATE, "game": GAME}


def by_size(dataset: str, size: str) -> List[Query]:
    return [q for q in WORKLOADS[dataset] if q.size == size]
