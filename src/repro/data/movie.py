"""Synthetic Movie dataset — schema-faithful regeneration of the paper's
OMDB-sourced benchmark (Table 3: 250 records, 22 attributes; numerical,
textual, and image modalities) with seeded, recoverable ground truth.

Posters are image handles whose blobs carry the hidden visual facts (style,
cast) — the paper's running example extracts cast from posters. Plot text
embeds the genre vocabulary the genre-extraction map must recover. A slice
of rows is deliberately ill-formatted (awards written as prose, box office
with currency words) to preserve the paper's UDF failure mode (Fig. 12b).
"""
from __future__ import annotations

import random

from repro.core import plan as plan_ir
from repro.core.table import Table
from repro.data.oracle import InstructionOracle

N_ROWS = 250

GENRES = {
    "crime": ("heist", "mob", "detective hunting a syndicate"),
    "drama": ("family saga", "courtroom confession", "quiet grief"),
    "sci-fi": ("starship", "time dilation", "android uprising"),
    "comedy": ("mistaken identity", "roadtrip gone wrong", "wedding chaos"),
    "thriller": ("conspiracy", "cat-and-mouse chase", "double agent"),
    "romance": ("long-distance letters", "second-chance love", "meet-cute"),
}
DIRECTORS = ("Christopher Nolan", "Quentin Tarantino", "Steven Spielberg",
             "Greta Gerwig", "Denis Villeneuve", "Ava DuVernay",
             "Bong Joon-ho", "Sofia Coppola")
ACTORS = ("Matt Damon", "Viola Davis", "Ken Watanabe", "Tilda Swinton",
          "Idris Elba", "Saoirse Ronan", "Oscar Isaac", "Lupita Nyong'o")
FIRST = ("Iron", "Silent", "Broken", "Golden", "Last", "Hidden", "Crimson",
         "Electric", "Paper", "Midnight")
SECOND = ("Harbor", "Protocol", "Garden", "Covenant", "Mile", "Signal",
          "Orchard", "Empire", "Letters", "Divide")


def generate(seed: int = 7) -> Table:
    rng = random.Random(seed)
    cols = {c: [] for c in (
        "Title", "Year", "Rated", "Released", "Runtime", "Director",
        "Writer", "Actors", "Plot", "Language", "Country", "Awards",
        "Poster", "Metascore", "IMDB_rating", "imdbVotes", "imdbID", "Type",
        "DVD", "BoxOffice", "Production", "Website")}
    blobs = {}
    for i in range(N_ROWS):
        genre = rng.choice(list(GENRES))
        motif = rng.choice(GENRES[genre])
        title = f"{rng.choice(FIRST)} {rng.choice(SECOND)} {i}"
        director = rng.choice(DIRECTORS)
        lead = rng.choice(ACTORS)
        support = rng.choice([a for a in ACTORS if a != lead])
        rating = round(rng.uniform(5.0, 9.6), 1)
        oscars = rng.choices((0, 1, 2, 3, 4), weights=(60, 15, 12, 8, 5))[0]
        runtime = rng.randint(84, 192)
        box_m = round(rng.uniform(1.0, 820.0), 1)
        year = rng.randint(1972, 2024)
        style = rng.choices(("dark", "vivid", "minimalist", "retro"),
                            weights=(30, 35, 20, 15))[0]

        poster = f"poster://movie/{i}"
        blobs[poster] = {"kind": "image", "style": style,
                         "cast": [lead, support],
                         "palette": "low-key lighting, heavy shadows"
                         if style == "dark" else "bright key light"}

        cols["Title"].append(title)
        cols["Year"].append(str(year))
        cols["Rated"].append(rng.choice(("PG", "PG-13", "R")))
        cols["Released"].append(f"{rng.randint(1, 28):02d} Jun {year}")
        cols["Runtime"].append(f"{runtime} min")
        cols["Director"].append(director)
        cols["Writer"].append(rng.choice(DIRECTORS))
        cols["Actors"].append(f"{lead}, {support}")
        cols["Plot"].append(
            f"A {genre} story about a {motif}: {lead} leads as the "
            f"protagonist whose choices unravel everything.")
        cols["Language"].append(rng.choice(("English", "French", "Korean")))
        cols["Country"].append(rng.choice(("USA", "UK", "South Korea")))
        # ~12% prose-style award strings defeat the split('Oscar') UDF
        if oscars and rng.random() < 0.12:
            cols["Awards"].append(
                f"Winner of {oscars} Academy Awards (Oscars) plus "
                f"{rng.randint(1, 9)} nominations")
        elif oscars:
            cols["Awards"].append(
                f"Won {oscars} Oscars. {rng.randint(0, 30)} wins & "
                f"{rng.randint(0, 40)} nominations total")
        else:
            cols["Awards"].append(f"{rng.randint(0, 12)} wins & "
                                  f"{rng.randint(0, 22)} nominations.")
        cols["Poster"].append(poster)
        cols["Metascore"].append(str(rng.randint(28, 99)))
        cols["IMDB_rating"].append(f"{rating}")
        cols["imdbVotes"].append(f"{rng.randint(4, 2400) * 1000:,}")
        cols["imdbID"].append(f"tt{seed:02d}{i:05d}")
        cols["Type"].append("movie")
        cols["DVD"].append(f"{rng.randint(1, 28):02d} Nov {year + 1}")
        if rng.random() < 0.1:
            cols["BoxOffice"].append(f"{box_m} million dollars")
        else:
            cols["BoxOffice"].append(f"${box_m:,}M")
        cols["Production"].append(rng.choice(
            ("Aurora Films", "Northlight", "Meridian Pictures")))
        cols["Website"].append(f"https://films.example/{i}")

    mods = {c: "text" for c in cols}
    mods.update(IMDB_rating="numeric", Metascore="numeric", Year="numeric",
                Poster="image")
    return Table(cols, mods, blobs, name="movie")


# ---------------------------------------------------------------------------
# Oracle
# ---------------------------------------------------------------------------

def make_oracle() -> InstructionOracle:
    o = InstructionOracle("movie")

    @o.map(r"extract the genre")
    def _genre(value, m):
        txt = str(value).lower()
        for g in GENRES:
            if f"a {g} story" in txt:
                return g
        return "unknown"

    @o.map(r"extract the (main character|cast)")
    def _cast(value, m):
        if isinstance(value, dict):                 # poster blob
            return ", ".join(value.get("cast", []))
        mm = [a for a in ACTORS if a in str(value)]
        return mm[0] if mm else "unknown"

    @o.filter(r"poster .*dark style|dark style.*poster|poster image is in "
              r"the dark")
    def _dark(value, m):
        return isinstance(value, dict) and value.get("style") == "dark"

    @o.filter(r"directed by ([\w\s\.\-']+)")
    def _director(value, m):
        return m.group(1).strip().rstrip(".?").lower() in str(value).lower()

    @o.filter(r"(stars|star in|casts?)\b")
    def _stars(value, m):
        if isinstance(value, dict):
            return any(a in value.get("cast", []) for a in ACTORS)
        return False

    @o.filter(r"belongs to (\w[\w\- ]*?) movies|is a (\w[\w\- ]*?) movie")
    def _genre_filter(value, m):
        g = (m.group(1) or m.group(2)).strip().lower()
        return g in str(value).lower()

    @o.filter(r"won (?:more than )?(\d+) Oscars?")
    def _oscars(value, m):
        import re as _re
        n = int(m.group(1))
        mm = _re.search(r"(\d+)\s+(?:Academy Awards|Oscars?)", str(value))
        won = int(mm.group(1)) if mm else 0
        if _re.search(r"more than", m.string, _re.I):
            return won > n
        return won == n

    @o.map(r"extract the total box office|extract the box office")
    def _box(value, m):
        from repro.core.udf import parse_money
        return parse_money(value)

    @o.reduce(r"summari[sz]e|common characteristics")
    def _summarize(values, m):
        themes = sorted({g for v in values for g in GENRES
                         if f"a {g} story" in str(v).lower()})
        leads = sorted({a for v in values for a in ACTORS if a in str(v)})
        return (f"Common characteristics: {', '.join(themes) or 'varied'} "
                f"stories led by {', '.join(leads[:3]) or 'ensemble casts'}.")

    return o
