"""Synthetic multi-modal datasets + oracles + workloads (paper §5.1).

``load_dataset(name)`` -> (Table, InstructionOracle). Row counts, attribute
counts and modality mixes match the paper's Table 3:

    movie   250 rows, 22 attrs — numeric, text, image
    estate  1,041 rows, 4 attrs — image, long text
    game    18,891 rows, 21 attrs — date, numeric, image, text
"""
from __future__ import annotations

from typing import Tuple

from repro.core.table import Table
from repro.data.oracle import InstructionOracle
from repro.data import estate, game, movie
from repro.data.workloads import WORKLOADS, Query, by_size   # noqa: F401

_GENERATORS = {"movie": movie, "estate": estate, "game": game}
_CACHE = {}


def load_dataset(name: str, seed: int = 0,
                 max_rows: int = 0) -> Tuple[Table, InstructionOracle]:
    key = (name, seed)
    if key not in _CACHE:
        mod = _GENERATORS[name]
        table = mod.generate() if seed == 0 else mod.generate(seed)
        _CACHE[key] = (table, mod.make_oracle())
    table, oracle = _CACHE[key]
    if max_rows and table.n_rows > max_rows:
        table = table.head(max_rows)
    return table, oracle


DATASETS = tuple(_GENERATORS)
