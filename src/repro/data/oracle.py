"""Instruction oracle — ground-truth provider for the capability simulator.

Each synthetic dataset registers (pattern, truth_fn) pairs for every
instruction family its workload uses; generated values are authored by the
same module, so truth functions recover the hidden semantics exactly
(e.g. the genre keyword planted in a plot, the PEGI rating inside an image
blob). Instructions the registry does not know fall back to the compiled-UDF
grammar; composite instructions produced by the fusion rule and negations
produced by the corruption harness are decomposed structurally.
"""
from __future__ import annotations

import re
from typing import Any, Callable, List, Sequence, Tuple

from repro.core import plan as plan_ir
from repro.core import udf as udf_mod

NEGATION_PREFIX = "It is NOT the case that: "


class InstructionOracle:
    def __init__(self, name: str = ""):
        self.name = name
        self._filters: List[Tuple[re.Pattern, Callable]] = []
        self._maps: List[Tuple[re.Pattern, Callable]] = []
        self._reduces: List[Tuple[re.Pattern, Callable]] = []

    # -- registration ------------------------------------------------------
    def filter(self, pattern: str):
        def deco(fn):
            self._filters.append((re.compile(pattern, re.I), fn))
            return fn
        return deco

    def map(self, pattern: str):
        def deco(fn):
            self._maps.append((re.compile(pattern, re.I), fn))
            return fn
        return deco

    def reduce(self, pattern: str):
        def deco(fn):
            self._reduces.append((re.compile(pattern, re.I), fn))
            return fn
        return deco

    # -- resolution ----------------------------------------------------------
    def _lookup(self, table, instruction: str):
        for pat, fn in table:
            m = pat.search(instruction)
            if m:
                return fn, m
        return None, None

    def answer(self, op: plan_ir.Operator, value: Any) -> Any:
        ins = op.instruction.strip()
        if ins.startswith(NEGATION_PREFIX):
            inner = op.with_(instruction=ins[len(NEGATION_PREFIX):])
            return not self.answer(inner, value)
        # composite predicates from operator fusion decompose FIRST — a
        # single registry pattern matching one conjunct must not swallow
        # the whole conjunction
        if op.kind == plan_ir.FILTER and " and " in ins:
            parts = [p.strip().rstrip(".") for p in ins.split(" and ")]
            try:
                return all(self.answer(op.with_(instruction=p + "."), value)
                           for p in parts)
            except KeyError:
                pass
        table = self._filters if op.kind == plan_ir.FILTER else self._maps
        fn, m = self._lookup(table, ins)
        if fn is not None:
            return fn(value, m)
        compiled = udf_mod.compile_udf(op)
        if compiled is not None:
            return compiled.fn(value)
        raise KeyError(f"[{self.name}] no oracle for {op.kind} instruction "
                       f"{op.instruction!r}")

    def answer_reduce(self, op: plan_ir.Operator, values: Sequence) -> Any:
        fn, m = self._lookup(self._reduces, op.instruction)
        if fn is not None:
            return fn(list(values), m)
        compiled = udf_mod.compile_reduce(op.instruction)
        if compiled is not None:
            return compiled.fn(list(values))
        raise KeyError(f"[{self.name}] no reduce oracle for "
                       f"{op.instruction!r}")
