"""Sharding-aware training data pipeline.

Deterministic, restart-safe token batches: batch content is a pure function
of (seed, step), and each data-parallel host materializes ONLY its shard —
`global_batch / dp_world` sequences — so input bandwidth scales with the
fleet. A background prefetch thread keeps `prefetch` steps in flight.

Sources:
  * synthetic LM streams (seeded)
  * text corpora via the byte tokenizer (list of documents, packed into
    fixed-length sequences with BOS separators)
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, List, Optional, Sequence

import numpy as np

from repro.data.tokenizer import ByteTokenizer


class TokenPipeline:
    def __init__(self, *, vocab_size: int, global_batch: int, seq_len: int,
                 dp_rank: int = 0, dp_world: int = 1, seed: int = 0,
                 documents: Optional[Sequence[str]] = None,
                 prefetch: int = 2):
        if global_batch % dp_world:
            raise ValueError(f"global_batch {global_batch} not divisible "
                             f"by dp_world {dp_world}")
        self.vocab_size = vocab_size
        self.global_batch = global_batch
        self.local_batch = global_batch // dp_world
        self.seq_len = seq_len
        self.dp_rank = dp_rank
        self.dp_world = dp_world
        self.seed = seed
        self._packed = self._pack(documents) if documents else None
        self._q: Optional[queue.Queue] = None
        self._stop = threading.Event()
        self.prefetch = prefetch

    # ------------------------------------------------------------------
    def _pack(self, documents: Sequence[str]) -> np.ndarray:
        """Pack documents into one token stream with BOS separators."""
        tok = ByteTokenizer()
        ids: List[int] = []
        for d in documents:
            ids.extend(tok.encode(d, bos=True, eos=True))
        arr = np.asarray(ids, np.int32) % self.vocab_size
        n = max(1, len(arr) // self.seq_len)
        return arr[: n * self.seq_len].reshape(n, self.seq_len)

    def batch_at(self, step: int) -> dict:
        """The dp-local batch for `step` — pure function of (seed, step,
        dp_rank), which is what makes checkpoint-restart deterministic."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) & 0x7FFFFFFF)
        if self._packed is not None:
            idx = rng.integers(0, self._packed.shape[0],
                               size=self.global_batch)
            lo = self.dp_rank * self.local_batch
            sel = idx[lo: lo + self.local_batch]
            return {"tokens": self._packed[sel]}
        # synthetic: draw the global batch, slice the local shard (ranks
        # agree on the stream; each materializes 1/dp_world of it)
        tokens = rng.integers(
            0, self.vocab_size,
            size=(self.global_batch, self.seq_len), dtype=np.int32)
        lo = self.dp_rank * self.local_batch
        return {"tokens": tokens[lo: lo + self.local_batch]}

    # ------------------------------------------------------------------
    def __iter__(self) -> Iterator[dict]:
        return self.iter_from(0)

    def iter_from(self, step: int) -> Iterator[dict]:
        """Prefetching iterator starting at `step` (restart entry point)."""
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def worker(start):
            s = start
            while not stop.is_set():
                q.put((s, self.batch_at(s)))
                s += 1

        t = threading.Thread(target=worker, args=(step,), daemon=True)
        t.start()
        try:
            while True:
                _, batch = q.get()
                yield batch
        finally:
            stop.set()
