"""Synthetic Estate dataset — regeneration of the paper's multimodal real-
estate benchmark (Table 3: 1,041 records, 4 attributes; images + long text).

Columns: image (handle; blob holds yard/pool visual facts), Title
("{n} bedroom {type} for sale"), Location (Lagos areas), Details (long
marketing text embedding amenities and a price in one of several Nigerian
formats — including the messy "430 Million Naira" / "N250m" styles from the
paper's Figure 12 that stress the UDF price parser).
"""
from __future__ import annotations

import random

from repro.core.table import Table
from repro.data.oracle import InstructionOracle

N_ROWS = 1041

LOCATIONS = ("Lekki Phase 1, Lekki, Lagos", "Ajah, Lagos", "Surulere, Lagos",
             "Ikoyi, Lagos", "Victoria Island, Lagos", "Yaba, Lagos",
             "Ikeja GRA, Lagos", "Banana Island, Lagos")
TYPES = ("detached duplex", "semi-detached duplex", "terrace duplex",
         "block of flats", "bungalow", "penthouse apartment")
AMENITIES = ("swimming pool", "gym", "BQ", "CCTV", "fitted kitchen",
             "24hrs electricity", "parking space", "elevator",
             "children playground", "rooftop terrace")


def _price_text(rng: random.Random, price_naira: float) -> str:
    mode = rng.random()
    m = price_naira / 1e6
    if mode < 0.35:
        return f"PRICE: {m:.0f} Million Naira"
    if mode < 0.65:
        return f"PRICE: N{m:.0f}m"
    if mode < 0.85:
        return f"PRICE: ₦{price_naira:,.0f}"
    return f"Asking {m:.0f}M (negotiable)"


def generate(seed: int = 11) -> Table:
    rng = random.Random(seed)
    cols = {"image": [], "Title": [], "Location": [], "Details": []}
    blobs = {}
    for i in range(N_ROWS):
        beds = rng.randint(1, 7)
        typ = rng.choice(TYPES)
        loc = rng.choice(LOCATIONS)
        n_amen = rng.randint(0, 4)
        amen = rng.sample(AMENITIES, n_amen)
        has_yard = rng.random() < 0.42
        price = rng.uniform(40, 950) * 1e6
        handle = f"photo://estate/{i}"
        blobs[handle] = {"kind": "image", "yard": has_yard,
                         "pool_visible": "swimming pool" in amen,
                         "facade": rng.choice(("white", "grey", "brick"))}
        details = (
            f"NEWLY BUILT {'FULLY DETACHED ' if 'detached' in typ else ''}"
            f"{typ.upper()}"
            + (f" WITH {' AND '.join(a.upper() for a in amen)}" if amen
               else "")
            + f". All rooms ensuite. Title: Governor's consent. "
            + _price_text(rng, price))
        cols["image"].append(handle)
        cols["Title"].append(f"{beds} bedroom {typ} for sale")
        cols["Location"].append(loc)
        cols["Details"].append(details)
    mods = {"image": "image", "Title": "text", "Location": "text",
            "Details": "text"}
    return Table(cols, mods, blobs, name="estate")


def make_oracle() -> InstructionOracle:
    o = InstructionOracle("estate")

    @o.filter(r"(house|estate) (picture|photo|image).*yard|yard.*(picture|"
              r"photo|image)|whether the house has a yard")
    def _yard(value, m):
        return isinstance(value, dict) and bool(value.get("yard"))

    @o.map(r"extract the house price|extract the price")
    def _price(value, m):
        from repro.core.udf import parse_money
        return parse_money(value)

    @o.filter(r"located in ([\w\s,\.\-']+)")
    def _loc(value, m):
        return m.group(1).strip().rstrip(".?").lower() in str(value).lower()

    @o.filter(r"more than (\d+) bedrooms?")
    def _beds_gt(value, m):
        import re as _re
        mm = _re.match(r"\s*(\d+)\s+bedroom", str(value))
        return bool(mm) and int(mm.group(1)) > int(m.group(1))

    @o.filter(r"less than (\d+) bedrooms?")
    def _beds_lt(value, m):
        import re as _re
        mm = _re.match(r"\s*(\d+)\s+bedroom", str(value))
        return bool(mm) and int(mm.group(1)) < int(m.group(1))

    @o.filter(r"has (\d+) or (\d+) bedrooms?")
    def _beds_in(value, m):
        import re as _re
        mm = _re.match(r"\s*(\d+)\s+bedroom", str(value))
        return bool(mm) and int(mm.group(1)) in (int(m.group(1)),
                                                 int(m.group(2)))

    @o.filter(r"is a detached duplex|estate is a detached")
    def _detached(value, m):
        s = str(value).lower()
        return "detached" in s and "semi-detached" not in s

    @o.map(r"extract (the )?amenities")
    def _amen(value, m):
        found = [a for a in AMENITIES if a.upper() in str(value)]
        return ", ".join(found) if found else "No amenities mentioned."

    @o.map(r"extract (the )?features")
    def _features(value, m):
        feats = []
        s = str(value)
        if "ensuite" in s.lower():
            feats.append("all rooms ensuite")
        if "Governor's consent" in s:
            feats.append("governor's consent title")
        found = [a for a in AMENITIES if a.upper() in s]
        feats.extend(found)
        return ", ".join(feats) if feats else "none"

    @o.filter(r"swimming pool")
    def _pool(value, m):
        if isinstance(value, dict):
            return bool(value.get("pool_visible"))
        return "swimming pool" in str(value).lower()

    @o.filter(r"\bgym\b")
    def _gym(value, m):
        return "gym" in str(value).lower()

    return o
