"""Byte-level tokenizer (vocab 256 bytes + specials). Dependency-free and
loss-free over arbitrary text — the right substrate for serving/training the
reduced model zoo and the local rewriter on CPU."""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

PAD, BOS, EOS = 256, 257, 258
VOCAB = 259


class ByteTokenizer:
    vocab_size = VOCAB
    pad_id, bos_id, eos_id = PAD, BOS, EOS

    def encode(self, text: str, *, bos: bool = True,
               eos: bool = False) -> List[int]:
        ids = list(text.encode("utf-8", errors="replace"))
        if bos:
            ids = [BOS] + ids
        if eos:
            ids = ids + [EOS]
        return ids

    def decode(self, ids: Sequence[int]) -> str:
        by = bytes(i for i in ids if 0 <= i < 256)
        return by.decode("utf-8", errors="replace")

    def pad_batch(self, seqs: Sequence[Sequence[int]], length: int = 0,
                  align: int = 1) -> np.ndarray:
        """Right-pad to a common length (rounded up to `align`)."""
        n = max(len(s) for s in seqs) if not length else length
        n = -(-n // align) * align
        out = np.full((len(seqs), n), PAD, np.int32)
        for i, s in enumerate(seqs):
            out[i, :min(len(s), n)] = s[:n]
        return out
