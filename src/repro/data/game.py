"""Synthetic Game dataset — regeneration of the paper's Steam benchmark
(Table 3: 18,891 records, 21 attributes; dates, numbers, images, text).

The `rating` column is a PEGI badge image handle (blob carries the age
rating); `discounted_price` is in IDR ("Rp 250000") as in the source data;
`metacriticts` keeps the source dataset's misspelling, as does the paper.
"""
from __future__ import annotations

import random

from repro.core.table import Table
from repro.data.oracle import InstructionOracle

N_ROWS = 18891

GENRES = ("shooting", "sports", "strategy", "puzzle", "racing",
          "role-playing", "simulation", "horror")
PUBS = ("Valve", "Ubisoft", "Devolver", "Annapurna", "Paradox", "SEGA",
        "Team17", "Raw Fury")
LANGS = ("English", "Chinese", "Japanese", "German", "French", "Spanish",
         "Portuguese", "Russian")
PLATFORM_SETS = ("Windows", "Windows, MacOS", "Windows, Linux",
                 "Windows, MacOS, Linux")


def generate(seed: int = 13) -> Table:
    rng = random.Random(seed)
    names1 = ("Neon", "Iron", "Star", "Pixel", "Turbo", "Shadow", "Hyper",
              "Lost", "Mega", "Quantum")
    names2 = ("Raiders", "League", "Tactics", "Drift", "Quest", "Arena",
              "Siege", "Farm", "Protocol", "Odyssey")
    cols = {c: [] for c in (
        "title", "rating", "release_date", "developer", "publisher",
        "platforms", "language", "original_price", "discounted_price",
        "discount_pct", "overall_reviews", "n_reviews", "metacriticts",
        "description", "tags", "achievements", "dlc_count", "vr_support",
        "min_ram_gb", "size_gb", "website")}
    blobs = {}
    for i in range(N_ROWS):
        genre = rng.choice(GENRES)
        pegi = rng.choices((3, 7, 12, 16, 18), weights=(25, 20, 25, 18, 12))[0]
        title = f"{rng.choice(names1)} {rng.choice(names2)} {i % 97}"
        meta = rng.randint(31, 97)
        vr = rng.random() < 0.13
        platforms = rng.choice(PLATFORM_SETS) + (", VR supported" if vr
                                                 else "")
        n_langs = rng.randint(1, 5)
        langs = ", ".join(rng.sample(LANGS, n_langs))
        price_idr = rng.randint(20, 900) * 1000
        disc = rng.choice((0, 10, 25, 33, 50, 75))
        n_dev = rng.choices((1, 2, 3), weights=(70, 20, 10))[0]
        devs = ", ".join(f"{rng.choice(names1)} Studio{d}"
                         for d in range(n_dev))
        badge = f"pegi://game/{i}"
        blobs[badge] = {"kind": "image", "pegi": pegi,
                        "badge_color": "red" if pegi == 18 else "green"}

        cols["title"].append(title)
        cols["rating"].append(badge)
        cols["release_date"].append(
            f"{rng.randint(2008, 2024)}-{rng.randint(1, 12):02d}-"
            f"{rng.randint(1, 28):02d}")
        cols["developer"].append(devs)
        cols["publisher"].append(rng.choice(PUBS))
        cols["platforms"].append(platforms)
        cols["language"].append(langs)
        cols["original_price"].append(f"Rp {price_idr:,}")
        cols["discounted_price"].append(
            f"Rp {int(price_idr * (100 - disc) / 100):,}")
        cols["discount_pct"].append(str(disc))
        pos = rng.random() < (0.35 + meta / 200.0)
        cols["overall_reviews"].append(
            ("Mostly Positive" if pos else "Mixed")
            + f" ({rng.randint(40, 90)}% of {rng.randint(100, 90000):,} "
              f"reviews)")
        cols["n_reviews"].append(str(rng.randint(100, 90000)))
        cols["metacriticts"].append(str(meta))
        cols["description"].append(
            f"A fast-paced {genre} game where you "
            f"{rng.choice(('build', 'conquer', 'explore', 'survive'))} "
            f"across {rng.randint(3, 40)} handcrafted levels.")
        cols["tags"].append(f"{genre}, indie, co-op")
        cols["achievements"].append(str(rng.randint(0, 120)))
        cols["dlc_count"].append(str(rng.randint(0, 14)))
        cols["vr_support"].append("yes" if vr else "no")
        cols["min_ram_gb"].append(str(rng.choice((4, 8, 16))))
        cols["size_gb"].append(f"{rng.uniform(0.4, 120):.1f}")
        cols["website"].append(f"https://games.example/{i}")

    mods = {c: "text" for c in cols}
    mods.update(rating="image", metacriticts="numeric", n_reviews="numeric",
                discount_pct="numeric", release_date="date")
    return Table(cols, mods, blobs, name="game")


def make_oracle() -> InstructionOracle:
    o = InstructionOracle("game")

    @o.filter(r"PEGI.*only suitable for adults|only suitable for adults")
    def _adult(value, m):
        return isinstance(value, dict) and value.get("pegi") == 18

    @o.map(r"binary review|binary label")
    def _binary(value, m):
        return "positive" if "Positive" in str(value) else "negative"

    @o.filter(r"support VR|video game support VR")
    def _vr(value, m):
        return "vr" in str(value).lower()

    @o.map(r"extract the genre")
    def _genre(value, m):
        s = str(value).lower()
        for g in GENRES:
            if g in s:
                return g
        return "unknown"

    @o.filter(r"is about (\w[\w\- ]*)|video game is about (\w[\w\- ]*)")
    def _about(value, m):
        g = (m.group(1) or m.group(2)).strip().rstrip(".?").lower()
        return g in str(value).lower()

    @o.filter(r"is a (\w[\w\- ]*?) game")
    def _is_genre(value, m):
        return m.group(1).strip().lower() in str(value).lower()

    @o.filter(r"MacOS in the list|support MacOS")
    def _mac(value, m):
        return "macos" in str(value).lower()

    @o.filter(r"(Chinese|English|Japanese|German|French) one of the "
              r"supported languages")
    def _lang(value, m):
        return m.group(1).lower() in str(value).lower()

    @o.filter(r"support(?:s)? both Windows and MacOS")
    def _winmac(value, m):
        s = str(value).lower()
        return "windows" in s and "macos" in s

    @o.filter(r"only (?:has |have )?one developer")
    def _one_dev(value, m):
        return "," not in str(value)

    @o.filter(r"receive[sd]? a positive review|positive review|"
              r"review is positive")
    def _positive(value, m):
        return "positive" in str(value).lower()

    @o.map(r"convert the price in IDR into .*USD")
    def _fx(value, m):
        from repro.core.udf import parse_money
        v = parse_money(value)
        return round(v * 6.5e-5, 2) if v is not None else None

    @o.reduce(r"publisher that appears the most")
    def _mode(values, m):
        import statistics
        return statistics.mode([str(v) for v in values]) if values else None

    return o
