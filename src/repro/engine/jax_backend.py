"""JAXBackend — a core.Backend whose tier is an actually-served JAX model.

Wires the Nirvana executor to the serving engine: each semantic-operator
record becomes a prompt; outputs come from real prefill+decode over a model
from the zoo (reduced configs on CPU; the full configs are exercised by the
dry-run). Usage is metered with *measured* wall-clock plus the tier's price
card, so end-to-end examples report true serving latency.

Untrained reduced models emit noise — examples use this backend to
demonstrate the real serving path, optionally composing it with the oracle
("echo" mode) so the analytics answer stays meaningful while latency/cost
numbers are real.

Thread-safety: ``run_values`` may be called from many worker threads at
once (the ``runtime.ThreadPoolDispatcher`` driver). All callers submit into
ONE shared :class:`ContinuousBatcher` and then cooperate on driving it —
each takes the backend lock for a single ``step()`` at a time — so
concurrent operators' requests genuinely share the engine's decode slots
(continuous batching across callers) instead of corrupting the KV cache.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional, Sequence

from repro.core import backends as bk
from repro.core import cost as cost_mod
from repro.core import plan as plan_ir
from repro.engine.engine import ContinuousBatcher, GenerationEngine


def render_prompt(op: plan_ir.Operator, value: Any) -> str:
    head = {plan_ir.FILTER: "Answer true or false.",
            plan_ir.MAP: "Answer concisely.",
            plan_ir.REDUCE: "Aggregate the inputs.",
            plan_ir.RANK: "Score the input 0-9."}[op.kind]
    return f"{head}\nInstruction: {op.instruction}\nInput: {value}\nAnswer:"


@dataclasses.dataclass
class JAXBackend:
    tier: cost_mod.TierSpec
    engine: GenerationEngine
    oracle: Optional[Any] = None      # echo mode: answers from the oracle,
    max_new_tokens: int = 16          # latency/cost from the real engine
    # shared continuous batcher + the lock serializing engine access; every
    # run_values (possibly from many dispatcher threads) submits here
    _lock: threading.RLock = dataclasses.field(
        default_factory=threading.RLock, init=False, repr=False,
        compare=False)
    _batcher: Optional[ContinuousBatcher] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)

    def _submit(self, prompts: Sequence[str]) -> List[int]:
        with self._lock:
            if self._batcher is None:
                self._batcher = ContinuousBatcher(self.engine)
            return [self._batcher.submit(p,
                                         max_new_tokens=self.max_new_tokens)
                    for p in prompts]

    def _collect(self, rids: Sequence[int]) -> Dict[int, Any]:
        """Drive the shared batcher until this caller's requests finish.

        Concurrent callers cooperate: whoever holds the lock advances the
        engine by one ``step`` (slot refill + one decode tick), then
        releases it so other threads can submit mid-flight — their
        requests join the same slot batch."""
        pending = set(rids)
        out: Dict[int, Any] = {}
        while pending:
            with self._lock:
                for r in list(pending):
                    req = self._batcher.finished.pop(r, None)
                    if req is not None:
                        out[r] = req
                        pending.discard(r)
                if pending:
                    self._batcher.step()
        return out

    def run_values(self, op: plan_ir.Operator, values: Sequence[Any],
                   meter: Optional[bk.UsageMeter] = None,
                   batch_size: int = 1) -> List[Any]:
        t0 = time.perf_counter()
        if op.kind == plan_ir.REDUCE:
            joined = "; ".join(str(v)[:60] for v in list(values)[:32])
            prompts = [render_prompt(op, joined)]
        else:
            prompts = [render_prompt(op, v) for v in values]

        rids = self._submit(prompts)
        finished = self._collect(rids)
        raw = [finished[r].text for r in rids]

        wall = time.perf_counter() - t0  # noqa: F841 — true batch wall
        tok_in = sum(cost_mod.text_tokens(p) for p in prompts)
        tok_out = sum(len(finished[r].output_ids or []) for r in rids)
        if meter is not None:
            # per-call latencies are the *measured* per-request SERVICE
            # times (slot insert -> done) from the continuous batcher; the
            # event scheduler re-queues jobs itself, so sojourn time
            # (submit -> done) would double-count the slot-queue wait
            per_call = [max(0.0, finished[r].done_s
                            - (finished[r].started_s
                               or finished[r].submitted_s))
                        for r in rids]
            meter.record(self.tier.name, bk.Usage(
                calls=len(prompts), tok_in=tok_in, tok_out=tok_out,
                usd=self.tier.usd(tok_in, tok_out),
                latency_s=sum(per_call)),
                per_call_latency_s=per_call, op_kind=op.kind)

        if self.oracle is not None:
            if op.kind == plan_ir.REDUCE:
                return [self.oracle.answer_reduce(op, values)]
            return [self.oracle.answer(op, v) for v in values]
        return self._parse(op, raw, values)

    def _parse(self, op: plan_ir.Operator, raw: List[str],
               values: Sequence[Any]) -> List[Any]:
        if op.kind == plan_ir.FILTER:
            return [r.strip().lower().startswith(("t", "y")) for r in raw]
        if op.kind == plan_ir.RANK:
            out = []
            for r in raw:
                digits = [c for c in r if c.isdigit()]
                out.append(int(digits[0]) if digits else 0)
            return out
        return raw
