"""Serving runtime: continuous-batching generation engine + JAX backend."""
from repro.engine.engine import (GenerationEngine, ContinuousBatcher,  # noqa: F401
                                 Request)
from repro.engine.jax_backend import JAXBackend, render_prompt        # noqa: F401
