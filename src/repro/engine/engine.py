"""Serving engine: continuous-batching generation over the model zoo.

Slot-based runtime in the vLLM mold, adapted to JAX/TPU:

  * a fixed slot-batched decode cache (``init_cache(..., per_slot_pos=True)``)
    — every slot decodes at its own depth; KV writes are per-slot one-hot
    blends (models/attention.write_kv)
  * prefill runs per request (B=1, lengths bucketed to limit recompiles)
    and is *inserted* into the slot batch with dynamic_update_slice along
    the batch axis of every cache leaf
  * decode steps run over all slots every tick; finished/empty slots decode
    garbage that the next insert overwrites (the standard trade: one wasted
    lane beats a re-trace)

The engine is architecture-agnostic: GQA / MLA KV caches and SSM / hybrid
recurrent states all flow through the same Param-tree insert because cache
leaves carry their logical axes ("batch" marks the slot dim).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import ByteTokenizer
from repro.models import common as cm

PREFILL_ALIGN = 16


@dataclasses.dataclass
class Request:
    rid: int
    prompt: str
    max_new_tokens: int = 32
    temperature: float = 0.0
    # filled during processing
    prompt_ids: Optional[list] = None
    output_ids: Optional[list] = None
    slot: int = -1
    prefill_s: float = 0.0
    submitted_s: float = 0.0
    started_s: float = 0.0      # slot insert (service start, not enqueue)
    done_s: float = 0.0

    @property
    def text(self) -> str:
        return ByteTokenizer().decode(self.output_ids or [])


def _batch_index(p: cm.Param) -> int:
    return p.axes.index("batch")


class GenerationEngine:
    def __init__(self, bundle, params, *, max_len: int = 256,
                 n_slots: int = 4, dtype=jnp.float32,
                 tokenizer: Optional[ByteTokenizer] = None):
        self.bundle = bundle
        self.params = params
        self.max_len = max_len
        self.n_slots = n_slots
        self.dtype = dtype
        self.tok = tokenizer or ByteTokenizer()
        self.cache = bundle.init_cache(n_slots, max_len, dtype=dtype,
                                       per_slot_pos=True)
        self.last_token = jnp.zeros((n_slots, 1), jnp.int32)
        self.active = np.zeros((n_slots,), bool)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self._decode_jit = jax.jit(
            lambda p, c, t: bundle.decode_step(p, c, t, dtype=dtype))
        self._prefill_jit = jax.jit(
            lambda p, b: bundle.prefill(p, b, max_len=max_len, dtype=dtype))
        self.stats = {"decode_steps": 0, "prefills": 0, "occupancy_sum": 0.0,
                      "decode_s": 0.0, "prefill_s": 0.0}

    # ------------------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i in range(self.n_slots) if not self.active[i]]

    def insert(self, req: Request, slot: int) -> Optional[Request]:
        """Prefill one request and splice it into the slot batch. Returns
        the request if it finished at prefill (prompt fills the window)."""
        t0 = time.perf_counter()
        req.started_s = t0
        ids = self.tok.encode(req.prompt)[: self.max_len - 1]
        req.prompt_ids = ids
        req.output_ids = []
        req.slot = slot
        tokens = self.tok.pad_batch([ids], align=PREFILL_ALIGN)
        logits, cache1 = self._prefill_jit(self.params,
                                           {"tokens": jnp.asarray(tokens)})
        # prefill padded the prompt; the next position is len(ids)
        pos_next = len(ids)

        def splice(dst: cm.Param, src: cm.Param) -> cm.Param:
            if dst.axes == ("batch",) or dst.axes == ():   # pos vector
                return dst
            bi = _batch_index(dst)
            idx = [0] * dst.value.ndim
            idx[bi] = slot
            return cm.Param(jax.lax.dynamic_update_slice(
                dst.value, src.value.astype(dst.value.dtype), tuple(idx)),
                dst.axes)

        self.cache = jax.tree.map(splice, self.cache, cache1,
                                  is_leaf=cm.is_param)
        pos = self.cache["pos"].value.at[slot].set(pos_next)
        self.cache["pos"] = cm.Param(pos, ("batch",))
        nxt = jnp.argmax(logits[0, -1]).astype(jnp.int32)
        self.last_token = self.last_token.at[slot, 0].set(nxt)
        req.output_ids.append(int(nxt))
        self.stats["prefills"] += 1
        req.prefill_s = time.perf_counter() - t0
        self.stats["prefill_s"] += req.prefill_s
        if (len(ids) + 1 >= self.max_len
                or len(req.output_ids) >= req.max_new_tokens):
            req.done_s = time.perf_counter()
            return req                      # finished at prefill
        self.active[slot] = True
        self.slot_req[slot] = req
        return None

    def decode_tick(self, key=None) -> List[Request]:
        """One decode step across all slots; returns finished requests."""
        t0 = time.perf_counter()
        logits, self.cache = self._decode_jit(self.params, self.cache,
                                              self.last_token)
        # keep idle slots parked at position 0 (their writes are overwritten
        # by the next insert; parking avoids pos growing past max_len)
        pos = self.cache["pos"].value
        pos = jnp.where(jnp.asarray(self.active), pos, 0)
        pos = jnp.minimum(pos, self.max_len - 1)
        self.cache["pos"] = cm.Param(pos, ("batch",))

        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        if key is not None:
            temps = np.array([self.slot_req[i].temperature
                              if self.slot_req[i] else 0.0
                              for i in range(self.n_slots)], np.float32)
            if (temps > 0).any():
                g = jax.random.gumbel(key, logits[:, -1].shape)
                samp = jnp.argmax(
                    logits[:, -1] / jnp.maximum(temps[:, None], 1e-6) + g,
                    axis=-1).astype(jnp.int32)
                nxt = jnp.where(jnp.asarray(temps > 0), samp, nxt)
        self.last_token = nxt[:, None]
        self.stats["decode_steps"] += 1
        self.stats["occupancy_sum"] += float(self.active.mean())
        self.stats["decode_s"] += time.perf_counter() - t0

        done: List[Request] = []
        nxt_host = np.asarray(nxt)
        for i in range(self.n_slots):
            req = self.slot_req[i]
            if req is None or not self.active[i]:
                continue
            req.output_ids.append(int(nxt_host[i]))
            eos = nxt_host[i] == self.tok.eos_id
            full = len(req.output_ids) >= req.max_new_tokens
            over = len(req.prompt_ids) + len(req.output_ids) >= self.max_len
            if eos or full or over:
                req.done_s = time.perf_counter()
                self.active[i] = False
                self.slot_req[i] = None
                done.append(req)
        return done

    @property
    def occupancy(self) -> float:
        n = max(1, self.stats["decode_steps"])
        return self.stats["occupancy_sum"] / n


class ContinuousBatcher:
    """Request queue + slot scheduler over a GenerationEngine."""

    def __init__(self, engine: GenerationEngine):
        self.engine = engine
        self.queue: List[Request] = []
        self.finished: Dict[int, Request] = {}
        self._next_rid = 0

    def submit(self, prompt: str, max_new_tokens: int = 32,
               temperature: float = 0.0) -> int:
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, prompt, max_new_tokens, temperature,
                      submitted_s=time.perf_counter())
        self.queue.append(req)
        return rid

    def _fill_slots(self) -> None:
        for slot in self.engine.free_slots():
            if not self.queue:
                break
            done = self.engine.insert(self.queue.pop(0), slot)
            if done is not None:
                self.finished[done.rid] = done

    def step(self, key=None) -> bool:
        """One scheduling round: fill free slots from the queue, then one
        decode tick. Returns True while work remains. This is the unit a
        cooperating driver thread executes under a lock — callers that
        share the batcher (e.g. ``JAXBackend`` under the threaded
        execution driver) alternate steps so their requests batch together
        on the engine's slots. ``key`` seeds THIS tick's sampling only;
        a caller looping step() with temperature>0 requests must split a
        fresh subkey per call (as ``run`` does) or every tick reuses the
        same noise."""
        self._fill_slots()
        if self.engine.active.any():
            for req in self.engine.decode_tick(key):
                self.finished[req.rid] = req
        return bool(self.queue or self.engine.active.any())

    def run(self, key=None) -> Dict[int, Request]:
        """Drive to completion: fill free slots, tick, repeat — one
        ``step`` per round, splitting a fresh sampling subkey per tick."""
        while self.queue or self.engine.active.any():
            self._fill_slots()
            sub = None
            if key is not None and self.engine.active.any():
                key, sub = jax.random.split(key)
            self.step(sub)
        return self.finished
