"""Minimal functional module system.

Params are nested dicts whose leaves are :class:`Param` — an array plus a
tuple of *logical axis names* (one per dim). Sharding rules
(``repro.distributed.sharding``) map logical axes -> mesh axes, with
automatic fallback to replication when a dim is not divisible by the
assigned mesh axes. ``values()`` strips to a plain pytree for compute.

Logical-axis vocabulary used across the model zoo:

  layer   scanned layer-stack dim (never sharded)
  embed   d_model            vocab  vocabulary
  heads   attention heads    kv_heads  KV heads      head_dim
  mlp     d_ff               expert  MoE expert dim
  q_lora / kv_lora           MLA latent ranks
  ssm_inner / ssm_state / ssm_heads / conv  Mamba dims
  batch / seq                activation dims (not params)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Param:
    value: Any   # jnp.ndarray | ShapeDtypeStruct
    axes: tuple  # logical axis names, len == value.ndim

    def __repr__(self):
        return f"Param({getattr(self.value, 'shape', None)}, axes={self.axes})"


# Param is a pytree node: `value` is the child, `axes` static metadata. This
# lets Param trees flow through jit/grad/scan while carrying sharding axes.
jax.tree_util.register_pytree_node(
    Param,
    lambda p: ((p.value,), p.axes),
    lambda aux, ch: Param(ch[0], aux),
)


def is_param(x) -> bool:
    return isinstance(x, Param)


def values(tree):
    """Param tree -> plain value pytree."""
    return jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)


def axes_tree(tree):
    """Param tree -> pytree of logical-axis tuples (leaves are tuples)."""
    return jax.tree.map(lambda p: p.axes, tree, is_leaf=is_param)


def zip_params(vals, axes):
    """Plain value tree + axes tree -> Param tree."""
    return jax.tree.map(lambda v, a: Param(v, a), vals, axes,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def param_count(tree) -> int:
    vals = values(tree)
    return sum(int(np.prod(v.shape)) for v in jax.tree.leaves(vals))


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def _trunc_normal(key, shape, scale, dtype=jnp.float32):
    stddev = scale / max(1.0, np.sqrt(shape[0] if len(shape) else 1))
    return jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype) * stddev


def dense(key, d_in, d_out, axes, *, bias=False, bias_axes=None,
          dtype=jnp.float32, scale=1.0):
    """Dense layer params. d_in/d_out may be ints or tuples (fused dims)."""
    d_in_t = d_in if isinstance(d_in, tuple) else (d_in,)
    d_out_t = d_out if isinstance(d_out, tuple) else (d_out,)
    shape = d_in_t + d_out_t
    fan_in = int(np.prod(d_in_t))
    w = jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype) * (
        scale / np.sqrt(fan_in))
    p = {"w": Param(w, axes)}
    if bias:
        if bias_axes is None:
            bias_axes = axes[len(d_in_t):]
        p["b"] = Param(jnp.zeros(d_out_t, dtype), bias_axes)
    return p


def apply_dense(p, x, *, in_dims=1, precision=None):
    """y = x @ w (+ b). Contracts the last `in_dims` dims of x with the first
    `in_dims` dims of w."""
    w = p["w"].value if is_param(p["w"]) else p["w"]
    dn = (tuple(range(x.ndim - in_dims, x.ndim)), tuple(range(in_dims)))
    y = jax.lax.dot_general(x, w.astype(x.dtype), (dn, ((), ())),
                            precision=precision)
    if "b" in p:
        b = p["b"].value if is_param(p["b"]) else p["b"]
        y = y + b.astype(y.dtype)
    return y


def embedding(key, vocab, d_model, *, dtype=jnp.float32):
    w = jax.random.normal(key, (vocab, d_model), dtype) * 0.02
    return {"embedding": Param(w, ("vocab", "embed"))}


def rmsnorm_init(d, name_axis="embed", dtype=jnp.float32):
    return {"scale": Param(jnp.ones((d,), dtype), (name_axis,))}


def rmsnorm(p, x, eps=1e-5):
    scale = p["scale"].value if is_param(p["scale"]) else p["scale"]
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Layer stacking for lax.scan
# ---------------------------------------------------------------------------

def stack_layers(init_fn: Callable, key, n_layers: int):
    """vmap `init_fn(key) -> Param tree` over layer keys; prepend 'layer' axis."""
    proto = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
    keys = jax.random.split(key, n_layers)
    vals = jax.vmap(lambda k: values(init_fn(k)))(keys)
    return jax.tree.map(
        lambda p, v: Param(v, ("layer",) + p.axes), proto, vals,
        is_leaf=is_param)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                     # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    sin = jnp.sin(angles)[..., None, :]              # (..., S, 1, D/2)
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softmax_cross_entropy(logits, labels, mask=None):
    """logits (..., V) fp32; labels int ids; mask optional {0,1}.

    The label logit is extracted with a one-hot reduction rather than
    take_along_axis: under GSPMD a gather along a sharded vocab dim would
    all-gather the logits, while the masked reduction stays sharded and
    turns into a small all-reduce.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    vocab = logits.shape[-1]
    onehot = labels[..., None] == jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, logits.ndim - 1)
    ll = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = logz - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
